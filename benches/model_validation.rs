//! E10 — model-vs-simulator validation sweep (the stand-in for the paper's
//! real-GPU validation), plus simulator throughput benchmarks.
//!
//! Run: `cargo bench --bench model_validation`

use codesign::area::params::HwParams;
use codesign::platform::Platform;
use codesign::sim::run::simulate;
use codesign::sim::validate_sweep;
use codesign::stencil::defs::{Stencil, StencilId};
use codesign::stencil::workload::ProblemSize;
use codesign::timemodel::talg::SoftwareParams;
use codesign::timemodel::tiling::TileSizes;
use codesign::timemodel::TimeModel;
use codesign::util::bench::{black_box, Bencher};
use codesign::util::csv::Table;

fn main() {
    let mut b = Bencher::new();
    let model = TimeModel::maxwell();

    // Timing: one model evaluation vs one simulation of the same instance.
    let st = *Stencil::get(StencilId::Jacobi2D);
    let size = ProblemSize::d2(1024, 128);
    let hw = HwParams::gtx980();
    let sw = SoftwareParams::new(TileSizes::d2(32, 64, 8), 2);
    b.bench("analytical_model_eval", || model.evaluate(black_box(&st), &size, &hw, &sw));
    b.bench("fluid_simulator_run", || simulate(&model.machine, black_box(&st), &size, &hw, &sw));

    // The validation sweep + per-case table.
    let (rep, _) = b.bench_once("validation_sweep", || validate_sweep(Platform::default_spec()));
    println!(
        "\nmodel vs simulator: {} configs, MAPE {:.1}%, Kendall tau {:.3}",
        rep.cases.len(),
        rep.mape_pct,
        rep.kendall_tau
    );
    let mut t = Table::new(&["config", "model_ms", "sim_ms", "rel_err_pct"]);
    for c in &rep.cases {
        t.push(&[
            c.label.clone(),
            format!("{:.4}", c.model_seconds * 1e3),
            format!("{:.4}", c.sim_seconds * 1e3),
            format!("{:.1}", c.rel_err_pct()),
        ]);
    }
    t.save(std::path::Path::new("reports/model_validation/cases.csv")).unwrap();
    println!("model_validation report saved under reports/model_validation/");
}
