//! E1 — regenerates Fig 2 (the four memory linear fits + Table I
//! coefficients) and times the calibration pipeline.
//!
//! Run: `cargo bench --bench fig2_memory_models`

use codesign::area::calibrate::calibrate_maxwell;
use codesign::cacti::{calibrate_to_paper, Knobs};
use codesign::report::fig2;
use codesign::util::bench::Bencher;
use std::path::Path;

fn main() {
    let mut b = if codesign::util::bench::quick_requested() {
        Bencher::quick()
    } else {
        Bencher::new()
    };

    // Timing: the fit pipeline and the knob calibration search.
    b.bench("area_calibration_pipeline", calibrate_maxwell);
    b.bench_once("cacti_knob_search", || calibrate_to_paper(Knobs::initial()));

    // Figure regeneration.
    let rep = fig2::generate_default();
    print!("{}", rep.summary);
    rep.save(Path::new("reports")).expect("save fig2");
    println!("fig2 report saved under reports/fig2_memory_models/");
}
