//! Bound-and-prune speedup trajectory: the quick paper sweep (explore,
//! Pareto, tune) with pruning on vs `--no-prune`, certified result-identical
//! and written to `BENCH_prune.json` (evals saved, wall clock per sweep).
//! A third leg replays the pruned sweep through `--scalar-eval` (the legacy
//! point-at-a-time loop) and records the batched-vs-scalar evals/sec delta —
//! the number `scripts/perf_compare.sh` gates in CI. A fourth leg sweeps a
//! fused multi-stencil chain (`fuse:heat2d+laplacian2d:t2`) pruned vs
//! `--no-prune`, recording `fused_evals_per_sec` so the chain path rides the
//! same CI throughput gate.
//!
//! Run: `cargo bench --bench prune_bench` (CI's bench-smoke job runs it and
//! archives the JSON).

use codesign::opt::problem::SolveOpts;
use codesign::service::{CodesignRequest, ScenarioSpec, Session, TuneRequest};
use codesign::stencil::defs::StencilId;
use codesign::util::json::Json;
use std::time::Instant;

struct SweepRow {
    name: &'static str,
    pruned_evals: u64,
    full_evals: u64,
    pruned_ms: f64,
    full_ms: f64,
}

fn requests(opts: SolveOpts) -> Vec<CodesignRequest> {
    let mut tune = TuneRequest::new(430.0)
        .pin_n_v(128)
        .pin_m_sm_kb(96.0)
        .for_stencil(StencilId::Heat2D);
    tune.solve_opts = opts.clone();
    vec![
        CodesignRequest::explore(ScenarioSpec::two_d().quick(8).with_solve_opts(opts.clone())),
        CodesignRequest::pareto(
            ScenarioSpec::two_d().quick(8).named("pareto-2d").with_solve_opts(opts.clone()),
        ),
        CodesignRequest::pareto(
            ScenarioSpec::three_d().quick(8).named("pareto-3d").with_solve_opts(opts),
        ),
        CodesignRequest::tune(tune),
    ]
}

fn run(opts: SolveOpts) -> (Vec<(String, u64)>, f64, u64, u64) {
    let mut session = Session::paper();
    let t0 = Instant::now();
    let rep = session.submit_all(&requests(opts));
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let evals: Vec<(String, u64)> = rep
        .answers
        .iter()
        .map(|a| (a.response.kind().to_string(), a.response.total_evals()))
        .collect();
    (evals, wall_ms, rep.prune.subtrees_cut, rep.prune.bounded_out)
}

/// The PR 10 fused-chain leg: explore + Pareto over a two-stage chain
/// (σ_eff = 4) through the same session machinery.
fn run_fused(opts: SolveOpts) -> (u64, f64) {
    let spec = || {
        ScenarioSpec::new(
            codesign::service::WorkloadClass::parse("fuse:heat2d+laplacian2d:t2")
                .expect("chain name must parse"),
        )
    };
    let requests = vec![
        CodesignRequest::explore(spec().quick(8).with_solve_opts(opts.clone())),
        CodesignRequest::pareto(spec().quick(8).named("fused-pareto").with_solve_opts(opts)),
    ];
    let mut session = Session::paper();
    let t0 = Instant::now();
    let rep = session.submit_all(&requests);
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let evals = rep.answers.iter().map(|a| a.response.total_evals()).sum();
    (evals, wall_ms)
}

fn main() {
    let (pruned, pruned_ms, subtrees_cut, bounded_out) = run(SolveOpts::default());
    let (full, full_ms, _, _) = run(SolveOpts::default().without_prune());
    let (scalar, scalar_ms, _, _) = run(SolveOpts::default().with_scalar_eval());
    let (fused_evals, fused_ms) = run_fused(SolveOpts::default());
    let (fused_full_evals, fused_full_ms) = run_fused(SolveOpts::default().without_prune());
    assert!(
        fused_evals <= fused_full_evals,
        "fused chain: pruning must never add evaluations ({fused_evals} vs {fused_full_evals})"
    );

    // The differential tier certifies bit-identity; here we certify the
    // accounting and record the trajectory.
    let mut rows: Vec<SweepRow> = Vec::new();
    let names = ["explore_2d", "pareto_2d", "pareto_3d", "tune_heat2d"];
    let mut pruned_total = 0u64;
    let mut full_total = 0u64;
    for (i, name) in names.iter().enumerate() {
        let (p, f) = (pruned[i].1, full[i].1);
        assert!(p <= f, "{name}: pruning must never add evaluations ({p} vs {f})");
        assert_eq!(
            p, scalar[i].1,
            "{name}: batched and scalar paths must count identical evaluations"
        );
        pruned_total += p;
        full_total += f;
        rows.push(SweepRow {
            name,
            pruned_evals: p,
            full_evals: f,
            pruned_ms: pruned_ms / names.len() as f64,
            full_ms: full_ms / names.len() as f64,
        });
    }

    let sweeps = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("sweep", Json::str(r.name)),
                    ("pruned_evals", Json::num(r.pruned_evals as f64)),
                    ("full_evals", Json::num(r.full_evals as f64)),
                    ("evals_saved", Json::num((r.full_evals - r.pruned_evals) as f64)),
                    ("pruned_wall_ms_share", Json::num(r.pruned_ms)),
                    ("full_wall_ms_share", Json::num(r.full_ms)),
                ])
            })
            .collect(),
    );
    let bench = Json::obj(vec![
        ("pruned_evals_total", Json::num(pruned_total as f64)),
        ("full_evals_total", Json::num(full_total as f64)),
        ("evals_saved_total", Json::num((full_total - pruned_total) as f64)),
        (
            "evals_reduction_factor",
            Json::num(full_total as f64 / pruned_total.max(1) as f64),
        ),
        ("pruned_wall_ms", Json::num(pruned_ms)),
        ("full_wall_ms", Json::num(full_ms)),
        ("subtrees_cut", Json::num(subtrees_cut as f64)),
        ("instances_bounded_out", Json::num(bounded_out as f64)),
        // Batched-vs-scalar leg: same pruned request set, identical eval
        // counts (asserted above), so evals/sec compares pure loop cost.
        ("batched_wall_ms", Json::num(pruned_ms)),
        ("scalar_wall_ms", Json::num(scalar_ms)),
        ("batched_evals_per_sec", Json::num(evals_per_sec(pruned_total, pruned_ms))),
        ("scalar_evals_per_sec", Json::num(evals_per_sec(pruned_total, scalar_ms))),
        ("batched_speedup", Json::num(scalar_ms / pruned_ms.max(1e-9))),
        // Fused-chain leg: explore + Pareto over fuse:heat2d+laplacian2d:t2.
        // `fused_evals_per_sec` matches perf_compare.sh's `*evals_per_sec`
        // harvest, so the chain path is throughput-gated like the others.
        ("fused_evals", Json::num(fused_evals as f64)),
        ("fused_full_evals", Json::num(fused_full_evals as f64)),
        ("fused_wall_ms", Json::num(fused_ms)),
        ("fused_full_wall_ms", Json::num(fused_full_ms)),
        ("fused_evals_per_sec", Json::num(evals_per_sec(fused_evals, fused_ms))),
        ("sweeps", sweeps),
    ]);
    std::fs::write("BENCH_prune.json", bench.to_string_pretty()).expect("write BENCH_prune.json");
    println!(
        "prune bench: {pruned_total} evals pruned vs {full_total} full \
         ({:.2}x reduction, {subtrees_cut} subtrees cut, {bounded_out} instances bounded out)\n\
         wall: {pruned_ms:.0} ms vs {full_ms:.0} ms -> BENCH_prune.json\n\
         batched vs scalar: {pruned_ms:.0} ms vs {scalar_ms:.0} ms \
         ({:.2}x, {:.0} vs {:.0} evals/sec)\n\
         fused chain: {fused_evals} evals pruned vs {fused_full_evals} full \
         ({fused_ms:.0} ms vs {fused_full_ms:.0} ms, {:.0} evals/sec)",
        full_total as f64 / pruned_total.max(1) as f64,
        scalar_ms / pruned_ms.max(1e-9),
        evals_per_sec(pruned_total, pruned_ms),
        evals_per_sec(pruned_total, scalar_ms),
        evals_per_sec(fused_evals, fused_ms),
    );
}

fn evals_per_sec(evals: u64, wall_ms: f64) -> f64 {
    evals as f64 / (wall_ms.max(1e-9) / 1e3)
}
