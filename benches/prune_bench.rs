//! Bound-and-prune speedup trajectory: the quick paper sweep (explore,
//! Pareto, tune) with pruning on vs `--no-prune`, certified result-identical
//! and written to `BENCH_prune.json` (evals saved, wall clock per sweep).
//! A third leg replays the pruned sweep through `--scalar-eval` (the legacy
//! point-at-a-time loop) and records the batched-vs-scalar evals/sec delta —
//! the number `scripts/perf_compare.sh` gates in CI.
//!
//! Run: `cargo bench --bench prune_bench` (CI's bench-smoke job runs it and
//! archives the JSON).

use codesign::opt::problem::SolveOpts;
use codesign::service::{CodesignRequest, ScenarioSpec, Session, TuneRequest};
use codesign::stencil::defs::StencilId;
use codesign::util::json::Json;
use std::time::Instant;

struct SweepRow {
    name: &'static str,
    pruned_evals: u64,
    full_evals: u64,
    pruned_ms: f64,
    full_ms: f64,
}

fn requests(opts: SolveOpts) -> Vec<CodesignRequest> {
    let mut tune = TuneRequest::new(430.0)
        .pin_n_v(128)
        .pin_m_sm_kb(96.0)
        .for_stencil(StencilId::Heat2D);
    tune.solve_opts = opts.clone();
    vec![
        CodesignRequest::explore(ScenarioSpec::two_d().quick(8).with_solve_opts(opts.clone())),
        CodesignRequest::pareto(
            ScenarioSpec::two_d().quick(8).named("pareto-2d").with_solve_opts(opts.clone()),
        ),
        CodesignRequest::pareto(
            ScenarioSpec::three_d().quick(8).named("pareto-3d").with_solve_opts(opts),
        ),
        CodesignRequest::tune(tune),
    ]
}

fn run(opts: SolveOpts) -> (Vec<(String, u64)>, f64, u64, u64) {
    let mut session = Session::paper();
    let t0 = Instant::now();
    let rep = session.submit_all(&requests(opts));
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let evals: Vec<(String, u64)> = rep
        .answers
        .iter()
        .map(|a| (a.response.kind().to_string(), a.response.total_evals()))
        .collect();
    (evals, wall_ms, rep.prune.subtrees_cut, rep.prune.bounded_out)
}

fn main() {
    let (pruned, pruned_ms, subtrees_cut, bounded_out) = run(SolveOpts::default());
    let (full, full_ms, _, _) = run(SolveOpts::default().without_prune());
    let (scalar, scalar_ms, _, _) = run(SolveOpts::default().with_scalar_eval());

    // The differential tier certifies bit-identity; here we certify the
    // accounting and record the trajectory.
    let mut rows: Vec<SweepRow> = Vec::new();
    let names = ["explore_2d", "pareto_2d", "pareto_3d", "tune_heat2d"];
    let mut pruned_total = 0u64;
    let mut full_total = 0u64;
    for (i, name) in names.iter().enumerate() {
        let (p, f) = (pruned[i].1, full[i].1);
        assert!(p <= f, "{name}: pruning must never add evaluations ({p} vs {f})");
        assert_eq!(
            p, scalar[i].1,
            "{name}: batched and scalar paths must count identical evaluations"
        );
        pruned_total += p;
        full_total += f;
        rows.push(SweepRow {
            name,
            pruned_evals: p,
            full_evals: f,
            pruned_ms: pruned_ms / names.len() as f64,
            full_ms: full_ms / names.len() as f64,
        });
    }

    let sweeps = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("sweep", Json::str(r.name)),
                    ("pruned_evals", Json::num(r.pruned_evals as f64)),
                    ("full_evals", Json::num(r.full_evals as f64)),
                    ("evals_saved", Json::num((r.full_evals - r.pruned_evals) as f64)),
                    ("pruned_wall_ms_share", Json::num(r.pruned_ms)),
                    ("full_wall_ms_share", Json::num(r.full_ms)),
                ])
            })
            .collect(),
    );
    let bench = Json::obj(vec![
        ("pruned_evals_total", Json::num(pruned_total as f64)),
        ("full_evals_total", Json::num(full_total as f64)),
        ("evals_saved_total", Json::num((full_total - pruned_total) as f64)),
        (
            "evals_reduction_factor",
            Json::num(full_total as f64 / pruned_total.max(1) as f64),
        ),
        ("pruned_wall_ms", Json::num(pruned_ms)),
        ("full_wall_ms", Json::num(full_ms)),
        ("subtrees_cut", Json::num(subtrees_cut as f64)),
        ("instances_bounded_out", Json::num(bounded_out as f64)),
        // Batched-vs-scalar leg: same pruned request set, identical eval
        // counts (asserted above), so evals/sec compares pure loop cost.
        ("batched_wall_ms", Json::num(pruned_ms)),
        ("scalar_wall_ms", Json::num(scalar_ms)),
        ("batched_evals_per_sec", Json::num(evals_per_sec(pruned_total, pruned_ms))),
        ("scalar_evals_per_sec", Json::num(evals_per_sec(pruned_total, scalar_ms))),
        ("batched_speedup", Json::num(scalar_ms / pruned_ms.max(1e-9))),
        ("sweeps", sweeps),
    ]);
    std::fs::write("BENCH_prune.json", bench.to_string_pretty()).expect("write BENCH_prune.json");
    println!(
        "prune bench: {pruned_total} evals pruned vs {full_total} full \
         ({:.2}x reduction, {subtrees_cut} subtrees cut, {bounded_out} instances bounded out)\n\
         wall: {pruned_ms:.0} ms vs {full_ms:.0} ms -> BENCH_prune.json\n\
         batched vs scalar: {pruned_ms:.0} ms vs {scalar_ms:.0} ms \
         ({:.2}x, {:.0} vs {:.0} evals/sec)",
        full_total as f64 / pruned_total.max(1) as f64,
        scalar_ms / pruned_ms.max(1e-9),
        evals_per_sec(pruned_total, pruned_ms),
        evals_per_sec(pruned_total, scalar_ms),
    );
}

fn evals_per_sec(evals: u64, wall_ms: f64) -> f64 {
    evals as f64 / (wall_ms.max(1e-9) / 1e3)
}
