//! Bound-and-prune speedup trajectory: the quick paper sweep (explore,
//! Pareto, tune) with pruning on vs `--no-prune`, certified result-identical
//! and written to `BENCH_prune.json` (evals saved, wall clock per sweep).
//!
//! Run: `cargo bench --bench prune_bench` (CI's bench-smoke job runs it and
//! archives the JSON).

use codesign::opt::problem::SolveOpts;
use codesign::service::{CodesignRequest, ScenarioSpec, Session, TuneRequest};
use codesign::stencil::defs::StencilId;
use codesign::util::json::Json;
use std::time::Instant;

struct SweepRow {
    name: &'static str,
    pruned_evals: u64,
    full_evals: u64,
    pruned_ms: f64,
    full_ms: f64,
}

fn requests(opts: SolveOpts) -> Vec<CodesignRequest> {
    let mut tune = TuneRequest::new(430.0)
        .pin_n_v(128)
        .pin_m_sm_kb(96.0)
        .for_stencil(StencilId::Heat2D);
    tune.solve_opts = opts.clone();
    vec![
        CodesignRequest::explore(ScenarioSpec::two_d().quick(8).with_solve_opts(opts.clone())),
        CodesignRequest::pareto(
            ScenarioSpec::two_d().quick(8).named("pareto-2d").with_solve_opts(opts.clone()),
        ),
        CodesignRequest::pareto(
            ScenarioSpec::three_d().quick(8).named("pareto-3d").with_solve_opts(opts),
        ),
        CodesignRequest::tune(tune),
    ]
}

fn run(opts: SolveOpts) -> (Vec<(String, u64)>, f64, u64, u64) {
    let mut session = Session::paper();
    let t0 = Instant::now();
    let rep = session.submit_all(&requests(opts));
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let evals: Vec<(String, u64)> = rep
        .answers
        .iter()
        .map(|a| (a.response.kind().to_string(), a.response.total_evals()))
        .collect();
    (evals, wall_ms, rep.prune.subtrees_cut, rep.prune.bounded_out)
}

fn main() {
    let (pruned, pruned_ms, subtrees_cut, bounded_out) = run(SolveOpts::default());
    let (full, full_ms, _, _) = run(SolveOpts::default().without_prune());

    // The differential tier certifies bit-identity; here we certify the
    // accounting and record the trajectory.
    let mut rows: Vec<SweepRow> = Vec::new();
    let names = ["explore_2d", "pareto_2d", "pareto_3d", "tune_heat2d"];
    let mut pruned_total = 0u64;
    let mut full_total = 0u64;
    for (i, name) in names.iter().enumerate() {
        let (p, f) = (pruned[i].1, full[i].1);
        assert!(p <= f, "{name}: pruning must never add evaluations ({p} vs {f})");
        pruned_total += p;
        full_total += f;
        rows.push(SweepRow {
            name,
            pruned_evals: p,
            full_evals: f,
            pruned_ms: pruned_ms / names.len() as f64,
            full_ms: full_ms / names.len() as f64,
        });
    }

    let sweeps = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("sweep", Json::str(r.name)),
                    ("pruned_evals", Json::num(r.pruned_evals as f64)),
                    ("full_evals", Json::num(r.full_evals as f64)),
                    ("evals_saved", Json::num((r.full_evals - r.pruned_evals) as f64)),
                    ("pruned_wall_ms_share", Json::num(r.pruned_ms)),
                    ("full_wall_ms_share", Json::num(r.full_ms)),
                ])
            })
            .collect(),
    );
    let bench = Json::obj(vec![
        ("pruned_evals_total", Json::num(pruned_total as f64)),
        ("full_evals_total", Json::num(full_total as f64)),
        ("evals_saved_total", Json::num((full_total - pruned_total) as f64)),
        (
            "evals_reduction_factor",
            Json::num(full_total as f64 / pruned_total.max(1) as f64),
        ),
        ("pruned_wall_ms", Json::num(pruned_ms)),
        ("full_wall_ms", Json::num(full_ms)),
        ("subtrees_cut", Json::num(subtrees_cut as f64)),
        ("instances_bounded_out", Json::num(bounded_out as f64)),
        ("sweeps", sweeps),
    ]);
    std::fs::write("BENCH_prune.json", bench.to_string_pretty()).expect("write BENCH_prune.json");
    println!(
        "prune bench: {pruned_total} evals pruned vs {full_total} full \
         ({:.2}x reduction, {subtrees_cut} subtrees cut, {bounded_out} instances bounded out)\n\
         wall: {pruned_ms:.0} ms vs {full_ms:.0} ms -> BENCH_prune.json",
        full_total as f64 / pruned_total.max(1) as f64
    );
}
