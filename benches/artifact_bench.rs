//! Artifact round-trip trajectory: the quick paper sweep cold vs
//! save → warm-start → replay, certified answer-identical and written to
//! `BENCH_artifacts.json` (wall clock per leg, artifact size, warm hit rate).
//!
//! Run: `cargo bench --bench artifact_bench` (CI's bench-smoke job runs it
//! and archives the JSON).

use codesign::service::{CodesignRequest, ScenarioSpec, Session, TuneRequest};
use codesign::stencil::defs::StencilId;
use codesign::util::json::Json;
use std::time::Instant;

fn requests() -> Vec<CodesignRequest> {
    vec![
        CodesignRequest::explore(ScenarioSpec::two_d().quick(12)),
        CodesignRequest::pareto(
            ScenarioSpec::two_d().quick(12).with_area_budget(380.0).named("pareto-2d"),
        ),
        CodesignRequest::tune(
            TuneRequest::new(430.0)
                .pin_n_v(128)
                .pin_m_sm_kb(96.0)
                .for_stencil(StencilId::Heat2D),
        ),
    ]
}

fn main() {
    let dir = std::env::temp_dir().join(format!("codesign-artifact-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // Cold leg: solve everything from scratch, then persist the sweep state.
    let mut cold = Session::paper();
    let t0 = Instant::now();
    let cold_report = cold.submit_all(&requests());
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;
    let cold_responses = cold_report.into_responses();

    let t0 = Instant::now();
    let manifest = cold.save_artifact(&dir).expect("save artifact");
    let save_ms = t0.elapsed().as_secs_f64() * 1e3;
    let artifact_bytes: u64 = manifest.shards.iter().map(|s| s.bytes).sum();

    // Warm leg: a fresh session loads the artifact and replays the sweep.
    let mut warm = Session::paper();
    let t0 = Instant::now();
    let load = warm.warm_start(&dir).expect("warm start");
    let load_ms = t0.elapsed().as_secs_f64() * 1e3;

    let t0 = Instant::now();
    let warm_report = warm.submit_all(&requests());
    let warm_ms = t0.elapsed().as_secs_f64() * 1e3;
    let hit_rate = warm_report.cache_hit_rate();
    let warm_responses = warm_report.into_responses();

    // The integration tier certifies bit-identity; re-assert it here so the
    // recorded speedup can never come from answering a different question.
    assert_eq!(cold_responses, warm_responses, "warm replay must match cold recompute");
    assert!(hit_rate >= 0.99, "warm replay must be cache-served (hit rate {hit_rate:.4})");

    let replay_speedup = cold_ms / warm_ms.max(1e-9);
    let bench = Json::obj(vec![
        ("cold_wall_ms", Json::num(cold_ms)),
        ("save_wall_ms", Json::num(save_ms)),
        ("load_wall_ms", Json::num(load_ms)),
        ("warm_replay_wall_ms", Json::num(warm_ms)),
        ("replay_speedup", Json::num(replay_speedup)),
        ("shards", Json::num(manifest.shards.len() as f64)),
        ("entries", Json::num(load.entries_installed as f64)),
        ("exact_entries", Json::num(load.exact_entries as f64)),
        ("bounded_entries", Json::num(load.bounded_entries as f64)),
        ("artifact_bytes", Json::num(artifact_bytes as f64)),
        ("warm_hit_rate", Json::num(hit_rate)),
    ]);
    std::fs::write("BENCH_artifacts.json", bench.to_string_pretty())
        .expect("write BENCH_artifacts.json");
    println!(
        "artifact bench: cold {cold_ms:.0} ms -> save {save_ms:.1} ms \
         ({} shard(s), {} entries, {artifact_bytes} B) -> load {load_ms:.1} ms \
         -> warm replay {warm_ms:.0} ms ({replay_speedup:.1}x, hit rate {hit_rate:.4}) \
         -> BENCH_artifacts.json",
        manifest.shards.len(),
        load.entries_installed,
    );

    let _ = std::fs::remove_dir_all(&dir);
}
