//! Tri-objective (area × perf × energy) sweep trajectory: the quick paper
//! scenarios served as `pareto_energy` requests with the 3-D bound gate on
//! vs `--no-prune`, certified front-identical and written to
//! `BENCH_energy.json` (evals saved, wall clock, front sizes, gated
//! throughput in evals/sec — the number `scripts/perf_compare.sh` gates).
//!
//! Run: `cargo bench --bench energy_bench` (CI's bench-smoke job runs it and
//! archives the JSON).

use codesign::opt::problem::SolveOpts;
use codesign::service::{
    CodesignRequest, CodesignResponse, ParetoEnergySummary, ScenarioSpec, Session,
};
use codesign::util::json::Json;
use std::time::Instant;

fn requests(opts: SolveOpts) -> Vec<CodesignRequest> {
    vec![
        CodesignRequest::pareto_energy(
            ScenarioSpec::two_d().quick(8).named("energy-2d").with_solve_opts(opts.clone()),
        ),
        CodesignRequest::pareto_energy(
            ScenarioSpec::three_d().quick(8).named("energy-3d").with_solve_opts(opts),
        ),
    ]
}

fn run(opts: SolveOpts) -> (Vec<ParetoEnergySummary>, f64, u64, u64) {
    let mut session = Session::paper();
    let t0 = Instant::now();
    let rep = session.submit_all(&requests(opts));
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let fronts: Vec<ParetoEnergySummary> = rep
        .answers
        .iter()
        .map(|a| match &a.response {
            CodesignResponse::ParetoEnergy(p) => p.clone(),
            other => panic!("expected pareto_energy response, got {}", other.kind()),
        })
        .collect();
    (fronts, wall_ms, rep.prune.subtrees_cut, rep.prune.bounded_out)
}

fn main() {
    let (pruned, pruned_ms, subtrees_cut, bounded_out) = run(SolveOpts::default());
    let (full, full_ms, _, _) = run(SolveOpts::default().without_prune());

    // The differential tier (`integration_energy`) certifies bit-identity
    // across platforms and thread counts; here we re-certify the two legs we
    // actually timed, then record the trajectory.
    assert_eq!(pruned.len(), full.len());
    let mut pruned_total = 0u64;
    let mut full_total = 0u64;
    let mut front_points = 0usize;
    let mut sweeps = Vec::new();
    for (p, f) in pruned.iter().zip(&full) {
        assert_eq!(p.scenario, f.scenario);
        assert_eq!(p.designs, f.designs, "{}: design counts must agree", p.scenario);
        assert_eq!(p.infeasible, f.infeasible, "{}: infeasible counts must agree", p.scenario);
        assert!(
            p.total_evals <= f.total_evals,
            "{}: the gate must never add evaluations ({} vs {})",
            p.scenario,
            p.total_evals,
            f.total_evals
        );
        assert_eq!(p.pareto.len(), f.pareto.len(), "{}: front sizes must agree", p.scenario);
        for (a, b) in p.pareto.iter().zip(&f.pareto) {
            assert_eq!((a.n_sm, a.n_v), (b.n_sm, b.n_v), "{}: front designs differ", p.scenario);
            for (name, x, y) in [
                ("m_sm_kb", a.m_sm_kb, b.m_sm_kb),
                ("area_mm2", a.area_mm2, b.area_mm2),
                ("gflops", a.gflops, b.gflops),
                ("seconds", a.seconds, b.seconds),
                ("power_w", a.power_w, b.power_w),
                ("energy_j", a.energy_j, b.energy_j),
            ] {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{}: {name} must be bit-identical with the gate on ({x} vs {y})",
                    p.scenario
                );
            }
        }
        pruned_total += p.total_evals;
        full_total += f.total_evals;
        front_points += p.pareto.len();
        sweeps.push(Json::obj(vec![
            ("sweep", Json::str(p.scenario.as_str())),
            ("designs", Json::num(p.designs as f64)),
            ("infeasible", Json::num(p.infeasible as f64)),
            ("front_points", Json::num(p.pareto.len() as f64)),
            ("pruned_evals", Json::num(p.total_evals as f64)),
            ("full_evals", Json::num(f.total_evals as f64)),
            ("evals_saved", Json::num((f.total_evals - p.total_evals) as f64)),
            ("bounded_out", Json::num(p.bounded_out as f64)),
        ]));
    }

    let bench = Json::obj(vec![
        ("pruned_evals_total", Json::num(pruned_total as f64)),
        ("full_evals_total", Json::num(full_total as f64)),
        ("evals_saved_total", Json::num((full_total - pruned_total) as f64)),
        (
            "evals_reduction_factor",
            Json::num(full_total as f64 / pruned_total.max(1) as f64),
        ),
        ("pruned_wall_ms", Json::num(pruned_ms)),
        ("full_wall_ms", Json::num(full_ms)),
        ("subtrees_cut", Json::num(subtrees_cut as f64)),
        ("instances_bounded_out", Json::num(bounded_out as f64)),
        ("front_points_total", Json::num(front_points as f64)),
        ("gated_evals_per_sec", Json::num(evals_per_sec(pruned_total, pruned_ms))),
        ("full_evals_per_sec", Json::num(evals_per_sec(full_total, full_ms))),
        ("sweeps", Json::Arr(sweeps)),
    ]);
    std::fs::write("BENCH_energy.json", bench.to_string_pretty())
        .expect("write BENCH_energy.json");
    println!(
        "energy bench: {pruned_total} evals gated vs {full_total} full \
         ({:.2}x reduction, {subtrees_cut} subtrees cut, {bounded_out} instances bounded out)\n\
         {front_points} tri-objective front points, bit-identical across both legs\n\
         wall: {pruned_ms:.0} ms vs {full_ms:.0} ms \
         ({:.0} vs {:.0} evals/sec) -> BENCH_energy.json",
        full_total as f64 / pruned_total.max(1) as f64,
        evals_per_sec(pruned_total, pruned_ms),
        evals_per_sec(full_total, full_ms),
    );
}

fn evals_per_sec(evals: u64, wall_ms: f64) -> f64 {
    evals as f64 / (wall_ms.max(1e-9) / 1e3)
}
