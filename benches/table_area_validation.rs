//! E2 — the §III-C validation: GTX 980-calibrated area model predicting the
//! Titan X die area, for both the exact eq. (5) decomposition and the
//! published eq. (6) form, plus timing of the area model itself (it sits on
//! the DSE hot path — called once per enumerated design).
//!
//! Run: `cargo bench --bench table_area_validation`

use codesign::area::{AreaModel, HwParams};
use codesign::util::bench::{black_box, Bencher};
use codesign::util::csv::Table;

fn main() {
    let mut b = Bencher::new();
    let model = AreaModel::paper();
    let titanx = HwParams::titanx();
    b.bench("area_model_eval", || model.area_mm2(black_box(&titanx)));
    b.bench("area_breakdown_eval", || model.breakdown(black_box(&titanx)));

    let mut t = Table::new(&["chip", "published_mm2", "eq5_mm2", "eq5_err_pct", "eq6_mm2", "eq6_err_pct"]);
    for (name, hw, published) in [
        ("gtx980", HwParams::gtx980(), 398.0),
        ("titanx", HwParams::titanx(), 601.0),
    ] {
        let a5 = model.area_mm2(&hw);
        let a6 = AreaModel::paper_eq6(&hw);
        t.push(&[
            name.to_string(),
            format!("{published:.0}"),
            format!("{a5:.1}"),
            format!("{:.2}", 100.0 * (a5 - published) / published),
            format!("{a6:.1}"),
            format!("{:.2}", 100.0 * (a6 - published) / published),
        ]);
    }
    println!("\n{}", t.to_ascii());
    println!("paper: predicts 589.2 mm² for the Titan X (1.96% error) from eq. (6)");
    t.save(std::path::Path::new("reports/table_area_validation/validation.csv")).unwrap();
}
