//! E6 — regenerates Table II (workload sensitivity) and times the §V-B
//! "scenarios for free" re-aggregation against a from-scratch solve.
//!
//! Run: `cargo bench --bench table2_sensitivity` (add `-- --quick`)

use codesign::codesign::scenario::Scenario;
use codesign::codesign::sensitivity::{reweighted_gflops, single_benchmark_weights};
use codesign::coordinator::Coordinator;
use codesign::report::table2;
use codesign::stencil::defs::StencilId;
use codesign::timemodel::CIterTable;
use codesign::util::bench::{black_box, Bencher};
use std::path::Path;

fn main() {
    let quick = codesign::util::bench::quick_requested();
    let mut b = Bencher::new();
    let coord = Coordinator::paper();
    let make = |base: Scenario| if quick { Scenario::quick(base, 8) } else { base };
    let sc2d = make(Scenario::paper_2d());
    let sc3d = make(Scenario::paper_3d());

    let (r2d, _) = b.bench_once("sweep_2d", || coord.run_scenario(&sc2d));
    let (r3d, _) = b.bench_once("sweep_3d", || coord.run_scenario(&sc3d));

    // The for-free knob: re-aggregating all points for one benchmark.
    let weights = single_benchmark_weights(&sc2d.workload, StencilId::Heat2D);
    b.bench("reweight_all_points_one_benchmark", || {
        r2d.result
            .points
            .iter()
            .filter_map(|p| reweighted_gflops(black_box(p), &sc2d.workload, &weights))
            .fold(0.0f64, f64::max)
    });

    let band = if quick { (380.0, 460.0) } else { (425.0, 450.0) };
    let rep = table2::generate(
        &r2d.result,
        &sc2d.workload,
        &r3d.result,
        &sc3d.workload,
        coord.platform(),
        &CIterTable::paper(),
        band,
    );
    print!("{}", rep.summary);
    rep.save(Path::new("reports")).expect("save table2");
    println!("table2 report saved under reports/table2_sensitivity/");
}
