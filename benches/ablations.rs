//! Ablation studies over the two reconstruction choices DESIGN.md §5 calls
//! out in the time model — the places where our reconstruction of [27] had
//! to commit to an assumption the paper does not publish:
//!
//! 1. **shared-memory latency scaling** (`shm_latency_exponent`): 0 makes
//!    scratchpad capacity latency-free (the optimizer then maxes out M_SM);
//!    0.25 is the default (Cacti-style √delay growth softened by banking);
//!    0.5 is full √ growth.
//! 2. **bandwidth scaling** (`mem_bw_per_sm_gbs`): per-SM 14 GB/s (Maxwell's
//!    observed 224/16 = 336/24 scaling, our default) vs a fixed chip-level
//!    budget divided by the *reference* 16 SMs (what a chip-global model
//!    would give every candidate regardless of n_SM).
//!
//! For each variant the bench re-runs the 2-D exploration on a reduced space
//! and reports where the optimum architecture lands — making explicit how
//! each assumption moves the Table II-style conclusions.
//!
//! Run: `cargo bench --bench ablations`

use codesign::codesign::scenario::{run, Scenario};
use codesign::platform::PlatformSpec;
use codesign::util::bench::Bencher;
use codesign::util::csv::Table;

fn main() {
    let quick = codesign::util::bench::quick_requested();
    let mut b = Bencher::new();

    // Every model variant is just a platform override name — the same
    // grammar `--platform` takes on the CLI.
    let variants: Vec<(&str, &str)> = vec![
        ("default (lat^0.25, per-SM BW)", "maxwell"),
        ("no shm latency scaling", "maxwell:lexp0"),
        ("full sqrt shm latency", "maxwell:lexp0.5"),
        ("2x per-SM bandwidth", "maxwell:bw28"),
        ("half per-SM bandwidth", "maxwell:bw7"),
    ];

    let mut t = Table::new(&[
        "variant",
        "best_n_sm",
        "best_n_v",
        "best_m_sm_kb",
        "best_area_mm2",
        "best_gflops",
        "gain_vs_gtx980_pct",
    ]);
    for (name, platform_name) in variants {
        let sc = Scenario::quick(Scenario::paper_2d(), if quick { 16 } else { 4 });
        let platform = PlatformSpec::parse(platform_name).expect("valid override name");
        let (res, _) = b.bench_once(&format!("ablation: {name}"), || run(&sc, &platform));
        let gtx = res.reference("gtx980").unwrap();
        let best = res.best_within(gtx.area_mm2).expect("non-empty space");
        t.push(&[
            name.to_string(),
            best.hw.n_sm.to_string(),
            best.hw.n_v.to_string(),
            format!("{}", best.hw.m_sm_kb),
            format!("{:.0}", best.area_mm2),
            format!("{:.0}", best.gflops),
            format!("{:.1}", 100.0 * (best.gflops / gtx.gflops - 1.0)),
        ]);
    }
    println!("\nBest same-area-as-GTX980 design under each model variant:");
    println!("{}", t.to_ascii());
    t.save(std::path::Path::new("reports/ablations/ablations.csv")).unwrap();
    println!("ablations report saved under reports/ablations/");
}
