//! E8 — solver cost: microseconds per inner instance (vs the paper's 19 s
//! bonmin average) and the joint-annealing baseline comparison.
//!
//! Run: `cargo bench --bench solver_cost`

use codesign::area::params::HwParams;
use codesign::opt::exhaustive::solve_exhaustive;
use codesign::opt::{solve_inner, InnerProblem, SolveOpts};
use codesign::report::solver_cost;
use codesign::stencil::defs::{Stencil, StencilId};
use codesign::stencil::workload::ProblemSize;
use codesign::timemodel::{CIterTable, TimeModel};
use codesign::util::bench::{black_box, Bencher};
use std::path::Path;

fn main() {
    let quick = codesign::util::bench::quick_requested();
    let mut b = Bencher::new();
    let model = TimeModel::maxwell();

    // Per-instance timings across representative shapes.
    for (label, id, size) in [
        ("inner_jacobi2d_8kx8k", StencilId::Jacobi2D, ProblemSize::d2(8192, 4096)),
        ("inner_gradient2d_16kx16k", StencilId::Gradient2D, ProblemSize::d2(16384, 16384)),
        ("inner_heat3d_512", StencilId::Heat3D, ProblemSize::d3(512, 256)),
    ] {
        let p = InnerProblem {
            stencil: *Stencil::get(id),
            size,
            hw: HwParams::gtx980(),
        };
        b.bench(label, || solve_inner(&model, black_box(&p), &SolveOpts::default()));
    }

    // The brute-force yardstick on a reduced instance.
    let small = InnerProblem {
        stencil: *Stencil::get(StencilId::Jacobi2D),
        size: ProblemSize::d2(1024, 256),
        hw: HwParams::gtx980(),
    };
    b.bench_once("exhaustive_reference_small", || {
        solve_exhaustive(&model, &small, 96, 256, 1, 24)
    });

    // Full report incl. the annealing baseline.
    let iters = if quick { 5_000 } else { 50_000 };
    let rep = solver_cost::generate(&model, &CIterTable::paper(), iters);
    print!("{}", rep.summary);
    rep.save(Path::new("reports")).expect("save solver_cost");
    println!("solver_cost report saved under reports/solver_cost/");
}
