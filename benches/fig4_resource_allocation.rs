//! E7 — regenerates Fig 4 (resource allocation) for both workload classes
//! and reports the clustering statistic.
//!
//! Run: `cargo bench --bench fig4_resource_allocation` (add `-- --quick`)

use codesign::codesign::scenario::Scenario;
use codesign::coordinator::Coordinator;
use codesign::report::fig4;
use codesign::util::bench::Bencher;
use std::path::Path;

fn main() {
    let quick = codesign::util::bench::quick_requested();
    let mut b = Bencher::new();
    let coord = Coordinator::paper();
    let area_model = coord.area_model();
    for base in [Scenario::paper_2d(), Scenario::paper_3d()] {
        let name = base.name.clone();
        let sc = if quick { Scenario::quick(base, 8) } else { base };
        let (rep, _) = b.bench_once(&format!("sweep_{name}"), || coord.run_scenario(&sc));
        let fig = fig4::generate(&rep.result, &area_model);
        print!("{}", fig.summary);
        fig.save(Path::new("reports")).expect("save fig4");
    }
    println!("fig4 reports saved under reports/fig4_allocation_*/");
}
