//! E3/E4/E5/E9 — regenerates both Fig 3 panels (and the cache-less
//! comparison) through the memoizing coordinator, and times the end-to-end
//! sweep — the headline system benchmark of this repo.
//!
//! Run: `cargo bench --bench fig3_pareto` (add `-- --quick` for the reduced
//! space; `--d2`/`--d3` restrict the class).

use codesign::codesign::scenario::Scenario;
use codesign::coordinator::Coordinator;
use codesign::report::fig3;
use codesign::util::bench::Bencher;
use std::path::Path;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = codesign::util::bench::quick_requested();
    let only_2d = args.iter().any(|a| a == "--d2");
    let only_3d = args.iter().any(|a| a == "--d3");

    let mut b = Bencher::new();
    let coord = Coordinator::paper();
    let area_model = coord.area_model();

    for base in [Scenario::paper_2d(), Scenario::paper_3d()] {
        if (only_2d && base.name != "2d") || (only_3d && base.name != "3d") {
            continue;
        }
        let name = base.name.clone();
        let sc = if quick { Scenario::quick(base, 8) } else { base };
        let (rep, wall) = b.bench_once(&format!("dse_sweep_{name}"), || coord.run_scenario(&sc));
        println!(
            "  {} design points, {} inner instances memoized, {} model evals, {:.2} s",
            rep.result.points.len(),
            rep.cache_entries,
            rep.result.total_evals,
            wall.as_secs_f64()
        );
        let fig = fig3::generate(&rep.result, &area_model);
        print!("{}", fig.summary);
        fig.save(Path::new("reports")).expect("save fig3");
    }
    println!("fig3 reports saved under reports/fig3_pareto_*/");
}
