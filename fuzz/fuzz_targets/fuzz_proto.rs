//! Randomized robustness harness for the serve daemon's frame decoder.
//!
//! Contract under fuzz: [`decode_frame`] and [`read_frame_line`] must never
//! panic, never recurse unboundedly, and must classify every input as either
//! a valid frame or a diagnosable error — on arbitrary bytes, on mutations
//! of valid frames, and on adversarial shapes (deep nesting, NUL bytes,
//! truncations, oversized lines, tiny reader buffers).
//!
//!     cargo run --manifest-path fuzz/Cargo.toml --release -- [iterations] [seed]
//!
//! Defaults: 200_000 iterations, seed 0xC0DE. Any panic is a finding; the
//! failing case's seed and iteration index are printed on every run so a
//! repro is one command away.

use codesign::serve::proto::{decode_frame, read_frame_line, FrameLimits, ReadLine};
use codesign::util::prng::Rng;
use std::io::BufReader;

/// A well-formed frame to mutate (ids, schema, a small request payload).
const TEMPLATE: &[u8] = br#"{"id": "fz-1", "schema": 4, "request": {"type": "pareto", "scenario": {"class": "2d", "quick_stride": 8}}}"#;

const INTERESTING: &[u8] = br#"{}[]":,\x00nulltrue1e308"#;

fn mutate(rng: &mut Rng, base: &[u8]) -> Vec<u8> {
    let mut bytes = base.to_vec();
    for _ in 0..=rng.index(8) {
        if bytes.is_empty() {
            break;
        }
        match rng.index(5) {
            // Flip a byte to anything (including NUL and invalid UTF-8).
            0 => {
                let i = rng.index(bytes.len());
                bytes[i] = rng.range_u64(0, 255) as u8;
            }
            // Truncate mid-token.
            1 => bytes.truncate(rng.index(bytes.len())),
            // Duplicate a span (breeds repeated keys and nested brackets).
            2 => {
                let i = rng.index(bytes.len());
                let j = i + rng.index(bytes.len() - i);
                let span: Vec<u8> = bytes[i..j].to_vec();
                bytes.splice(i..i, span);
            }
            // Insert an interesting structural byte.
            3 => {
                let i = rng.index(bytes.len() + 1);
                bytes.insert(i, *rng.choose(INTERESTING));
            }
            // Remove a span.
            _ => {
                let i = rng.index(bytes.len());
                let j = i + rng.index(bytes.len() - i);
                bytes.drain(i..j);
            }
        }
    }
    bytes
}

fn raw_noise(rng: &mut Rng) -> Vec<u8> {
    (0..rng.index(512)).map(|_| rng.range_u64(0, 255) as u8).collect()
}

fn adversarial(rng: &mut Rng) -> Vec<u8> {
    match rng.index(4) {
        // Nesting far past any sane limit — must be rejected by the depth
        // scan, not by blowing the stack.
        0 => {
            let depth = 1_000 + rng.index(200_000);
            let mut v = br#"{"id": "d", "request": "#.to_vec();
            v.extend(std::iter::repeat(b'[').take(depth));
            v
        }
        // Brackets inside strings (the depth scan must not count these).
        1 => {
            let n = rng.index(4_000);
            let mut v = br#"{"id": ""#.to_vec();
            v.extend(std::iter::repeat(b'[').take(n));
            v.extend(br#"", "request": {"type": "stats"}}"#);
            v
        }
        // A line of NULs.
        2 => vec![0u8; rng.index(256) + 1],
        // Escape-sequence soup.
        _ => {
            let mut v = br#"{"id": ""#.to_vec();
            for _ in 0..rng.index(64) {
                v.extend(br"\");
                v.push(*rng.choose(b"\"\\/bfnrtuxq"));
            }
            v.extend(br#""}"#);
            v
        }
    }
}

fn main() {
    let mut args = std::env::args().skip(1);
    let iterations: u64 =
        args.next().and_then(|a| a.parse().ok()).unwrap_or(200_000);
    let seed: u64 = args.next().and_then(|a| a.parse().ok()).unwrap_or(0xC0DE);
    println!("fuzz_proto: {iterations} iterations, seed {seed}");

    let limits = FrameLimits::default();
    let mut rng = Rng::new(seed);
    let mut decoded_ok = 0u64;
    let mut errors = 0u64;

    for i in 0..iterations {
        let line = match rng.index(10) {
            0..=5 => mutate(&mut rng, TEMPLATE),
            6..=7 => raw_noise(&mut rng),
            _ => adversarial(&mut rng),
        };

        // 1. Single-frame decode: never panics, always classifies.
        match decode_frame(&line, &limits) {
            Ok(_) => decoded_ok += 1,
            Err(e) => {
                errors += 1;
                assert!(!e.message.is_empty(), "iteration {i}: empty error message");
            }
        }

        // 2. The bounded reader over a chunked stream (tiny buffers exercise
        //    the fill_buf/consume loop): must terminate and account for every
        //    byte, whatever the line contents.
        if i % 16 == 0 {
            let mut stream = line.clone();
            stream.push(b'\n');
            stream.extend_from_slice(&line);
            let cap = 1 + rng.index(32);
            let max_line = 1 + rng.index(2 * line.len().max(1));
            let mut reader = BufReader::with_capacity(cap, &stream[..]);
            let mut lines = 0usize;
            loop {
                match read_frame_line(&mut reader, max_line) {
                    Ok(ReadLine::Eof) => break,
                    Ok(ReadLine::Line(_)) | Ok(ReadLine::Oversized { .. }) => {
                        lines += 1;
                        assert!(lines <= 2, "iteration {i}: phantom line");
                    }
                    Err(e) => panic!("iteration {i}: in-memory read failed: {e}"),
                }
            }
        }

        // The pristine template must always decode — guards against a
        // mutation harness bug silently fuzzing garbage only.
        if i % 10_000 == 0 {
            assert!(
                decode_frame(TEMPLATE, &limits).is_ok(),
                "iteration {i}: template no longer decodes"
            );
        }
    }

    println!(
        "done: {decoded_ok} decoded, {errors} classified errors, 0 panics \
         ({:.1}% still-valid after mutation)",
        100.0 * decoded_ok as f64 / iterations as f64
    );
}
