#!/usr/bin/env bash
# perf_compare.sh BASELINE.json FRESH.json
#
# CI perf gate for the bench-smoke job: compare a freshly generated bench
# report (BENCH_prune.json, BENCH_service.json, BENCH_serve_daemon.json)
# against the committed baseline under benches/baselines/ and fail on a
# >10% regression in any gated metric:
#
#   higher-is-better: evals/sec (recorded or derived as
#                     total_evals / wall_ms), batched_speedup
#   lower-is-better:  p95 latency (daemon reports)
#
# Metrics present in only one of the two files are reported but not gated
# (schemas may grow). A baseline carrying `"provisional": true` switches the
# script to informational mode: everything is printed, nothing fails, and
# the refresh instructions are shown — this is how first-ever baselines land
# before a CI runner has produced measured numbers (see
# benches/baselines/README.md for the promotion step).
set -euo pipefail

if [ $# -ne 2 ]; then
    echo "usage: $0 BASELINE.json FRESH.json" >&2
    exit 2
fi

BASELINE="$1" FRESH="$2" python3 - <<'PY'
import json, os, sys

TOLERANCE = 0.10  # >10% regression fails

baseline_path = os.environ["BASELINE"]
fresh_path = os.environ["FRESH"]
with open(baseline_path) as f:
    baseline = json.load(f)
with open(fresh_path) as f:
    fresh = json.load(f)


def metrics(doc):
    """Gated metrics of a bench report: {name: (value, higher_is_better)}."""
    out = {}
    # Recorded throughput metrics (BENCH_prune.json, BENCH_energy.json):
    # any top-level *evals_per_sec counter gates higher-is-better.
    for key, val in doc.items():
        if isinstance(val, (int, float)) and key.endswith("evals_per_sec"):
            out[key] = (float(val), True)
    if isinstance(doc.get("batched_speedup"), (int, float)):
        out["batched_speedup"] = (float(doc["batched_speedup"]), True)
    # Derived throughput for reports that record totals + wall clock
    # (BENCH_service.json and friends).
    evals, wall = doc.get("total_evals"), doc.get("wall_ms")
    if isinstance(evals, (int, float)) and isinstance(wall, (int, float)) and wall > 0:
        out["evals_per_sec"] = (float(evals) / (wall / 1e3), True)
    # Latency tails (daemon bench reports), whatever nesting they use.
    def find_p95(node, prefix=""):
        if isinstance(node, dict):
            for k, v in node.items():
                name = f"{prefix}{k}"
                if isinstance(v, (int, float)) and "p95" in k:
                    out[name] = (float(v), False)
                elif isinstance(v, dict):
                    find_p95(v, name + ".")
    find_p95(doc)
    return out


base_m, fresh_m = metrics(baseline), metrics(fresh)
provisional = baseline.get("provisional") is True
failures = []

print(f"perf gate: {fresh_path} vs baseline {baseline_path}"
      + (" [PROVISIONAL — informational only]" if provisional else ""))
for name in sorted(set(base_m) | set(fresh_m)):
    if name not in base_m or name not in fresh_m:
        where = "baseline" if name in base_m else "fresh"
        print(f"  ~ {name}: only in {where}, not gated")
        continue
    (b, higher), (f_, _) = base_m[name], fresh_m[name]
    if b <= 0:
        print(f"  ~ {name}: baseline {b} not positive, not gated")
        continue
    ratio = f_ / b
    regressed = ratio < (1 - TOLERANCE) if higher else ratio > (1 + TOLERANCE)
    arrow = "higher=better" if higher else "lower=better"
    mark = "FAIL" if regressed and not provisional else ("warn" if regressed else "ok")
    print(f"  {mark:>4} {name}: baseline {b:.4g} fresh {f_:.4g} "
          f"({100 * (ratio - 1):+.1f}%, {arrow})")
    if regressed and not provisional:
        failures.append(name)

if provisional:
    print("baseline is provisional: no gating. To promote it, replace "
          f"{baseline_path} with a CI-produced {os.path.basename(fresh_path)} "
          "and delete the \"provisional\" flag (benches/baselines/README.md).")
    sys.exit(0)
if failures:
    print(f"perf gate FAILED: >{TOLERANCE:.0%} regression in: {', '.join(failures)}")
    sys.exit(1)
print("perf gate passed.")
PY
