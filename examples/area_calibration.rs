//! Reproduce the paper's §III-B calibration end to end:
//!
//! 1. calibrate the Cacti-like estimator's knobs against the paper's
//!    published Cacti fit coefficients,
//! 2. run the four memory sweeps (Fig 2) with the calibrated estimator,
//! 3. assemble the full area model (adding die-photo-derived β_VU and α_oh),
//! 4. cross-check against the measured die blocks, the GTX 980 die area, and
//!    validate on the Titan X (§III-C).
//!
//! Run with: `cargo run --release --example area_calibration`

use codesign::area::{calibrate::calibrate_maxwell, AreaCoeffs};
use codesign::cacti::{calibrate_to_paper, Knobs, PAPER_TARGETS};

fn main() {
    println!("== Cacti-knob calibration against the paper's published fits ==");
    let rep = calibrate_to_paper(Knobs::initial());
    println!("converged after {} objective evaluations", rep.iterations);
    println!("knobs: {:#?}", rep.knobs);
    println!("objective: {:.6e}", rep.objective);
    println!("\n{:<16} {:>10} {:>10} | {:>10} {:>10}", "memory", "β err %", "α err %", "β paper", "α paper");
    for (&(_, bt, at), &(name, eb, ea)) in PAPER_TARGETS.iter().zip(rep.errors_pct.iter()) {
        println!("{name:<16} {eb:>10.2} {ea:>10.2} | {bt:>10.5} {at:>10.5}");
    }

    println!("\n== Full area-model calibration (Fig 2 + die photo) ==");
    let cal = calibrate_maxwell();
    for fit in &cal.sweeps {
        println!(
            "{:<16} beta={:.6} mm2/kB  alpha={:.6} mm2  r2={:.5}",
            fit.name,
            fit.beta(),
            fit.alpha(),
            fit.fit.r2
        );
    }
    let p = AreaCoeffs::paper();
    println!("\npaper:   beta_r={:.6} beta_m={:.5} beta_l1={:.4} beta_l2={:.5}", p.beta_r, p.beta_m, p.beta_l1, p.beta_l2);
    println!("\nmemory block cross-check (die-photo measured vs model predicted, mm²):");
    for (name, m, pr) in &cal.memory_crosscheck {
        println!("  {name:<12} measured={m:>8.2}  predicted={pr:>8.2}");
    }
    println!("\nGTX 980 predicted die area: {:.1} mm² (published 398)", cal.gtx980_pred_mm2);
    println!(
        "Titan X predicted die area: {:.1} mm² (published 601, error {:.2}%)",
        cal.titanx_pred_mm2, cal.titanx_err_pct
    );
}
