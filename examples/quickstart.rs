//! Quickstart: the whole stack in one minute.
//!
//! 1. load an AOT-compiled Pallas stencil artifact and run it on the PJRT
//!    CPU client (L3 executing L2/L1 output — Python is not involved);
//! 2. ask the codesign optimizer for the optimal tile sizes of that stencil
//!    on the stock GTX 980;
//! 3. ask it for a better *hardware* design at the same silicon area.
//!
//! Run with: `make artifacts && cargo run --release --example quickstart`

use codesign::area::HwParams;
use codesign::codesign::scenario::{run, Scenario};
use codesign::platform::Platform;
use codesign::opt::{solve_inner, InnerProblem, SolveOpts};
use codesign::runtime::Engine;
use codesign::stencil::defs::{Stencil, StencilId};
use codesign::stencil::workload::ProblemSize;
use codesign::timemodel::TimeModel;

fn main() -> anyhow::Result<()> {
    // --- 1. real numerics through PJRT ------------------------------------
    let mut engine = Engine::from_default_artifacts()?;
    println!("PJRT platform: {}", engine.platform());
    let entry = engine.manifest().get("heat2d_256x256_t8").expect("make artifacts").clone();
    let input = Engine::random_input(&entry, 7);
    let sweep = engine.run_sweep(&entry.name, &input)?;
    println!(
        "ran {}: {} point-updates in {:?} ({:.1} ns/update)",
        entry.name,
        entry.points_per_sweep,
        sweep.elapsed,
        sweep.elapsed.as_nanos() as f64 / entry.points_per_sweep
    );

    // --- 2. optimal tile sizes on stock hardware (the PPoPP'17 problem) ---
    let model = TimeModel::maxwell();
    let p = InnerProblem {
        stencil: *Stencil::get(StencilId::Heat2D),
        size: ProblemSize::d2(8192, 4096),
        hw: HwParams::gtx980(),
    };
    let sol = solve_inner(&model, &p, &SolveOpts::default()).expect("feasible");
    println!(
        "optimal tiles on GTX 980 for heat2d 8192x8192xT4096: tiles {} k={} -> {:.0} GFLOP/s ({:?}-bound)",
        sol.sw.tiles.label(),
        sol.sw.k,
        sol.est.gflops,
        sol.est.bound
    );

    // --- 3. codesign: a better machine at the same area -------------------
    let sc = Scenario::quick(Scenario::paper_2d(), 8);
    let res = run(&sc, Platform::default_spec());
    let gtx = res.reference("gtx980").unwrap();
    let best = res.best_within(gtx.area_mm2).unwrap();
    println!(
        "codesign: GTX 980 ({:.0} mm²) does {:.0} GFLOP/s on the 2-D mix; the optimizer finds {} at {:.0} mm² doing {:.0} GFLOP/s ({:+.0}%)",
        gtx.area_mm2,
        gtx.gflops,
        best.hw.label(),
        best.area_mm2,
        best.gflops,
        100.0 * (best.gflops / gtx.gflops - 1.0)
    );
    Ok(())
}
