//! End-to-end reproduction of the paper's design-space exploration (§V-A,
//! Fig 3, E3/E4/E5/E9): enumerate every feasible cache-less accelerator in
//! the 200–650 mm² range, solve the eq. (18) codesign problem on each for
//! both workload classes, extract the Pareto frontiers, and print the
//! improvement statistics against the stock GTX 980 / Titan X.
//!
//! Run with: `cargo run --release --example codesign_full [-- --quick]`

use codesign::codesign::cacheless::cacheless_comparison;
use codesign::codesign::scenario::{run, Scenario};
use codesign::platform::Platform;
use codesign::util::ascii_plot::ScatterPlot;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let platform = Platform::default_spec();
    let area_model = platform.area_model();

    for base in [Scenario::paper_2d(), Scenario::paper_3d()] {
        let name = base.name.clone();
        let sc = if quick { Scenario::quick(base, 4) } else { base };
        let t0 = std::time::Instant::now();
        let res = run(&sc, platform);
        let dt = t0.elapsed();

        println!("\n================ {name} stencils ================");
        println!(
            "design points: {} solved (+{} infeasible), pareto-optimal: {} ({:.1}%), {} model evals, {:.2?}",
            res.points.len(),
            res.infeasible_points,
            res.pareto.len(),
            100.0 * res.pareto.len() as f64 / res.points.len() as f64,
            res.total_evals,
            dt
        );
        for r in &res.references {
            println!(
                "  {:<8} area {:.0} mm² (published {:.0}), {:.0} GFLOP/s",
                r.name, r.area_mm2, r.published_area_mm2, r.gflops
            );
        }
        for (name, impr, hw) in &res.stats.vs_reference {
            println!("  vs {name}: +{impr:.1}% at comparable area  (best: {})", hw.label());
        }
        for row in cacheless_comparison(&res, &area_model) {
            println!(
                "  cache-less {}: area {:.0}->{:.0} mm², improvement at reduced budget +{:.2}% (full budget +{:.2}%)",
                row.reference,
                row.full_area_mm2,
                row.reduced_area_mm2,
                row.improvement_pct,
                row.full_budget_improvement_pct
            );
        }
        // Fig 3 in the terminal.
        let xy = res.xy();
        let front: Vec<(f64, f64)> = res.pareto.iter().map(|&i| xy[i]).collect();
        let refs: Vec<(f64, f64)> =
            res.references.iter().map(|r| (r.area_mm2, r.gflops)).collect();
        let mut plot = ScatterPlot::new(
            &format!("Fig 3 ({name}): optimal performance vs chip area"),
            "chip area (mm^2)",
            "GFLOP/s",
        );
        plot.series("feasible designs", '.', xy);
        plot.series("pareto", 'o', front);
        plot.series("GTX980/TitanX", 'X', refs);
        println!("\n{}", plot.render());

        // Table II-style best-in-band summary.
        if let Some(best) = res.best_within(450.0) {
            println!(
                "best design <= 450 mm²: {} -> {:.0} GFLOP/s ({:.0} mm²)",
                best.hw.label(),
                best.gflops,
                best.area_mm2
            );
        }
    }
}
