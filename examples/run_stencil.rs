//! E11 — end-to-end stencil execution: every artifact in the manifest is
//! loaded, compiled and executed through PJRT; per-point costs are reported
//! and the measured-mode C_iter table is derived (the paper's "measured
//! C_iter" step, on this repo's CPU substrate).
//!
//! Run with: `make artifacts && cargo run --release --example run_stencil`

use codesign::runtime::{citer_measure, Engine};
use codesign::stencil::defs::ALL_STENCILS;
use codesign::timemodel::CIterTable;

fn main() -> anyhow::Result<()> {
    let mut engine = Engine::from_default_artifacts()?;
    println!("PJRT platform: {}", engine.platform());
    println!("{:<28} {:>14} {:>12} {:>14}", "artifact", "points", "time", "ns/update");
    let names: Vec<String> =
        engine.manifest().entries.iter().map(|e| e.name.clone()).collect();
    for name in names {
        let entry = engine.manifest().get(&name).unwrap().clone();
        let input = Engine::random_input(&entry, 1);
        engine.run_sweep(&name, &input)?; // warm-up (compile)
        let run = engine.run_sweep(&name, &input)?;
        println!(
            "{:<28} {:>14} {:>12?} {:>14.2}",
            entry.name,
            entry.points_per_sweep,
            run.elapsed,
            run.elapsed.as_nanos() as f64 / entry.points_per_sweep
        );
    }

    // L1 time-tiling experiment: the fused ghost-zone artifacts do the same
    // total point-updates as their plain twins with ~t_steps× fewer HBM
    // round-trips per block (at the cost of redundant halo compute).
    println!("\nfused (time-tiled) vs plain variants:");
    let fused: Vec<String> = engine
        .manifest()
        .entries
        .iter()
        .filter(|e| e.pad > 1)
        .map(|e| e.name.clone())
        .collect();
    for name in fused {
        let plain_name = name.split("_fused").next().unwrap().to_string();
        let mut time_of = |n: &str| -> anyhow::Result<f64> {
            let entry = engine.manifest().get(n).unwrap().clone();
            let input = Engine::random_input(&entry, 2);
            engine.run_sweep(n, &input)?;
            let mut best = f64::INFINITY;
            for _ in 0..3 {
                let r = engine.run_sweep(n, &input)?;
                best = best.min(r.elapsed.as_nanos() as f64 / entry.points_per_sweep);
            }
            Ok(best)
        };
        let (fused_ns, plain_ns) = (time_of(&name)?, time_of(&plain_name)?);
        println!(
            "  {name}: {fused_ns:.2} ns/update vs plain {plain_ns:.2} ns/update ({:+.0}%)",
            100.0 * (fused_ns / plain_ns - 1.0)
        );
    }

    println!("\nmeasured-mode C_iter (anchored on jacobi2d paper value):");
    let raw = citer_measure::measure_raw(&mut engine, 3)?;
    let table = citer_measure::measure_citer(&mut engine, 3)?;
    let paper = CIterTable::paper();
    for st in &ALL_STENCILS {
        let m = raw.iter().find(|m| m.stencil == st.id);
        println!(
            "  {:<12} {:>8.2} ns/pt -> {:>6.2} model cycles (paper mode {:>5.1})",
            st.name(),
            m.map(|m| m.ns_per_point).unwrap_or(f64::NAN),
            table.get(st.id),
            paper.get(st.id)
        );
    }
    Ok(())
}
