//! §V-B / Table II: workload sensitivity. Runs both sweeps through the
//! memoizing coordinator, then derives per-benchmark optimal architectures
//! by pure re-aggregation ("other scenarios for free") and prints the
//! three-way Table II comparison (ours / paper / paper-config-under-our-model).
//!
//! Run with: `cargo run --release --example workload_sensitivity [-- --quick]`

use codesign::codesign::scenario::Scenario;
use codesign::coordinator::Coordinator;
use codesign::report::table2;
use codesign::timemodel::CIterTable;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let coord = Coordinator::paper().with_progress(1000);
    let make = |base: Scenario| if quick { Scenario::quick(base, 4) } else { base };
    let sc2d = make(Scenario::paper_2d());
    let sc3d = make(Scenario::paper_3d());

    eprintln!("running 2-D sweep…");
    let r2d = coord.run_scenario(&sc2d);
    eprintln!("running 3-D sweep…");
    let r3d = coord.run_scenario(&sc3d);
    eprintln!(
        "cache: {} entries, {:.0}% hit rate over both sweeps",
        coord.cache.len(),
        100.0 * coord.cache.stats.hit_rate()
    );

    // The quick space may not reach the paper's 425–450 band; widen for -q.
    let band = if quick { (380.0, 460.0) } else { (425.0, 450.0) };
    let rep = table2::generate(
        &r2d.result,
        &sc2d.workload,
        &r3d.result,
        &sc3d.workload,
        coord.platform(),
        &CIterTable::paper(),
        band,
    );
    print!("{}", rep.summary);
    for f in rep.save(std::path::Path::new("reports")).unwrap() {
        println!("wrote {}", f.display());
    }
}
