//! Batched DSE through the session service: answer many typed requests from
//! ONE shared hardware sweep, then repeat the batch against the warm cache.
//!
//! The production question the service answers: given one sweep of the
//! hardware grid, serve an arbitrary mix of requests — full explorations,
//! §V-B what-if re-weightings, Pareto queries under chip-area budgets —
//! without re-solving a single inner problem. Nine requests below share one
//! sweep; the second submission of the same batch is pure cache service.
//!
//! Run with: `cargo run --release --example batch_scenarios`

use codesign::service::{CodesignRequest, CodesignResponse, ScenarioSpec, Session};
use codesign::stencil::defs::StencilId;

fn main() {
    let base = ScenarioSpec::two_d().quick(8);
    let only = |id: StencilId| {
        CodesignRequest::what_if(
            base.clone().named(&format!("only-{}", id.name())),
            vec![(id, 1.0)],
        )
    };
    let requests = vec![
        CodesignRequest::explore(base.clone().named("uniform-2d")),
        only(StencilId::Jacobi2D),
        only(StencilId::Heat2D),
        only(StencilId::Laplacian2D),
        only(StencilId::Gradient2D),
        CodesignRequest::pareto(base.clone().with_area_budget(300.0).named("budget-300mm2")),
        CodesignRequest::pareto(base.clone().with_area_budget(380.0).named("budget-380mm2")),
        CodesignRequest::pareto(base.clone().with_area_budget(460.0).named("budget-460mm2")),
        CodesignRequest::what_if(
            base.clone().named("jacobi-heavy-70/10/10/10"),
            vec![
                (StencilId::Jacobi2D, 7.0),
                (StencilId::Heat2D, 1.0),
                (StencilId::Laplacian2D, 1.0),
                (StencilId::Gradient2D, 1.0),
            ],
        ),
    ];
    assert!(requests.len() >= 8, "the demo promises at least 8 requests");

    let mut session = Session::paper();
    let rep = session.submit_all(&requests);
    assert_eq!(rep.answers.len(), requests.len());

    println!(
        "{:<28} {:>7} {:>7} {:>12} {:>14}",
        "request", "designs", "pareto", "best GFLOP/s", "vs GTX980"
    );
    for a in &rep.answers {
        match &a.response {
            CodesignResponse::Explore(s) | CodesignResponse::WhatIf(s) => {
                let best = s.best.as_ref().map(|d| d.gflops).unwrap_or(0.0);
                let vs = s
                    .references
                    .iter()
                    .find(|r| r.name == "gtx980")
                    .and_then(|r| r.improvement_pct)
                    .unwrap_or(f64::NAN);
                println!(
                    "{:<28} {:>7} {:>7} {:>12.0} {:>+12.1}% (gtx980)",
                    s.scenario,
                    s.designs,
                    s.pareto.len(),
                    best,
                    vs
                );
            }
            CodesignResponse::Pareto(p) => {
                let best = p.pareto.last().map(|d| d.gflops).unwrap_or(0.0);
                println!(
                    "{:<28} {:>7} {:>7} {:>12.0} {:>14}",
                    p.scenario,
                    p.designs,
                    p.pareto.len(),
                    best,
                    "-"
                );
            }
            other => panic!("unexpected response '{}'", other.kind()),
        }
    }

    // The whole point: request-by-request solving would have cost the
    // serve-phase lookups in inner solves; the shared sweep solved only the
    // deduplicated union.
    let serve_lookups = rep.lookups() - rep.unique_instances as u64;
    println!(
        "\n{} requests answered from one sweep in {:?}:",
        rep.answers.len(),
        rep.wall
    );
    println!(
        "  {} unique (hw, stencil, size) instances solved; {} lookups served \
         ({:.1}% cache hits)",
        rep.unique_instances,
        serve_lookups,
        100.0 * rep.cache_hit_rate()
    );
    println!(
        "  request-by-request solving would have needed {serve_lookups} inner solves \
         ({:.1}x the shared sweep)",
        serve_lookups as f64 / rep.unique_instances as f64
    );

    // A second submission of the same batch is pure cache service.
    let again = session.submit_all(&requests);
    println!(
        "  repeated batch: {:.2}% hits in {:?}",
        100.0 * again.cache_hit_rate(),
        again.wall
    );
    for (a, b) in rep.answers.iter().zip(&again.answers) {
        assert_eq!(a.response, b.response, "warm repeat must be bit-identical");
    }
}
