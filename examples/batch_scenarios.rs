//! Batched DSE: answer many scenario queries from ONE shared hardware sweep.
//!
//! The production question the coordinator's batch API serves: given one
//! sweep of the hardware grid, answer an arbitrary mix of scenario queries —
//! workload re-weightings, per-stencil subsets, chip-area budgets — without
//! re-solving a single inner problem. Nine scenarios below share one sweep;
//! the printed cache accounting shows the sweep cost is flat in the number
//! of scenarios.
//!
//! Run with: `cargo run --release --example batch_scenarios`

use codesign::area::AreaModel;
use codesign::codesign::scenario::Scenario;
use codesign::coordinator::Coordinator;
use codesign::stencil::defs::StencilId;
use codesign::timemodel::TimeModel;

fn main() {
    let base = Scenario::quick(Scenario::paper_2d(), 8);
    let only = |id: StencilId| {
        base.clone()
            .with_workload(
                base.workload.reweighted(|e| if e.stencil == id { 1.0 } else { 0.0 }),
            )
            .named(&format!("only-{}", id.name()))
    };
    let scenarios = vec![
        base.clone().named("uniform-2d"),
        only(StencilId::Jacobi2D),
        only(StencilId::Heat2D),
        only(StencilId::Laplacian2D),
        only(StencilId::Gradient2D),
        base.clone().with_area_budget(300.0).named("budget-300mm2"),
        base.clone().with_area_budget(380.0).named("budget-380mm2"),
        base.clone().with_area_budget(460.0).named("budget-460mm2"),
        base.clone()
            .with_workload(
                base.workload
                    .reweighted(|e| if e.stencil == StencilId::Jacobi2D { 7.0 } else { 1.0 }),
            )
            .named("jacobi-heavy-70/10/10/10"),
    ];
    assert!(scenarios.len() >= 8, "the demo promises at least 8 scenarios");

    let coord = Coordinator::new(AreaModel::paper(), TimeModel::maxwell());
    let rep = coord.run_batch_report(&scenarios);
    assert_eq!(rep.reports.len(), scenarios.len());

    println!(
        "{:<28} {:>7} {:>7} {:>12} {:>14}",
        "scenario", "designs", "pareto", "best GFLOP/s", "vs GTX980"
    );
    for r in &rep.reports {
        let res = &r.result;
        let best = res.points.iter().map(|p| p.gflops).fold(0.0, f64::max);
        let (ref_name, impr, _) = &res.stats.vs_reference[0];
        println!(
            "{:<28} {:>7} {:>7} {:>12.0} {:>+12.1}% ({ref_name})",
            res.scenario_name,
            res.points.len(),
            res.pareto.len(),
            best,
            impr
        );
    }

    // The whole point: scenario-by-scenario solving would have cost the
    // serve-phase lookups in inner solves; the shared sweep solved only the
    // deduplicated union.
    let serve_lookups = rep.lookups - rep.unique_instances as u64;
    println!(
        "\n{} scenarios answered from one sweep in {:?}:",
        rep.reports.len(),
        rep.wall
    );
    println!(
        "  {} unique (hw, stencil, size) instances solved; {} lookups served \
         ({:.1}% cache hits)",
        rep.unique_instances,
        serve_lookups,
        100.0 * rep.cache_hit_rate
    );
    println!(
        "  scenario-by-scenario solving would have needed {serve_lookups} inner solves \
         ({:.1}x the shared sweep)",
        serve_lookups as f64 / rep.unique_instances as f64
    );

    // A second batch over the same grid is pure cache service.
    let again = coord.run_batch_report(&scenarios);
    println!(
        "  repeated batch: {:.2}% hits in {:?}",
        100.0 * again.cache_hit_rate,
        again.wall
    );
}
