//! §V-A / E5: the cache-deletion comparison — how much of the codesign win
//! is "remove the caches" versus "rebalance the architecture"?
//!
//! For each reference GPU this prints its stock performance, its area with
//! caches deleted, and the best cache-less candidate design at (a) the full
//! budget and (b) the reduced budget, against the paper's numbers.
//!
//! Run with: `cargo run --release --example cacheless [-- --quick]`

use codesign::area::HwParams;
use codesign::codesign::cacheless::cacheless_comparison;
use codesign::codesign::scenario::{run, Scenario};
use codesign::platform::Platform;
use codesign::report::fig3::paper_improvements;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let platform = Platform::default_spec();
    let area_model = platform.area_model();

    // The area decomposition first: what do the caches cost?
    for (name, hw) in [("GTX 980", HwParams::gtx980()), ("Titan X", HwParams::titanx())] {
        let b = area_model.breakdown(&hw);
        println!(
            "{name}: die {:.0} mm² = cores {:.0} + registers {:.0} + shm {:.0} + L1 {:.0} + L2 {:.0} + overhead {:.0}",
            b.total(),
            b.cores_mm2,
            b.registers_mm2,
            b.shared_mm2,
            b.l1_mm2,
            b.l2_mm2,
            b.overhead_mm2
        );
        println!(
            "  -> caches are {:.0} mm² ({:.0}% of the die); deleting them leaves {:.0} mm²",
            b.caches_mm2(),
            100.0 * b.caches_mm2() / b.total(),
            b.total() - b.caches_mm2()
        );
    }

    for base in [Scenario::paper_2d(), Scenario::paper_3d()] {
        let name = base.name.clone();
        let sc = if quick { Scenario::quick(base, 4) } else { base };
        let res = run(&sc, platform);
        println!("\n== {name} stencils ==");
        for row in cacheless_comparison(&res, &area_model) {
            println!(
                "{}: stock {:.0} GFLOP/s @ {:.0} mm² | best candidate @ full budget {:+.1}% | @ cache-less budget ({:.0} mm²) {:+.1}%",
                row.reference,
                row.ref_gflops,
                row.full_area_mm2,
                row.full_budget_improvement_pct,
                row.reduced_area_mm2,
                row.improvement_pct
            );
        }
        if let Some((g_full, t_full, g_cl, t_cl)) = paper_improvements(&name) {
            println!("paper: gtx980 +{g_full}% full / +{g_cl}% cache-less; titanx +{t_full}% / +{t_cl}%");
        }
    }
}
