"""Fused time-tiled kernels: a fused t_steps-block must equal t_steps plain
reference steps exactly (zero-Dirichlet ring), across shapes and stencils."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused, ref


def rand_wide_padded(seed, shape, h):
    rng = np.random.default_rng(seed)
    interior = rng.uniform(-1.0, 1.0, size=shape).astype(np.float32)
    return jnp.asarray(np.pad(interior, h))


def ref_multi_step(name, a_wide, h, t_steps):
    """t_steps reference steps on the 1-padded view, re-embedded in the
    h-padded array."""
    # Reduce to the canonical 1-ring padding, sweep, re-embed.
    interior = np.asarray(a_wide)[h:-h, h:-h]
    a1 = jnp.asarray(np.pad(interior, 1))
    out = ref.sweep_ref(name, a1, t_steps)
    return np.asarray(out)[1:-1, 1:-1]


@pytest.mark.parametrize("name", ["jacobi2d", "heat2d", "laplacian2d", "gradient2d"])
@pytest.mark.parametrize("t_steps", [2, 4])
def test_fused_equals_repeated_reference(name, t_steps):
    a = rand_wide_padded(0, (32, 32), t_steps)
    step = fused.make_fused_step_2d(name, t_steps)
    got = np.asarray(step(a, 16, 16))
    want = ref_multi_step(name, a, t_steps, t_steps)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(
    name=st.sampled_from(["jacobi2d", "heat2d"]),
    t_steps=st.sampled_from([2, 3, 4]),
    blocks=st.tuples(st.integers(1, 3), st.integers(1, 3)),
    tile=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_swept_shapes(name, t_steps, blocks, tile, seed):
    shape = (blocks[0] * tile, blocks[1] * tile)
    a = rand_wide_padded(seed, shape, t_steps)
    step = fused.make_fused_step_2d(name, t_steps)
    got = np.asarray(step(a, tile, tile))
    want = ref_multi_step(name, a, t_steps, t_steps)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_sweep_fn_matches_plain_sweep():
    t_steps, total = 4, 8
    a = rand_wide_padded(7, (32, 32), t_steps)
    fn = fused.fused_sweep_fn("heat2d", a.shape, total, t_steps, tiles=(16, 16))
    (got,) = jax.jit(fn)(a)
    want = ref_multi_step("heat2d", a, t_steps, total)
    np.testing.assert_allclose(np.asarray(got)[t_steps:-t_steps, t_steps:-t_steps], want, rtol=1e-5, atol=1e-5)


def test_traffic_amortization_bookkeeping():
    # The point of fusion: staged bytes per point-update drop ~t_steps x.
    t1 = t2 = 64
    plain = fused.vmem_footprint_bytes(t1, t2, 1) / (t1 * t2 * 1)
    fused4 = fused.vmem_footprint_bytes(t1, t2, 4) / (t1 * t2 * 4)
    assert fused4 < plain / 2.5, f"{plain} -> {fused4} bytes/update"


def test_redundancy_factor_bounds():
    # 64x64 block, 4 fused steps: modest redundancy.
    r = fused.redundancy_factor(64, 64, 4)
    assert 1.0 < r < 1.2, r
    # Tiny blocks with deep fusion: redundancy blows up — the constraint-(9)
    # trade-off the codesign model navigates.
    r_small = fused.redundancy_factor(8, 8, 4)
    assert r_small > 1.5, r_small
    assert fused.redundancy_factor(64, 64, 1) == 1.0
