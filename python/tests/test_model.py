"""L2 correctness: the jitted time sweep equals T applications of the
reference step; donation and lowering behave."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import common, ref


def rand_padded(seed, shape):
    rng = np.random.default_rng(seed)
    interior = rng.uniform(-1.0, 1.0, size=shape).astype(np.float32)
    return jnp.asarray(np.pad(interior, common.SIGMA))


@pytest.mark.parametrize("name", ["jacobi2d", "heat2d", "gradient2d"])
def test_sweep_matches_ref_2d(name):
    a = rand_padded(10, (32, 32))
    fn = model.sweep_fn(name, a.shape, t_steps=5)
    (got,) = jax.jit(fn)(a)
    want = ref.sweep_ref(name, a, 5)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_sweep_matches_ref_3d():
    a = rand_padded(11, (8, 8, 8))
    fn = model.sweep_fn("heat3d", a.shape, t_steps=3)
    (got,) = jax.jit(fn)(a)
    want = ref.sweep_ref("heat3d", a, 3)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_zero_steps_is_identity():
    a = rand_padded(12, (16, 16))
    fn = model.sweep_fn("jacobi2d", a.shape, t_steps=0)
    (got,) = jax.jit(fn)(a)
    np.testing.assert_array_equal(got, a)


def test_lowering_produces_single_while_loop():
    lowered = model.lower_sweep("heat2d", (32, 32), 4)
    text = str(lowered.compiler_ir("stablehlo"))
    # The sweep must stay a rolled loop (scan/while), not unroll 4 copies.
    assert text.count("stablehlo.while") >= 1
    from compile.aot import to_hlo_text

    hlo = to_hlo_text(lowered)
    assert "ENTRY" in hlo and len(hlo) > 100
