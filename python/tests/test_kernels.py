"""L1 correctness: every Pallas kernel against the pure-jnp oracle, with
hypothesis sweeping domain shapes, tile shapes and input distributions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import STEP_FNS, common, ref

STENCILS_2D = ["jacobi2d", "heat2d", "laplacian2d", "gradient2d"]
STENCILS_3D = ["heat3d", "laplacian3d"]


def rand_padded(rng, shape):
    """Random interior in [-1, 1] with a zero halo ring."""
    interior = rng.uniform(-1.0, 1.0, size=shape).astype(np.float32)
    return jnp.asarray(np.pad(interior, common.SIGMA))


@pytest.mark.parametrize("name", STENCILS_2D)
def test_2d_kernel_matches_ref_default_tiles(name):
    rng = np.random.default_rng(0)
    a = rand_padded(rng, (64, 64))
    got = STEP_FNS[name](a)
    want = ref.STEPS[name](a)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("name", STENCILS_3D)
def test_3d_kernel_matches_ref_default_tiles(name):
    rng = np.random.default_rng(1)
    a = rand_padded(rng, (16, 16, 16))
    got = STEP_FNS[name](a)
    want = ref.STEPS[name](a)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    name=st.sampled_from(STENCILS_2D),
    s1_blocks=st.integers(1, 4),
    s2_blocks=st.integers(1, 4),
    t1=st.sampled_from([4, 8, 16]),
    t2=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_2d_kernel_matches_ref_swept(name, s1_blocks, s2_blocks, t1, t2, seed):
    rng = np.random.default_rng(seed)
    a = rand_padded(rng, (s1_blocks * t1, s2_blocks * t2))
    got = STEP_FNS[name](a, t1, t2)
    want = ref.STEPS[name](a)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    name=st.sampled_from(STENCILS_3D),
    blocks=st.tuples(st.integers(1, 2), st.integers(1, 2), st.integers(1, 2)),
    tile=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_3d_kernel_matches_ref_swept(name, blocks, tile, seed):
    rng = np.random.default_rng(seed)
    shape = tuple(b * tile for b in blocks)
    a = rand_padded(rng, shape)
    got = STEP_FNS[name](a, tile, tile, tile)
    want = ref.STEPS[name](a)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_tile_must_divide_domain():
    a = rand_padded(np.random.default_rng(2), (10, 10))
    with pytest.raises(AssertionError):
        STEP_FNS["jacobi2d"](a, 4, 4)  # 10 % 4 != 0


def test_choose_tile():
    assert common.choose_tile(128) == 64
    assert common.choose_tile(96) == 32
    assert common.choose_tile(10) == 2
    assert common.choose_tile(7) == 1


def test_vmem_footprint():
    # 64x64 fp32: (66*66 + 64*64) * 4 B ≈ 33.8 kB.
    fp = common.vmem_footprint_bytes((64, 64))
    assert fp == 4 * (66 * 66 + 64 * 64)
    assert fp < 16 * 2**20, "block must fit VMEM"


def test_boundary_ring_untouched_by_sweep():
    rng = np.random.default_rng(3)
    a = rand_padded(rng, (16, 16))
    out = ref.sweep_ref("heat2d", a, 3)
    np.testing.assert_array_equal(np.asarray(out)[0, :], 0.0)
    np.testing.assert_array_equal(np.asarray(out)[:, -1], 0.0)


def test_jacobi_constant_field_midpoint():
    # Interior of all-ones: away from the boundary the 4-neighbour average
    # stays 1.
    a = jnp.asarray(np.pad(np.ones((8, 8), np.float32), 1))
    out = STEP_FNS["jacobi2d"](a)
    assert abs(float(out[4, 4]) - 1.0) < 1e-6


def test_gradient_nonnegative():
    rng = np.random.default_rng(4)
    a = rand_padded(rng, (32, 32))
    out = np.asarray(STEP_FNS["gradient2d"](a))
    assert (out >= 0.0).all()


def test_flops_table_covers_all_stencils():
    assert set(ref.FLOPS_PER_POINT) == set(STEP_FNS)
    assert all(v > 0 for v in ref.FLOPS_PER_POINT.values())
