"""AOT exporter: manifest shape, naming, and one end-to-end export."""

import json
import pathlib

from compile import aot


def test_variant_names():
    assert aot.variant_name("heat2d", (128, 128), 4) == "heat2d_128x128_t4"
    assert aot.variant_name("heat3d", (32, 32, 32), 2) == "heat3d_32x32x32_t2"


def test_variants_cover_all_six_stencils():
    stencils = {v[0] for v in aot.VARIANTS}
    assert stencils == {
        "jacobi2d",
        "heat2d",
        "laplacian2d",
        "gradient2d",
        "heat3d",
        "laplacian3d",
    }


def test_export_one_variant(tmp_path: pathlib.Path):
    # Full export is exercised by `make artifacts`; keep the test quick by
    # exporting a single small variant through the same code path.
    saved = aot.VARIANTS
    try:
        aot.VARIANTS = [("jacobi2d", (32, 32), 2)]
        manifest = aot.export_all(tmp_path)
    finally:
        aot.VARIANTS = saved
    entry = manifest["artifacts"][0]
    assert entry["name"] == "jacobi2d_32x32_t2"
    hlo = (tmp_path / entry["file"]).read_text()
    assert "ENTRY" in hlo
    on_disk = json.loads((tmp_path / "manifest.json").read_text())
    assert on_disk["artifacts"][0]["points_per_sweep"] == 32 * 32 * 2
