"""Mathematical properties of the reference stencils (and hence, via the
allclose tests, of the Pallas kernels): invariance on constant fields,
convexity bounds, linearity, symmetry."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import common, ref


def const_padded(value, shape):
    a = np.full(shape, value, np.float32)
    return jnp.asarray(np.pad(a, common.SIGMA))


def rand_padded(seed, shape, lo=-1.0, hi=1.0):
    rng = np.random.default_rng(seed)
    interior = rng.uniform(lo, hi, size=shape).astype(np.float32)
    return jnp.asarray(np.pad(interior, common.SIGMA))


def deep_interior(x):
    """Values at least 2 cells away from the boundary ring."""
    if x.ndim == 2:
        return x[2:-2, 2:-2]
    return x[2:-2, 2:-2, 2:-2]


def test_jacobi_preserves_constant_in_deep_interior():
    a = const_padded(3.5, (16, 16))
    out = ref.jacobi2d(a)
    np.testing.assert_allclose(deep_interior(np.pad(np.asarray(out), 1)), 3.5, rtol=1e-6)


def test_heat_preserves_constant_in_deep_interior():
    # 0.5 + 4*0.125 = 1: the step is an affine combination with weight 1.
    a = const_padded(2.0, (16, 16))
    out = np.asarray(ref.heat2d(a))
    np.testing.assert_allclose(out[2:-2, 2:-2], 2.0, rtol=1e-6)


def test_heat3d_preserves_constant_in_deep_interior():
    # 0.4 + 6*0.1 = 1.
    a = const_padded(1.5, (8, 8, 8))
    out = np.asarray(ref.heat3d(a))
    np.testing.assert_allclose(out[2:-2, 2:-2, 2:-2], 1.5, rtol=1e-6)


@pytest.mark.parametrize("name", ["laplacian2d", "laplacian3d", "gradient2d"])
def test_derivative_stencils_vanish_on_constants(name):
    shape = (8, 8, 8) if name.endswith("3d") else (16, 16)
    a = const_padded(7.0, shape)
    out = np.asarray(ref.STEPS[name](a))
    # Interior away from the zero boundary ring.
    inner = out[2:-2, 2:-2] if out.ndim == 2 else out[2:-2, 2:-2, 2:-2]
    np.testing.assert_allclose(inner, 0.0, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_convex_steps_are_bounded(seed):
    # Jacobi/Heat are convex combinations: outputs stay within input range.
    a = rand_padded(seed, (16, 16))
    amin, amax = float(jnp.min(a)), float(jnp.max(a))
    for name in ["jacobi2d", "heat2d"]:
        out = ref.STEPS[name](a)
        assert float(jnp.min(out)) >= amin - 1e-6
        assert float(jnp.max(out)) <= amax + 1e-6


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 10.0))
def test_linear_stencils_are_homogeneous(seed, scale):
    a = rand_padded(seed, (16, 16))
    for name in ["jacobi2d", "heat2d", "laplacian2d"]:
        out1 = np.asarray(ref.STEPS[name](a)) * scale
        out2 = np.asarray(ref.STEPS[name](a * scale))
        np.testing.assert_allclose(out1, out2, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_gradient_is_scale_homogeneous_of_degree_one(seed):
    a = rand_padded(seed, (16, 16))
    out1 = np.asarray(ref.gradient2d(a)) * 2.0
    out2 = np.asarray(ref.gradient2d(a * 2.0))
    np.testing.assert_allclose(out1, out2, rtol=1e-4, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_symmetric_stencils_commute_with_transpose(seed):
    a = rand_padded(seed, (16, 16))
    for name in ["jacobi2d", "heat2d", "laplacian2d", "gradient2d"]:
        out_t = np.asarray(ref.STEPS[name](a.T))
        t_out = np.asarray(ref.STEPS[name](a)).T
        np.testing.assert_allclose(out_t, t_out, rtol=1e-5, atol=1e-6)


def test_heat_sweep_converges_towards_zero_with_zero_boundary():
    # With a zero Dirichlet ring, repeated heat steps dissipate energy.
    a = rand_padded(5, (16, 16))
    e0 = float(jnp.sum(a * a))
    out = ref.sweep_ref("heat2d", a, 50)
    e1 = float(jnp.sum(out * out))
    assert e1 < e0 * 0.5, f"energy {e0} -> {e1}"
