"""Shared machinery for the Pallas stencil kernels (Layer 1).

Every stencil operates on a zero-padded array (halo ring of width sigma = 1,
Dirichlet boundary): a step computes the interior from its neighbours and
leaves the ring untouched.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA view
— a threadblock stages a tile + halo into shared memory — maps here to one
grid step of a ``pallas_call`` staging a block + halo into VMEM. The halo
load is expressed with explicit dynamic slices from the full (ANY-space)
input ref, because overlapping input windows are not expressible as a plain
blocked ``BlockSpec``; the output is a standard blocked spec. Kernels are
lowered with ``interpret=True`` — real-TPU lowering emits Mosaic custom
calls the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).

VMEM footprint per grid step (the L1 analogue of constraint (9)):
``4 B · [(t1+2)(t2+2) + t1·t2]`` for 2-D, and the analogous product for 3-D
— e.g. the default 64×64 fp32 block stages ~33 kB, comfortably inside a
TPU core's ~16 MB VMEM; block shapes are chosen by `choose_tile` to divide
the domain exactly.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SIGMA = 1  # halo width of every paper stencil (all first-order)


def choose_tile(extent: int, preferred: int = 64) -> int:
    """Largest power-of-two block size <= preferred that divides `extent`."""
    t = preferred
    while t > 1:
        if extent % t == 0:
            return t
        t //= 2
    return 1


def make_step_2d(compute):
    """Build a 2-D stencil step: padded (S1+2, S2+2) -> interior (S1, S2).

    `compute` maps a loaded (t1+2, t2+2) tile to its (t1, t2) output block.
    """

    def step(a_padded, t1=None, t2=None):
        s1 = a_padded.shape[0] - 2 * SIGMA
        s2 = a_padded.shape[1] - 2 * SIGMA
        t1 = t1 or choose_tile(s1)
        t2 = t2 or choose_tile(s2)
        assert s1 % t1 == 0 and s2 % t2 == 0, "tiles must divide the domain"

        def kernel(inp_ref, out_ref):
            i = pl.program_id(0)
            j = pl.program_id(1)
            tile = inp_ref[
                pl.dslice(i * t1, t1 + 2 * SIGMA), pl.dslice(j * t2, t2 + 2 * SIGMA)
            ]
            out_ref[...] = compute(tile)

        return pl.pallas_call(
            kernel,
            grid=(s1 // t1, s2 // t2),
            in_specs=[pl.BlockSpec(a_padded.shape, lambda i, j: (0, 0))],
            out_specs=pl.BlockSpec((t1, t2), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((s1, s2), a_padded.dtype),
            interpret=True,
        )(a_padded)

    return step


def make_step_3d(compute):
    """Build a 3-D stencil step: padded (S+2,)*3 -> interior (S1, S2, S3)."""

    def step(a_padded, t1=None, t2=None, t3=None):
        s1 = a_padded.shape[0] - 2 * SIGMA
        s2 = a_padded.shape[1] - 2 * SIGMA
        s3 = a_padded.shape[2] - 2 * SIGMA
        t1 = t1 or choose_tile(s1, 32)
        t2 = t2 or choose_tile(s2, 32)
        t3 = t3 or choose_tile(s3, 32)
        assert s1 % t1 == 0 and s2 % t2 == 0 and s3 % t3 == 0

        def kernel(inp_ref, out_ref):
            i = pl.program_id(0)
            j = pl.program_id(1)
            k = pl.program_id(2)
            tile = inp_ref[
                pl.dslice(i * t1, t1 + 2 * SIGMA),
                pl.dslice(j * t2, t2 + 2 * SIGMA),
                pl.dslice(k * t3, t3 + 2 * SIGMA),
            ]
            out_ref[...] = compute(tile)

        return pl.pallas_call(
            kernel,
            grid=(s1 // t1, s2 // t2, s3 // t3),
            in_specs=[pl.BlockSpec(a_padded.shape, lambda i, j, k: (0, 0, 0))],
            out_specs=pl.BlockSpec((t1, t2, t3), lambda i, j, k: (i, j, k)),
            out_shape=jax.ShapeDtypeStruct((s1, s2, s3), a_padded.dtype),
            interpret=True,
        )(a_padded)

    return step


def pad(a):
    """Zero halo ring of width SIGMA around a 2-D or 3-D array."""
    return jnp.pad(a, SIGMA)


def vmem_footprint_bytes(tile_shape, dtype_bytes: int = 4) -> int:
    """Staged bytes per grid step: input tile + halo, plus the output block."""
    halo = 1
    inp = 1
    out = 1
    for t in tile_shape:
        inp *= t + 2 * halo
        out *= t
    return dtype_bytes * (inp + out)
