"""Time-tiled (fused multi-step) Pallas kernels — the paper's core software
idea expressed at Layer 1.

The codesign model's whole premise is that time tiling amortizes off-chip
traffic: a tile stages once and advances `t_T` time steps before writing
back. This module realizes that at kernel level with the *ghost-zone /
redundant-computation* scheme (Meng & Skadron [21], cited by the paper):
one grid step loads a block plus a `t_steps`-deep halo into VMEM, applies
the stencil `t_steps` times — the valid region shrinking by σ per step, the
halo cells being recomputed redundantly — and stores the final block. HBM
traffic per point-update drops by ~`t_steps`× at the cost of
O(t_steps·σ/t) redundant compute per block edge.

With the zero-Dirichlet ring held at zero for all time, a fused sweep is
bit-for-bit the same computation as `t_steps` separate steps (asserted in
`python/tests/test_fused.py`).

VMEM footprint per grid step: `4 B · [(t1+2h)(t2+2h) + t1·t2]` with
`h = t_steps` — e.g. 64×64, h = 4: 21.6 kB, still ~0.1% of VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import gradient2d, heat2d, jacobi2d, laplacian2d
from .common import choose_tile

SIGMA = 1

# The single-step tile computations, reused from the plain kernels.
_COMPUTE_2D = {
    "jacobi2d": jacobi2d._compute,
    "heat2d": heat2d._compute,
    "laplacian2d": laplacian2d._compute,
    "gradient2d": gradient2d._compute,
}


def make_fused_step_2d(name: str, t_steps: int):
    """Build a fused 2-D stencil step advancing `t_steps` time steps per
    VMEM residency. Input is padded by `h = t_steps·σ`; returns the interior.
    """
    compute = _COMPUTE_2D[name]
    h = t_steps * SIGMA

    def step(a_padded, t1=None, t2=None):
        s1 = a_padded.shape[0] - 2 * h
        s2 = a_padded.shape[1] - 2 * h
        t1 = t1 or choose_tile(s1)
        t2 = t2 or choose_tile(s2)
        assert s1 % t1 == 0 and s2 % t2 == 0, "tiles must divide the domain"

        def kernel(inp_ref, out_ref):
            i = pl.program_id(0)
            j = pl.program_id(1)
            # Stage block + t_steps-deep halo.
            tile = inp_ref[
                pl.dslice(i * t1, t1 + 2 * h), pl.dslice(j * t2, t2 + 2 * h)
            ]
            # Advance time in VMEM; the valid region shrinks by σ per step.
            # Cells of the global Dirichlet ring must stay zero at every
            # intermediate time, so boundary tiles re-zero them (otherwise a
            # ring cell inside the shrinking halo would evolve and pollute
            # its interior neighbours at the next step).
            for s in range(1, t_steps + 1):
                tile = compute(tile)
                rows = (
                    jax.lax.broadcasted_iota(jnp.int32, tile.shape, 0)
                    + i * t1
                    + s * SIGMA
                )
                cols = (
                    jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1)
                    + j * t2
                    + s * SIGMA
                )
                inside = (
                    (rows >= h) & (rows < h + s1) & (cols >= h) & (cols < h + s2)
                )
                tile = jnp.where(inside, tile, jnp.float32(0.0))
            out_ref[...] = tile

        return pl.pallas_call(
            kernel,
            grid=(s1 // t1, s2 // t2),
            in_specs=[pl.BlockSpec(a_padded.shape, lambda i, j: (0, 0))],
            out_specs=pl.BlockSpec((t1, t2), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((s1, s2), a_padded.dtype),
            interpret=True,
        )(a_padded)

    return step


def fused_sweep_fn(name: str, padded_shape, total_steps: int, t_steps: int, tiles=None):
    """A jit-able `padded -> (padded,)` advancing `total_steps` via fused
    blocks of `t_steps` (`total_steps` must be a multiple of `t_steps`).
    The carry is padded by `h = t_steps·σ` zeros (the Dirichlet ring is zero
    at every time, so the wider ring stays consistent)."""
    assert total_steps % t_steps == 0, "total_steps must be a multiple of t_steps"
    h = t_steps * SIGMA
    step = make_fused_step_2d(name, t_steps)
    tiles = tiles or ()

    def body(_, a):
        interior = step(a, *tiles)
        return a.at[h:-h, h:-h].set(interior)

    def fn(a):
        return (jax.lax.fori_loop(0, total_steps // t_steps, body, a),)

    _ = padded_shape
    return fn


def vmem_footprint_bytes(t1: int, t2: int, t_steps: int, dtype_bytes: int = 4) -> int:
    """Staged bytes per fused grid step (input block + halo, output block)."""
    h = t_steps * SIGMA
    return dtype_bytes * ((t1 + 2 * h) * (t2 + 2 * h) + t1 * t2)


def redundancy_factor(t1: int, t2: int, t_steps: int) -> float:
    """Redundant-compute overhead of the ghost-zone scheme: total stencil
    applications (shrinking trapezoid) divided by the useful t1·t2·t_steps."""
    total = 0.0
    for s in range(t_steps):
        w1 = t1 + 2 * SIGMA * (t_steps - 1 - s)
        w2 = t2 + 2 * SIGMA * (t_steps - 1 - s)
        total += w1 * w2
    return total / (t1 * t2 * t_steps)
