"""Laplacian-2D (5-point operator) Pallas kernel: o = N+S+E+W − 4·C."""

from . import common


def _compute(tile):
    c = tile[1:-1, 1:-1]
    n = tile[:-2, 1:-1]
    s = tile[2:, 1:-1]
    w = tile[1:-1, :-2]
    e = tile[1:-1, 2:]
    return n + s + w + e - 4.0 * c


step = common.make_step_2d(_compute)
