"""Laplacian-3D (7-point operator) Pallas kernel: o = Σ₆ neighbours − 6·C."""

from . import common


def _compute(tile):
    c = tile[1:-1, 1:-1, 1:-1]
    xm = tile[:-2, 1:-1, 1:-1]
    xp = tile[2:, 1:-1, 1:-1]
    ym = tile[1:-1, :-2, 1:-1]
    yp = tile[1:-1, 2:, 1:-1]
    zm = tile[1:-1, 1:-1, :-2]
    zp = tile[1:-1, 1:-1, 2:]
    return xm + xp + ym + yp + zm + zp - 6.0 * c


step = common.make_step_3d(_compute)
