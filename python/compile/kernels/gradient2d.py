"""Gradient-2D (central-difference magnitude) Pallas kernel:
o = sqrt(gx² + gy²), gx = (E−W)/2, gy = (S−N)/2."""

import jax.numpy as jnp

from . import common


def _compute(tile):
    n = tile[:-2, 1:-1]
    s = tile[2:, 1:-1]
    w = tile[1:-1, :-2]
    e = tile[1:-1, 2:]
    gx = 0.5 * (e - w)
    gy = 0.5 * (s - n)
    return jnp.sqrt(gx * gx + gy * gy)


step = common.make_step_2d(_compute)
