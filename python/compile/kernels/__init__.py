"""Layer-1 Pallas stencil kernels (build-time only; never on the request path)."""

from . import common, gradient2d, heat2d, heat3d, jacobi2d, laplacian2d, laplacian3d, ref

STEP_FNS = {
    "jacobi2d": jacobi2d.step,
    "heat2d": heat2d.step,
    "laplacian2d": laplacian2d.step,
    "gradient2d": gradient2d.step,
    "heat3d": heat3d.step,
    "laplacian3d": laplacian3d.step,
}

__all__ = [
    "common",
    "ref",
    "STEP_FNS",
    "jacobi2d",
    "heat2d",
    "laplacian2d",
    "gradient2d",
    "heat3d",
    "laplacian3d",
]
