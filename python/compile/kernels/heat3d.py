"""Heat-3D (explicit 7-point heat step) Pallas kernel:
o = 0.4·C + 0.1·Σ₆ neighbours."""

from . import common


def _compute(tile):
    c = tile[1:-1, 1:-1, 1:-1]
    xm = tile[:-2, 1:-1, 1:-1]
    xp = tile[2:, 1:-1, 1:-1]
    ym = tile[1:-1, :-2, 1:-1]
    yp = tile[1:-1, 2:, 1:-1]
    zm = tile[1:-1, 1:-1, :-2]
    zp = tile[1:-1, 1:-1, 2:]
    return 0.4 * c + 0.1 * (xm + xp + ym + yp + zm + zp)


step = common.make_step_3d(_compute)
