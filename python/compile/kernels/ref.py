"""Pure-jnp oracle for every stencil (the correctness reference).

Semantics shared with the Pallas kernels and the Rust model: arrays carry a
zero Dirichlet halo ring of width 1; a step rewrites the interior only.
Operation counts (flops/point) follow `rust/src/stencil/defs.rs` — they are
the reporting convention for GFLOP/s, identical across all three layers.
"""

import jax.numpy as jnp

SIGMA = 1


def _interior_2d(a):
    c = a[1:-1, 1:-1]
    n = a[:-2, 1:-1]
    s = a[2:, 1:-1]
    w = a[1:-1, :-2]
    e = a[1:-1, 2:]
    return c, n, s, w, e


def _interior_3d(a):
    c = a[1:-1, 1:-1, 1:-1]
    xm = a[:-2, 1:-1, 1:-1]
    xp = a[2:, 1:-1, 1:-1]
    ym = a[1:-1, :-2, 1:-1]
    yp = a[1:-1, 2:, 1:-1]
    zm = a[1:-1, 1:-1, :-2]
    zp = a[1:-1, 1:-1, 2:]
    return c, xm, xp, ym, yp, zm, zp


def jacobi2d(a):
    _, n, s, w, e = _interior_2d(a)
    return 0.25 * (n + s + w + e)


def heat2d(a):
    c, n, s, w, e = _interior_2d(a)
    return 0.5 * c + 0.125 * (n + s + w + e)


def laplacian2d(a):
    c, n, s, w, e = _interior_2d(a)
    return n + s + w + e - 4.0 * c


def gradient2d(a):
    _, n, s, w, e = _interior_2d(a)
    gx = 0.5 * (e - w)
    gy = 0.5 * (s - n)
    return jnp.sqrt(gx * gx + gy * gy)


def heat3d(a):
    c, xm, xp, ym, yp, zm, zp = _interior_3d(a)
    return 0.4 * c + 0.1 * (xm + xp + ym + yp + zm + zp)


def laplacian3d(a):
    c, xm, xp, ym, yp, zm, zp = _interior_3d(a)
    return xm + xp + ym + yp + zm + zp - 6.0 * c


STEPS = {
    "jacobi2d": jacobi2d,
    "heat2d": heat2d,
    "laplacian2d": laplacian2d,
    "gradient2d": gradient2d,
    "heat3d": heat3d,
    "laplacian3d": laplacian3d,
}

# Canonical flops/point — keep in sync with rust/src/stencil/defs.rs.
FLOPS_PER_POINT = {
    "jacobi2d": 4.0,
    "heat2d": 10.0,
    "laplacian2d": 6.0,
    "gradient2d": 14.0,
    "heat3d": 14.0,
    "laplacian3d": 8.0,
}


def step_ref(name, a_padded):
    """One reference step: returns the padded array with interior updated."""
    interior = STEPS[name](a_padded)
    if a_padded.ndim == 2:
        return a_padded.at[1:-1, 1:-1].set(interior)
    return a_padded.at[1:-1, 1:-1, 1:-1].set(interior)


def sweep_ref(name, a_padded, t_steps):
    """T reference steps."""
    for _ in range(t_steps):
        a_padded = step_ref(name, a_padded)
    return a_padded
