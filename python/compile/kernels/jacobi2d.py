"""Jacobi-2D (5-point average) Pallas kernel: o = 0.25·(N+S+E+W)."""

from . import common


def _compute(tile):
    n = tile[:-2, 1:-1]
    s = tile[2:, 1:-1]
    w = tile[1:-1, :-2]
    e = tile[1:-1, 2:]
    return 0.25 * (n + s + w + e)


step = common.make_step_2d(_compute)
