"""Heat-2D (explicit 5-point heat step) Pallas kernel:
o = 0.5·C + 0.125·(N+S+E+W)."""

from . import common


def _compute(tile):
    c = tile[1:-1, 1:-1]
    n = tile[:-2, 1:-1]
    s = tile[2:, 1:-1]
    w = tile[1:-1, :-2]
    e = tile[1:-1, 2:]
    return 0.5 * c + 0.125 * (n + s + w + e)


step = common.make_step_2d(_compute)
