"""AOT export: lower every (stencil, size) artifact variant to HLO **text**
under `artifacts/`, plus a `manifest.json` the Rust runtime indexes.

HLO text — not `.serialize()` — is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids that the crate-side XLA
(xla_extension 0.5.1) rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run as: `cd python && python -m compile.aot --out-dir ../artifacts`
(idempotent; `make artifacts` wires the freshness check).
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels import fused
from .kernels.ref import FLOPS_PER_POINT
from .model import lower_sweep

# Artifact variants: small ones exercise the end-to-end path (quickstart,
# integration tests); the *_citer ones are the per-point cost measurement
# workloads (runtime::citer_measure). Sizes are CPU-interpret tractable.
VARIANTS = [
    # (stencil, interior shape, T)
    ("jacobi2d", (128, 128), 4),
    ("heat2d", (128, 128), 4),
    ("laplacian2d", (128, 128), 4),
    ("gradient2d", (128, 128), 4),
    ("heat3d", (32, 32, 32), 2),
    ("laplacian3d", (32, 32, 32), 2),
    ("jacobi2d", (256, 256), 8),
    ("heat2d", (256, 256), 8),
    ("laplacian2d", (256, 256), 8),
    ("gradient2d", (256, 256), 8),
    ("heat3d", (64, 64, 64), 4),
    ("laplacian3d", (64, 64, 64), 4),
]

# Time-tiled (ghost-zone fused) variants: (stencil, shape, total T, fused
# t_steps). Same total work as the matching plain variant — the L1
# traffic-amortization experiment (EXPERIMENTS.md §Perf).
FUSED_VARIANTS = [
    ("jacobi2d", (256, 256), 8, 4),
    ("heat2d", (256, 256), 8, 4),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def variant_name(stencil: str, shape, t: int) -> str:
    dims = "x".join(str(s) for s in shape)
    return f"{stencil}_{dims}_t{t}"


def export_all(out_dir: pathlib.Path) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    entries = []
    for stencil, shape, t in VARIANTS:
        name = variant_name(stencil, shape, t)
        path = out_dir / f"{name}.hlo.txt"
        lowered = lower_sweep(stencil, shape, t)
        text = to_hlo_text(lowered)
        path.write_text(text)
        points = 1.0
        for s in shape:
            points *= s
        entries.append(
            {
                "name": name,
                "file": path.name,
                "stencil": stencil,
                "shape": list(shape),
                "t_steps": t,
                "pad": 1,
                "points_per_sweep": points * t,
                "flops_per_point": FLOPS_PER_POINT[stencil],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")
    for stencil, shape, total_t, t_steps in FUSED_VARIANTS:
        name = f"{variant_name(stencil, shape, total_t)}_fused{t_steps}"
        path = out_dir / f"{name}.hlo.txt"
        h = t_steps * fused.SIGMA
        padded_shape = tuple(s + 2 * h for s in shape)
        fn = fused.fused_sweep_fn(stencil, padded_shape, total_t, t_steps)
        spec = jax.ShapeDtypeStruct(padded_shape, jnp.float32)
        lowered = jax.jit(fn, donate_argnums=(0,)).lower(spec)
        text = to_hlo_text(lowered)
        path.write_text(text)
        points = 1.0
        for s in shape:
            points *= s
        entries.append(
            {
                "name": name,
                "file": path.name,
                "stencil": stencil,
                "shape": list(shape),
                "t_steps": total_t,
                "pad": h,
                "points_per_sweep": points * total_t,
                "flops_per_point": FLOPS_PER_POINT[stencil],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")
    manifest = {"version": 1, "artifacts": entries}
    # Manifest written last: it is the Makefile's freshness marker.
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'} ({len(entries)} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    export_all(pathlib.Path(args.out_dir))


if __name__ == "__main__":
    main()
