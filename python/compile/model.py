"""Layer 2 — the JAX compute graph: a T-step time sweep over a Layer-1
Pallas stencil kernel.

This is the graph that gets AOT-lowered to HLO text (see `aot.py`) and then
executed from Rust via PJRT. The sweep is a `lax.fori_loop` whose body runs
one Pallas step over the spatially-tiled domain and writes the interior back
into the padded array — the time dimension stays sequential (the hexagonal
time-tiling of the *model* is a schedule for the hypothetical accelerator;
the artifact's job is numerics and per-point cost measurement on the CPU
substrate, DESIGN.md §2).

XLA-level optimization notes (the L2 perf checklist of the brief):
* the loop carry is a single padded array — no growing live set, no
  rematerialization hazard;
* `donate_argnums=(0,)` lets XLA reuse the input buffer across the whole
  sweep (verified to remove the copy in the lowered HLO);
* the interior write-back fuses with the pallas-emitted loop nest under
  interpret mode — the lowered module contains a single while loop.
"""

import jax
import jax.numpy as jnp

from .kernels import STEP_FNS, common


def sweep_fn(name: str, padded_shape, t_steps: int, tiles=None):
    """Return a jit-able `padded -> (padded,)` running `t_steps` steps."""
    step = STEP_FNS[name]
    ndim = len(padded_shape)
    tiles = tiles or ()

    def body(_, a):
        interior = step(a, *tiles)
        if ndim == 2:
            return a.at[1:-1, 1:-1].set(interior)
        return a.at[1:-1, 1:-1, 1:-1].set(interior)

    def fn(a):
        return (jax.lax.fori_loop(0, t_steps, body, a),)

    return fn


def lower_sweep(name: str, interior_shape, t_steps: int):
    """Lower a sweep for a given interior shape; returns the jax Lowered."""
    padded_shape = tuple(s + 2 * common.SIGMA for s in interior_shape)
    fn = sweep_fn(name, padded_shape, t_steps)
    spec = jax.ShapeDtypeStruct(padded_shape, jnp.float32)
    return jax.jit(fn, donate_argnums=(0,)).lower(spec)
