"""Build-time compile path: Pallas kernels (L1), JAX sweep graphs (L2) and
the AOT HLO-text exporter. Python never runs on the Rust request path."""
