//! Offline vendored stand-in for the `anyhow` crate.
//!
//! This image builds with no crates.io access, so the small slice of the
//! `anyhow` API the repo uses is implemented here: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the [`Context`]
//! extension trait for `Result<_, E: std::error::Error>` and `Option<_>`.
//!
//! Semantics match the real crate where it matters to callers:
//! `{e}` displays the outermost message, `{e:#}` displays the whole cause
//! chain joined by `": "` (the format the CLI and the manifest tests rely
//! on), and `?` converts any `std::error::Error + Send + Sync + 'static`.

use std::fmt;

/// A dynamic error: an outermost message plus its cause chain, outermost
/// first. Like the real `anyhow::Error`, this type deliberately does *not*
/// implement `std::error::Error` (that keeps the blanket `From` below
/// coherent).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a plain message (what [`anyhow!`] expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — plain `Result` defaulting the error to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to fallible values, converting the error to [`Error`].
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, context: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, context: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, context: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(context()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Result::<(), _>::Err(io_err())
            .with_context(|| format!("reading {:?}", "x"))
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading \"x\"");
        assert_eq!(format!("{e:#}"), "reading \"x\": missing thing");
    }

    #[test]
    fn option_context_and_macros() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            let v = Some(7u32).context("always present")?;
            if v == 0 {
                bail!("impossible");
            }
            Ok(v)
        }
        assert_eq!(inner(true).unwrap(), 7);
        let e = inner(false).unwrap_err();
        assert_eq!(format!("{e:#}"), "flag was false");
        let m = anyhow!("x = {}", 3);
        assert_eq!(format!("{m}"), "x = 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{:#}", f().unwrap_err()), "missing thing");
    }
}
