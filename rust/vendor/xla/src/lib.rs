//! Offline API stub of the `xla` crate (PJRT C API bindings).
//!
//! The native XLA/PJRT shared library is not vendored in this image, so this
//! stub provides the exact type-and-method surface `runtime::engine` compiles
//! against while reporting the runtime as unavailable from the single entry
//! point ([`PjRtClient::cpu`]). Everything model-based — area model, time
//! model, optimizer, DSE coordinator, reports — is independent of this crate;
//! the runtime integration tests skip when no client can be constructed, just
//! as they do in a checkout without `make artifacts`.

use std::fmt;

/// Stub error carrying a human-readable explanation.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the XLA/PJRT native runtime is not vendored in this offline build \
         (model-based paths are unaffected)"
    ))
}

/// PJRT client handle. The only constructor fails in this stub, so every
/// other method is statically reachable but dynamically dead.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module handle.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper around a parsed HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal handle.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not vendored"));
    }
}
