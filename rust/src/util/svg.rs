//! Minimal SVG scatter/line plot writer for the report generators. Each paper
//! figure is emitted both as CSV (data) and SVG (visual) under `reports/`.

use std::fmt::Write as _;
use std::path::Path;

/// Point marker style.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Marker {
    Circle,
    Square,
    Cross,
}

/// A plotted series (scatter, optionally connected by a polyline).
#[derive(Clone, Debug)]
pub struct SvgSeries {
    pub name: String,
    pub color: String,
    pub marker: Marker,
    pub connect: bool,
    pub points: Vec<(f64, f64)>,
}

/// A simple 2-D chart.
pub struct SvgPlot {
    pub title: String,
    pub xlabel: String,
    pub ylabel: String,
    pub width: f64,
    pub height: f64,
    pub series: Vec<SvgSeries>,
    /// Optional log-scale x axis (used by Fig 2's wide size sweeps).
    pub logx: bool,
}

const MARGIN_L: f64 = 64.0;
const MARGIN_R: f64 = 16.0;
const MARGIN_T: f64 = 32.0;
const MARGIN_B: f64 = 48.0;

impl SvgPlot {
    pub fn new(title: &str, xlabel: &str, ylabel: &str) -> SvgPlot {
        SvgPlot {
            title: title.to_string(),
            xlabel: xlabel.to_string(),
            ylabel: ylabel.to_string(),
            width: 640.0,
            height: 420.0,
            series: Vec::new(),
            logx: false,
        }
    }

    pub fn series(
        &mut self,
        name: &str,
        color: &str,
        marker: Marker,
        connect: bool,
        points: Vec<(f64, f64)>,
    ) -> &mut Self {
        self.series.push(SvgSeries {
            name: name.to_string(),
            color: color.to_string(),
            marker,
            connect,
            points,
        });
        self
    }

    fn tx(&self, x: f64, xmin: f64, xmax: f64) -> f64 {
        let (x, xmin, xmax) = if self.logx {
            (x.ln(), xmin.ln(), xmax.ln())
        } else {
            (x, xmin, xmax)
        };
        MARGIN_L + (x - xmin) / (xmax - xmin) * (self.width - MARGIN_L - MARGIN_R)
    }

    fn ty(&self, y: f64, ymin: f64, ymax: f64) -> f64 {
        self.height - MARGIN_B - (y - ymin) / (ymax - ymin) * (self.height - MARGIN_T - MARGIN_B)
    }

    /// Render the SVG document.
    pub fn render(&self) -> String {
        let all: Vec<(f64, f64)> =
            self.series.iter().flat_map(|s| s.points.iter().copied()).collect();
        let (mut xmin, mut xmax, mut ymin, mut ymax) =
            (f64::INFINITY, f64::NEG_INFINITY, f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &all {
            xmin = xmin.min(x);
            xmax = xmax.max(x);
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
        if all.is_empty() {
            xmin = 0.0;
            xmax = 1.0;
            ymin = 0.0;
            ymax = 1.0;
        }
        if xmin == xmax {
            xmax = xmin + 1.0;
        }
        if ymin == ymax {
            ymax = ymin + 1.0;
        }
        // pad y a little
        let ypad = (ymax - ymin) * 0.05;
        ymin -= ypad;
        ymax += ypad;

        let mut s = String::new();
        let _ = write!(
            s,
            r#"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" viewBox="0 0 {w} {h}">"#,
            w = self.width,
            h = self.height
        );
        s.push_str("<rect width=\"100%\" height=\"100%\" fill=\"white\"/>");
        // Title + axis labels.
        let _ = write!(
            s,
            r#"<text x="{}" y="20" font-family="sans-serif" font-size="14" text-anchor="middle">{}</text>"#,
            self.width / 2.0,
            esc(&self.title)
        );
        let _ = write!(
            s,
            r#"<text x="{}" y="{}" font-family="sans-serif" font-size="12" text-anchor="middle">{}</text>"#,
            self.width / 2.0,
            self.height - 10.0,
            esc(&self.xlabel)
        );
        let _ = write!(
            s,
            r#"<text x="14" y="{}" font-family="sans-serif" font-size="12" text-anchor="middle" transform="rotate(-90 14 {})">{}</text>"#,
            self.height / 2.0,
            self.height / 2.0,
            esc(&self.ylabel)
        );
        // Axes box + ticks.
        let _ = write!(
            s,
            r#"<rect x="{}" y="{}" width="{}" height="{}" fill="none" stroke="black" stroke-width="1"/>"#,
            MARGIN_L,
            MARGIN_T,
            self.width - MARGIN_L - MARGIN_R,
            self.height - MARGIN_T - MARGIN_B
        );
        for i in 0..=4 {
            let fx = xmin + (xmax - xmin) * i as f64 / 4.0;
            let fy = ymin + (ymax - ymin) * i as f64 / 4.0;
            let px = MARGIN_L + (self.width - MARGIN_L - MARGIN_R) * i as f64 / 4.0;
            let py = self.ty(fy, ymin, ymax);
            let _ = write!(
                s,
                r#"<text x="{px}" y="{}" font-family="sans-serif" font-size="10" text-anchor="middle">{}</text>"#,
                self.height - MARGIN_B + 14.0,
                fmt_tick(if self.logx { (xmin.ln() + (xmax.ln() - xmin.ln()) * i as f64 / 4.0).exp() } else { fx })
            );
            let _ = write!(
                s,
                r#"<text x="{}" y="{}" font-family="sans-serif" font-size="10" text-anchor="end">{}</text>"#,
                MARGIN_L - 6.0,
                py + 3.0,
                fmt_tick(fy)
            );
        }
        // Series.
        for ser in &self.series {
            if ser.connect && ser.points.len() > 1 {
                let mut d = String::new();
                let mut pts = ser.points.clone();
                pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                for (i, &(x, y)) in pts.iter().enumerate() {
                    let _ = write!(
                        d,
                        "{}{:.2},{:.2} ",
                        if i == 0 { "M" } else { "L" },
                        self.tx(x, xmin, xmax),
                        self.ty(y, ymin, ymax)
                    );
                }
                let _ = write!(
                    s,
                    r#"<path d="{}" fill="none" stroke="{}" stroke-width="1.5"/>"#,
                    d.trim_end(),
                    ser.color
                );
            }
            for &(x, y) in &ser.points {
                let (px, py) = (self.tx(x, xmin, xmax), self.ty(y, ymin, ymax));
                match ser.marker {
                    Marker::Circle => {
                        let _ = write!(
                            s,
                            r#"<circle cx="{px:.2}" cy="{py:.2}" r="2.5" fill="{}" fill-opacity="0.7"/>"#,
                            ser.color
                        );
                    }
                    Marker::Square => {
                        let _ = write!(
                            s,
                            r#"<rect x="{:.2}" y="{:.2}" width="5" height="5" fill="{}"/>"#,
                            px - 2.5,
                            py - 2.5,
                            ser.color
                        );
                    }
                    Marker::Cross => {
                        let _ = write!(
                            s,
                            r#"<path d="M{:.2},{:.2}L{:.2},{:.2}M{:.2},{:.2}L{:.2},{:.2}" stroke="{}" stroke-width="1.5"/>"#,
                            px - 3.0, py - 3.0, px + 3.0, py + 3.0,
                            px - 3.0, py + 3.0, px + 3.0, py - 3.0,
                            ser.color
                        );
                    }
                }
            }
        }
        // Legend.
        let mut ly = MARGIN_T + 12.0;
        for ser in &self.series {
            let _ = write!(
                s,
                r#"<circle cx="{}" cy="{}" r="3" fill="{}"/><text x="{}" y="{}" font-family="sans-serif" font-size="11">{}</text>"#,
                MARGIN_L + 10.0,
                ly - 3.0,
                ser.color,
                MARGIN_L + 18.0,
                ly,
                esc(&ser.name)
            );
            ly += 14.0;
        }
        s.push_str("</svg>");
        s
    }

    /// Write the rendered SVG to a file, creating parent directories.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.render())
    }
}

fn fmt_tick(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1000.0 || v.abs() < 0.01 {
        format!("{v:.1e}")
    } else if v.fract().abs() < 1e-9 {
        format!("{}", v as i64)
    } else {
        format!("{v:.2}")
    }
}

fn esc(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_valid_looking_svg() {
        let mut p = SvgPlot::new("T", "x", "y");
        p.series("a", "#1f77b4", Marker::Circle, false, vec![(0.0, 1.0), (2.0, 3.0)]);
        p.series("fit", "#d62728", Marker::Square, true, vec![(0.0, 1.0), (2.0, 3.0)]);
        let s = p.render();
        assert!(s.starts_with("<svg"));
        assert!(s.ends_with("</svg>"));
        assert!(s.contains("<circle"));
        assert!(s.contains("<path"));
        assert!(s.matches("fill-opacity").count() >= 2);
    }

    #[test]
    fn empty_plot_renders() {
        let p = SvgPlot::new("empty", "x", "y");
        let s = p.render();
        assert!(s.contains("</svg>"));
    }

    #[test]
    fn title_escaped() {
        let p = SvgPlot::new("a < b & c", "x", "y");
        assert!(p.render().contains("a &lt; b &amp; c"));
    }
}
