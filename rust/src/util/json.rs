//! Minimal JSON value model with a writer and a strict parser.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`), memoized DSE result caches, and report metadata.
//! Replaces `serde_json`, which is unavailable offline.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as `f64` (the manifest only carries sizes
/// and names; integer fidelity up to 2^53 is sufficient everywhere we use it).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed, nothing else).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        if self.i + 4 > self.b.len() {
                            return Err(self.err("truncated \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.i += 4;
                        // Surrogate pairs are not needed for our manifests.
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(first) => {
                    // Re-decode the UTF-8 sequence starting at i-1.
                    let start = self.i - 1;
                    let len = match first {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid utf-8")),
                    };
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let v = Json::obj(vec![
            ("name", Json::str("heat2d")),
            ("sizes", Json::Arr(vec![Json::num(256.0), Json::num(512.0)])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let s = v.to_string_compact();
        let back = parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2.5, {"b": "x\ny"}], "c": -3e2}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_f64().unwrap(), -300.0);
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.5);
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(3.0).to_string_compact(), "3");
        assert_eq!(Json::num(3.5).to_string_compact(), "3.5");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""tab\t quote\" back\\ uA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "tab\t quote\" back\\ uA");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::str("µарch 日本");
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap().as_str().unwrap(), "µарch 日本");
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::obj(vec![("k", Json::Arr(vec![Json::num(1.0)]))]);
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
