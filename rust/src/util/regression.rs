//! Least-squares linear regression.
//!
//! This is the paper's calibration workhorse: §III-B fits independent linear
//! area models `area = β·size + α` to Cacti-estimated bank areas for each of
//! the four memory types (register file, shared memory, L1, L2), and a final
//! measurement-based linear model for the per-SM core area.

use crate::util::stats;

/// Result of a 1-D least-squares fit `y ≈ slope·x + intercept`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinearFit {
    pub slope: f64,
    pub intercept: f64,
    /// Coefficient of determination of the fit.
    pub r2: f64,
}

impl LinearFit {
    /// Evaluate the fitted line.
    pub fn eval(&self, x: f64) -> f64 {
        self.slope * x + self.intercept
    }

    /// Maximum relative error of the fit over the given points (in %).
    pub fn max_rel_err_pct(&self, xs: &[f64], ys: &[f64]) -> f64 {
        xs.iter()
            .zip(ys)
            .filter(|(_, &y)| y != 0.0)
            .map(|(&x, &y)| ((self.eval(x) - y) / y).abs() * 100.0)
            .fold(0.0, f64::max)
    }
}

/// Ordinary least squares over `(x, y)` pairs. Panics on fewer than 2 points
/// or zero x-variance.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> LinearFit {
    assert_eq!(xs.len(), ys.len(), "linear_fit: length mismatch");
    assert!(xs.len() >= 2, "linear_fit: need at least 2 points");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    assert!(sxx > 0.0, "linear_fit: zero variance in x");
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let pred: Vec<f64> = xs.iter().map(|&x| slope * x + intercept).collect();
    let r2 = stats::r_squared(&pred, ys);
    LinearFit { slope, intercept, r2 }
}

/// Least squares through the origin: `y ≈ slope·x`.
pub fn proportional_fit(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let num: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let den: f64 = xs.iter().map(|x| x * x).sum();
    assert!(den > 0.0, "proportional_fit: degenerate x");
    num / den
}

/// Multivariate OLS `y ≈ X·b` via normal equations with Gaussian elimination
/// (small, well-conditioned systems only — the area-model calibration has
/// ≤ 6 regressors). `xs[i]` is the i-th row of regressors.
pub fn multilinear_fit(xs: &[Vec<f64>], ys: &[f64]) -> Vec<f64> {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    let k = xs[0].len();
    assert!(xs.iter().all(|r| r.len() == k), "ragged design matrix");
    assert!(xs.len() >= k, "underdetermined system");
    // Normal equations A = XᵀX (k×k), b = Xᵀy.
    let mut a = vec![vec![0.0f64; k]; k];
    let mut b = vec![0.0f64; k];
    for (row, &y) in xs.iter().zip(ys) {
        for i in 0..k {
            b[i] += row[i] * y;
            for j in 0..k {
                a[i][j] += row[i] * row[j];
            }
        }
    }
    solve_dense(&mut a, &mut b);
    b
}

/// In-place Gaussian elimination with partial pivoting; solution left in `b`.
fn solve_dense(a: &mut [Vec<f64>], b: &mut [f64]) {
    let n = b.len();
    for col in 0..n {
        // Pivot.
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        assert!(a[piv][col].abs() > 1e-12, "singular normal matrix");
        a.swap(col, piv);
        b.swap(col, piv);
        // Eliminate below.
        for row in col + 1..n {
            let f = a[row][col] / a[col][col];
            for j in col..n {
                a[row][j] -= f * a[col][j];
            }
            b[row] -= f * b[col];
        }
    }
    // Back-substitute.
    for col in (0..n).rev() {
        let mut acc = b[col];
        for j in col + 1..n {
            acc -= a[col][j] * b[j];
        }
        b[col] = acc / a[col][col];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovered() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.5 * x + 1.0).collect();
        let fit = linear_fit(&xs, &ys);
        assert!((fit.slope - 2.5).abs() < 1e-12);
        assert!((fit.intercept - 1.0).abs() < 1e-12);
        assert!((fit.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noisy_line_close() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        // deterministic "noise"
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 3.0 * x - 5.0 + ((x * 12.9898).sin() * 0.5))
            .collect();
        let fit = linear_fit(&xs, &ys);
        assert!((fit.slope - 3.0).abs() < 0.01);
        assert!((fit.intercept + 5.0).abs() < 0.5);
        assert!(fit.r2 > 0.999);
    }

    #[test]
    fn eval_and_max_err() {
        let fit = LinearFit { slope: 2.0, intercept: 0.0, r2: 1.0 };
        assert_eq!(fit.eval(3.0), 6.0);
        let err = fit.max_rel_err_pct(&[1.0, 2.0], &[2.0, 5.0]);
        assert!((err - 20.0).abs() < 1e-12); // 4 vs 5 -> 20%
    }

    #[test]
    fn proportional_recovers_slope() {
        let xs = [1.0, 2.0, 4.0];
        let ys = [3.0, 6.0, 12.0];
        assert!((proportional_fit(&xs, &ys) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn multilinear_exact() {
        // y = 2*x0 + 3*x1 + 4
        let xs = vec![
            vec![1.0, 0.0, 1.0],
            vec![0.0, 1.0, 1.0],
            vec![1.0, 1.0, 1.0],
            vec![2.0, 3.0, 1.0],
        ];
        let ys = vec![6.0, 7.0, 9.0, 17.0];
        let b = multilinear_fit(&xs, &ys);
        assert!((b[0] - 2.0).abs() < 1e-9);
        assert!((b[1] - 3.0).abs() < 1e-9);
        assert!((b[2] - 4.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn degenerate_x_panics() {
        linear_fit(&[1.0, 1.0], &[2.0, 3.0]);
    }
}
