//! FNV-1a 64-bit — the repo's one stable hash.
//!
//! Everything that needs a deterministic, platform-independent 64-bit
//! digest routes through here: platform fingerprints
//! ([`PlatformSpec::fingerprint`](crate::platform::PlatformSpec::fingerprint)),
//! artifact shard checksums and partition digests
//! ([`artifact`](crate::artifact)). FNV-1a is tiny, has no seed state
//! (unlike `RandomState`-backed `DefaultHasher`, whose output varies per
//! process), and its byte-at-a-time structure makes the hashed byte stream
//! easy to keep stable across refactors — which is the actual contract:
//! **changing the byte stream of an existing caller invalidates every
//! persisted fingerprint and artifact in the wild.**

/// The FNV-1a 64-bit offset basis.
pub const OFFSET_BASIS: u64 = 0xcbf2_9ce4_8422_2325;

/// The FNV-1a 64-bit prime.
pub const PRIME: u64 = 0x0000_0100_0000_01b3;

/// Streaming FNV-1a 64-bit hasher.
#[derive(Clone, Copy, Debug)]
pub struct Fnv64 {
    state: u64,
}

impl Default for Fnv64 {
    fn default() -> Fnv64 {
        Fnv64::new()
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64 { state: OFFSET_BASIS }
    }

    /// Absorb raw bytes.
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(PRIME);
        }
    }

    /// Absorb one 64-bit word as its little-endian bytes (the word-stream
    /// convention platform fingerprints use).
    pub fn write_u64(&mut self, word: u64) {
        self.write(&word.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// One-shot digest of a byte slice.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_published_fnv1a_vectors() {
        // Reference vectors from the FNV specification (Noll's test suite).
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn streaming_equals_one_shot() {
        let mut h = Fnv64::new();
        h.write(b"foo");
        h.write(b"bar");
        assert_eq!(h.finish(), fnv64(b"foobar"));
    }

    #[test]
    fn write_u64_is_le_bytes() {
        let mut a = Fnv64::new();
        a.write_u64(0x0102_0304_0506_0708);
        let mut b = Fnv64::new();
        b.write(&[0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01]);
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn distinct_streams_distinct_digests() {
        assert_ne!(fnv64(b"maxwell"), fnv64(b"maxwell+"));
        assert_ne!(fnv64(&[0, 1]), fnv64(&[1, 0]));
    }
}
