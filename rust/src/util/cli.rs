//! Tiny command-line parser for the `codesign` binary (offline stand-in for
//! `clap`). Supports subcommands, `--flag`, `--opt value` / `--opt=value`,
//! and positional arguments, with generated `--help` text.

use std::collections::BTreeMap;

/// Declarative option spec.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub flags: BTreeMap<String, bool>,
    pub opts: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or(&false)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_f64(&self, name: &str) -> Option<f64> {
        self.opt(name).and_then(|s| s.parse().ok())
    }

    pub fn opt_usize(&self, name: &str) -> Option<usize> {
        self.opt(name).and_then(|s| s.parse().ok())
    }
}

/// One subcommand definition.
#[derive(Clone, Debug)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

/// A CLI with subcommands.
pub struct Cli {
    pub bin: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

/// Parse outcome.
#[derive(Debug)]
pub enum Parsed {
    /// `(command name, parsed args)`
    Run(String, Args),
    /// Help was requested (text already composed).
    Help(String),
    /// Parse error (message suitable for stderr).
    Error(String),
}

impl Cli {
    pub fn parse(&self, argv: &[String]) -> Parsed {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            return Parsed::Help(self.help());
        }
        let cmd_name = &argv[0];
        let Some(cmd) = self.commands.iter().find(|c| c.name == cmd_name.as_str()) else {
            return Parsed::Error(format!(
                "unknown command '{cmd_name}'; run '{} --help'",
                self.bin
            ));
        };
        let mut args = Args::default();
        // Seed defaults.
        for o in &cmd.opts {
            if let Some(d) = o.default {
                args.opts.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Parsed::Help(self.help_command(cmd));
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let Some(spec) = cmd.opts.iter().find(|o| o.name == name) else {
                    return Parsed::Error(format!("unknown option '--{name}' for '{cmd_name}'"));
                };
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            match argv.get(i) {
                                Some(v) => v.clone(),
                                None => {
                                    return Parsed::Error(format!("option '--{name}' needs a value"))
                                }
                            }
                        }
                    };
                    args.opts.insert(name.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        return Parsed::Error(format!("flag '--{name}' does not take a value"));
                    }
                    args.flags.insert(name.to_string(), true);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Parsed::Run(cmd.name.to_string(), args)
    }

    /// Top-level help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n", self.bin, self.about, self.bin);
        for c in &self.commands {
            s.push_str(&format!("  {:<14} {}\n", c.name, c.about));
        }
        s.push_str(&format!("\nRun '{} <command> --help' for command options.\n", self.bin));
        s
    }

    fn help_command(&self, cmd: &Command) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.bin, cmd.name, cmd.about);
        for o in &cmd.opts {
            let arg = if o.takes_value {
                format!("--{} <value>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  {:<26} {}{}\n", arg, o.help, def));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            bin: "codesign",
            about: "test cli",
            commands: vec![Command {
                name: "explore",
                about: "run DSE",
                opts: vec![
                    OptSpec { name: "area", takes_value: true, default: Some("450"), help: "area budget" },
                    OptSpec { name: "verbose", takes_value: false, default: None, help: "chatty" },
                ],
            }],
        }
    }

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_defaults_and_overrides() {
        match cli().parse(&argv(&["explore"])) {
            Parsed::Run(name, a) => {
                assert_eq!(name, "explore");
                assert_eq!(a.opt("area"), Some("450"));
                assert!(!a.flag("verbose"));
            }
            other => panic!("{other:?}"),
        }
        match cli().parse(&argv(&["explore", "--area", "600", "--verbose", "pos1"])) {
            Parsed::Run(_, a) => {
                assert_eq!(a.opt_f64("area"), Some(600.0));
                assert!(a.flag("verbose"));
                assert_eq!(a.positional, vec!["pos1"]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn equals_syntax() {
        match cli().parse(&argv(&["explore", "--area=512"])) {
            Parsed::Run(_, a) => assert_eq!(a.opt_usize("area"), Some(512)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_command_and_option() {
        assert!(matches!(cli().parse(&argv(&["bogus"])), Parsed::Error(_)));
        assert!(matches!(cli().parse(&argv(&["explore", "--nope"])), Parsed::Error(_)));
        assert!(matches!(cli().parse(&argv(&["explore", "--area"])), Parsed::Error(_)));
    }

    #[test]
    fn help_paths() {
        assert!(matches!(cli().parse(&argv(&[])), Parsed::Help(_)));
        assert!(matches!(cli().parse(&argv(&["--help"])), Parsed::Help(_)));
        match cli().parse(&argv(&["explore", "--help"])) {
            Parsed::Help(h) => assert!(h.contains("--area")),
            other => panic!("{other:?}"),
        }
    }
}
