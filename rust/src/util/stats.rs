//! Descriptive statistics used by the bench harness, calibration residual
//! reporting and the model-validation experiments.

/// Arithmetic mean. Returns `NaN` for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance. Returns `NaN` for an empty slice.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Geometric mean of strictly positive samples.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// p-th percentile (0..=100) with linear interpolation. Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Minimum of a non-empty slice; `NaN` otherwise.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NAN, f64::min)
}

/// Maximum of a non-empty slice; `NaN` otherwise.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NAN, f64::max)
}

/// Mean absolute percentage error of predictions vs. reference values.
/// Skips reference entries equal to zero.
pub fn mape(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    let mut acc = 0.0;
    let mut n = 0usize;
    for (&p, &a) in pred.iter().zip(actual) {
        if a != 0.0 {
            acc += ((p - a) / a).abs();
            n += 1;
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        100.0 * acc / n as f64
    }
}

/// Coefficient of determination R² of predictions vs. observations.
pub fn r_squared(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    let m = mean(actual);
    let ss_res: f64 = pred
        .iter()
        .zip(actual)
        .map(|(&p, &a)| (a - p) * (a - p))
        .sum();
    let ss_tot: f64 = actual.iter().map(|&a| (a - m) * (a - m)).sum();
    if ss_tot == 0.0 {
        if ss_res == 0.0 {
            1.0
        } else {
            f64::NEG_INFINITY
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!(mean(&[]).is_nan());
    }

    #[test]
    fn variance_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [9.0, 1.0, 5.0];
        assert_eq!(median(&xs), 5.0);
    }

    #[test]
    fn minmax() {
        let xs = [3.0, -1.0, 2.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 3.0);
    }

    #[test]
    fn mape_basic() {
        let p = [110.0, 90.0];
        let a = [100.0, 100.0];
        assert!((mape(&p, &a) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn r_squared_perfect_fit() {
        let a = [1.0, 2.0, 3.0];
        assert!((r_squared(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn r_squared_mean_predictor_is_zero() {
        let a = [1.0, 2.0, 3.0];
        let p = [2.0, 2.0, 2.0];
        assert!(r_squared(&p, &a).abs() < 1e-12);
    }
}
