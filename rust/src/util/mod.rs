//! Dependency-free utility substrate.
//!
//! This image builds fully offline with only the `xla` crate's dependency
//! closure vendored, so the usual ecosystem crates (`rand`, `serde`, `rayon`,
//! `clap`, `criterion`, `proptest`) are unavailable. Everything the rest of
//! the library needs from them is implemented here, small and tested:
//!
//! * [`prng`] — SplitMix64 / xoshiro256** PRNG (replaces `rand`)
//! * [`stats`] — descriptive statistics and percentiles
//! * [`regression`] — least-squares linear fits (the paper's calibration tool)
//! * [`json`] — minimal JSON value model, writer and parser (replaces `serde_json`)
//! * [`csv`] — CSV table writer
//! * [`fnv`] — stable FNV-1a 64-bit hash (fingerprints, artifact checksums)
//! * [`threadpool`] — scoped parallel map + persistent worker pool (replaces `rayon`)
//! * [`propcheck`] — mini property-based testing harness (replaces `proptest`)
//! * [`bench`] — mini-criterion used by the `benches/` targets (replaces `criterion`)
//! * [`cli`] — tiny argument parser for the `codesign` binary (replaces `clap`)
//! * [`ascii_plot`] — terminal scatter plots for report output
//! * [`svg`] — SVG scatter/line plot writer for report output

pub mod ascii_plot;
pub mod bench;
pub mod cli;
pub mod csv;
pub mod fnv;
pub mod json;
pub mod prng;
pub mod propcheck;
pub mod regression;
pub mod stats;
pub mod svg;
pub mod threadpool;
