//! Mini property-based testing harness (offline stand-in for `proptest`).
//!
//! Usage:
//! ```no_run
//! // (no_run: rustdoc test binaries miss the xla rpath in this image)
//! use codesign::util::propcheck::{forall, Config};
//! forall(Config::default().cases(200), |rng| {
//!     let x = rng.range_i64(-100, 100);
//!     let prop = (x * x) >= 0;
//!     prop
//! });
//! ```
//!
//! Failures report the seed and case index so they can be replayed
//! deterministically with [`Config::seed`].

use crate::util::prng::Rng;

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 100, seed: 0xC0DE_5160_u64 ^ 0xA5A5 }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `prop` on `cfg.cases` independently seeded RNGs; panic with the
/// replayable (seed, case) pair on the first returned `false`.
pub fn forall<F: FnMut(&mut Rng) -> bool>(cfg: Config, mut prop: F) {
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        if !prop(&mut rng) {
            panic!(
                "property failed at case {case}/{} (replay with Config::default().seed({}).cases(1) after advancing {} cases, or seed {})",
                cfg.cases,
                cfg.seed,
                case,
                cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15),
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` so failures
/// can carry a description of the counterexample.
pub fn forall_res<F: FnMut(&mut Rng) -> Result<(), String>>(cfg: Config, mut prop: F) {
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at case {case}/{} (seed {seed}): {msg}", cfg.cases);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(Config::default().cases(50), |rng| {
            count += 1;
            let x = rng.range_i64(-1000, 1000);
            x.abs() >= 0
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(Config::default().cases(100), |rng| rng.range_u64(0, 10) != 5);
    }

    #[test]
    #[should_panic(expected = "counterexample: 5")]
    fn failing_res_property_carries_message() {
        forall_res(Config::default().cases(100), |rng| {
            let v = rng.range_u64(0, 10);
            if v == 5 {
                Err("counterexample: 5".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn deterministic_replay() {
        let mut first: Vec<u64> = Vec::new();
        forall(Config::default().cases(10), |rng| {
            first.push(rng.next_u64());
            true
        });
        let mut second: Vec<u64> = Vec::new();
        forall(Config::default().cases(10), |rng| {
            second.push(rng.next_u64());
            true
        });
        assert_eq!(first, second);
    }
}
