//! Mini-criterion: the measurement harness behind every `benches/` target
//! (`criterion` itself is unavailable offline). Provides warmup, multiple
//! timed samples, simple statistics and a stable one-line-per-benchmark
//! output format, plus a `black_box` to defeat constant folding.
//!
//! The `benches/` targets are `harness = false` binaries that mix *timing*
//! benchmarks (this module) with *figure regeneration* (module `report`),
//! one per paper table/figure, per DESIGN.md §9.

use crate::util::stats;
use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-export of `std::hint::black_box` under the criterion-familiar name.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Wall-clock budget for the warmup phase.
    pub warmup: Duration,
    /// Number of measured samples.
    pub samples: usize,
    /// Minimum time per sample; iterations are batched to reach it.
    pub min_sample_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            samples: 20,
            min_sample_time: Duration::from_millis(20),
        }
    }
}

/// Summary statistics of one benchmark, all in nanoseconds per iteration.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub iters_total: u64,
}

impl BenchResult {
    /// criterion-like single line: `name  time: [median] mean ± stddev`.
    pub fn summary_line(&self) -> String {
        format!(
            "{:<44} time: {:>12} (mean {:>12} ± {})",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.stddev_ns),
        )
    }
}

/// Human-readable duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// A bench runner that accumulates results and prints them criterion-style.
pub struct Bencher {
    cfg: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new() -> Bencher {
        Bencher { cfg: BenchConfig::default(), results: Vec::new() }
    }

    pub fn with_config(cfg: BenchConfig) -> Bencher {
        Bencher { cfg, results: Vec::new() }
    }

    /// Fast configuration for CI-style runs (fewer samples, shorter warmup).
    pub fn quick() -> Bencher {
        Bencher::with_config(BenchConfig {
            warmup: Duration::from_millis(50),
            samples: 10,
            min_sample_time: Duration::from_millis(5),
        })
    }

    /// Measure `f`, printing a summary line. The closure's return value is
    /// black-boxed to keep the computation alive.
    pub fn bench<R, F: FnMut() -> R>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup & calibration: find iterations per sample.
        let warm_start = Instant::now();
        let mut one = Duration::ZERO;
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.cfg.warmup || warm_iters == 0 {
            let t = Instant::now();
            std_black_box(f());
            one = t.elapsed();
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = (warm_start.elapsed() / warm_iters.max(1) as u32).max(Duration::from_nanos(1));
        let _ = one;
        let iters_per_sample = ((self.cfg.min_sample_time.as_nanos() / per_iter.as_nanos().max(1))
            as u64)
            .clamp(1, 1_000_000);

        let mut samples_ns = Vec::with_capacity(self.cfg.samples);
        let mut total_iters = 0u64;
        for _ in 0..self.cfg.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                std_black_box(f());
            }
            let el = t.elapsed().as_nanos() as f64;
            samples_ns.push(el / iters_per_sample as f64);
            total_iters += iters_per_sample;
        }
        let res = BenchResult {
            name: name.to_string(),
            mean_ns: stats::mean(&samples_ns),
            median_ns: stats::median(&samples_ns),
            stddev_ns: stats::stddev(&samples_ns),
            min_ns: stats::min(&samples_ns),
            max_ns: stats::max(&samples_ns),
            iters_total: total_iters,
        };
        println!("{}", res.summary_line());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Time a one-shot (non-repeating) operation, e.g. a full DSE sweep.
    pub fn bench_once<R, F: FnOnce() -> R>(&mut self, name: &str, f: F) -> (R, Duration) {
        let t = Instant::now();
        let r = std_black_box(f());
        let el = t.elapsed();
        let ns = el.as_nanos() as f64;
        let res = BenchResult {
            name: name.to_string(),
            mean_ns: ns,
            median_ns: ns,
            stddev_ns: 0.0,
            min_ns: ns,
            max_ns: ns,
            iters_total: 1,
        };
        println!("{}", res.summary_line());
        self.results.push(res);
        (r, el)
    }
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher::new()
    }
}

/// `true` when the bench binary should run in abbreviated mode: either
/// `cargo bench -- --quick` or the `CODESIGN_BENCH_QUICK` env var. `cargo test`
/// also runs bench targets with `--test`, which we treat as quick mode.
pub fn quick_requested() -> bool {
    std::env::args().any(|a| a == "--quick" || a == "--test")
        || std::env::var("CODESIGN_BENCH_QUICK").is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher::with_config(BenchConfig {
            warmup: Duration::from_millis(1),
            samples: 5,
            min_sample_time: Duration::from_micros(100),
        });
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..100 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.max_ns);
    }

    #[test]
    fn bench_once_returns_value() {
        let mut b = Bencher::quick();
        let (v, d) = b.bench_once("one", || 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e3).contains("µs"));
        assert!(fmt_ns(5e6).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
