//! Tiny CSV table builder. Every report in `report/` emits one CSV per paper
//! table/figure so results can be diffed and re-plotted externally.

use std::fmt::Write as _;
use std::path::Path;

/// An in-memory CSV table with a fixed header.
#[derive(Clone, Debug)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column names.
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row; panics if the arity does not match the header.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "csv row arity mismatch");
        self.rows.push(cells);
    }

    /// Convenience: append a row of display-able values.
    pub fn push<T: std::fmt::Display>(&mut self, cells: &[T]) {
        self.row(cells.iter().map(|c| c.to_string()).collect());
    }

    /// Render to CSV text (RFC-4180 quoting where needed).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        write_row(&mut out, &self.header);
        for r in &self.rows {
            write_row(&mut out, r);
        }
        out
    }

    /// Render as an aligned ASCII table (for terminal report output).
    pub fn to_ascii(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |out: &mut String, cells: &[String]| {
            for i in 0..ncol {
                let _ = write!(out, "| {:width$} ", cells[i], width = widths[i]);
            }
            out.push_str("|\n");
        };
        fmt_row(&mut out, &self.header);
        for (i, w) in widths.iter().enumerate() {
            out.push_str(if i == 0 { "|" } else { "" });
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for r in &self.rows {
            fmt_row(&mut out, r);
        }
        out
    }

    /// Write the CSV rendering to a file, creating parent directories.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

fn write_row(out: &mut String, cells: &[String]) {
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if c.contains([',', '"', '\n']) {
            out.push('"');
            out.push_str(&c.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(c);
        }
    }
    out.push('\n');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_csv() {
        let mut t = Table::new(&["a", "b"]);
        t.push(&[1, 2]);
        t.push(&[3, 4]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n3,4\n");
    }

    #[test]
    fn quoting() {
        let mut t = Table::new(&["x"]);
        t.row(vec!["hello, \"world\"".to_string()]);
        assert_eq!(t.to_csv(), "x\n\"hello, \"\"world\"\"\"\n");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.push(&[1]);
    }

    #[test]
    fn ascii_alignment() {
        let mut t = Table::new(&["name", "v"]);
        t.push(&["x", "10"]);
        t.push(&["longer", "7"]);
        let a = t.to_ascii();
        assert!(a.contains("| name   | v  |"));
        assert!(a.contains("| longer | 7  |"));
    }
}
