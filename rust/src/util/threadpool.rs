//! Scoped data parallelism without `rayon`.
//!
//! The DSE coordinator fans thousands of independent inner optimization
//! problems across cores. [`parallel_map`] gives an order-preserving parallel
//! map with work-stealing via a shared atomic cursor; [`Pool`] is a small
//! persistent worker pool for long-lived coordinator jobs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Number of worker threads to use by default: all available cores, capped to
/// the number of items where relevant.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Order-preserving parallel map over `items` using `nthreads` OS threads.
///
/// `f` must be `Sync` (it is shared by reference across workers). Items are
/// claimed through a shared atomic index, so uneven per-item cost balances
/// automatically. With `nthreads <= 1` this degrades to a plain serial map.
pub fn parallel_map<T, R, F>(items: &[T], nthreads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_chunked(items, nthreads, 1, f)
}

/// [`parallel_map`] with shard-sized work claiming: workers claim contiguous
/// chunks of `chunk` items through the shared cursor instead of one item at a
/// time. For cheap per-item work (e.g. the batched coordinator's serve phase,
/// or sweeps that are mostly cache hits) this divides cursor contention by
/// `chunk` while keeping the same order-preserving output and automatic load
/// balancing across uneven shards.
pub fn parallel_map_chunked<T, R, F>(items: &[T], nthreads: usize, chunk: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    if items.is_empty() {
        return Vec::new();
    }
    let chunk = chunk.max(1);
    let nchunks = items.len().div_ceil(chunk);
    let nthreads = nthreads.max(1).min(nchunks);
    if nthreads == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    thread::scope(|scope| {
        for _ in 0..nthreads {
            let cursor = &cursor;
            let f = &f;
            let slots_ptr = &slots_ptr;
            scope.spawn(move || loop {
                let c = cursor.fetch_add(1, Ordering::Relaxed);
                if c >= nchunks {
                    break;
                }
                let start = c * chunk;
                let end = (start + chunk).min(items.len());
                for i in start..end {
                    let r = f(&items[i]);
                    // SAFETY: each chunk (and so each index i) is claimed by
                    // exactly one worker, and `slots` outlives the scope;
                    // distinct workers write disjoint slots.
                    unsafe {
                        *slots_ptr.0.add(i) = Some(r);
                    }
                }
            });
        }
    });
    slots.into_iter().map(|s| s.expect("worker missed a slot")).collect()
}

/// A raw pointer wrapper that asserts cross-thread sendability for the
/// disjoint-slot write pattern above.
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A small persistent worker pool (FIFO). Jobs are arbitrary closures; results
/// travel back through whatever channel the caller closes over.
pub struct Pool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl Pool {
    /// Spawn a pool with `n` workers (`n >= 1`).
    pub fn new(n: usize) -> Pool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            queued.fetch_sub(1, Ordering::Release);
                        }
                        Err(_) => break, // sender dropped -> shut down
                    }
                })
            })
            .collect();
        Pool { tx: Some(tx), workers, queued }
    }

    /// Enqueue a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::Acquire);
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool workers gone");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::Acquire)
    }

    /// Block until all submitted jobs have completed.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            thread::yield_now();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_serial_path() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn map_empty() {
        let items: Vec<u32> = vec![];
        assert!(parallel_map(&items, 4, |&x| x).is_empty());
    }

    #[test]
    fn map_uneven_work() {
        // Items with wildly different costs still return correct results.
        let items: Vec<u64> = (0..64).collect();
        let out = parallel_map(&items, 8, |&x| {
            let mut acc = 0u64;
            for i in 0..(x * 1000) {
                acc = acc.wrapping_add(i);
            }
            (x, acc).0
        });
        assert_eq!(out, items);
    }

    #[test]
    fn chunked_map_matches_serial_for_all_chunk_sizes() {
        let items: Vec<u64> = (0..257).collect();
        let expect: Vec<u64> = items.iter().map(|x| x * 3 + 1).collect();
        for chunk in [1, 2, 7, 64, 256, 257, 1000] {
            for threads in [1, 3, 8] {
                let out = parallel_map_chunked(&items, threads, chunk, |&x| x * 3 + 1);
                assert_eq!(out, expect, "chunk {chunk}, threads {threads}");
            }
        }
    }

    #[test]
    fn chunked_map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map_chunked(&empty, 4, 16, |&x| x).is_empty());
        assert_eq!(parallel_map_chunked(&[9u32], 4, 16, |&x| x + 1), vec![10]);
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = Pool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits for workers
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
