//! Terminal scatter plots. Every figure report prints one of these next to
//! its CSV/SVG output so the paper's plots can be eyeballed directly in the
//! terminal (Fig 3's Pareto clouds, Fig 4's allocation clusters, Fig 2's fits).

/// A named point series.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub glyph: char,
    pub points: Vec<(f64, f64)>,
}

/// Scatter-plot canvas with axes and legend.
pub struct ScatterPlot {
    pub title: String,
    pub xlabel: String,
    pub ylabel: String,
    pub width: usize,
    pub height: usize,
    pub series: Vec<Series>,
}

impl ScatterPlot {
    pub fn new(title: &str, xlabel: &str, ylabel: &str) -> ScatterPlot {
        ScatterPlot {
            title: title.to_string(),
            xlabel: xlabel.to_string(),
            ylabel: ylabel.to_string(),
            width: 72,
            height: 24,
            series: Vec::new(),
        }
    }

    pub fn series(&mut self, name: &str, glyph: char, points: Vec<(f64, f64)>) -> &mut Self {
        self.series.push(Series { name: name.to_string(), glyph, points });
        self
    }

    /// Render to a string. Later series overwrite earlier ones on collision,
    /// so put highlights (e.g. Pareto points) last.
    pub fn render(&self) -> String {
        let all: Vec<(f64, f64)> = self.series.iter().flat_map(|s| s.points.iter().copied()).collect();
        if all.is_empty() {
            return format!("{}\n(no data)\n", self.title);
        }
        let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &all {
            xmin = xmin.min(x);
            xmax = xmax.max(x);
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
        if xmax == xmin {
            xmax = xmin + 1.0;
        }
        if ymax == ymin {
            ymax = ymin + 1.0;
        }
        let w = self.width;
        let h = self.height;
        let mut grid = vec![vec![' '; w]; h];
        for s in &self.series {
            for &(x, y) in &s.points {
                let cx = ((x - xmin) / (xmax - xmin) * (w - 1) as f64).round() as usize;
                let cy = ((y - ymin) / (ymax - ymin) * (h - 1) as f64).round() as usize;
                grid[h - 1 - cy][cx] = s.glyph;
            }
        }
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        let ylab_w = 10;
        for (r, row) in grid.iter().enumerate() {
            let yv = ymax - (ymax - ymin) * r as f64 / (h - 1) as f64;
            let label = if r % 4 == 0 {
                format!("{:>9.4}", yv)
            } else {
                " ".repeat(9)
            };
            out.push_str(&format!("{label} |"));
            out.extend(row.iter());
            out.push('\n');
        }
        out.push_str(&" ".repeat(ylab_w));
        out.push('+');
        out.push_str(&"-".repeat(w));
        out.push('\n');
        out.push_str(&format!(
            "{}{:<12.4}{}{:>12.4}\n",
            " ".repeat(ylab_w + 1),
            xmin,
            " ".repeat(w.saturating_sub(24)),
            xmax
        ));
        out.push_str(&format!("{}x: {}   y: {}\n", " ".repeat(ylab_w + 1), self.xlabel, self.ylabel));
        let legend: Vec<String> = self
            .series
            .iter()
            .map(|s| format!("'{}' {} ({} pts)", s.glyph, s.name, s.points.len()))
            .collect();
        out.push_str(&format!("{}legend: {}\n", " ".repeat(ylab_w + 1), legend.join(", ")));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_points_and_legend() {
        let mut p = ScatterPlot::new("t", "x", "y");
        p.series("all", '.', vec![(0.0, 0.0), (1.0, 1.0), (2.0, 4.0)]);
        p.series("best", '*', vec![(2.0, 4.0)]);
        let s = p.render();
        assert!(s.contains('*'));
        assert!(s.contains('.'));
        assert!(s.contains("legend: '.' all (3 pts), '*' best (1 pts)"));
    }

    #[test]
    fn empty_plot_is_graceful() {
        let p = ScatterPlot::new("empty", "x", "y");
        assert!(p.render().contains("no data"));
    }

    #[test]
    fn degenerate_range_no_panic() {
        let mut p = ScatterPlot::new("deg", "x", "y");
        p.series("s", 'o', vec![(1.0, 1.0), (1.0, 1.0)]);
        let _ = p.render();
    }
}
