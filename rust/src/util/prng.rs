//! Small, fast, reproducible PRNG: SplitMix64 for seeding, xoshiro256** for
//! the stream. Deterministic across platforms; used by the annealing baseline,
//! the property-test harness and synthetic workload generators.

/// SplitMix64 step — used to expand a single `u64` seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // xoshiro must not start from the all-zero state.
        if s.iter().all(|&x| x == 0) {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi]` (inclusive). Panics if `lo > hi`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "range_u64: lo > hi");
        let span = hi - lo;
        if span == u64::MAX {
            return self.next_u64();
        }
        // Debiased modulo (Lemire-style rejection kept simple).
        let span = span + 1;
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + v % span;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` (inclusive) as `i64`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "range_i64: lo > hi");
        lo.wrapping_add(self.range_u64(0, (hi - lo) as u64) as i64)
    }

    /// Uniform usize in `[0, n)`. Panics if `n == 0`.
    pub fn index(&mut self, n: usize) -> usize {
        assert!(n > 0, "index: empty range");
        self.range_u64(0, (n - 1) as u64) as usize
    }

    /// Uniform choice from a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// `true` with probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple, adequate).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut r = Rng::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range_u64(10, 13);
            assert!((10..=13).contains(&v));
            seen_lo |= v == 10;
            seen_hi |= v == 13;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn range_singleton() {
        let mut r = Rng::new(5);
        assert_eq!(r.range_u64(9, 9), 9);
        assert_eq!(r.range_i64(-4, -4), -4);
    }

    #[test]
    fn range_i64_negative() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            let v = r.range_i64(-100, -50);
            assert!((-100..=-50).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely to be identity
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(23);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(31);
        let n = 100_000;
        let hits = (0..n).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }
}
