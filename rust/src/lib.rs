//! # codesign — Accelerator Codesign as Non-Linear Optimization
//!
//! A full reproduction of *"Accelerator Codesign as Non-Linear Optimization"*
//! (Prajapati et al., 2017): an analytical silicon-area model for GPU-like
//! vector-parallel accelerators, an analytical execution-time model for
//! hybrid-hexagonally tiled dense stencils, and a mixed-integer non-linear
//! codesign optimizer that simultaneously selects hardware parameters
//! (`n_SM`, `n_V`, `M_SM`) and software parameters (tile sizes, hyperthreading
//! factor) to maximize workload performance under a chip-area budget.
//!
//! ## Architecture (three layers, Python never on the request path)
//!
//! * **L1** (`python/compile/kernels/`): the six paper stencils as Pallas
//!   kernels (interpret mode), checked against a pure-`jnp` oracle.
//! * **L2** (`python/compile/model.py`): JAX time-sweep graphs per stencil,
//!   AOT-lowered once to HLO text under `artifacts/`.
//! * **L3** (this crate): area model ([`area`]), Cacti-like memory estimator
//!   ([`cacti`]), execution-time model ([`timemodel`]), MINLP optimizer
//!   ([`opt`]), codesign engine ([`codesign`]), cycle-approximate GPU
//!   simulator ([`sim`]), PJRT runtime ([`runtime`]), DSE coordinator
//!   ([`coordinator`]), report generation ([`report`]), the session
//!   service ([`service`]) — the typed request API everything public
//!   routes through — persisted sweep artifacts ([`artifact`]) that
//!   warm-start a session certified bit-identical to cold recompute, and
//!   the persistent serve daemon ([`serve`]): a streaming request loop
//!   with concurrent batch groups, bounded admission and memo-memory
//!   budgets, all certified to change cost, never answers.
//!
//! ## Workloads and platforms beyond the paper
//!
//! The six paper kernels are presets of a parametric stencil-family
//! subsystem ([`stencil::spec`]): any star/box stencil of radius 1–8 in
//! 2-D/3-D is a first-class workload, addressed by names like `star3d:r2`
//! everywhere a stencil name is accepted (CLI, wire, workloads). The
//! hardware baseline is parametric the same way ([`platform`]): presets
//! `maxwell` / `maxwell+` / `maxwell-nocache` plus an override grammar
//! (`maxwell:bw20:clk1.4`) open clocks, bandwidth, latency constants and
//! grid bounds as scenario dimensions (CLI `--platform`, wire schema v3).
//!
//! ## Energy as a third objective
//!
//! Beyond the paper's area/perf trade-off, `pareto_energy` requests (wire
//! schema v6, CLI `explore --objective energy`) answer with tri-objective
//! (area ↓, perf ↑, energy ↓) Pareto fronts ([`codesign::energy`],
//! [`codesign::pareto::ParetoFront3`]), swept under a certified energy
//! roofline bound ([`opt::bounds::energy_lower_bound`]) and certified
//! bit-identical to the ungated path against a brute-force oracle.
//!
//! See `DESIGN.md` (repo root) for the system inventory, the batched DSE
//! engine's contract, the stencil characterization math, and the
//! per-experiment index.

pub mod area;
pub mod artifact;
pub mod cacti;
pub mod codesign;
pub mod coordinator;
pub mod opt;
pub mod platform;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod service;
pub mod sim;
pub mod stencil;
pub mod timemodel;
pub mod util;

// Modules are introduced bottom-up; see DESIGN.md §4 for the inventory.
