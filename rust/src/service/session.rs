//! The persistent session: one long-lived service object that owns the
//! coordinators and keeps their memo caches warm across calls.
//!
//! A [`Session`] answers arbitrary mixes of [`CodesignRequest`]s. Scenario
//! evaluation is defined by the (platform, C_iter, solver-options) triple —
//! the platform fixes the model bundle, the pair fixes the batch engine's
//! `solved_under` invariant — so the session keeps **one coordinator per
//! distinct triple** and auto-partitions each submission into compatible
//! batch groups instead of rejecting mixed request sets. Partitioning is by
//! [`PlatformSpec::fingerprint`]: two identically-valued platform spellings
//! share one warm coordinator (and therefore its memoized sweeps), while any
//! model delta gets its own. Repeat queries over the same grids are answered
//! almost entirely from cache (~100% hits), and the partial-codesign tune
//! path reads and feeds the same memo store.

use crate::codesign::scenario::{DesignEval, Scenario, ScenarioResult};
use crate::codesign::sensitivity::best_for_benchmark;
use crate::codesign::tuner::{candidate_grid, Pinned};
use crate::coordinator::{
    CacheEntry, CacheKey, Coordinator, EvictionSnapshot, MemoBudget, StatsSnapshot, SweepReport,
};
use crate::opt::bounds::{lower_bound_entry, PruneStats};
use crate::opt::inner::InnerSolution;
use crate::opt::problem::SolveOpts;
use crate::opt::separable::{aggregate_weighted, solve_entry};
use crate::platform::registry::{Platform, PlatformId};
use crate::platform::spec::PlatformSpec;
use crate::report::{self, Report};
use crate::service::request::{
    CodesignRequest, CodesignResponse, DesignSummary, EnergyDesignSummary, ErrorInfo,
    ParetoEnergySummary, ParetoSummary, ReferenceSummary, ScenarioSpec, ScenarioSummary,
    SensitivityRow, SensitivitySummary, SolverCostSummary, TuneRequest, TuneSummary,
    ValidateSummary,
};
use crate::sim::{validate_sweep, ValidationReport};
use crate::stencil::defs::StencilId;
use crate::stencil::workload::Workload;
use crate::timemodel::citer::CIterTable;
use crate::util::threadpool::{default_threads, parallel_map};
use std::time::{Duration, Instant};

/// The full in-process artifacts behind one response, for consumers (the CLI
/// report renderers) that need more than the wire-sized summary.
pub enum ResponseDetail {
    None,
    /// The materialized scenario(s) and their full results: one for
    /// Explore/Pareto/WhatIf, two (2-D then 3-D) for Sensitivity.
    Scenarios(Vec<ScenarioDetail>),
    /// The generated report bundle (SolverCost).
    Report(Box<Report>),
    /// The model-vs-simulator case list (Validate).
    Validation(Box<ValidationReport>),
}

pub struct ScenarioDetail {
    pub scenario: Scenario,
    /// The platform this scenario was evaluated on (its spec's platform, or
    /// the session default when the spec named none).
    pub platform: PlatformSpec,
    pub result: ScenarioResult,
}

/// One answered request: the wire-typed response plus in-process detail.
pub struct SessionAnswer {
    pub response: CodesignResponse,
    pub detail: ResponseDetail,
}

/// What one `submit_all` reports beyond the responses themselves.
pub struct SubmitReport {
    /// One answer per request, in request order.
    pub answers: Vec<SessionAnswer>,
    /// Exact hit/miss deltas summed over every partition this submission
    /// touched (the same accounting `BatchReport` certifies).
    pub cache: StatsSnapshot,
    /// Distinct (hardware, stencil, size) instances the batch sweeps covered.
    pub unique_instances: usize,
    /// Bound-and-prune telemetry summed over every partition this
    /// submission touched (inner-solver subtree cuts plus instances the
    /// objective-driven paths answered from bounds alone).
    pub prune: PruneStats,
    pub wall: Duration,
}

impl SubmitReport {
    pub fn lookups(&self) -> u64 {
        self.cache.lookups()
    }

    /// Hit rate over this submission's lookups (0.0 when it made none).
    pub fn cache_hit_rate(&self) -> f64 {
        self.cache.hit_rate()
    }

    /// Just the wire-typed responses, dropping the in-process detail.
    pub fn into_responses(self) -> Vec<CodesignResponse> {
        self.answers.into_iter().map(|a| a.response).collect()
    }
}

/// One session partition's full provenance and memo contents: everything the
/// artifact subsystem needs to persist it and later re-identify it. Entries
/// are in deterministic key order ([`MemoCache::export_entries`]), so two
/// snapshots of equal state serialize byte-identically.
///
/// [`MemoCache::export_entries`]: crate::coordinator::MemoCache::export_entries
pub struct PartitionSnapshot {
    pub platform: PlatformSpec,
    pub citer: CIterTable,
    pub opts: SolveOpts,
    pub entries: Vec<(CacheKey, CacheEntry)>,
}

/// Where a planned request's scenarios sit in the group batches.
type Slot = (usize, usize); // (group index, scenario index within the group)

enum OneKind {
    Explore,
    Pareto,
    WhatIf,
}

enum Plan {
    /// Already answered during planning (errors, Validate, SolverCost).
    Direct(CodesignResponse, ResponseDetail),
    /// One scenario in a batch group, on its resolved platform (carried
    /// here rather than read back from the coordinator: fingerprint-equal
    /// platforms share a coordinator but may differ in bounds/spelling).
    One { slot: Slot, kind: OneKind, platform: PlatformSpec },
    /// A standalone Pareto request on the bound-gated fast path: runs after
    /// the batches (so it rides any sweep this submission warmed) through
    /// [`Coordinator::run_pareto_gated`] on its partition's coordinator.
    ParetoGated { ci: usize, scenario: Box<Scenario> },
    /// A tri-objective (area, perf, energy) Pareto request. Always routed
    /// through [`Coordinator::run_pareto_energy_gated`] — with pruning off
    /// the same entry point runs its audit arm (every candidate solved), so
    /// one code path owns the energy accumulation in both prune states.
    ParetoEnergyGated { ci: usize, scenario: Box<Scenario> },
    /// Two scenarios (2-D, 3-D) plus the Table II area band.
    Sensitivity { s2: Slot, s3: Slot, p2: PlatformSpec, p3: PlatformSpec, band: (f64, f64) },
    /// Runs after the batches, against the then-warm memo store.
    Tune(TuneRequest),
}

/// The long-lived session service.
pub struct Session {
    /// The platform requests run on when their spec names none.
    default_platform: PlatformSpec,
    /// One coordinator per (platform fingerprint, C_iter, solver options)
    /// triple ever submitted — the auto-partitioning that replaces the batch
    /// engine's hard `solved_under` rejection at this layer.
    coordinators: Vec<(CIterTable, SolveOpts, Coordinator)>,
    progress_every: Option<usize>,
    /// Memo-store budget applied to every partition coordinator this
    /// session creates (`None` = unbounded, the one-shot default).
    memo_budget: Option<MemoBudget>,
}

impl Session {
    /// Build a session whose requests default to `default_platform`.
    ///
    /// Panics if the spec fails [`PlatformSpec::validate`] — registry-parsed
    /// platforms are always valid; failing a malformed hand-built spec here
    /// beats panicking later inside a long-lived service request.
    pub fn new(default_platform: PlatformSpec) -> Session {
        if let Err(e) = default_platform.validate() {
            panic!("invalid PlatformSpec for Session: {e}");
        }
        Session {
            default_platform,
            coordinators: Vec::new(),
            progress_every: None,
            memo_budget: None,
        }
    }

    /// A session on the default baseline (the paper's Maxwell platform).
    pub fn paper() -> Session {
        Session::new(Platform::default_spec().clone())
    }

    /// The platform requests without an explicit `platform` run on.
    pub fn default_platform(&self) -> &PlatformSpec {
        &self.default_platform
    }

    /// Print a progress line every `n` solved instances (per coordinator).
    pub fn with_progress(mut self, n: usize) -> Session {
        self.progress_every = Some(n.max(1));
        self
    }

    /// Bound every partition's memo store (see
    /// [`MemoCache`](crate::coordinator::MemoCache) for the eviction
    /// policy). Applies to coordinators created from here on — set it
    /// before the first submission (as the CLI and the serve daemon do);
    /// partitions that already exist keep the budget they were built with.
    /// `None` keeps new partitions unbounded.
    pub fn with_memo_budget(mut self, budget: Option<MemoBudget>) -> Session {
        self.memo_budget = budget;
        self
    }

    /// The memo budget new partitions are created with.
    pub fn memo_budget(&self) -> Option<MemoBudget> {
        self.memo_budget
    }

    /// Eviction telemetry summed over every partition's memo store.
    pub fn eviction_total(&self) -> EvictionSnapshot {
        let mut total = EvictionSnapshot::default();
        for (_, _, c) in &self.coordinators {
            let s = c.cache.eviction_snapshot();
            total.evicted_exact += s.evicted_exact;
            total.evicted_bounded += s.evicted_bounded;
            total.passes += s.passes;
            total.futile_passes += s.futile_passes;
        }
        total
    }

    /// Sweep every partition's memo store down to its configured budget
    /// ([`MemoCache::sweep_to_budget`](crate::coordinator::MemoCache::sweep_to_budget)),
    /// returning the number of entries evicted. The serve daemon calls this
    /// when its mailbox drains, so eviction debt deferred by pinned sweeps
    /// is paid during idle time instead of at the start of the next request.
    /// A no-op (returns 0) for unbounded partitions or when any sweep holds
    /// a pin.
    pub fn sweep_idle(&self) -> u64 {
        self.coordinators.iter().map(|(_, _, c)| c.cache.sweep_to_budget()).sum()
    }

    /// Number of (platform, C_iter, solver-options) partitions this session
    /// holds.
    pub fn partitions(&self) -> usize {
        self.coordinators.len()
    }

    /// Memoized instances across every partition.
    pub fn cache_entries(&self) -> usize {
        self.coordinators.iter().map(|(_, _, c)| c.cache.len()).sum()
    }

    fn stats_total(&self) -> StatsSnapshot {
        let mut total = StatsSnapshot::default();
        for (_, _, c) in &self.coordinators {
            let s = c.cache.stats.snapshot();
            total.hits += s.hits;
            total.misses += s.misses;
        }
        total
    }

    fn prune_total(&self) -> PruneStats {
        let mut total = PruneStats::default();
        for (_, _, c) in &self.coordinators {
            total.add(&c.prune.snapshot());
        }
        total
    }

    /// `BoundedOut` marks currently held across every partition's memo
    /// store (instances a pruned sweep answered from bounds; an exact
    /// demand upgrades them in place).
    pub fn bounded_entries(&self) -> usize {
        self.coordinators.iter().map(|(_, _, c)| c.cache.bounded_len()).sum()
    }

    /// Snapshot every partition's full provenance and memo contents, in
    /// deterministic per-partition key order — the save side of the artifact
    /// subsystem ([`crate::artifact`]).
    pub fn partition_snapshots(&self) -> Vec<PartitionSnapshot> {
        self.coordinators
            .iter()
            .map(|(citer, opts, coord)| PartitionSnapshot {
                platform: coord.platform().clone(),
                citer: citer.clone(),
                opts: opts.clone(),
                entries: coord.export_entries(),
            })
            .collect()
    }

    /// Dry-run the provenance checks [`Self::absorb_partition`] would apply,
    /// without creating a coordinator or mutating anything — the artifact
    /// loader calls this for *every* shard before absorbing *any*, so a
    /// conflict on a later shard can't leave earlier ones installed.
    pub fn check_partition(
        &self,
        platform: &PlatformSpec,
        citer: &CIterTable,
        opts: &SolveOpts,
    ) -> anyhow::Result<()> {
        let fp = platform.fingerprint();
        match self.coordinators.iter().find(|(c, o, coord)| {
            coord.platform_fingerprint() == fp && c == citer && o == opts
        }) {
            Some((_, _, coord)) => coord.can_import(citer, opts),
            None => Ok(()), // a fresh coordinator accepts any partition
        }
    }

    /// Install a decoded artifact partition into the matching coordinator
    /// (created on first sight, exactly as live submissions partition).
    /// Returns the number of cache slots actually installed; existing slots
    /// are never downgraded and hit/miss counters are untouched, so the
    /// warm-started session's telemetry replays a cold run bit-identically.
    pub fn absorb_partition(
        &mut self,
        platform: &PlatformSpec,
        citer: &CIterTable,
        opts: &SolveOpts,
        entries: &[(CacheKey, CacheEntry)],
    ) -> anyhow::Result<usize> {
        let ci = self.coordinator_index(platform, citer, opts);
        self.coordinators[ci].2.import_entries(citer, opts, entries)
    }

    /// Persist this session's memoized sweep state to an artifact directory
    /// (see [`crate::artifact`] for the format and guarantees).
    pub fn save_artifact(
        &self,
        dir: &std::path::Path,
    ) -> Result<crate::artifact::Manifest, crate::artifact::ArtifactError> {
        crate::artifact::save(self, dir)
    }

    /// Warm-start this session from an artifact directory. All-or-nothing:
    /// on `Err` the session is exactly as before (see [`crate::artifact`]
    /// for the refuse-to-alias contract).
    pub fn warm_start(
        &mut self,
        dir: &std::path::Path,
    ) -> Result<crate::artifact::LoadReport, crate::artifact::ArtifactError> {
        crate::artifact::load(self, dir)
    }

    fn coordinator_index(
        &mut self,
        platform: &PlatformSpec,
        citer: &CIterTable,
        opts: &SolveOpts,
    ) -> usize {
        let fp = platform.fingerprint();
        if let Some(i) = self.coordinators.iter().position(|(c, o, coord)| {
            coord.platform_fingerprint() == fp && c == citer && o == opts
        }) {
            return i;
        }
        let mut coord = Coordinator::with_memo_budget(platform.clone(), self.memo_budget);
        if let Some(n) = self.progress_every {
            coord = coord.with_progress(n);
        }
        self.coordinators.push((citer.clone(), opts.clone(), coord));
        self.coordinators.len() - 1
    }

    /// Resolve a request's optional platform id: the named registered
    /// platform, or this session's default. The single resolution point for
    /// both the scenario and tune paths.
    fn resolve_platform(&self, id: Option<PlatformId>) -> PlatformSpec {
        match id {
            Some(id) => Platform::get(id).spec.clone(),
            None => self.default_platform.clone(),
        }
    }

    /// The platform a spec's scenarios run on.
    fn platform_for(&self, spec: &ScenarioSpec) -> PlatformSpec {
        self.resolve_platform(spec.platform)
    }

    /// Answer one request (a submission of one).
    pub fn submit(&mut self, request: &CodesignRequest) -> SessionAnswer {
        self.submit_all(std::slice::from_ref(request))
            .answers
            .pop()
            .expect("one request in, one answer out")
    }

    /// Answer a request set: materialize scenarios, auto-partition them into
    /// compatible batch groups, run each group through its warm coordinator,
    /// and assemble per-request answers in request order.
    pub fn submit_all(&mut self, requests: &[CodesignRequest]) -> SubmitReport {
        let t0 = Instant::now();
        let before = self.stats_total();
        let prune_before = self.prune_total();

        // Plan: one entry per request; scenario-backed requests enqueue into
        // per-(platform, C_iter, SolveOpts) groups, with identical specs
        // within this submission deduplicated onto one batch slot (e.g.
        // `report` asks for a scenario both as Explore and inside
        // Sensitivity — it should be served, not re-aggregated, twice).
        // Specs any Explore in this submission will sweep in full anyway:
        // a Pareto over the same spec stays on the batch path regardless of
        // request order, instead of paying a redundant bound-gating pass.
        let explored: Vec<&ScenarioSpec> = requests
            .iter()
            .filter_map(|r| match r {
                CodesignRequest::Explore { scenario } => Some(scenario),
                _ => None,
            })
            .collect();
        let mut groups: Vec<(usize, Vec<Scenario>)> = Vec::new();
        let mut seen: Vec<(ScenarioSpec, Slot)> = Vec::new();
        let mut plans: Vec<Plan> = Vec::with_capacity(requests.len());
        for req in requests {
            let plan = self.plan(req, &explored, &mut groups, &mut seen);
            plans.push(plan);
        }

        // Sweep + serve each group on its coordinator. One shared sweep per
        // group answers every scenario in it.
        let mut unique_instances = 0usize;
        let mut batches: Vec<Vec<SweepReport>> = Vec::with_capacity(groups.len());
        for (ci, scenarios) in &groups {
            let rep = self.coordinators[*ci].2.run_batch_report(scenarios);
            unique_instances += rep.unique_instances;
            batches.push(rep.reports);
        }

        // Assemble answers; tunes execute here, against the warm store.
        let mut answers = Vec::with_capacity(plans.len());
        for plan in plans {
            answers.push(self.finish(plan, &groups, &batches));
        }

        let after = self.stats_total();
        let prune_after = self.prune_total();
        SubmitReport {
            answers,
            cache: StatsSnapshot {
                hits: after.hits - before.hits,
                misses: after.misses - before.misses,
            },
            unique_instances,
            prune: PruneStats {
                bounds_computed: prune_after.bounds_computed - prune_before.bounds_computed,
                subtrees_cut: prune_after.subtrees_cut - prune_before.subtrees_cut,
                bounded_out: prune_after.bounded_out - prune_before.bounded_out,
                groups_evaluated: prune_after.groups_evaluated - prune_before.groups_evaluated,
                lanes_evaluated: prune_after.lanes_evaluated - prune_before.lanes_evaluated,
            },
            wall: t0.elapsed(),
        }
    }

    fn plan(
        &mut self,
        req: &CodesignRequest,
        explored: &[&ScenarioSpec],
        groups: &mut Vec<(usize, Vec<Scenario>)>,
        seen: &mut Vec<(ScenarioSpec, Slot)>,
    ) -> Plan {
        match req {
            CodesignRequest::Explore { scenario } => {
                self.plan_one(scenario, OneKind::Explore, req, groups, seen)
            }
            CodesignRequest::Pareto { scenario } => {
                // Standalone Pareto requests ride the bound-gated fast path:
                // only the front is needed, so dominated design points are
                // answered from their certified bounds without solving. A
                // spec this submission needs in full anyway (an identical
                // spec already planned, or an Explore over it anywhere in
                // the request list) stays on the batch path, as does a
                // request that disabled pruning (`--no-prune`).
                let already_batched = seen.iter().any(|(s, _)| s == scenario)
                    || explored.iter().any(|s| *s == scenario);
                if !scenario.solve_opts.prune || already_batched {
                    return self.plan_one(scenario, OneKind::Pareto, req, groups, seen);
                }
                let platform = self.platform_for(scenario);
                match scenario.to_scenario(&platform) {
                    Ok(sc) => {
                        let ci = self.coordinator_index(&platform, &sc.citer, &sc.solve_opts);
                        Plan::ParetoGated { ci, scenario: Box::new(sc) }
                    }
                    Err(e) => Plan::Direct(error_response(req, &e), ResponseDetail::None),
                }
            }
            CodesignRequest::ParetoEnergy { scenario } => {
                // Unlike the 2-D fast path there is no batch fallback:
                // tri-objective fronts need per-design energy, which only the
                // gated sweep (and its no-prune audit arm) computes. Sharing
                // a spec with an Explore costs nothing extra — the gated run
                // rides the warmed memo store.
                let platform = self.platform_for(scenario);
                match scenario.to_scenario(&platform) {
                    Ok(sc) => {
                        let ci = self.coordinator_index(&platform, &sc.citer, &sc.solve_opts);
                        Plan::ParetoEnergyGated { ci, scenario: Box::new(sc) }
                    }
                    Err(e) => Plan::Direct(error_response(req, &e), ResponseDetail::None),
                }
            }
            CodesignRequest::WhatIf { scenario, weights } => {
                let mut spec = scenario.clone().with_weights(weights.clone());
                if spec.name.is_none() {
                    // Fold the weight vector into the derived name so two
                    // unnamed what-ifs over one base stay distinguishable in
                    // a response file.
                    let sig: Vec<String> =
                        weights.iter().map(|(id, w)| format!("{}={w}", id.name())).collect();
                    spec.name = Some(format!(
                        "{}-whatif[{}]",
                        scenario.scenario_name(),
                        sig.join(",")
                    ));
                }
                self.plan_one(&spec, OneKind::WhatIf, req, groups, seen)
            }
            CodesignRequest::Sensitivity { scenario_2d, scenario_3d, area_band } => {
                // Materialize both specs before enqueueing either, so a bad
                // sibling can't leave an orphan scenario in a batch group
                // (which would be swept at full cost and never consumed).
                let p2 = self.platform_for(scenario_2d);
                let p3 = self.platform_for(scenario_3d);
                match (scenario_2d.to_scenario(&p2), scenario_3d.to_scenario(&p3)) {
                    (Ok(sc2), Ok(sc3)) => {
                        let s2 = self.enqueue_materialized(scenario_2d, sc2, &p2, groups, seen);
                        let s3 = self.enqueue_materialized(scenario_3d, sc3, &p3, groups, seen);
                        Plan::Sensitivity { s2, s3, p2, p3, band: *area_band }
                    }
                    (Err(e), _) | (_, Err(e)) => {
                        Plan::Direct(error_response(req, &e), ResponseDetail::None)
                    }
                }
            }
            CodesignRequest::Tune(t) => Plan::Tune(t.clone()),
            CodesignRequest::Validate => {
                let rep = validate_sweep(&self.default_platform);
                let summary = ValidateSummary {
                    cases: rep.cases.len(),
                    mape_pct: rep.mape_pct,
                    kendall_tau: rep.kendall_tau,
                };
                Plan::Direct(
                    CodesignResponse::Validate(summary),
                    ResponseDetail::Validation(Box::new(rep)),
                )
            }
            CodesignRequest::SolverCost { anneal_iters, citer } => {
                let rep = report::solver_cost::generate(
                    &self.default_platform.time_model(),
                    citer,
                    *anneal_iters,
                );
                let summary = SolverCostSummary {
                    anneal_iters: *anneal_iters,
                    summary: rep.summary.clone(),
                };
                Plan::Direct(
                    CodesignResponse::SolverCost(summary),
                    ResponseDetail::Report(Box::new(rep)),
                )
            }
        }
    }

    fn plan_one(
        &mut self,
        spec: &ScenarioSpec,
        kind: OneKind,
        req: &CodesignRequest,
        groups: &mut Vec<(usize, Vec<Scenario>)>,
        seen: &mut Vec<(ScenarioSpec, Slot)>,
    ) -> Plan {
        let platform = self.platform_for(spec);
        match self.enqueue(spec, &platform, groups, seen) {
            Ok(slot) => Plan::One { slot, kind, platform },
            Err(e) => Plan::Direct(error_response(req, &e), ResponseDetail::None),
        }
    }

    /// Materialize a spec on its resolved platform and place it in the
    /// batch group matching its (platform, C_iter, solver options) —
    /// creating the group (and its coordinator) on first sight. A spec
    /// identical to one already planned in this submission reuses its slot
    /// instead of being served twice.
    fn enqueue(
        &mut self,
        spec: &ScenarioSpec,
        platform: &PlatformSpec,
        groups: &mut Vec<(usize, Vec<Scenario>)>,
        seen: &mut Vec<(ScenarioSpec, Slot)>,
    ) -> anyhow::Result<Slot> {
        if let Some((_, slot)) = seen.iter().find(|(s, _)| s == spec) {
            return Ok(*slot);
        }
        let sc = spec.to_scenario(platform)?;
        Ok(self.enqueue_materialized(spec, sc, platform, groups, seen))
    }

    /// [`Self::enqueue`] for a scenario already materialized from `spec` on
    /// `platform` (the Sensitivity path validates both siblings first and
    /// hands the results straight in). Infallible: materialization is the
    /// only failing step.
    fn enqueue_materialized(
        &mut self,
        spec: &ScenarioSpec,
        sc: Scenario,
        platform: &PlatformSpec,
        groups: &mut Vec<(usize, Vec<Scenario>)>,
        seen: &mut Vec<(ScenarioSpec, Slot)>,
    ) -> Slot {
        if let Some((_, slot)) = seen.iter().find(|(s, _)| s == spec) {
            return *slot;
        }
        let ci = self.coordinator_index(platform, &sc.citer, &sc.solve_opts);
        let g = match groups.iter().position(|(c, _)| *c == ci) {
            Some(g) => g,
            None => {
                groups.push((ci, Vec::new()));
                groups.len() - 1
            }
        };
        groups[g].1.push(sc);
        let slot = (g, groups[g].1.len() - 1);
        seen.push((spec.clone(), slot));
        slot
    }

    fn finish(
        &mut self,
        plan: Plan,
        groups: &[(usize, Vec<Scenario>)],
        batches: &[Vec<SweepReport>],
    ) -> SessionAnswer {
        match plan {
            Plan::Direct(response, detail) => SessionAnswer { response, detail },
            Plan::One { slot: (g, i), kind, platform } => {
                let scenario = groups[g].1[i].clone();
                let result = batches[g][i].result.clone();
                let response = match kind {
                    OneKind::Explore => CodesignResponse::Explore(scenario_summary(&result)),
                    OneKind::WhatIf => CodesignResponse::WhatIf(scenario_summary(&result)),
                    OneKind::Pareto => CodesignResponse::Pareto(ParetoSummary {
                        scenario: result.scenario_name.clone(),
                        designs: result.points.len(),
                        infeasible: result.infeasible_points,
                        pareto: result
                            .pareto
                            .iter()
                            .map(|&i| design_summary(&result.points[i]))
                            .collect(),
                        total_evals: result.total_evals,
                        bounded_out: 0, // batch path: every point solved exactly
                    }),
                };
                SessionAnswer {
                    response,
                    detail: ResponseDetail::Scenarios(vec![ScenarioDetail {
                        scenario,
                        platform,
                        result,
                    }]),
                }
            }
            Plan::ParetoGated { ci, scenario } => {
                let gated = self.coordinators[ci].2.run_pareto_gated(&scenario);
                let response = CodesignResponse::Pareto(ParetoSummary {
                    scenario: gated.scenario_name.clone(),
                    designs: gated.designs,
                    infeasible: gated.infeasible,
                    pareto: gated
                        .front
                        .iter()
                        .map(|p| DesignSummary {
                            n_sm: p.hw.n_sm,
                            n_v: p.hw.n_v,
                            m_sm_kb: p.hw.m_sm_kb,
                            area_mm2: p.area_mm2,
                            gflops: p.gflops,
                            seconds: p.seconds,
                        })
                        .collect(),
                    total_evals: gated.total_evals,
                    bounded_out: gated.bounded_out as u64,
                });
                SessionAnswer { response, detail: ResponseDetail::None }
            }
            Plan::ParetoEnergyGated { ci, scenario } => {
                let gated = self.coordinators[ci].2.run_pareto_energy_gated(&scenario);
                let response = CodesignResponse::ParetoEnergy(ParetoEnergySummary {
                    scenario: gated.scenario_name.clone(),
                    designs: gated.designs,
                    infeasible: gated.infeasible,
                    pareto: gated
                        .front
                        .iter()
                        .map(|p| EnergyDesignSummary {
                            n_sm: p.hw.n_sm,
                            n_v: p.hw.n_v,
                            m_sm_kb: p.hw.m_sm_kb,
                            area_mm2: p.area_mm2,
                            gflops: p.gflops,
                            seconds: p.seconds,
                            power_w: p.power_w,
                            energy_j: p.energy_j,
                        })
                        .collect(),
                    total_evals: gated.total_evals,
                    bounded_out: gated.bounded_out as u64,
                });
                SessionAnswer { response, detail: ResponseDetail::None }
            }
            Plan::Sensitivity { s2: (g2, i2), s3: (g3, i3), p2, p3, band } => {
                let d2 = ScenarioDetail {
                    scenario: groups[g2].1[i2].clone(),
                    platform: p2,
                    result: batches[g2][i2].result.clone(),
                };
                let d3 = ScenarioDetail {
                    scenario: groups[g3].1[i3].clone(),
                    platform: p3,
                    result: batches[g3][i3].result.clone(),
                };
                let response =
                    CodesignResponse::Sensitivity(sensitivity_summary(&d2, &d3, band));
                SessionAnswer { response, detail: ResponseDetail::Scenarios(vec![d2, d3]) }
            }
            Plan::Tune(req) => self.run_tune(&req),
        }
    }

    /// §V-D tuning through the session's memo store: the same candidate grid
    /// and best-selection (tie) semantics as `codesign::tuner::tune`, but
    /// every (hardware, entry) instance is read from / written to the
    /// partition's cache, so tunes ride on prior sweeps and warm future
    /// ones.
    ///
    /// With pruning enabled (the default), candidates are visited in
    /// ascending order of their certified objective lower bound and skipped
    /// — entries recorded `BoundedOut` in the memo store — once the bound
    /// already reaches the incumbent's weighted seconds; the winner is
    /// provably the unpruned scan's (skipped candidates are *strictly*
    /// worse — the bound carries a one-sided safety margin — so they could
    /// never replace the incumbent under its strict-improvement rule, and
    /// any exact tie for the winning objective is always solved, keeping
    /// first-in-grid-order tie-breaking intact).
    fn run_tune(&mut self, req: &TuneRequest) -> SessionAnswer {
        let pinned =
            Pinned { n_sm: req.n_sm, n_v: req.n_v, m_sm_kb: req.m_sm_kb, caches: None };
        let workload = match req.stencil {
            Some(id) => Workload::single(id),
            None => Workload::uniform_2d(),
        };
        let platform = self.resolve_platform(req.platform);
        // Characterization-level cache keys, exactly as the batch engine
        // builds them (cache.rs: the stencil must carry its table C_iter).
        let chars = req.citer.characterize_workload(&workload);
        let candidates =
            candidate_grid(&pinned, req.budget_mm2, &platform.space, &platform.area_model());
        let ci = self.coordinator_index(&platform, &req.citer, &req.solve_opts);
        let coord = &self.coordinators[ci].2;
        let fp = coord.platform_fingerprint();
        let threads = req.threads.unwrap_or_else(default_threads).max(1);
        let time_model = coord.time_model();
        let (citer, opts) = (&req.citer, &req.solve_opts);
        // Pin the memo store for the scan: under a budget, the instances a
        // tune reads and records must stay resident until it finishes.
        let _pin = coord.cache.pin();

        let mut candidates_pruned = 0u64;
        let mut total_evals = 0u64;
        // (candidate index, weighted seconds, weighted gflops)
        let mut solved: Vec<(usize, f64, f64)> = Vec::new();
        if !opts.prune {
            // The historical full scan: every candidate solved, in parallel.
            let results: Vec<(Option<(f64, f64)>, u64)> =
                parallel_map(&candidates, threads, |cand| {
                    let per_entry: Vec<Option<InnerSolution>> = workload
                        .entries
                        .iter()
                        .zip(&chars)
                        .map(|(e, st)| {
                            let key = CacheKey::new(fp, &cand.hw, st, &e.size);
                            coord.cache.get_or_compute(key, || {
                                solve_entry(&time_model, citer, &cand.hw, e, opts)
                            })
                        })
                        .collect();
                    let evals: u64 = per_entry.iter().flatten().map(|s| s.evals).sum();
                    (aggregate_weighted(&workload, &per_entry), evals)
                });
            for (i, (s, evals)) in results.iter().enumerate() {
                total_evals += evals;
                if let Some((seconds, gflops)) = *s {
                    solved.push((i, seconds, gflops));
                }
            }
        } else {
            // Bound-gated scan: lower bounds first, candidates in
            // bound-ascending order, ramp-up chunks (sized by candidate
            // count, never thread count) so the gating and its telemetry
            // are identical across thread counts — and an incumbent exists
            // after the single-candidate first chunk.
            let mut stats = PruneStats::default();
            let entry_bounds: Vec<(Vec<f64>, f64)> =
                parallel_map(&candidates, threads.min(candidates.len().max(1)), |cand| {
                    let mut per = Vec::with_capacity(workload.entries.len());
                    let mut sum = 0.0f64;
                    for e in &workload.entries {
                        if e.weight > 0.0 {
                            let lb = lower_bound_entry(&time_model, citer, &cand.hw, e, opts);
                            per.push(lb);
                            sum += e.weight * lb;
                        } else {
                            per.push(f64::NAN); // never read
                        }
                    }
                    (per, sum)
                });
            stats.bounds_computed += (candidates.len()
                * workload.entries.iter().filter(|e| e.weight > 0.0).count())
                as u64;
            let mut order: Vec<usize> =
                (0..candidates.len()).filter(|&i| entry_bounds[i].1.is_finite()).collect();
            order.sort_by(|&a, &b| {
                entry_bounds[a].1.partial_cmp(&entry_bounds[b].1).unwrap().then(a.cmp(&b))
            });
            let mut best_seconds = f64::INFINITY;
            for range in crate::coordinator::driver::rampup_chunks(order.len(), 32) {
                let chunk = &order[range];
                let survivors: Vec<usize> = chunk
                    .iter()
                    .copied()
                    .filter(|&i| {
                        if entry_bounds[i].1 >= best_seconds {
                            candidates_pruned += 1;
                            for (j, e) in workload.entries.iter().enumerate() {
                                if e.weight > 0.0 {
                                    stats.bounded_out += 1;
                                    let key =
                                        CacheKey::new(fp, &candidates[i].hw, &chars[j], &e.size);
                                    coord.cache.insert_bound(key, entry_bounds[i].0[j]);
                                }
                            }
                            return false;
                        }
                        true
                    })
                    .collect();
                // The incumbent's weighted seconds is this chunk's budget;
                // the shared progressive-cutoff core (also behind the gated
                // Pareto sweep) does the rest.
                let cutoff_at = best_seconds;
                let results: Vec<(Option<(f64, f64)>, u64, PruneStats)> =
                    parallel_map(&survivors, threads.min(survivors.len().max(1)), |&i| {
                        coord.solve_candidate_gated(
                            &candidates[i].hw,
                            &workload.entries,
                            &chars,
                            citer,
                            opts,
                            &entry_bounds[i].0,
                            cutoff_at.is_finite().then_some(cutoff_at),
                        )
                    });
                for (&i, (outcome, evals, ps)) in survivors.iter().zip(&results) {
                    total_evals += evals;
                    coord.prune.add(ps);
                    if let Some((seconds, gflops)) = outcome {
                        solved.push((i, *seconds, *gflops));
                        if *seconds < best_seconds {
                            best_seconds = *seconds;
                        }
                    } else {
                        // Bounded out mid-candidate (progressive cutoff).
                        candidates_pruned += 1;
                    }
                }
            }
            coord.prune.add(&stats);
            // Winner semantics need grid order below.
            solved.sort_by_key(|&(i, _, _)| i);
        }

        let mut best: Option<(usize, f64, f64)> = None;
        for &(i, seconds, gflops) in &solved {
            if best.map_or(true, |(_, _, bg)| gflops > bg) {
                best = Some((i, seconds, gflops));
            }
        }
        let best = best.map(|(i, seconds, gflops)| DesignSummary {
            n_sm: candidates[i].hw.n_sm,
            n_v: candidates[i].hw.n_v,
            m_sm_kb: candidates[i].hw.m_sm_kb,
            area_mm2: candidates[i].area_mm2,
            gflops,
            seconds,
        });
        SessionAnswer {
            response: CodesignResponse::Tune(TuneSummary {
                budget_mm2: req.budget_mm2,
                candidates: candidates.len(),
                best,
                total_evals,
                candidates_pruned,
            }),
            detail: ResponseDetail::None,
        }
    }
}

fn error_response(req: &CodesignRequest, err: &anyhow::Error) -> CodesignResponse {
    CodesignResponse::Error(ErrorInfo {
        request: req.kind().to_string(),
        message: format!("{err:#}"),
    })
}

fn design_summary(p: &DesignEval) -> DesignSummary {
    DesignSummary {
        n_sm: p.hw.n_sm,
        n_v: p.hw.n_v,
        m_sm_kb: p.hw.m_sm_kb,
        area_mm2: p.area_mm2,
        gflops: p.gflops,
        seconds: p.seconds,
    }
}

fn scenario_summary(result: &ScenarioResult) -> ScenarioSummary {
    let mut best: Option<&DesignEval> = None;
    for p in &result.points {
        if best.map_or(true, |b| p.gflops > b.gflops) {
            best = Some(p);
        }
    }
    let references = result
        .references
        .iter()
        .map(|r| {
            // `None` (not NaN) when no feasible design fits under the
            // reference's area, so response equality and the wire stay exact.
            let improvement_pct = result
                .stats
                .vs_reference
                .iter()
                .find(|(name, _, _)| *name == r.name)
                .map(|(_, pct, _)| *pct)
                .filter(|pct| pct.is_finite());
            ReferenceSummary {
                name: r.name.clone(),
                area_mm2: r.area_mm2,
                published_area_mm2: r.published_area_mm2,
                gflops: r.gflops,
                improvement_pct,
            }
        })
        .collect();
    ScenarioSummary {
        scenario: result.scenario_name.clone(),
        designs: result.points.len(),
        infeasible: result.infeasible_points,
        best: best.map(design_summary),
        pareto: result.pareto.iter().map(|&i| design_summary(&result.points[i])).collect(),
        references,
        total_evals: result.total_evals,
    }
}

const TABLE2_2D: [StencilId; 4] =
    [StencilId::Jacobi2D, StencilId::Heat2D, StencilId::Gradient2D, StencilId::Laplacian2D];
const TABLE2_3D: [StencilId; 2] = [StencilId::Heat3D, StencilId::Laplacian3D];

fn sensitivity_summary(
    d2: &ScenarioDetail,
    d3: &ScenarioDetail,
    band: (f64, f64),
) -> SensitivitySummary {
    let mut rows = Vec::new();
    let sides: [(&ScenarioDetail, &[StencilId]); 2] =
        [(d2, &TABLE2_2D), (d3, &TABLE2_3D)];
    for (detail, ids) in sides {
        for &id in ids {
            if !detail.scenario.workload.entries.iter().any(|e| e.stencil == id) {
                continue;
            }
            if let Some(r) =
                best_for_benchmark(&detail.result, &detail.scenario.workload, id, band)
            {
                rows.push(SensitivityRow {
                    stencil: r.stencil,
                    n_sm: r.n_sm,
                    n_v: r.n_v,
                    m_sm_kb: r.m_sm_kb,
                    area_mm2: r.area_mm2,
                    gflops: r.gflops,
                });
            }
        }
    }
    SensitivitySummary {
        band,
        rows,
        total_evals: d2.result.total_evals + d3.result.total_evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn direct_requests_answer_without_coordinators() {
        let mut s = Session::paper();
        let a = s.submit(&CodesignRequest::validate());
        match &a.response {
            CodesignResponse::Validate(v) => {
                assert!(v.cases > 0);
                assert!(v.mape_pct.is_finite());
            }
            other => panic!("unexpected response {}", other.kind()),
        }
        assert!(matches!(a.detail, ResponseDetail::Validation(_)));
        assert_eq!(s.partitions(), 0, "validate touches no memo partition");
    }

    #[test]
    fn malformed_scenario_yields_error_response() {
        let mut s = Session::paper();
        let bad = CodesignRequest::explore(
            ScenarioSpec::two_d().weighted(StencilId::Heat3D, 1.0),
        );
        let a = s.submit(&bad);
        match &a.response {
            CodesignResponse::Error(e) => {
                assert_eq!(e.request, "explore");
                assert!(e.message.contains("zero out"));
            }
            other => panic!("unexpected response {}", other.kind()),
        }
    }
}
