//! The typed request/response vocabulary of the session service — the one
//! public API surface of the crate.
//!
//! Every experiment the CLI, examples and benches used to hand-roll is a
//! [`CodesignRequest`] variant: full exploration, Pareto-front queries,
//! §V-B what-if re-weightings, Table II sensitivity, §V-D partial-codesign
//! tuning, model validation and solver-cost accounting. Requests are built
//! with builder-style constructors ([`ScenarioSpec`]), answered by a
//! [`crate::service::Session`], and carried over the versioned JSON wire
//! format of [`crate::service::wire`].

use crate::codesign::scenario::Scenario;
use crate::opt::problem::SolveOpts;
use crate::platform::registry::PlatformId;
use crate::platform::spec::PlatformSpec;
use crate::stencil::defs::{Stencil, StencilId};
use crate::stencil::spec::StencilSpec;
use crate::stencil::workload::Workload;
use crate::timemodel::citer::CIterTable;

/// Which workload family a scenario draws its program instances from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadClass {
    /// The four 2-D stencils over §IV-A's 16-size grid.
    TwoD,
    /// The two 3-D stencils over the cube grid.
    ThreeD,
    /// One stencil — preset, registered parametric family member, or fused
    /// chain — over its dimension-appropriate size grid.
    Single(StencilId),
}

impl WorkloadClass {
    pub fn name(&self) -> String {
        match self {
            WorkloadClass::TwoD => "2d".to_string(),
            WorkloadClass::ThreeD => "3d".to_string(),
            WorkloadClass::Single(id) => id.name().to_string(),
        }
    }

    /// Parse a class name: `2d`, `3d`, a preset stencil name, a parametric
    /// family name (`star3d:r2`), or a fused chain
    /// (`fuse:heat2d+laplacian2d:t4`). Unknown names error with the full
    /// list of valid presets and both grammars — the message the CLI's
    /// `--class`/`--stencil` and the wire decoder surface.
    pub fn parse(s: &str) -> anyhow::Result<WorkloadClass> {
        match s {
            "2d" => Ok(WorkloadClass::TwoD),
            "3d" => Ok(WorkloadClass::ThreeD),
            other => match Stencil::by_name_err(other) {
                Ok(st) => Ok(WorkloadClass::Single(st.id)),
                Err(msg) => anyhow::bail!("{msg} (or a workload class: 2d, 3d)"),
            },
        }
    }
}

/// A serializable scenario description — what a request carries instead of a
/// materialized [`Scenario`]. Construction is builder-style; the session
/// materializes it late, so request-level filtering (e.g. `explore --class`)
/// never pays for scenarios it discards.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    /// Display name (derived from the modifiers when `None`).
    pub name: Option<String>,
    pub class: WorkloadClass,
    /// Hardware baseline to evaluate on; `None` = the session's default
    /// platform (itself defaulting to `maxwell`). Registered platforms only
    /// — untrusted names resolve through `Platform::by_name_err` first.
    pub platform: Option<PlatformId>,
    /// Keep every `stride`-th workload entry and shrink to the small space.
    pub quick_stride: Option<usize>,
    /// Total-silicon budget; tighter budgets enumerate a subset of the same
    /// grid, so a warm session answers them without new inner solves.
    pub area_budget_mm2: Option<f64>,
    /// Per-stencil weights (§V-B re-weighting). Empty = uniform; when
    /// non-empty, stencils not listed weigh zero.
    pub stencil_weights: Vec<(StencilId, f64)>,
    pub threads: Option<usize>,
    pub citer: CIterTable,
    pub solve_opts: SolveOpts,
}

impl ScenarioSpec {
    pub fn new(class: WorkloadClass) -> ScenarioSpec {
        ScenarioSpec {
            name: None,
            class,
            platform: None,
            quick_stride: None,
            area_budget_mm2: None,
            stencil_weights: Vec::new(),
            threads: None,
            citer: CIterTable::paper(),
            solve_opts: SolveOpts::default(),
        }
    }

    pub fn two_d() -> ScenarioSpec {
        ScenarioSpec::new(WorkloadClass::TwoD)
    }

    pub fn three_d() -> ScenarioSpec {
        ScenarioSpec::new(WorkloadClass::ThreeD)
    }

    pub fn single(id: StencilId) -> ScenarioSpec {
        ScenarioSpec::new(WorkloadClass::Single(id))
    }

    /// A single-stencil scenario over a parametric family member, registering
    /// the spec on construction.
    ///
    /// ```no_run
    /// use codesign::service::ScenarioSpec;
    /// use codesign::stencil::spec::{Dim, StencilSpec};
    ///
    /// let spec = ScenarioSpec::parametric(StencilSpec::star(Dim::D3, 2));
    /// assert_eq!(spec.scenario_name(), "star3d:r2");
    /// ```
    pub fn parametric(spec: StencilSpec) -> ScenarioSpec {
        ScenarioSpec::single(spec.register())
    }

    /// A single-stencil scenario over a fused chain, registering the chain's
    /// derived characterization on construction.
    ///
    /// ```no_run
    /// use codesign::service::ScenarioSpec;
    /// use codesign::stencil::spec::FusedChain;
    ///
    /// let chain = FusedChain::parse("fuse:heat2d+laplacian2d:t4").unwrap();
    /// assert_eq!(ScenarioSpec::fused(&chain).scenario_name(),
    ///            "fuse:heat2d+laplacian2d:t4");
    /// ```
    pub fn fused(chain: &crate::stencil::spec::FusedChain) -> ScenarioSpec {
        ScenarioSpec::single(chain.register())
    }

    pub fn named(mut self, name: &str) -> ScenarioSpec {
        self.name = Some(name.to_string());
        self
    }

    /// Evaluate on a specific registered platform instead of the session
    /// default.
    pub fn on_platform(mut self, id: PlatformId) -> ScenarioSpec {
        self.platform = Some(id);
        self
    }

    pub fn quick(mut self, stride: usize) -> ScenarioSpec {
        self.quick_stride = Some(stride.max(1));
        self
    }

    pub fn with_area_budget(mut self, mm2: f64) -> ScenarioSpec {
        self.area_budget_mm2 = Some(mm2);
        self
    }

    /// Add one stencil's weight (replaces an earlier weight for the same
    /// stencil). Any stencil never weighted is excluded once weights exist.
    pub fn weighted(mut self, id: StencilId, weight: f64) -> ScenarioSpec {
        self.stencil_weights.retain(|(s, _)| *s != id);
        self.stencil_weights.push((id, weight));
        self
    }

    pub fn with_weights(mut self, weights: Vec<(StencilId, f64)>) -> ScenarioSpec {
        self.stencil_weights = weights;
        self
    }

    pub fn with_threads(mut self, threads: usize) -> ScenarioSpec {
        self.threads = Some(threads.max(1));
        self
    }

    pub fn with_citer(mut self, citer: CIterTable) -> ScenarioSpec {
        self.citer = citer;
        self
    }

    pub fn with_solve_opts(mut self, opts: SolveOpts) -> ScenarioSpec {
        self.solve_opts = opts;
        self
    }

    /// The display name this spec materializes under.
    pub fn scenario_name(&self) -> String {
        if let Some(n) = &self.name {
            return n.clone();
        }
        let mut n = self.class.name();
        if let Some(p) = self.platform {
            n.push_str(&format!("@{}", p.name()));
        }
        if !self.stencil_weights.is_empty() {
            n.push_str("-reweighted");
        }
        if let Some(b) = self.area_budget_mm2 {
            n.push_str(&format!("-b{b:.0}"));
        }
        n
    }

    /// Materialize the scenario this spec describes, on `platform` (the
    /// resolution of this spec's `platform` field against the session
    /// default — see `Session::platform_for`). The platform supplies the
    /// enumeration bounds; its models bind at the coordinator. Fails
    /// (instead of panicking downstream) when the weight vector zeroes out
    /// every kept workload entry.
    pub fn to_scenario(&self, platform: &PlatformSpec) -> anyhow::Result<Scenario> {
        let mut sc = match self.class {
            WorkloadClass::TwoD => Scenario::paper_2d(),
            WorkloadClass::ThreeD => Scenario::paper_3d(),
            WorkloadClass::Single(id) => {
                let mut s = if Stencil::get(id).is_3d() {
                    Scenario::paper_3d()
                } else {
                    Scenario::paper_2d()
                };
                s.workload = Workload::single(id);
                s
            }
        };
        if let Some(stride) = self.quick_stride {
            sc = Scenario::quick(sc, stride);
        }
        // The platform supplies the enumeration bounds (quick runs clamp
        // them to the historical small grid, which `Scenario::quick`
        // hard-codes); the area budget below then tightens the ceiling.
        sc.space = match self.quick_stride {
            Some(_) => platform.space.shrunk(),
            None => platform.space,
        };
        if !self.stencil_weights.is_empty() {
            for (id, w) in &self.stencil_weights {
                anyhow::ensure!(
                    w.is_finite() && *w >= 0.0,
                    "weight for {} must be finite and non-negative (got {w})",
                    id.name()
                );
            }
            let weight_of = |id: StencilId| {
                self.stencil_weights
                    .iter()
                    .find(|(s, _)| *s == id)
                    .map(|(_, w)| *w)
                    .unwrap_or(0.0)
            };
            let total: f64 = sc.workload.entries.iter().map(|e| weight_of(e.stencil)).sum();
            anyhow::ensure!(
                total > 0.0,
                "stencil weights zero out every workload entry of scenario '{}'",
                self.scenario_name()
            );
            sc.workload = sc.workload.reweighted(|e| weight_of(e.stencil));
        }
        if let Some(b) = self.area_budget_mm2 {
            sc = sc.with_area_budget(b);
        }
        if let Some(t) = self.threads {
            sc = sc.with_threads(t);
        }
        sc.name = self.scenario_name();
        sc.citer = self.citer.clone();
        sc.solve_opts = self.solve_opts.clone();
        Ok(sc)
    }
}

/// §V-D partial-codesign tuning request: pin any subset of
/// {n_SM, n_V, M_SM} and search the rest under an area budget.
#[derive(Clone, Debug, PartialEq)]
pub struct TuneRequest {
    pub budget_mm2: f64,
    pub n_sm: Option<u32>,
    pub n_v: Option<u32>,
    pub m_sm_kb: Option<f64>,
    /// Single-benchmark workload; `None` = the uniform 2-D mix.
    pub stencil: Option<StencilId>,
    /// Hardware baseline to tune on; `None` = the session default.
    pub platform: Option<PlatformId>,
    pub threads: Option<usize>,
    pub citer: CIterTable,
    pub solve_opts: SolveOpts,
}

impl TuneRequest {
    pub fn new(budget_mm2: f64) -> TuneRequest {
        TuneRequest {
            budget_mm2,
            n_sm: None,
            n_v: None,
            m_sm_kb: None,
            stencil: None,
            platform: None,
            threads: None,
            citer: CIterTable::paper(),
            solve_opts: SolveOpts::default(),
        }
    }

    /// Tune on a specific registered platform instead of the session
    /// default.
    pub fn on_platform(mut self, id: PlatformId) -> TuneRequest {
        self.platform = Some(id);
        self
    }

    pub fn pin_n_sm(mut self, v: u32) -> TuneRequest {
        self.n_sm = Some(v);
        self
    }

    pub fn pin_n_v(mut self, v: u32) -> TuneRequest {
        self.n_v = Some(v);
        self
    }

    pub fn pin_m_sm_kb(mut self, v: f64) -> TuneRequest {
        self.m_sm_kb = Some(v);
        self
    }

    pub fn for_stencil(mut self, id: StencilId) -> TuneRequest {
        self.stencil = Some(id);
        self
    }

    pub fn with_threads(mut self, threads: usize) -> TuneRequest {
        self.threads = Some(threads.max(1));
        self
    }
}

/// One typed request — the single entry point every experiment goes through.
#[derive(Clone, Debug, PartialEq)]
pub enum CodesignRequest {
    /// Full DSE over one scenario: point cloud, Pareto front, references,
    /// improvement statistics (Fig 3 / Fig 4's input).
    Explore { scenario: ScenarioSpec },
    /// Pareto front only — the cheap production query.
    Pareto { scenario: ScenarioSpec },
    /// Tri-objective (area × perf × energy) Pareto front: the energy
    /// subsystem's production query, answered by the coordinator's
    /// 3-D-gated sweep (`run_pareto_energy_gated`).
    ParetoEnergy { scenario: ScenarioSpec },
    /// §V-B what-if: the base scenario under new per-stencil weights. Over a
    /// warm session this is pure re-aggregation — no new inner solves.
    WhatIf { scenario: ScenarioSpec, weights: Vec<(StencilId, f64)> },
    /// Table II: per-benchmark optimal architectures within an area band.
    Sensitivity {
        scenario_2d: ScenarioSpec,
        scenario_3d: ScenarioSpec,
        area_band: (f64, f64),
    },
    /// §V-D partial codesign under pinned parameters.
    Tune(TuneRequest),
    /// E10: time model vs the cycle-approximate simulator.
    Validate,
    /// E8: inner-solver cost vs the joint-annealing baseline.
    SolverCost { anneal_iters: u64, citer: CIterTable },
}

impl CodesignRequest {
    pub fn explore(scenario: ScenarioSpec) -> CodesignRequest {
        CodesignRequest::Explore { scenario }
    }

    pub fn pareto(scenario: ScenarioSpec) -> CodesignRequest {
        CodesignRequest::Pareto { scenario }
    }

    pub fn pareto_energy(scenario: ScenarioSpec) -> CodesignRequest {
        CodesignRequest::ParetoEnergy { scenario }
    }

    pub fn what_if(scenario: ScenarioSpec, weights: Vec<(StencilId, f64)>) -> CodesignRequest {
        CodesignRequest::WhatIf { scenario, weights }
    }

    pub fn sensitivity(
        scenario_2d: ScenarioSpec,
        scenario_3d: ScenarioSpec,
        area_band: (f64, f64),
    ) -> CodesignRequest {
        CodesignRequest::Sensitivity { scenario_2d, scenario_3d, area_band }
    }

    pub fn tune(request: TuneRequest) -> CodesignRequest {
        CodesignRequest::Tune(request)
    }

    pub fn validate() -> CodesignRequest {
        CodesignRequest::Validate
    }

    pub fn solver_cost(anneal_iters: u64) -> CodesignRequest {
        CodesignRequest::SolverCost { anneal_iters, citer: CIterTable::paper() }
    }

    /// The platform this request names, if any (`None` = the serving
    /// session's default). Sensitivity requests report the 2-D scenario's
    /// platform first and the 3-D one second; all other variants have at
    /// most one.
    pub fn platforms(&self) -> (Option<PlatformId>, Option<PlatformId>) {
        match self {
            CodesignRequest::Explore { scenario }
            | CodesignRequest::Pareto { scenario }
            | CodesignRequest::ParetoEnergy { scenario }
            | CodesignRequest::WhatIf { scenario, .. } => (scenario.platform, None),
            CodesignRequest::Sensitivity { scenario_2d, scenario_3d, .. } => {
                (scenario_2d.platform, scenario_3d.platform)
            }
            CodesignRequest::Tune(t) => (t.platform, None),
            CodesignRequest::Validate | CodesignRequest::SolverCost { .. } => (None, None),
        }
    }

    /// Wire-level type tag (also used in error responses).
    pub fn kind(&self) -> &'static str {
        match self {
            CodesignRequest::Explore { .. } => "explore",
            CodesignRequest::Pareto { .. } => "pareto",
            CodesignRequest::ParetoEnergy { .. } => "pareto_energy",
            CodesignRequest::WhatIf { .. } => "what_if",
            CodesignRequest::Sensitivity { .. } => "sensitivity",
            CodesignRequest::Tune(_) => "tune",
            CodesignRequest::Validate => "validate",
            CodesignRequest::SolverCost { .. } => "solver_cost",
        }
    }
}

// ---------------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------------

/// One solved design, wire-sized (the full per-entry software parameters stay
/// in the session; see [`crate::service::ResponseDetail`]).
#[derive(Clone, Debug, PartialEq)]
pub struct DesignSummary {
    pub n_sm: u32,
    pub n_v: u32,
    pub m_sm_kb: f64,
    pub area_mm2: f64,
    pub gflops: f64,
    pub seconds: f64,
}

impl DesignSummary {
    /// Short human-readable identifier matching `HwParams::label` for the
    /// cache-less candidates the service explores.
    pub fn label(&self) -> String {
        format!("{}sm x {}v, {}kB shm, cacheless", self.n_sm, self.n_v, self.m_sm_kb)
    }
}

/// A reference (stock) architecture evaluated under the same models.
#[derive(Clone, Debug, PartialEq)]
pub struct ReferenceSummary {
    pub name: String,
    pub area_mm2: f64,
    pub published_area_mm2: f64,
    pub gflops: f64,
    /// Best same-area optimized design vs this reference, percent. `None`
    /// when no feasible design fits under the reference's area (kept
    /// NaN-free so derived equality and the wire format stay exact).
    pub improvement_pct: Option<f64>,
}

/// What an Explore / WhatIf request answers with.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSummary {
    pub scenario: String,
    /// Feasible design points evaluated.
    pub designs: usize,
    pub infeasible: usize,
    /// Highest-throughput feasible design.
    pub best: Option<DesignSummary>,
    /// The Pareto front, area-ascending.
    pub pareto: Vec<DesignSummary>,
    pub references: Vec<ReferenceSummary>,
    pub total_evals: u64,
}

/// What a Pareto request answers with.
#[derive(Clone, Debug, PartialEq)]
pub struct ParetoSummary {
    pub scenario: String,
    pub designs: usize,
    pub infeasible: usize,
    pub pareto: Vec<DesignSummary>,
    pub total_evals: u64,
    /// Design points answered from certified bounds without solving
    /// (pruning telemetry; 0 on the batch/`--no-prune` path and on files
    /// written before wire schema v4).
    pub bounded_out: u64,
}

/// One tri-objective front member: a [`DesignSummary`] plus the energy
/// axis.
#[derive(Clone, Debug, PartialEq)]
pub struct EnergyDesignSummary {
    pub n_sm: u32,
    pub n_v: u32,
    pub m_sm_kb: f64,
    pub area_mm2: f64,
    pub gflops: f64,
    pub seconds: f64,
    /// Workload-average power, W.
    pub power_w: f64,
    /// Workload energy, J per sweep-unit.
    pub energy_j: f64,
}

impl EnergyDesignSummary {
    /// Short human-readable identifier, matching [`DesignSummary::label`].
    pub fn label(&self) -> String {
        format!("{}sm x {}v, {}kB shm, cacheless", self.n_sm, self.n_v, self.m_sm_kb)
    }
}

/// What a ParetoEnergy request answers with.
#[derive(Clone, Debug, PartialEq)]
pub struct ParetoEnergySummary {
    pub scenario: String,
    pub designs: usize,
    pub infeasible: usize,
    /// The tri-objective front, enumeration-ordered.
    pub pareto: Vec<EnergyDesignSummary>,
    pub total_evals: u64,
    /// Design points answered from certified 3-D bounds without solving
    /// (pruning telemetry; 0 on the `--no-prune` path).
    pub bounded_out: u64,
}

/// One Table II row.
#[derive(Clone, Debug, PartialEq)]
pub struct SensitivityRow {
    pub stencil: StencilId,
    pub n_sm: u32,
    pub n_v: u32,
    pub m_sm_kb: f64,
    pub area_mm2: f64,
    pub gflops: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct SensitivitySummary {
    pub band: (f64, f64),
    pub rows: Vec<SensitivityRow>,
    pub total_evals: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct TuneSummary {
    pub budget_mm2: f64,
    /// Area-feasible candidates examined.
    pub candidates: usize,
    /// `None` when no candidate fits the budget with a feasible tiling.
    pub best: Option<DesignSummary>,
    pub total_evals: u64,
    /// Candidates answered from certified objective bounds without a model
    /// evaluation (pruning telemetry; 0 on the `--no-prune` path and on
    /// files written before wire schema v4).
    pub candidates_pruned: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ValidateSummary {
    pub cases: usize,
    pub mape_pct: f64,
    pub kendall_tau: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct SolverCostSummary {
    pub anneal_iters: u64,
    /// The generated report's text summary (timings are machine-dependent;
    /// the structured CSVs stay with the in-process report detail).
    pub summary: String,
}

/// A request that could not be answered (malformed spec, infeasible weights,
/// …) — carried on the wire instead of tearing the batch down.
#[derive(Clone, Debug, PartialEq)]
pub struct ErrorInfo {
    /// The failing request's type tag.
    pub request: String,
    pub message: String,
}

/// One typed response, variant-matched to its request.
#[derive(Clone, Debug, PartialEq)]
pub enum CodesignResponse {
    Explore(ScenarioSummary),
    Pareto(ParetoSummary),
    ParetoEnergy(ParetoEnergySummary),
    WhatIf(ScenarioSummary),
    Sensitivity(SensitivitySummary),
    Tune(TuneSummary),
    Validate(ValidateSummary),
    SolverCost(SolverCostSummary),
    Error(ErrorInfo),
}

impl CodesignResponse {
    pub fn kind(&self) -> &'static str {
        match self {
            CodesignResponse::Explore(_) => "explore",
            CodesignResponse::Pareto(_) => "pareto",
            CodesignResponse::ParetoEnergy(_) => "pareto_energy",
            CodesignResponse::WhatIf(_) => "what_if",
            CodesignResponse::Sensitivity(_) => "sensitivity",
            CodesignResponse::Tune(_) => "tune",
            CodesignResponse::Validate(_) => "validate",
            CodesignResponse::SolverCost(_) => "solver_cost",
            CodesignResponse::Error(_) => "error",
        }
    }

    pub fn is_error(&self) -> bool {
        matches!(self, CodesignResponse::Error(_))
    }

    /// The scenario summary behind an Explore or WhatIf response.
    pub fn scenario_summary(&self) -> Option<&ScenarioSummary> {
        match self {
            CodesignResponse::Explore(s) | CodesignResponse::WhatIf(s) => Some(s),
            _ => None,
        }
    }

    /// Total inner-solver model evaluations this response accounts for
    /// (attributed per answer; cached solutions shared across answers are
    /// counted by each answer that reads them, as everywhere else).
    pub fn total_evals(&self) -> u64 {
        match self {
            CodesignResponse::Explore(s) | CodesignResponse::WhatIf(s) => s.total_evals,
            CodesignResponse::Pareto(p) => p.total_evals,
            CodesignResponse::ParetoEnergy(p) => p.total_evals,
            CodesignResponse::Sensitivity(s) => s.total_evals,
            CodesignResponse::Tune(t) => t.total_evals,
            CodesignResponse::Validate(_)
            | CodesignResponse::SolverCost(_)
            | CodesignResponse::Error(_) => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::registry::Platform;

    #[test]
    fn spec_builders_materialize() {
        let sc = ScenarioSpec::two_d().quick(8).with_area_budget(300.0).to_scenario(Platform::default_spec()).unwrap();
        assert_eq!(sc.name, "2d-b300");
        assert_eq!(sc.workload.entries.len(), 8);
        assert_eq!(sc.space.max_area_mm2, 300.0);
        let named = ScenarioSpec::two_d().named("mine").to_scenario(Platform::default_spec()).unwrap();
        assert_eq!(named.name, "mine");
    }

    #[test]
    fn spec_weights_reweight_by_stencil() {
        let sc = ScenarioSpec::two_d()
            .weighted(StencilId::Jacobi2D, 1.0)
            .to_scenario(Platform::default_spec())
            .unwrap();
        let jac: f64 = sc
            .workload
            .entries
            .iter()
            .filter(|e| e.stencil == StencilId::Jacobi2D)
            .map(|e| e.weight)
            .sum();
        assert!((jac - 1.0).abs() < 1e-12);
        assert!((sc.workload.total_weight() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spec_negative_or_nonfinite_weights_rejected() {
        for bad in [-0.5, f64::NAN, f64::INFINITY] {
            let err = ScenarioSpec::two_d()
                .weighted(StencilId::Jacobi2D, 1.0)
                .weighted(StencilId::Heat2D, bad)
                .to_scenario(Platform::default_spec())
                .unwrap_err();
            assert!(format!("{err:#}").contains("non-negative"), "{bad}: {err:#}");
        }
    }

    #[test]
    fn spec_zero_weights_error_cleanly() {
        // 3-D stencil weights over a 2-D workload leave nothing.
        let err = ScenarioSpec::two_d()
            .weighted(StencilId::Heat3D, 1.0)
            .to_scenario(Platform::default_spec())
            .unwrap_err();
        assert!(format!("{err:#}").contains("zero out"));
    }

    #[test]
    fn single_class_uses_matching_space_dims() {
        let s2 = ScenarioSpec::single(StencilId::Heat2D).to_scenario(Platform::default_spec()).unwrap();
        assert!(s2.workload.entries.iter().all(|e| e.size.s3.is_none()));
        let s3 = ScenarioSpec::single(StencilId::Heat3D).to_scenario(Platform::default_spec()).unwrap();
        assert!(s3.workload.entries.iter().all(|e| e.size.s3.is_some()));
    }

    #[test]
    fn class_parse_covers_presets_and_families() {
        assert_eq!(WorkloadClass::parse("2d").unwrap(), WorkloadClass::TwoD);
        assert_eq!(WorkloadClass::parse("3d").unwrap(), WorkloadClass::ThreeD);
        assert_eq!(
            WorkloadClass::parse("heat3d").unwrap(),
            WorkloadClass::Single(StencilId::Heat3D)
        );
        let WorkloadClass::Single(id) = WorkloadClass::parse("star3d:r2").unwrap() else {
            panic!("family name must parse to Single");
        };
        assert_eq!(id.name(), "star3d:r2");
        let WorkloadClass::Single(id) =
            WorkloadClass::parse("fuse:heat2d+laplacian2d:t4").unwrap()
        else {
            panic!("chain name must parse to Single");
        };
        assert_eq!(id.name(), "fuse:heat2d+laplacian2d:t4");
        // The rejection lists every valid option, not a bare "unknown".
        let err = format!("{:#}", WorkloadClass::parse("warp5d").unwrap_err());
        for needle in ["jacobi2d", "heat3d", "star|box", "fuse:", "2d, 3d"] {
            assert!(err.contains(needle), "'{err}' should mention '{needle}'");
        }
    }

    #[test]
    fn fused_chain_materializes_dimension_matched_scenario() {
        use crate::stencil::spec::FusedChain;
        let chain = FusedChain::parse("fuse:heat3d+laplacian3d:t2").unwrap();
        let sc = ScenarioSpec::fused(&chain)
            .quick(3)
            .to_scenario(Platform::default_spec())
            .unwrap();
        assert_eq!(sc.name, "fuse:heat3d+laplacian3d:t2");
        assert!(sc.workload.entries.iter().all(|e| e.size.s3.is_some()));
    }

    #[test]
    fn parametric_spec_materializes_dimension_matched_scenario() {
        use crate::stencil::spec::{Dim, StencilSpec};
        let sc = ScenarioSpec::parametric(StencilSpec::star(Dim::D3, 2))
            .quick(3)
            .to_scenario(Platform::default_spec())
            .unwrap();
        assert_eq!(sc.name, "star3d:r2");
        assert!(sc.workload.entries.iter().all(|e| e.size.s3.is_some()));
    }

    #[test]
    fn request_kinds_are_stable() {
        assert_eq!(CodesignRequest::explore(ScenarioSpec::two_d()).kind(), "explore");
        assert_eq!(CodesignRequest::pareto_energy(ScenarioSpec::two_d()).kind(), "pareto_energy");
        assert_eq!(CodesignRequest::validate().kind(), "validate");
        assert_eq!(CodesignRequest::solver_cost(10).kind(), "solver_cost");
        assert_eq!(CodesignRequest::tune(TuneRequest::new(450.0)).kind(), "tune");
    }
}
