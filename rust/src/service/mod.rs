//! The session service — the crate's one public API.
//!
//! Three pieces:
//!
//! * [`request`] — the typed vocabulary: a [`CodesignRequest`] variant per
//!   experiment (Explore, Pareto, ParetoEnergy, WhatIf, Sensitivity, Tune,
//!   Validate, SolverCost), builder-style [`ScenarioSpec`] construction, and
//!   a typed [`CodesignResponse`] per variant.
//! * [`session`] — the persistent [`Session`]: owns the coordinators, keeps
//!   their memo caches warm across calls, and auto-partitions each submission
//!   into compatible batch groups by (platform fingerprint, C_iter, solver
//!   options) so mixed request sets batch instead of being rejected.
//! * [`wire`] — the versioned JSON wire format: bit-exact request/response
//!   round-trips and the `{"schema": 6, …}` file envelopes behind
//!   `codesign serve --requests` (older files still decode; v2 added
//!   parametric stencil-family names like `star3d:r2` everywhere a stencil
//!   name is accepted, v3 optional `platform` names like
//!   `maxwell:bw20:clk1.4` on scenario specs and tune requests, v4 pruning
//!   controls/telemetry, v5 `scalar_eval`, v6 the `pareto_energy` request
//!   plus per-design energy telemetry).
//!
//! ```no_run
//! use codesign::service::{CodesignRequest, ScenarioSpec, Session};
//!
//! let mut session = Session::paper();
//! let first = session.submit(&CodesignRequest::explore(ScenarioSpec::two_d()));
//! // A follow-up over the same grid is answered almost entirely from cache.
//! let again = session.submit(&CodesignRequest::explore(ScenarioSpec::two_d()));
//! assert_eq!(first.response, again.response);
//! ```

pub mod request;
pub mod session;
pub mod wire;

pub use request::{
    CodesignRequest, CodesignResponse, DesignSummary, EnergyDesignSummary, ErrorInfo,
    ParetoEnergySummary, ParetoSummary, ReferenceSummary, ScenarioSpec, ScenarioSummary,
    SensitivityRow, SensitivitySummary, SolverCostSummary, TuneRequest, TuneSummary,
    ValidateSummary, WorkloadClass,
};
pub use session::{
    PartitionSnapshot, ResponseDetail, ScenarioDetail, Session, SessionAnswer, SubmitReport,
};
pub use wire::{
    decode_requests, decode_responses, encode_requests, encode_responses, request_from_json,
    request_to_json, response_from_json, response_to_json, MIN_SCHEMA_VERSION, SCHEMA_VERSION,
};
