//! Versioned JSON wire format for the session service.
//!
//! Every [`CodesignRequest`] / [`CodesignResponse`] variant encodes to a
//! `{"type": …}`-tagged object and decodes back **bit-exactly** (floats ride
//! Rust's shortest-round-trip formatting; non-finite values encode as
//! `null` and decode as NaN). Request and response files share one envelope,
//! `{"schema": 7, "requests"|"responses": […]}`; an unknown schema version is
//! a clean error, never a guess.
//!
//! **Schema history.** Each version is a strict superset of its predecessor
//! (older files still decode):
//!
//! * **v2** — every field where v1 accepted a stencil name (`class`,
//!   `stencil`, weights and `citer` entries) also accepts a parametric
//!   family name like `star3d:r2` or `box2d:r1:f20` (the canonical
//!   [`StencilSpec`](crate::stencil::spec::StencilSpec) grammar), which
//!   registers the family member on decode; `citer` tables may carry
//!   entries beyond the six presets.
//! * **v3** — scenario specs and tune requests gain an optional `platform`
//!   field carrying a platform name: a preset (`maxwell`, `maxwell+`,
//!   `maxwell-nocache`) or an override name like `maxwell:bw20:clk1.4` (the
//!   canonical [`PlatformSpec`](crate::platform::PlatformSpec) grammar),
//!   registered on decode. Absent or `null` means the serving session's
//!   default platform — so v1/v2 files decode unchanged and resolve to
//!   `maxwell`.
//! * **v4** — bound-and-prune: solver options gain an optional `prune`
//!   boolean (absent = `true`, the default path; `--no-prune` writes
//!   `false`), and Pareto / Tune responses gain optional pruning-telemetry
//!   counters (`bounded_out`, `candidates_pruned`; absent = 0). Older files
//!   decode unchanged.
//! * **v5** — batched evaluation: solver options gain an optional
//!   `scalar_eval` boolean (absent = `false`, the batched SoA default;
//!   `--scalar-eval` writes `true` to route the legacy point-at-a-time
//!   loop). The two paths answer bit-identically, so the field only selects
//!   *how* — and partitions memo stores. Older files decode unchanged.
//! * **v6** — the energy objective: a `pareto_energy` request (same scenario
//!   payload as `pareto`) asking for the tri-objective (area, performance,
//!   energy) front, and its response whose designs carry two extra fields,
//!   `power_w` and `energy_j`. No existing field changed meaning, so v1–v5
//!   files decode unchanged.
//! * **v7** — fused chains: every stencil-name field additionally accepts a
//!   fused-chain name `fuse:<stage>(+<stage>)*[:t<1-8>]` (the canonical
//!   [`FusedChain`](crate::stencil::spec::FusedChain) grammar, e.g.
//!   `fuse:heat2d+laplacian2d:t4`), which registers the chain's derived
//!   characterization on decode. Purely a wider name grammar — no envelope
//!   or field changed shape, so v1–v6 files decode unchanged.
//!
//! Encoding emits canonical names, so specs round-trip bit-exactly through
//! their name.
//!
//! # Examples
//!
//! ```no_run
//! use codesign::service::{wire, CodesignRequest, ScenarioSpec};
//!
//! let requests = vec![CodesignRequest::explore(ScenarioSpec::two_d())];
//! let text = wire::encode_requests(&requests).to_string_pretty();
//! assert_eq!(wire::decode_requests(&text).unwrap(), requests);
//! ```

use crate::opt::problem::SolveOpts;
use crate::platform::registry::{Platform, PlatformId};
use crate::service::request::{
    CodesignRequest, CodesignResponse, DesignSummary, EnergyDesignSummary, ErrorInfo,
    ParetoEnergySummary, ParetoSummary, ReferenceSummary, ScenarioSpec, ScenarioSummary,
    SensitivityRow, SensitivitySummary, SolverCostSummary, TuneRequest, TuneSummary,
    ValidateSummary, WorkloadClass,
};
use crate::stencil::defs::{Stencil, StencilId};
use crate::timemodel::citer::CIterTable;
use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, ensure, Result};

/// The wire schema this build emits.
pub const SCHEMA_VERSION: u64 = 7;

/// The oldest schema this build still accepts (each version is additive).
pub const MIN_SCHEMA_VERSION: u64 = 1;

// ---------------------------------------------------------------------------
// Field helpers
// ---------------------------------------------------------------------------

fn field<'a>(obj: &'a Json, key: &str) -> Result<&'a Json> {
    obj.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
}

/// Finite numbers as-is; NaN/∞ as null (JSON has no non-finite literals).
fn fnum(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

fn get_f64(obj: &Json, key: &str) -> Result<f64> {
    match field(obj, key)? {
        Json::Num(x) => Ok(*x),
        Json::Null => Ok(f64::NAN),
        _ => bail!("field '{key}' must be a number"),
    }
}

fn get_u64(obj: &Json, key: &str) -> Result<u64> {
    let x = get_f64(obj, key)?;
    ensure!(x.is_finite() && x >= 0.0, "field '{key}' must be a non-negative integer");
    Ok(x as u64)
}

fn get_usize(obj: &Json, key: &str) -> Result<usize> {
    Ok(get_u64(obj, key)? as usize)
}

fn get_bool(obj: &Json, key: &str) -> Result<bool> {
    match field(obj, key)? {
        Json::Bool(b) => Ok(*b),
        _ => bail!("field '{key}' must be a boolean"),
    }
}

fn get_str<'a>(obj: &'a Json, key: &str) -> Result<&'a str> {
    field(obj, key)?.as_str().ok_or_else(|| anyhow!("field '{key}' must be a string"))
}

/// Absent or null → `None`.
fn get_opt_f64(obj: &Json, key: &str) -> Result<Option<f64>> {
    match obj.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Num(x)) => Ok(Some(*x)),
        _ => bail!("field '{key}' must be a number or null"),
    }
}

fn get_opt_u64(obj: &Json, key: &str) -> Result<Option<u64>> {
    match get_opt_f64(obj, key)? {
        None => Ok(None),
        Some(x) => {
            ensure!(x.is_finite() && x >= 0.0, "field '{key}' must be a non-negative integer");
            Ok(Some(x as u64))
        }
    }
}

fn get_opt_str<'a>(obj: &'a Json, key: &str) -> Result<Option<&'a str>> {
    match obj.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Str(s)) => Ok(Some(s.as_str())),
        _ => bail!("field '{key}' must be a string or null"),
    }
}

fn opt_num(v: Option<f64>) -> Json {
    v.map(fnum).unwrap_or(Json::Null)
}

fn opt_unum(v: Option<u64>) -> Json {
    v.map(|x| Json::Num(x as f64)).unwrap_or(Json::Null)
}

// ---------------------------------------------------------------------------
// Shared pieces
// ---------------------------------------------------------------------------

/// A stencil name on the wire: a preset or a parametric family name (v2),
/// registered on decode. Unknown names list the valid options.
fn stencil_from_json(j: &Json) -> Result<StencilId> {
    let s = j.as_str().ok_or_else(|| anyhow!("stencil must be a string"))?;
    Stencil::by_name_err(s).map(|st| st.id).map_err(|msg| anyhow!("{msg}"))
}

fn weights_to_json(w: &[(StencilId, f64)]) -> Json {
    Json::Arr(
        w.iter()
            .map(|(id, x)| {
                Json::obj(vec![("stencil", Json::str(id.name())), ("weight", fnum(*x))])
            })
            .collect(),
    )
}

fn weights_from_json(j: &Json) -> Result<Vec<(StencilId, f64)>> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("weights must be an array"))?;
    arr.iter()
        .map(|item| Ok((stencil_from_json(field(item, "stencil")?)?, get_f64(item, "weight")?)))
        .collect()
}

/// Encode a `C_iter` table as its entry list. Public beyond the wire: the
/// sweep-artifact shards (`crate::artifact`) persist partition provenance
/// through these exact codecs, so a table round-trips identically whether it
/// travels in a request file or a warm-start artifact.
pub fn citer_to_json(t: &CIterTable) -> Json {
    // The table's own entries, in table order: the paper table serializes
    // exactly as under schema v1 (the six presets), measured tables carry
    // any parametric extras too (v2).
    Json::Arr(
        t.entries()
            .iter()
            .map(|&(id, cycles)| {
                Json::obj(vec![
                    ("stencil", Json::str(id.name())),
                    ("cycles", fnum(cycles)),
                ])
            })
            .collect(),
    )
}

/// Absent / null → paper-mode defaults, so hand-written request files can
/// omit the table.
fn opt_citer_from_json(obj: &Json, key: &str) -> Result<CIterTable> {
    match obj.get(key) {
        None | Some(Json::Null) => Ok(CIterTable::paper()),
        Some(c) => citer_from_json(c),
    }
}

/// Decode a `C_iter` table (see [`citer_to_json`]).
pub fn citer_from_json(j: &Json) -> Result<CIterTable> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("citer must be an array"))?;
    let mut pairs = Vec::with_capacity(arr.len());
    for item in arr {
        let id = stencil_from_json(field(item, "stencil")?)?;
        let cycles = get_f64(item, "cycles")?;
        ensure!(cycles.is_finite() && cycles > 0.0, "C_iter for {} must be positive", id.name());
        pairs.push((id, cycles));
    }
    Ok(CIterTable::with_measured(&pairs))
}

/// Encode solver options. Public beyond the wire for the same reason as
/// [`citer_to_json`]: artifact shards persist their prune partition through
/// this codec.
pub fn solve_opts_to_json(o: &SolveOpts) -> Json {
    Json::obj(vec![
        ("all_k", Json::Bool(o.all_k)),
        ("refine", Json::Bool(o.refine)),
        ("max_t_t", Json::Num(o.max_t_t as f64)),
        ("prune", Json::Bool(o.prune)),
        ("scalar_eval", Json::Bool(o.scalar_eval)),
    ])
}

/// Absent / null `prune` → `true` (the default path), so pre-v4 files keep
/// decoding to the options they always meant.
fn get_opt_bool_or(obj: &Json, key: &str, default: bool) -> Result<bool> {
    match obj.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(Json::Bool(b)) => Ok(*b),
        _ => bail!("field '{key}' must be a boolean or null"),
    }
}

/// Decode solver options (see [`solve_opts_to_json`]).
pub fn solve_opts_from_json(j: &Json) -> Result<SolveOpts> {
    Ok(SolveOpts {
        all_k: get_bool(j, "all_k")?,
        refine: get_bool(j, "refine")?,
        max_t_t: get_u64(j, "max_t_t")?,
        prune: get_opt_bool_or(j, "prune", true)?,
        // Absent / null → the batched default (pre-v5 files keep meaning
        // what they always meant: answers are path-independent).
        scalar_eval: get_opt_bool_or(j, "scalar_eval", false)?,
    })
}

/// Absent / null → default solver options.
fn opt_solve_opts_from_json(obj: &Json, key: &str) -> Result<SolveOpts> {
    match obj.get(key) {
        None | Some(Json::Null) => Ok(SolveOpts::default()),
        Some(o) => solve_opts_from_json(o),
    }
}

/// Absent / null → no re-weighting.
fn opt_weights_from_json(obj: &Json, key: &str) -> Result<Vec<(StencilId, f64)>> {
    match obj.get(key) {
        None | Some(Json::Null) => Ok(Vec::new()),
        Some(w) => weights_from_json(w),
    }
}

/// A platform name on the wire (v3): a preset or an override name
/// (`maxwell:bw20`), registered on decode. Absent or null → the serving
/// session's default. Unknown names list the presets and the grammar.
fn opt_platform_from_json(obj: &Json, key: &str) -> Result<Option<PlatformId>> {
    match obj.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Str(s)) => {
            Platform::by_name_err(s).map(|p| Some(p.id)).map_err(|msg| anyhow!("{msg}"))
        }
        _ => bail!("field '{key}' must be a platform name or null"),
    }
}

fn opt_platform_to_json(p: Option<PlatformId>) -> Json {
    p.map(|id| Json::str(id.name())).unwrap_or(Json::Null)
}

fn class_to_json(c: WorkloadClass) -> Json {
    Json::str(c.name())
}

fn class_from_json(j: &Json) -> Result<WorkloadClass> {
    let s = j.as_str().ok_or_else(|| anyhow!("class must be a string"))?;
    WorkloadClass::parse(s)
}

pub fn spec_to_json(s: &ScenarioSpec) -> Json {
    Json::obj(vec![
        ("name", s.name.as_deref().map(Json::str).unwrap_or(Json::Null)),
        ("class", class_to_json(s.class)),
        ("platform", opt_platform_to_json(s.platform)),
        ("quick_stride", opt_unum(s.quick_stride.map(|v| v as u64))),
        ("area_budget_mm2", opt_num(s.area_budget_mm2)),
        ("weights", weights_to_json(&s.stencil_weights)),
        ("threads", opt_unum(s.threads.map(|v| v as u64))),
        ("citer", citer_to_json(&s.citer)),
        ("solve", solve_opts_to_json(&s.solve_opts)),
    ])
}

pub fn spec_from_json(j: &Json) -> Result<ScenarioSpec> {
    Ok(ScenarioSpec {
        name: get_opt_str(j, "name")?.map(str::to_string),
        class: class_from_json(field(j, "class")?)?,
        platform: opt_platform_from_json(j, "platform")?,
        quick_stride: get_opt_u64(j, "quick_stride")?.map(|v| v as usize),
        area_budget_mm2: get_opt_f64(j, "area_budget_mm2")?,
        stencil_weights: opt_weights_from_json(j, "weights")?,
        threads: get_opt_u64(j, "threads")?.map(|v| v as usize),
        citer: opt_citer_from_json(j, "citer")?,
        solve_opts: opt_solve_opts_from_json(j, "solve")?,
    })
}

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

pub fn request_to_json(r: &CodesignRequest) -> Json {
    let tag = ("type", Json::str(r.kind()));
    match r {
        CodesignRequest::Explore { scenario }
        | CodesignRequest::Pareto { scenario }
        | CodesignRequest::ParetoEnergy { scenario } => {
            Json::obj(vec![tag, ("scenario", spec_to_json(scenario))])
        }
        CodesignRequest::WhatIf { scenario, weights } => Json::obj(vec![
            tag,
            ("scenario", spec_to_json(scenario)),
            ("weights", weights_to_json(weights)),
        ]),
        CodesignRequest::Sensitivity { scenario_2d, scenario_3d, area_band } => Json::obj(vec![
            tag,
            ("scenario_2d", spec_to_json(scenario_2d)),
            ("scenario_3d", spec_to_json(scenario_3d)),
            ("area_band", Json::Arr(vec![fnum(area_band.0), fnum(area_band.1)])),
        ]),
        CodesignRequest::Tune(t) => Json::obj(vec![
            tag,
            ("budget_mm2", fnum(t.budget_mm2)),
            ("n_sm", opt_unum(t.n_sm.map(|v| v as u64))),
            ("n_v", opt_unum(t.n_v.map(|v| v as u64))),
            ("m_sm_kb", opt_num(t.m_sm_kb)),
            ("stencil", t.stencil.map(|id| Json::str(id.name())).unwrap_or(Json::Null)),
            ("platform", opt_platform_to_json(t.platform)),
            ("threads", opt_unum(t.threads.map(|v| v as u64))),
            ("citer", citer_to_json(&t.citer)),
            ("solve", solve_opts_to_json(&t.solve_opts)),
        ]),
        CodesignRequest::Validate => Json::obj(vec![tag]),
        CodesignRequest::SolverCost { anneal_iters, citer } => Json::obj(vec![
            tag,
            ("anneal_iters", Json::Num(*anneal_iters as f64)),
            ("citer", citer_to_json(citer)),
        ]),
    }
}

pub fn request_from_json(j: &Json) -> Result<CodesignRequest> {
    match get_str(j, "type")? {
        "explore" => Ok(CodesignRequest::Explore { scenario: spec_from_json(field(j, "scenario")?)? }),
        "pareto" => Ok(CodesignRequest::Pareto { scenario: spec_from_json(field(j, "scenario")?)? }),
        "pareto_energy" => Ok(CodesignRequest::ParetoEnergy {
            scenario: spec_from_json(field(j, "scenario")?)?,
        }),
        "what_if" => Ok(CodesignRequest::WhatIf {
            scenario: spec_from_json(field(j, "scenario")?)?,
            weights: weights_from_json(field(j, "weights")?)?,
        }),
        "sensitivity" => {
            let band = field(j, "area_band")?
                .as_arr()
                .filter(|a| a.len() == 2)
                .ok_or_else(|| anyhow!("area_band must be a [lo, hi] array"))?;
            let lo = band[0].as_f64().ok_or_else(|| anyhow!("area_band entries must be numbers"))?;
            let hi = band[1].as_f64().ok_or_else(|| anyhow!("area_band entries must be numbers"))?;
            Ok(CodesignRequest::Sensitivity {
                scenario_2d: spec_from_json(field(j, "scenario_2d")?)?,
                scenario_3d: spec_from_json(field(j, "scenario_3d")?)?,
                area_band: (lo, hi),
            })
        }
        "tune" => Ok(CodesignRequest::Tune(TuneRequest {
            budget_mm2: get_f64(j, "budget_mm2")?,
            n_sm: get_opt_u64(j, "n_sm")?.map(|v| v as u32),
            n_v: get_opt_u64(j, "n_v")?.map(|v| v as u32),
            m_sm_kb: get_opt_f64(j, "m_sm_kb")?,
            stencil: match j.get("stencil") {
                None | Some(Json::Null) => None,
                Some(s) => Some(stencil_from_json(s)?),
            },
            platform: opt_platform_from_json(j, "platform")?,
            threads: get_opt_u64(j, "threads")?.map(|v| v as usize),
            citer: opt_citer_from_json(j, "citer")?,
            solve_opts: opt_solve_opts_from_json(j, "solve")?,
        })),
        "validate" => Ok(CodesignRequest::Validate),
        "solver_cost" => Ok(CodesignRequest::SolverCost {
            anneal_iters: get_u64(j, "anneal_iters")?,
            citer: opt_citer_from_json(j, "citer")?,
        }),
        other => bail!("unknown request type '{other}'"),
    }
}

// ---------------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------------

fn design_to_json(d: &DesignSummary) -> Json {
    Json::obj(vec![
        ("n_sm", Json::Num(d.n_sm as f64)),
        ("n_v", Json::Num(d.n_v as f64)),
        ("m_sm_kb", fnum(d.m_sm_kb)),
        ("area_mm2", fnum(d.area_mm2)),
        ("gflops", fnum(d.gflops)),
        ("seconds", fnum(d.seconds)),
    ])
}

fn design_from_json(j: &Json) -> Result<DesignSummary> {
    Ok(DesignSummary {
        n_sm: get_u64(j, "n_sm")? as u32,
        n_v: get_u64(j, "n_v")? as u32,
        m_sm_kb: get_f64(j, "m_sm_kb")?,
        area_mm2: get_f64(j, "area_mm2")?,
        gflops: get_f64(j, "gflops")?,
        seconds: get_f64(j, "seconds")?,
    })
}

fn energy_design_to_json(d: &EnergyDesignSummary) -> Json {
    Json::obj(vec![
        ("n_sm", Json::Num(d.n_sm as f64)),
        ("n_v", Json::Num(d.n_v as f64)),
        ("m_sm_kb", fnum(d.m_sm_kb)),
        ("area_mm2", fnum(d.area_mm2)),
        ("gflops", fnum(d.gflops)),
        ("seconds", fnum(d.seconds)),
        ("power_w", fnum(d.power_w)),
        ("energy_j", fnum(d.energy_j)),
    ])
}

fn energy_design_from_json(j: &Json) -> Result<EnergyDesignSummary> {
    Ok(EnergyDesignSummary {
        n_sm: get_u64(j, "n_sm")? as u32,
        n_v: get_u64(j, "n_v")? as u32,
        m_sm_kb: get_f64(j, "m_sm_kb")?,
        area_mm2: get_f64(j, "area_mm2")?,
        gflops: get_f64(j, "gflops")?,
        seconds: get_f64(j, "seconds")?,
        power_w: get_f64(j, "power_w")?,
        energy_j: get_f64(j, "energy_j")?,
    })
}

fn reference_to_json(r: &ReferenceSummary) -> Json {
    Json::obj(vec![
        ("name", Json::str(r.name.as_str())),
        ("area_mm2", fnum(r.area_mm2)),
        ("published_area_mm2", fnum(r.published_area_mm2)),
        ("gflops", fnum(r.gflops)),
        ("improvement_pct", opt_num(r.improvement_pct)),
    ])
}

fn reference_from_json(j: &Json) -> Result<ReferenceSummary> {
    Ok(ReferenceSummary {
        name: get_str(j, "name")?.to_string(),
        area_mm2: get_f64(j, "area_mm2")?,
        published_area_mm2: get_f64(j, "published_area_mm2")?,
        gflops: get_f64(j, "gflops")?,
        improvement_pct: get_opt_f64(j, "improvement_pct")?,
    })
}

fn scenario_summary_to_json(s: &ScenarioSummary) -> Json {
    Json::obj(vec![
        ("scenario", Json::str(s.scenario.as_str())),
        ("designs", Json::Num(s.designs as f64)),
        ("infeasible", Json::Num(s.infeasible as f64)),
        ("best", s.best.as_ref().map(design_to_json).unwrap_or(Json::Null)),
        ("pareto", Json::Arr(s.pareto.iter().map(design_to_json).collect())),
        ("references", Json::Arr(s.references.iter().map(reference_to_json).collect())),
        ("total_evals", Json::Num(s.total_evals as f64)),
    ])
}

fn scenario_summary_from_json(j: &Json) -> Result<ScenarioSummary> {
    let best = match field(j, "best")? {
        Json::Null => None,
        d => Some(design_from_json(d)?),
    };
    let pareto = field(j, "pareto")?
        .as_arr()
        .ok_or_else(|| anyhow!("pareto must be an array"))?
        .iter()
        .map(design_from_json)
        .collect::<Result<Vec<_>>>()?;
    let references = field(j, "references")?
        .as_arr()
        .ok_or_else(|| anyhow!("references must be an array"))?
        .iter()
        .map(reference_from_json)
        .collect::<Result<Vec<_>>>()?;
    Ok(ScenarioSummary {
        scenario: get_str(j, "scenario")?.to_string(),
        designs: get_usize(j, "designs")?,
        infeasible: get_usize(j, "infeasible")?,
        best,
        pareto,
        references,
        total_evals: get_u64(j, "total_evals")?,
    })
}

pub fn response_to_json(r: &CodesignResponse) -> Json {
    let tag = ("type", Json::str(r.kind()));
    match r {
        CodesignResponse::Explore(s) | CodesignResponse::WhatIf(s) => {
            let mut obj = scenario_summary_to_json(s);
            if let Json::Obj(m) = &mut obj {
                m.insert("type".to_string(), Json::str(r.kind()));
            }
            obj
        }
        CodesignResponse::Pareto(p) => Json::obj(vec![
            tag,
            ("scenario", Json::str(p.scenario.as_str())),
            ("designs", Json::Num(p.designs as f64)),
            ("infeasible", Json::Num(p.infeasible as f64)),
            ("pareto", Json::Arr(p.pareto.iter().map(design_to_json).collect())),
            ("total_evals", Json::Num(p.total_evals as f64)),
            ("bounded_out", Json::Num(p.bounded_out as f64)),
        ]),
        CodesignResponse::ParetoEnergy(p) => Json::obj(vec![
            tag,
            ("scenario", Json::str(p.scenario.as_str())),
            ("designs", Json::Num(p.designs as f64)),
            ("infeasible", Json::Num(p.infeasible as f64)),
            ("pareto", Json::Arr(p.pareto.iter().map(energy_design_to_json).collect())),
            ("total_evals", Json::Num(p.total_evals as f64)),
            ("bounded_out", Json::Num(p.bounded_out as f64)),
        ]),
        CodesignResponse::Sensitivity(s) => Json::obj(vec![
            tag,
            ("band", Json::Arr(vec![fnum(s.band.0), fnum(s.band.1)])),
            (
                "rows",
                Json::Arr(
                    s.rows
                        .iter()
                        .map(|row| {
                            Json::obj(vec![
                                ("stencil", Json::str(row.stencil.name())),
                                ("n_sm", Json::Num(row.n_sm as f64)),
                                ("n_v", Json::Num(row.n_v as f64)),
                                ("m_sm_kb", fnum(row.m_sm_kb)),
                                ("area_mm2", fnum(row.area_mm2)),
                                ("gflops", fnum(row.gflops)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("total_evals", Json::Num(s.total_evals as f64)),
        ]),
        CodesignResponse::Tune(t) => Json::obj(vec![
            tag,
            ("budget_mm2", fnum(t.budget_mm2)),
            ("candidates", Json::Num(t.candidates as f64)),
            ("best", t.best.as_ref().map(design_to_json).unwrap_or(Json::Null)),
            ("total_evals", Json::Num(t.total_evals as f64)),
            ("candidates_pruned", Json::Num(t.candidates_pruned as f64)),
        ]),
        CodesignResponse::Validate(v) => Json::obj(vec![
            tag,
            ("cases", Json::Num(v.cases as f64)),
            ("mape_pct", fnum(v.mape_pct)),
            ("kendall_tau", fnum(v.kendall_tau)),
        ]),
        CodesignResponse::SolverCost(s) => Json::obj(vec![
            tag,
            ("anneal_iters", Json::Num(s.anneal_iters as f64)),
            ("summary", Json::str(s.summary.as_str())),
        ]),
        CodesignResponse::Error(e) => Json::obj(vec![
            tag,
            ("request", Json::str(e.request.as_str())),
            ("message", Json::str(e.message.as_str())),
        ]),
    }
}

pub fn response_from_json(j: &Json) -> Result<CodesignResponse> {
    match get_str(j, "type")? {
        "explore" => Ok(CodesignResponse::Explore(scenario_summary_from_json(j)?)),
        "what_if" => Ok(CodesignResponse::WhatIf(scenario_summary_from_json(j)?)),
        "pareto" => Ok(CodesignResponse::Pareto(ParetoSummary {
            scenario: get_str(j, "scenario")?.to_string(),
            designs: get_usize(j, "designs")?,
            infeasible: get_usize(j, "infeasible")?,
            pareto: field(j, "pareto")?
                .as_arr()
                .ok_or_else(|| anyhow!("pareto must be an array"))?
                .iter()
                .map(design_from_json)
                .collect::<Result<Vec<_>>>()?,
            total_evals: get_u64(j, "total_evals")?,
            // v4 telemetry: absent on older files = no gating happened.
            bounded_out: get_opt_u64(j, "bounded_out")?.unwrap_or(0),
        })),
        "pareto_energy" => Ok(CodesignResponse::ParetoEnergy(ParetoEnergySummary {
            scenario: get_str(j, "scenario")?.to_string(),
            designs: get_usize(j, "designs")?,
            infeasible: get_usize(j, "infeasible")?,
            pareto: field(j, "pareto")?
                .as_arr()
                .ok_or_else(|| anyhow!("pareto must be an array"))?
                .iter()
                .map(energy_design_from_json)
                .collect::<Result<Vec<_>>>()?,
            total_evals: get_u64(j, "total_evals")?,
            bounded_out: get_opt_u64(j, "bounded_out")?.unwrap_or(0),
        })),
        "sensitivity" => {
            let band = field(j, "band")?
                .as_arr()
                .filter(|a| a.len() == 2)
                .ok_or_else(|| anyhow!("band must be a [lo, hi] array"))?;
            let lo = band[0].as_f64().ok_or_else(|| anyhow!("band entries must be numbers"))?;
            let hi = band[1].as_f64().ok_or_else(|| anyhow!("band entries must be numbers"))?;
            let rows = field(j, "rows")?
                .as_arr()
                .ok_or_else(|| anyhow!("rows must be an array"))?
                .iter()
                .map(|row| {
                    Ok(SensitivityRow {
                        stencil: stencil_from_json(field(row, "stencil")?)?,
                        n_sm: get_u64(row, "n_sm")? as u32,
                        n_v: get_u64(row, "n_v")? as u32,
                        m_sm_kb: get_f64(row, "m_sm_kb")?,
                        area_mm2: get_f64(row, "area_mm2")?,
                        gflops: get_f64(row, "gflops")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(CodesignResponse::Sensitivity(SensitivitySummary {
                band: (lo, hi),
                rows,
                total_evals: get_u64(j, "total_evals")?,
            }))
        }
        "tune" => Ok(CodesignResponse::Tune(TuneSummary {
            budget_mm2: get_f64(j, "budget_mm2")?,
            candidates: get_usize(j, "candidates")?,
            best: match field(j, "best")? {
                Json::Null => None,
                d => Some(design_from_json(d)?),
            },
            total_evals: get_u64(j, "total_evals")?,
            // v4 telemetry: absent on older files = nothing was pruned.
            candidates_pruned: get_opt_u64(j, "candidates_pruned")?.unwrap_or(0),
        })),
        "validate" => Ok(CodesignResponse::Validate(ValidateSummary {
            cases: get_usize(j, "cases")?,
            mape_pct: get_f64(j, "mape_pct")?,
            kendall_tau: get_f64(j, "kendall_tau")?,
        })),
        "solver_cost" => Ok(CodesignResponse::SolverCost(SolverCostSummary {
            anneal_iters: get_u64(j, "anneal_iters")?,
            summary: get_str(j, "summary")?.to_string(),
        })),
        "error" => Ok(CodesignResponse::Error(ErrorInfo {
            request: get_str(j, "request")?.to_string(),
            message: get_str(j, "message")?.to_string(),
        })),
        other => bail!("unknown response type '{other}'"),
    }
}

// ---------------------------------------------------------------------------
// Envelopes
// ---------------------------------------------------------------------------

fn check_schema(j: &Json) -> Result<()> {
    let v = field(j, "schema")?
        .as_f64()
        .ok_or_else(|| anyhow!("schema version must be a number"))?;
    ensure!(
        v.fract() == 0.0 && v >= MIN_SCHEMA_VERSION as f64 && v <= SCHEMA_VERSION as f64,
        "unsupported schema version {v} (this build speaks \
         {MIN_SCHEMA_VERSION}..={SCHEMA_VERSION})"
    );
    Ok(())
}

/// `{"schema": 7, "requests": […]}`.
pub fn encode_requests(requests: &[CodesignRequest]) -> Json {
    Json::obj(vec![
        ("schema", Json::Num(SCHEMA_VERSION as f64)),
        ("requests", Json::Arr(requests.iter().map(request_to_json).collect())),
    ])
}

pub fn decode_requests(text: &str) -> Result<Vec<CodesignRequest>> {
    let j = parse(text).map_err(|e| anyhow!("{e}"))?;
    check_schema(&j)?;
    let arr = field(&j, "requests")?
        .as_arr()
        .ok_or_else(|| anyhow!("'requests' must be an array"))?;
    arr.iter()
        .enumerate()
        .map(|(i, r)| request_from_json(r).map_err(|e| anyhow!("request {i}: {e:#}")))
        .collect()
}

/// `{"schema": 7, "responses": […]}`.
pub fn encode_responses(responses: &[CodesignResponse]) -> Json {
    Json::obj(vec![
        ("schema", Json::Num(SCHEMA_VERSION as f64)),
        ("responses", Json::Arr(responses.iter().map(response_to_json).collect())),
    ])
}

pub fn decode_responses(text: &str) -> Result<Vec<CodesignResponse>> {
    let j = parse(text).map_err(|e| anyhow!("{e}"))?;
    check_schema(&j)?;
    let arr = field(&j, "responses")?
        .as_arr()
        .ok_or_else(|| anyhow!("'responses' must be an array"))?;
    arr.iter()
        .enumerate()
        .map(|(i, r)| response_from_json(r).map_err(|e| anyhow!("response {i}: {e:#}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_roundtrip_defaults() {
        let spec = ScenarioSpec::two_d();
        let back = spec_from_json(&spec_to_json(&spec)).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn envelope_schema_enforced() {
        assert!(decode_requests(r#"{"schema": 99, "requests": []}"#).is_err());
        assert!(decode_requests(r#"{"schema": 0, "requests": []}"#).is_err());
        assert!(decode_requests(r#"{"schema": 1.5, "requests": []}"#).is_err(),
            "fractional versions are not a thing");
        assert!(decode_requests(r#"{"requests": []}"#).is_err());
        assert!(decode_requests("not json").is_err());
        // The emitted version and every legacy envelope decode.
        assert!(decode_requests(r#"{"schema": 7, "requests": []}"#).unwrap().is_empty());
        assert!(decode_requests(r#"{"schema": 6, "requests": []}"#).unwrap().is_empty());
        assert!(decode_requests(r#"{"schema": 5, "requests": []}"#).unwrap().is_empty());
        assert!(decode_requests(r#"{"schema": 4, "requests": []}"#).unwrap().is_empty());
        assert!(decode_requests(r#"{"schema": 3, "requests": []}"#).unwrap().is_empty());
        assert!(decode_requests(r#"{"schema": 2, "requests": []}"#).unwrap().is_empty());
        assert!(decode_requests(r#"{"schema": 1, "requests": []}"#).unwrap().is_empty());
    }

    #[test]
    fn pareto_energy_request_and_response_roundtrip() {
        let req = CodesignRequest::pareto_energy(ScenarioSpec::two_d().quick());
        let back = request_from_json(&request_to_json(&req)).unwrap();
        assert_eq!(req, back);
        let resp = CodesignResponse::ParetoEnergy(ParetoEnergySummary {
            scenario: "paper-2d".to_string(),
            designs: 700,
            infeasible: 3,
            pareto: vec![EnergyDesignSummary {
                n_sm: 16,
                n_v: 128,
                m_sm_kb: 96.0,
                area_mm2: 398.25,
                gflops: 1234.5,
                seconds: 0.0625,
                power_w: 151.75,
                energy_j: 9.484375,
            }],
            total_evals: 123456,
            bounded_out: 42,
        });
        let back = response_from_json(&response_to_json(&resp)).unwrap();
        assert_eq!(resp, back);
        // Telemetry absent on the wire decodes to 0, like the 2-D front's.
        let mut j = response_to_json(&resp);
        if let Json::Obj(m) = &mut j {
            m.remove("bounded_out");
        }
        match response_from_json(&j).unwrap() {
            CodesignResponse::ParetoEnergy(p) => assert_eq!(p.bounded_out, 0),
            other => panic!("unexpected response {}", other.kind()),
        }
    }

    #[test]
    fn parametric_class_names_decode_and_roundtrip() {
        let spec = ScenarioSpec::parametric(
            crate::stencil::spec::StencilSpec::star(crate::stencil::spec::Dim::D3, 2),
        );
        let back = spec_from_json(&spec_to_json(&spec)).unwrap();
        assert_eq!(spec, back);
        // Hand-written v2 field values parse too, and bad ones list options.
        let j = parse(r#"{"class": "box2d:r1:f20"}"#).unwrap();
        let s = spec_from_json(&j).unwrap();
        assert_eq!(s.class.name(), "box2d:r1:f20");
        let j = parse(r#"{"class": "pentagon2d:r1"}"#).unwrap();
        let err = format!("{:#}", spec_from_json(&j).unwrap_err());
        assert!(err.contains("jacobi2d"), "{err}");
    }

    #[test]
    fn fused_chain_names_decode_and_roundtrip() {
        // v7: stencil-name fields accept fused-chain names; encoding emits
        // the canonical spelling, so chains round-trip through their name.
        let chain = crate::stencil::spec::FusedChain::parse("fuse:heat2d+laplacian2d:t4")
            .unwrap();
        let spec = ScenarioSpec::single(chain.register());
        let back = spec_from_json(&spec_to_json(&spec)).unwrap();
        assert_eq!(spec, back);
        let j = parse(r#"{"class": "fuse:jacobi2d+heat2d:t2"}"#).unwrap();
        let s = spec_from_json(&j).unwrap();
        assert_eq!(s.class.name(), "fuse:jacobi2d+heat2d:t2");
        // A bad chain reports the chain-specific failure plus the grammar.
        let j = parse(r#"{"class": "fuse:heat2d+heat3d:t2"}"#).unwrap();
        let err = format!("{:#}", spec_from_json(&j).unwrap_err());
        assert!(err.contains("share one dimensionality"), "{err}");
        assert!(err.contains("fuse:"), "{err}");
    }

    #[test]
    fn unknown_tags_rejected() {
        let j = parse(r#"{"type": "frobnicate"}"#).unwrap();
        assert!(request_from_json(&j).is_err());
        assert!(response_from_json(&j).is_err());
    }

    #[test]
    fn platform_names_decode_and_roundtrip() {
        // Explicit presets and override names round-trip through the name.
        let spec = ScenarioSpec::two_d().on_platform(PlatformId::MaxwellPlus);
        let back = spec_from_json(&spec_to_json(&spec)).unwrap();
        assert_eq!(spec, back);
        let j = parse(r#"{"class": "2d", "platform": "maxwell:bw20:clk1.4"}"#).unwrap();
        let s = spec_from_json(&j).unwrap();
        assert_eq!(s.platform.unwrap().name(), "maxwell:clk1.4:bw20");
        // v2-style specs without a platform field decode to None (session
        // default = maxwell), as do explicit nulls.
        let j = parse(r#"{"class": "2d"}"#).unwrap();
        assert_eq!(spec_from_json(&j).unwrap().platform, None);
        let j = parse(r#"{"class": "2d", "platform": null}"#).unwrap();
        assert_eq!(spec_from_json(&j).unwrap().platform, None);
        // Unknown platforms list the presets and the override grammar.
        let j = parse(r#"{"class": "2d", "platform": "kepler"}"#).unwrap();
        let err = format!("{:#}", spec_from_json(&j).unwrap_err());
        for needle in ["maxwell", "maxwell+", "maxwell-nocache", "clk (GHz)"] {
            assert!(err.contains(needle), "'{err}' should mention '{needle}'");
        }
    }
}
