//! Hybrid hexagonal / classical tiling geometry (Grosser et al. [16]).
//!
//! The (time × S1) plane is covered by hexagonal tiles of time-height `t_T`
//! and base width `t_S1`, whose slanted edges follow the stencil's dependence
//! cone (slope σ). Hexagons come in two *phases* per time band; all tiles of
//! one phase are mutually independent (they form a wavefront and can run
//! concurrently), and phase B of a band depends on phase A. The remaining
//! space dimensions are tiled classically: S2 into strips of `t_S2` (mapped
//! to the threads of a block), and for 3-D stencils S3 into strips of `t_S3`.
//!
//! Every term is parametric in the stencil radius: σ = `Stencil::sigma` sets
//! the hexagon slope, the per-dimension halo (`2σ` cells per classical
//! dimension) and therefore the footprint/traffic of higher-order families —
//! nothing here assumes the paper's first-order σ = 1.

use crate::stencil::defs::Stencil;
use crate::stencil::workload::ProblemSize;

/// Software tile-size vector (the s-vector of the codesign problem).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TileSizes {
    /// Hexagon base width along S1 (integer ≥ 1, constraint (12)).
    pub t_s1: u64,
    /// Strip width along S2 = threads per block slice (multiple of 32,
    /// constraint (13)).
    pub t_s2: u64,
    /// Strip width along S3; `None` for 2-D stencils.
    pub t_s3: Option<u64>,
    /// Hexagon time height (even, constraint (15): hybrid-hexagonal tiling
    /// requires it).
    pub t_t: u64,
}

impl TileSizes {
    pub fn d2(t_s1: u64, t_s2: u64, t_t: u64) -> TileSizes {
        TileSizes { t_s1, t_s2, t_s3: None, t_t }
    }

    pub fn d3(t_s1: u64, t_s2: u64, t_s3: u64, t_t: u64) -> TileSizes {
        TileSizes { t_s1, t_s2, t_s3: Some(t_s3), t_t }
    }

    pub fn label(&self) -> String {
        match self.t_s3 {
            Some(s3) => format!("({},{},{},{})", self.t_s1, self.t_s2, s3, self.t_t),
            None => format!("({},{},{})", self.t_s1, self.t_s2, self.t_t),
        }
    }
}

/// Geometry of a tiling applied to one problem instance.
#[derive(Clone, Copy, Debug)]
pub struct TilingGeometry {
    /// Time bands: `ceil(T / t_T)`.
    pub n_bands: u64,
    /// Hexagonal tiles per band across S1 **per phase**.
    pub tiles_s1_per_phase: u64,
    /// Classical blocks across S2.
    pub blocks_s2: u64,
    /// Classical blocks across S3 (1 for 2-D).
    pub blocks_s3: u64,
    /// Points computed per (hexagon × S2×S3 strip) threadblock, averaged
    /// over the hexagon (its s1 extent varies with t).
    pub points_per_block: f64,
    /// Iterations each thread executes inside one block = hexagon area in
    /// the (t, s1) plane divided by… 1 thread per (s2[, s3]) column.
    pub iters_per_thread: f64,
    /// Threads per block.
    pub threads_per_block: u64,
}

impl TilingGeometry {
    /// Wavefronts in the whole computation: two phases per time band.
    pub fn n_wavefronts(&self) -> u64 {
        2 * self.n_bands
    }

    /// Independent threadblocks per wavefront (one phase of one band).
    pub fn blocks_per_wavefront(&self) -> u64 {
        self.tiles_s1_per_phase * self.blocks_s2 * self.blocks_s3
    }

    /// Total threadblocks launched.
    pub fn total_blocks(&self) -> u64 {
        self.n_wavefronts() * self.blocks_per_wavefront()
    }
}

/// Average s1-extent of a hexagonal tile: the base contributes `t_S1`, the
/// slanted edges add σ·(t_T − 1) on average over the tile's height.
pub fn hex_avg_width(t_s1: u64, t_t: u64, sigma: u32) -> f64 {
    t_s1 as f64 + sigma as f64 * (t_t as f64 - 1.0)
}

/// Maximum s1-extent of a hexagonal tile (at its widest row) — this is what
/// must be staged in shared memory, plus halo.
pub fn hex_max_width(t_s1: u64, t_t: u64, sigma: u32) -> f64 {
    t_s1 as f64 + 2.0 * sigma as f64 * (t_t as f64 - 1.0)
}

/// Points in the (t, s1) cross-section of one hexagon.
pub fn hex_area(t_s1: u64, t_t: u64, sigma: u32) -> f64 {
    t_t as f64 * hex_avg_width(t_s1, t_t, sigma)
}

/// The `t_S1`-invariant part of a tiling geometry: everything one
/// `(t_T, t_S2[, t_S3])` grid group of the inner solver shares across its
/// candidate hexagon widths. The group-batched solver computes this once per
/// group and completes it per `t_S1` lane via [`complete_geometry`];
/// [`geometry`] itself is the composition of the two, so both paths run the
/// identical expressions (the bit-identity argument in DESIGN.md §8).
#[derive(Clone, Copy, Debug)]
pub struct GroupGeometry {
    /// Time bands: `ceil(T / t_T)`.
    pub n_bands: u64,
    /// Classical blocks across S2.
    pub blocks_s2: u64,
    /// Classical blocks across S3 (1 for 2-D).
    pub blocks_s3: u64,
    /// Threads per block (`t_S2 · t_S3`).
    pub threads_per_block: u64,
}

/// Compute the `t_S1`-invariant geometry of one `(t_T, t_S2[, t_S3])` group.
/// Panics on a stencil/size/tile dimensionality mismatch, exactly as
/// [`geometry`] does (it is the same check, hoisted).
pub fn group_geometry(
    stencil: &Stencil,
    size: &ProblemSize,
    t_s2: u64,
    t_s3: Option<u64>,
    t_t: u64,
) -> GroupGeometry {
    let n_bands = div_ceil_f(size.t as f64, t_t as f64);
    let blocks_s2 = div_ceil_f(size.s2 as f64, t_s2 as f64);
    let blocks_s3 = match (stencil.is_3d(), size.s3, t_s3) {
        (true, Some(s3), Some(t_s3)) => div_ceil_f(s3 as f64, t_s3 as f64),
        (false, None, None) => 1,
        _ => panic!("dimensionality mismatch between stencil, size and tiles"),
    };
    GroupGeometry { n_bands, blocks_s2, blocks_s3, threads_per_block: t_s2 * t_s3.unwrap_or(1) }
}

/// Complete a [`GroupGeometry`] with the `t_S1`-dependent terms (average
/// hexagon width, per-phase tile count, hexagon area).
pub fn complete_geometry(
    stencil: &Stencil,
    size: &ProblemSize,
    t_s1: u64,
    t_t: u64,
    g: &GroupGeometry,
) -> TilingGeometry {
    let sigma = stencil.sigma;
    let avg_w = hex_avg_width(t_s1, t_t, sigma);
    let tiles_s1_per_phase = div_ceil_f(size.s1 as f64 + avg_w, 2.0 * avg_w);
    let area = hex_area(t_s1, t_t, sigma);
    TilingGeometry {
        n_bands: g.n_bands,
        tiles_s1_per_phase,
        blocks_s2: g.blocks_s2,
        blocks_s3: g.blocks_s3,
        points_per_block: area * g.threads_per_block as f64,
        iters_per_thread: area,
        threads_per_block: g.threads_per_block,
    }
}

/// Compute the tiling geometry of `tiles` applied to `(stencil, size)`.
///
/// A phase pair covers `2·avg_width` of S1 per band period, so each phase
/// contributes `ceil(S1 / (2·avg_width))` tiles (+1 boundary tile on the
/// phase whose hexagons straddle the band edge — folded into the ceil by
/// adding the half-period offset).
pub fn geometry(stencil: &Stencil, size: &ProblemSize, tiles: &TileSizes) -> TilingGeometry {
    let g = group_geometry(stencil, size, tiles.t_s2, tiles.t_s3, tiles.t_t);
    complete_geometry(stencil, size, tiles.t_s1, tiles.t_t, &g)
}

/// Shared-memory footprint of one threadblock, bytes: the hexagon's widest
/// row plus halo in every classical dimension, double-buffered across
/// `n_buffers` live arrays (constraint (9)'s `M_tile`).
pub fn tile_footprint_bytes(stencil: &Stencil, tiles: &TileSizes) -> f64 {
    let sigma = stencil.sigma as f64;
    let w1 = hex_max_width(tiles.t_s1, tiles.t_t, stencil.sigma) + 2.0 * sigma;
    let w2 = tiles.t_s2 as f64 + 2.0 * sigma;
    let w3 = tiles.t_s3.map(|s| s as f64 + 2.0 * sigma).unwrap_or(1.0);
    stencil.bytes_per_cell * stencil.n_buffers * w1 * w2 * w3
}

/// Global-memory traffic of one threadblock, bytes: stream the footprint in
/// and the computed face back out.
pub fn tile_traffic_bytes(stencil: &Stencil, tiles: &TileSizes) -> f64 {
    let in_bytes = tile_footprint_bytes(stencil, tiles) / stencil.n_buffers;
    let out_w1 = hex_avg_width(tiles.t_s1, tiles.t_t, stencil.sigma);
    let out_bytes = stencil.bytes_per_cell
        * out_w1
        * tiles.t_s2 as f64
        * tiles.t_s3.map(|s| s as f64).unwrap_or(1.0);
    in_bytes + out_bytes
}

fn div_ceil_f(a: f64, b: f64) -> u64 {
    (a / b).ceil() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::defs::{Stencil, StencilId};

    fn jac() -> &'static Stencil {
        Stencil::get(StencilId::Jacobi2D)
    }

    fn heat3d() -> &'static Stencil {
        Stencil::get(StencilId::Heat3D)
    }

    #[test]
    fn hex_geometry_basics() {
        assert_eq!(hex_avg_width(32, 1, 1), 32.0);
        assert_eq!(hex_avg_width(32, 9, 1), 40.0);
        assert_eq!(hex_max_width(32, 9, 1), 48.0);
        assert_eq!(hex_area(32, 9, 1), 360.0);
    }

    #[test]
    fn geometry_counts_cover_problem() {
        let size = ProblemSize::d2(4096, 1024);
        let tiles = TileSizes::d2(64, 128, 16);
        let g = geometry(jac(), &size, &tiles);
        // Tiles must (over-)cover the iteration space.
        let covered = g.total_blocks() as f64 * g.points_per_block;
        assert!(covered >= size.points(), "covered {covered} < {}", size.points());
        // …but not by more than the boundary slack (≈ one extra tile per
        // row/column of tiles, well under 2x for these sizes).
        assert!(covered < 2.0 * size.points());
        assert_eq!(g.n_wavefronts(), 2 * 64);
        assert_eq!(g.blocks_s3, 1);
        assert_eq!(g.threads_per_block, 128);
    }

    #[test]
    fn geometry_3d() {
        let size = ProblemSize::d3(256, 64);
        let tiles = TileSizes::d3(16, 32, 8, 8);
        let g = geometry(heat3d(), &size, &tiles);
        assert_eq!(g.blocks_s3, 32);
        assert_eq!(g.threads_per_block, 256);
        let covered = g.total_blocks() as f64 * g.points_per_block;
        assert!(covered >= size.points());
    }

    #[test]
    fn group_split_composes_to_geometry() {
        // group_geometry + complete_geometry must agree with the one-shot
        // geometry() for every field — the two are one implementation, so
        // any drift here is a refactor bug, not a tolerance question.
        let cases: [(&Stencil, ProblemSize, TileSizes); 3] = [
            (jac(), ProblemSize::d2(4096, 1024), TileSizes::d2(64, 128, 16)),
            (jac(), ProblemSize::d2(333, 77), TileSizes::d2(7, 32, 6)),
            (heat3d(), ProblemSize::d3(256, 64), TileSizes::d3(16, 32, 8, 8)),
        ];
        for (st, size, tiles) in cases {
            let whole = geometry(st, &size, &tiles);
            let g = group_geometry(st, &size, tiles.t_s2, tiles.t_s3, tiles.t_t);
            assert_eq!(g.n_bands, whole.n_bands);
            assert_eq!(g.blocks_s2, whole.blocks_s2);
            assert_eq!(g.blocks_s3, whole.blocks_s3);
            assert_eq!(g.threads_per_block, whole.threads_per_block);
            let done = complete_geometry(st, &size, tiles.t_s1, tiles.t_t, &g);
            assert_eq!(done.tiles_s1_per_phase, whole.tiles_s1_per_phase);
            assert_eq!(done.iters_per_thread.to_bits(), whole.iters_per_thread.to_bits());
            assert_eq!(done.points_per_block.to_bits(), whole.points_per_block.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "dimensionality mismatch")]
    fn dim_mismatch_panics() {
        let size = ProblemSize::d2(128, 64);
        let tiles = TileSizes::d3(8, 32, 8, 4);
        geometry(jac(), &size, &tiles);
    }

    #[test]
    fn footprint_grows_with_every_tile_dim() {
        let base = TileSizes::d2(32, 64, 8);
        let f0 = tile_footprint_bytes(jac(), &base);
        for t in [
            TileSizes::d2(64, 64, 8),
            TileSizes::d2(32, 128, 8),
            TileSizes::d2(32, 64, 16),
        ] {
            assert!(tile_footprint_bytes(jac(), &t) > f0);
        }
    }

    #[test]
    fn footprint_example_value() {
        // Jacobi2D, (32, 64, 8): w1 = 32+2*7+2 = 48, w2 = 66, 2 buffers, fp32.
        let f = tile_footprint_bytes(jac(), &TileSizes::d2(32, 64, 8));
        assert_eq!(f, 4.0 * 2.0 * 48.0 * 66.0);
    }

    #[test]
    fn traffic_less_than_two_footprints() {
        let t = TileSizes::d2(32, 64, 8);
        let traffic = tile_traffic_bytes(jac(), &t);
        assert!(traffic > 0.0);
        assert!(traffic < 2.0 * tile_footprint_bytes(jac(), &t));
    }

    #[test]
    fn radius_widens_halo_footprint_and_traffic() {
        // The σ-generalization: a radius-2 star must stage a wider hexagon
        // row and a deeper halo than its radius-1 sibling, at equal tiles.
        use crate::stencil::spec::{Dim, StencilSpec};
        let r1 = *Stencil::get(StencilSpec::star(Dim::D2, 1).register());
        let r2 = *Stencil::get(StencilSpec::star(Dim::D2, 2).register());
        let tiles = TileSizes::d2(32, 64, 8);
        assert_eq!(hex_max_width(32, 8, 2), 32.0 + 2.0 * 2.0 * 7.0);
        assert!(tile_footprint_bytes(&r2, &tiles) > tile_footprint_bytes(&r1, &tiles));
        assert!(tile_traffic_bytes(&r2, &tiles) > tile_traffic_bytes(&r1, &tiles));
        // Exact footprint: w1 = 32+2·2·7+2·2 = 64, w2 = 64+4 = 68, 2 buffers.
        assert_eq!(tile_footprint_bytes(&r2, &tiles), 4.0 * 2.0 * 64.0 * 68.0);
        // Geometry stays consistent for σ = 2: coverage still holds.
        let size = ProblemSize::d2(4096, 1024);
        let g = geometry(&r2, &size, &tiles);
        assert!(g.total_blocks() as f64 * g.points_per_block >= size.points());
    }

    #[test]
    fn bigger_time_tiles_amortize_traffic() {
        // Traffic per computed point must fall as t_T grows — the reuse
        // argument that makes time tiling worthwhile.
        let small = TileSizes::d2(64, 128, 2);
        let big = TileSizes::d2(64, 128, 32);
        let per_point = |t: &TileSizes| {
            let g = geometry(jac(), &ProblemSize::d2(4096, 1024), t);
            tile_traffic_bytes(jac(), t) / g.points_per_block
        };
        assert!(per_point(&big) < per_point(&small));
    }
}
