//! Analytical execution-time model `T_alg(p, h, s)` for hybrid-hexagonally
//! tiled stencils on GPU-like accelerators — the reconstruction of the
//! authors' PPoPP'17 model [27] described in DESIGN.md §5.
//!
//! The model is deliberately non-smooth: it keeps the floor/ceil wavefront
//! quantization, the `max` of compute vs memory phases and the occupancy
//! `min`s, because those non-convexities are exactly what makes the codesign
//! problem "non-linear optimization" (§IV-A) and what the inner solver
//! ([`crate::opt`]) must cope with.

pub mod batch;
pub mod citer;
pub mod machine;
pub mod talg;
pub mod tiling;

pub use batch::LaneBatch;
pub use citer::CIterTable;
pub use machine::MachineSpec;
pub use talg::{eval_lane, EvalInvariants, EvalLane, Infeasibility, SoftwareParams, TimeEstimate, TimeModel};
pub use tiling::TileSizes;
