//! Structure-of-arrays batched evaluation of `T_alg` (DESIGN.md §8).
//!
//! The inner solver's hot path evaluates every candidate `(t_S1, k)` lane of
//! one `(t_T, t_S2[, t_S3])` grid group under identical group context:
//! thread shape, band count and S2/S3 block grids are `t_S1`-invariant
//! ([`crate::timemodel::tiling::GroupGeometry`]), and the machine/instance constants are
//! invariant across the whole solve ([`talg::EvalInvariants`]). This module
//! holds the flat lane buffers that exploit that: the solver fills one
//! [`LaneBatch`] per group (fill phase), evaluates every lane through the
//! shared [`talg::eval_lane`] kernel in one branch-free loop over parallel
//! arrays (eval phase), and then scans the results in enumeration order
//! (scan phase, back in `opt::inner`).
//!
//! No explicit SIMD: the win is layout. Per-lane inputs live in parallel
//! `Vec<f64>`/`Vec<u32>` columns, the kernel has no data-dependent branches
//! (the `bound` label is a select), and the eval loop indexes all columns by
//! one counter — the shape auto-vectorizers and prefetchers like. Buffers
//! are allocated once per solve at a fixed capacity hint and reused
//! (`clear()` keeps the allocation), so the steady state is allocation-free
//! even in `all_k` mode where a group can carry thousands of lanes.
//!
//! **Bit-identity.** Every lane value is computed by the same kernel, from
//! the same hoisted invariants, in the same f64 expression order as the
//! scalar path ([`crate::timemodel::TimeModel::evaluate_pre`] is itself a
//! one-lane shim over [`talg::eval_lane`]) — so batching changes *when*
//! values are computed, never *what* they are. `integration_batch_eval.rs`
//! certifies this end to end against the `--scalar-eval` escape hatch.

use crate::timemodel::talg::{self, EvalInvariants, EvalLane, TimeEstimate};

/// Capacity hint for one group's lane buffers: the default solver visits at
/// most ~17 grid + ~96 wavefront `t_S1` candidates × ≤3 `k` candidates; the
/// `all_k` reference mode can reach 113 × 32 ≈ 3.6k lanes. Starting at 512
/// keeps the common case in one allocation and lets `all_k` grow once —
/// `Vec` growth is correctness-neutral, the capacity is purely a perf hint.
pub const LANE_CAPACITY_HINT: usize = 512;

/// SoA buffers for one group's candidate lanes, plus the evaluated results.
///
/// Parallel arrays: index `i` of every column describes lane `i`, pushed in
/// the solver's canonical enumeration order (`t_S1` grid then wavefront
/// extras, `k` candidates innermost) — the scan phase relies on that order
/// to reproduce the scalar path's strict-improvement incumbent trajectory.
#[derive(Debug, Default)]
pub struct LaneBatch {
    /// Hexagon base width of the lane's tile vector.
    pub t_s1: Vec<u64>,
    /// Hyperthreading factor.
    pub k: Vec<u32>,
    /// Hexagon area (iterations per thread) — `t_S1`-dependent.
    pub iters_per_thread: Vec<f64>,
    /// Global-memory traffic per block, bytes — `t_S1`-dependent.
    pub traffic: Vec<f64>,
    /// Blocks per wavefront as f64 — `t_S1`-dependent.
    pub blocks_per_wavefront: Vec<f64>,
    /// Shared-memory footprint per block, bytes — `t_S1`-dependent.
    pub m_tile: Vec<f64>,
    /// Evaluated estimates, filled by [`LaneBatch::evaluate`]; parallel to
    /// the input columns.
    pub est: Vec<TimeEstimate>,
}

impl LaneBatch {
    /// A batch whose columns start at `capacity` lanes each.
    pub fn with_capacity(capacity: usize) -> LaneBatch {
        LaneBatch {
            t_s1: Vec::with_capacity(capacity),
            k: Vec::with_capacity(capacity),
            iters_per_thread: Vec::with_capacity(capacity),
            traffic: Vec::with_capacity(capacity),
            blocks_per_wavefront: Vec::with_capacity(capacity),
            m_tile: Vec::with_capacity(capacity),
            est: Vec::with_capacity(capacity),
        }
    }

    /// Drop all lanes, keeping every allocation (the per-group reset).
    pub fn clear(&mut self) {
        self.t_s1.clear();
        self.k.clear();
        self.iters_per_thread.clear();
        self.traffic.clear();
        self.blocks_per_wavefront.clear();
        self.m_tile.clear();
        self.est.clear();
    }

    /// Lanes currently staged.
    pub fn len(&self) -> usize {
        self.t_s1.len()
    }

    /// True when no lanes are staged.
    pub fn is_empty(&self) -> bool {
        self.t_s1.is_empty()
    }

    /// Stage one candidate lane (fill phase).
    #[allow(clippy::too_many_arguments)]
    pub fn push(
        &mut self,
        t_s1: u64,
        k: u32,
        iters_per_thread: f64,
        traffic: f64,
        blocks_per_wavefront: f64,
        m_tile: f64,
    ) {
        self.t_s1.push(t_s1);
        self.k.push(k);
        self.iters_per_thread.push(iters_per_thread);
        self.traffic.push(traffic);
        self.blocks_per_wavefront.push(blocks_per_wavefront);
        self.m_tile.push(m_tile);
    }

    /// Eval phase: run the shared lane kernel across every staged lane in
    /// one flat loop. `threads_per_block` and `n_wavefronts` are the group
    /// scalars every lane shares; `inv` is the solve-level invariant set.
    /// Results land in [`LaneBatch::est`], parallel to the inputs.
    pub fn evaluate(&mut self, inv: &EvalInvariants, threads_per_block: u64, n_wavefronts: f64) {
        self.est.clear();
        let n = self.len();
        self.est.reserve(n);
        for i in 0..n {
            let lane = EvalLane {
                k: self.k[i],
                threads_per_block,
                iters_per_thread: self.iters_per_thread[i],
                traffic: self.traffic[i],
                blocks_per_wavefront: self.blocks_per_wavefront[i],
                n_wavefronts,
                m_tile: self.m_tile[i],
            };
            self.est.push(talg::eval_lane(inv, &lane));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::params::HwParams;
    use crate::stencil::defs::{Stencil, StencilId};
    use crate::stencil::workload::ProblemSize;
    use crate::timemodel::talg::{SoftwareParams, TimeModel};
    use crate::timemodel::tiling::{self, TileSizes};

    #[test]
    fn batch_matches_scalar_evaluate_bit_exactly() {
        // Fill a batch the way the solver does (group scalars hoisted, lane
        // columns per (t_S1, k)) and check every lane against the scalar
        // evaluate() — the contract the whole module exists to keep.
        let model = TimeModel::maxwell();
        let st = Stencil::get(StencilId::Jacobi2D);
        let hw = HwParams::gtx980();
        let size = ProblemSize::d2(4096, 1024);
        let (t_s2, t_s3, t_t) = (64u64, None, 8u64);
        let g = tiling::group_geometry(st, &size, t_s2, t_s3, t_t);
        let inv = model.invariants(st, &size, &hw);
        let mut batch = LaneBatch::with_capacity(8);
        let lanes: Vec<(u64, u32)> =
            vec![(1, 1), (1, 3), (16, 1), (16, 2), (32, 1), (32, 2), (48, 1)];
        for &(t_s1, k) in &lanes {
            let tiles = TileSizes { t_s1, t_s2, t_s3, t_t };
            let geo = tiling::complete_geometry(st, &size, t_s1, t_t, &g);
            batch.push(
                t_s1,
                k,
                geo.iters_per_thread,
                tiling::tile_traffic_bytes(st, &tiles),
                geo.blocks_per_wavefront() as f64,
                tiling::tile_footprint_bytes(st, &tiles),
            );
        }
        let n_wavefronts = 2 * g.n_bands;
        batch.evaluate(&inv, g.threads_per_block, n_wavefronts as f64);
        assert_eq!(batch.est.len(), lanes.len());
        for (i, &(t_s1, k)) in lanes.iter().enumerate() {
            let sw = SoftwareParams::new(TileSizes { t_s1, t_s2, t_s3, t_t }, k);
            let reference = model.evaluate(st, &size, &hw, &sw);
            assert_eq!(
                batch.est[i].seconds.to_bits(),
                reference.seconds.to_bits(),
                "lane {i} (t_s1={t_s1}, k={k})"
            );
            assert_eq!(batch.est[i].gflops.to_bits(), reference.gflops.to_bits());
            assert_eq!(batch.est[i].bound, reference.bound);
        }
    }

    #[test]
    fn clear_keeps_capacity() {
        let mut b = LaneBatch::with_capacity(LANE_CAPACITY_HINT);
        for i in 0..100u64 {
            b.push(i, 1, 1.0, 1.0, 1.0, 1.0);
        }
        assert_eq!(b.len(), 100);
        let cap = b.t_s1.capacity();
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.t_s1.capacity(), cap, "clear must keep the allocation");
    }
}
