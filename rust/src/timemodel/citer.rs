//! `C_iter` handling — the per-iteration, single-thread issue cost.
//!
//! §IV-B: *"in the execution time model we use a parameter C_iter, the
//! execution time of a single iteration on one thread. For optimal tile size
//! selection, we measured this parameter for the different stencils."* The
//! paper measured it on GTX 980 silicon; we carry
//!
//! * **paper mode** — the defaults stored on [`crate::stencil::defs::Stencil`],
//!   calibrated so the GTX 980-configured model lands on the paper's Fig 3 /
//!   Table II GFLOP/s scale, and
//! * **measured mode** — values measured by the PJRT runtime
//!   (`runtime::citer_measure`) running the real Pallas-built kernels on this
//!   machine's CPU backend, rescaled into model cycles.

use crate::stencil::defs::{Stencil, StencilId, ALL_STENCILS};
use crate::stencil::workload::Workload;

/// A per-stencil override table for `C_iter`. Stencils not listed — e.g.
/// freshly registered parametric family members — fall back to their own
/// registry default (`Stencil::c_iter_cycles`), so any table works with any
/// workload.
#[derive(Clone, Debug, PartialEq)]
pub struct CIterTable {
    entries: Vec<(StencilId, f64)>,
}

impl CIterTable {
    /// Paper-mode table (the defaults baked into [`ALL_STENCILS`]).
    pub fn paper() -> CIterTable {
        CIterTable {
            entries: ALL_STENCILS.iter().map(|s| (s.id, s.c_iter_cycles)).collect(),
        }
    }

    /// Build from measured (stencil, cycles) pairs; stencils not measured
    /// fall back to paper mode (presets) or their spec-derived default
    /// (parametric members). Measured pairs for non-preset stencils are
    /// appended.
    pub fn with_measured(pairs: &[(StencilId, f64)]) -> CIterTable {
        let mut t = CIterTable::paper();
        for &(id, c) in pairs {
            assert!(c > 0.0, "C_iter must be positive");
            match t.entries.iter_mut().find(|e| e.0 == id) {
                Some(e) => e.1 = c,
                None => t.entries.push((id, c)),
            }
        }
        t
    }

    /// Effective `C_iter` for `id`: the table entry, else the stencil's own
    /// registry default.
    pub fn get(&self, id: StencilId) -> f64 {
        self.entries
            .iter()
            .find(|e| e.0 == id)
            .map(|e| e.1)
            .unwrap_or_else(|| Stencil::get(id).c_iter_cycles)
    }

    /// The explicit (stencil, cycles) entries this table carries, in table
    /// order (what the wire format serializes).
    pub fn entries(&self) -> &[(StencilId, f64)] {
        &self.entries
    }

    /// A copy of `stencil` with this table's `C_iter` applied — what the
    /// optimizer feeds to the time model.
    pub fn apply(&self, stencil: &Stencil) -> Stencil {
        Stencil { c_iter_cycles: self.get(stencil.id), ..*stencil }
    }

    /// Characterize a workload's stencils under this table — one [`apply`]
    /// per entry, aligned with `workload.entries`. This is the **single**
    /// source of the stencils that cache keys are built from
    /// (`coordinator::cache::CacheKey` requires the effective `C_iter`);
    /// the batch engine's plan/serve phases and the session's tune path all
    /// call it so keys can never diverge.
    ///
    /// [`apply`]: CIterTable::apply
    pub fn characterize_workload(&self, workload: &Workload) -> Vec<Stencil> {
        workload.entries.iter().map(|e| self.apply(Stencil::get(e.stencil))).collect()
    }

    /// Uniformly scale every explicit entry (used to translate CPU-substrate
    /// measurements onto the model's GPU-cycle scale, anchored on one
    /// stencil's paper value — see `runtime::citer_measure`). Stencils not
    /// in the table keep their unscaled registry defaults.
    pub fn scaled(&self, factor: f64) -> CIterTable {
        assert!(factor > 0.0);
        CIterTable {
            entries: self.entries.iter().map(|&(id, c)| (id, c * factor)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table_covers_all_stencils() {
        let t = CIterTable::paper();
        for s in &ALL_STENCILS {
            assert!(t.get(s.id) > 0.0);
            assert_eq!(t.get(s.id), s.c_iter_cycles);
        }
    }

    #[test]
    fn measured_overrides_only_given() {
        let t = CIterTable::with_measured(&[(StencilId::Jacobi2D, 42.0)]);
        assert_eq!(t.get(StencilId::Jacobi2D), 42.0);
        assert_eq!(
            t.get(StencilId::Heat2D),
            Stencil::get(StencilId::Heat2D).c_iter_cycles
        );
    }

    #[test]
    fn apply_rewrites_c_iter_only() {
        let t = CIterTable::with_measured(&[(StencilId::Heat3D, 99.0)]);
        let s = t.apply(Stencil::get(StencilId::Heat3D));
        assert_eq!(s.c_iter_cycles, 99.0);
        assert_eq!(s.flops_per_point, Stencil::get(StencilId::Heat3D).flops_per_point);
    }

    #[test]
    fn scaling() {
        let t = CIterTable::paper().scaled(2.0);
        for s in &ALL_STENCILS {
            assert_eq!(t.get(s.id), 2.0 * s.c_iter_cycles);
        }
    }

    #[test]
    #[should_panic]
    fn nonpositive_measured_rejected() {
        CIterTable::with_measured(&[(StencilId::Jacobi2D, 0.0)]);
    }

    #[test]
    fn parametric_stencils_fall_back_to_registry_default() {
        use crate::stencil::spec::{Dim, StencilSpec};
        let id = StencilSpec::star(Dim::D2, 3).register();
        let t = CIterTable::paper();
        assert_eq!(t.get(id), Stencil::get(id).c_iter_cycles);
        // A measured override for a non-preset id is appended and applied.
        let t = CIterTable::with_measured(&[(id, 21.5)]);
        assert_eq!(t.get(id), 21.5);
        assert_eq!(t.apply(Stencil::get(id)).c_iter_cycles, 21.5);
        assert_eq!(t.entries().len(), 7);
    }
}
