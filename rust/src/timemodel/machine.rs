//! Machine-level constants that the codesign search does *not* vary.
//!
//! The paper optimizes (n_SM, n_V, M_SM); clock, off-chip bandwidth and the
//! SM's fixed microarchitectural limits are held at Maxwell-class values for
//! every candidate design (the off-chip memory system is outside the chip
//! area budget). Kept in one struct so the sensitivity of results to these
//! assumptions can be probed (see `benches/model_validation.rs`).

/// Fixed machine parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MachineSpec {
    /// Core clock, GHz (Maxwell boost ≈ 1.2).
    pub clock_ghz: f64,
    /// Off-chip (global) memory bandwidth **per SM**, GB/s.
    ///
    /// Maxwell's memory system scales with SM count — the GTX 980 has
    /// 224 GB/s over 16 SMs and the Titan X 336 GB/s over 24, i.e. exactly
    /// 14 GB/s per SM — and the paper's per-SM overhead term α_oh explicitly
    /// includes the memory controllers. Candidate designs therefore carry
    /// `n_SM · 14` GB/s of off-chip bandwidth.
    pub mem_bw_per_sm_gbs: f64,
    /// Max resident threadblocks per SM (`MTB_SM`, constraint (10)).
    pub max_blocks_per_sm: u32,
    /// Max resident warps per SM (Maxwell: 64).
    pub max_warps_per_sm: u32,
    /// Max threads per block (CUDA architectural limit).
    pub max_threads_per_block: u32,
    /// Warp width (32 lanes).
    pub warp: u32,
    /// Latency-hiding factor λ: an SM needs ≈ λ·n_V resident threads to
    /// fully hide pipeline + shared-memory latency — at the reference
    /// shared-memory capacity `shm_ref_kb`.
    pub latency_factor: f64,
    /// Shared-memory access latency grows with capacity (Cacti's delay
    /// scales ≈ √capacity through longer word/bit lines); the effective λ is
    /// `latency_factor · (M_SM / shm_ref_kb)^shm_latency_exponent`. This is
    /// what stops the optimizer from treating scratchpad capacity as free
    /// performance: a 480 kB SM needs ~1.5× the resident parallelism of a
    /// 96 kB one.
    pub shm_latency_exponent: f64,
    /// The shared-memory capacity (kB) at which `latency_factor` was
    /// calibrated — Maxwell's 96 kB. Historically this reference was baked
    /// into `latency_factor_for` as a literal; platforms calibrated at a
    /// different capacity override it here.
    pub shm_ref_kb: f64,
    /// Per-wavefront synchronization / block-dispatch overhead, cycles.
    pub sync_cycles: f64,
}

impl MachineSpec {
    /// Maxwell-class constants (used for every design point, §IV-B).
    pub fn maxwell() -> MachineSpec {
        MachineSpec {
            clock_ghz: 1.2,
            mem_bw_per_sm_gbs: 14.0,
            max_blocks_per_sm: 32,
            max_warps_per_sm: 64,
            max_threads_per_block: 1024,
            warp: 32,
            latency_factor: 4.0,
            shm_latency_exponent: 0.25,
            shm_ref_kb: 96.0,
            sync_cycles: 600.0,
        }
    }

    /// Effective latency-hiding factor for a given shared-memory capacity.
    pub fn latency_factor_for(&self, m_sm_kb: f64) -> f64 {
        self.latency_factor
            * (m_sm_kb.max(1.0) / self.shm_ref_kb).powf(self.shm_latency_exponent)
    }

    /// Bytes one SM's bandwidth slice delivers per core clock cycle.
    pub fn bytes_per_cycle_per_sm(&self) -> f64 {
        self.mem_bw_per_sm_gbs / self.clock_ghz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxwell_constants_sane() {
        let m = MachineSpec::maxwell();
        assert_eq!(m.warp, 32);
        assert!(m.clock_ghz > 1.0 && m.clock_ghz < 2.0);
        // GTX 980: 16 SM × 14 = 224 GB/s; Titan X: 24 × 14 = 336 GB/s.
        assert_eq!(m.mem_bw_per_sm_gbs * 16.0, 224.0);
        assert_eq!(m.mem_bw_per_sm_gbs * 24.0, 336.0);
        // 14 GB/s at 1.2 GHz ≈ 11.7 B/cycle/SM.
        assert!((m.bytes_per_cycle_per_sm() - 11.667).abs() < 0.01);
    }

    #[test]
    fn latency_factor_scales_around_the_reference_capacity() {
        let m = MachineSpec::maxwell();
        // At the reference capacity the factor is the calibrated λ itself.
        assert_eq!(m.latency_factor_for(m.shm_ref_kb), m.latency_factor);
        // A platform calibrated at 48 kB pivots there instead.
        let half_ref = MachineSpec { shm_ref_kb: 48.0, ..m };
        assert_eq!(half_ref.latency_factor_for(48.0), m.latency_factor);
        assert!(half_ref.latency_factor_for(96.0) > m.latency_factor);
    }
}
