//! `T_alg` — the execution-time model proper, with the feasibility
//! constraints (8)–(15) of the codesign formulation.

use crate::area::params::HwParams;
use crate::stencil::defs::Stencil;
use crate::stencil::workload::ProblemSize;
use crate::timemodel::machine::MachineSpec;
use crate::timemodel::tiling::{self, TileSizes};

/// Software parameter vector: tile sizes plus the hyperthreading factor `k`
/// (resident blocks per SM, constraints (10)–(11)).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SoftwareParams {
    pub tiles: TileSizes,
    pub k: u32,
}

impl SoftwareParams {
    pub fn new(tiles: TileSizes, k: u32) -> SoftwareParams {
        SoftwareParams { tiles, k }
    }
}

/// Why a parameter combination is infeasible.
#[derive(Clone, Debug, PartialEq)]
pub enum Infeasibility {
    /// Violates an integrality/divisibility pattern of (12)–(15).
    Pattern(&'static str),
    /// (9)/(11): `k · M_tile > M_SM`.
    SharedMemory { m_tile_bytes: f64, m_sm_bytes: f64, k: u32 },
    /// (10): `k > MTB_SM`.
    TooManyBlocks { k: u32, max: u32 },
    /// Threads per block exceed the architectural limit.
    TooManyThreads { threads: u64, max: u32 },
    /// Resident warps exceed the SM's warp contexts.
    TooManyWarps { warps: u64, max: u32 },
}

impl std::fmt::Display for Infeasibility {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Infeasibility::Pattern(p) => write!(f, "pattern violation: {p}"),
            Infeasibility::SharedMemory { m_tile_bytes, m_sm_bytes, k } => write!(
                f,
                "shared memory: k={k} x M_tile={m_tile_bytes}B > M_SM={m_sm_bytes}B"
            ),
            Infeasibility::TooManyBlocks { k, max } => write!(f, "k={k} > MTB_SM={max}"),
            Infeasibility::TooManyThreads { threads, max } => {
                write!(f, "{threads} threads/block > {max}")
            }
            Infeasibility::TooManyWarps { warps, max } => {
                write!(f, "{warps} resident warps > {max}")
            }
        }
    }
}

/// Which phase bounds each round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bound {
    Compute,
    Memory,
    Latency,
}

/// Full output of one model evaluation.
#[derive(Clone, Copy, Debug)]
pub struct TimeEstimate {
    pub cycles: f64,
    pub seconds: f64,
    pub gflops: f64,
    /// Shared-memory bytes per threadblock (`M_tile`).
    pub m_tile_bytes: f64,
    /// Per-round compute / memory phase lengths, cycles.
    pub compute_cycles: f64,
    pub mem_cycles: f64,
    /// Dispatch rounds summed over all wavefronts.
    pub rounds: f64,
    pub bound: Bound,
    /// SM occupancy actually achieved, resident threads / (λ·n_V), capped 1.
    pub occupancy: f64,
}

/// The instance-level invariants of `T_alg`: every subterm of
/// [`TimeModel::evaluate_pre`] that depends only on `(machine, stencil,
/// size, hw)` — never on the tile vector or `k` — hoisted once per inner
/// solve so batched evaluation ([`crate::timemodel::batch`]) pays for them
/// once instead of per candidate lane.
///
/// **Bit-identity contract.** Each field is the *exact* expression the
/// scalar model computes, cached — never an algebraic rearrangement. IEEE
/// f64 arithmetic makes compute-once-reuse safe but reassociation unsafe
/// (e.g. pre-multiplying `iters_per_thread · c_iter` would change the
/// rounding of `lane_work`), so anything whose association order involves a
/// per-lane factor stays in [`eval_lane`].
#[derive(Clone, Copy, Debug)]
pub struct EvalInvariants {
    /// Latency factor λ at this shared-memory size.
    pub lam: f64,
    /// `λ · n_V` — resident threads needed to fully hide latency.
    pub needed: f64,
    /// `n_V` as f64 (the issue-rate cap).
    pub n_v: f64,
    /// `C_iter` cycles per point iteration (after any `CIterTable` override).
    pub c_iter: f64,
    /// Off-chip bytes per cycle per SM.
    pub bytes_per_cycle: f64,
    /// Per-round sync/dispatch overhead, cycles.
    pub sync_cycles: f64,
    /// `clock_ghz · 1e9` — the cycles→seconds divisor.
    pub clock_hz: f64,
    /// `flops_per_point · points` — the GFLOP/s numerator.
    pub total_flops: f64,
    /// SM count (kept integral: `n_SM · k` multiplies in u32 exactly as the
    /// scalar path does before the f64 cast).
    pub n_sm: u32,
}

/// One candidate lane of a batched `T_alg` evaluation: the per-`(tiles, k)`
/// inputs [`eval_lane`] consumes. The group-batched inner solver fills these
/// from SoA buffers; [`TimeModel::evaluate_pre`] builds one on the fly — both
/// paths run the identical kernel.
#[derive(Clone, Copy, Debug)]
pub struct EvalLane {
    /// Hyperthreading factor (resident blocks per SM).
    pub k: u32,
    /// Threads per block (`t_S2 · t_S3`).
    pub threads_per_block: u64,
    /// Iterations per thread (the hexagon area — `t_S1`-dependent).
    pub iters_per_thread: f64,
    /// Global-memory traffic per block, bytes.
    pub traffic: f64,
    /// `blocks_per_wavefront` as f64 (`t_S1`-dependent through the per-phase
    /// tile count).
    pub blocks_per_wavefront: f64,
    /// `n_wavefronts` as f64 (`2 · n_bands`, group-invariant).
    pub n_wavefronts: f64,
    /// Shared-memory footprint per block, bytes (reported, not consumed).
    pub m_tile: f64,
}

/// The `T_alg` lane kernel: one round/wavefront model evaluation from
/// precomputed invariants and one candidate lane. This is **the** model —
/// [`TimeModel::evaluate_pre`] (scalar path) and
/// [`crate::timemodel::batch::LaneBatch::evaluate`] (batched path) both
/// delegate here, so the two paths are bit-identical by construction rather
/// than by parallel maintenance. Branch-free except for the bound
/// classification (a reported label, not a control dependency), which is what
/// lets the batched caller run it across a flat SoA loop the vectorizer can
/// chew on.
#[inline(always)]
pub fn eval_lane(inv: &EvalInvariants, lane: &EvalLane) -> TimeEstimate {
    // Resident threads per SM and achievable issue rate.
    let resident = (lane.k as u64 * lane.threads_per_block) as f64;
    let occupancy = (resident / inv.needed).min(1.0);
    let issue_lanes = inv.n_v.min(resident / inv.lam);

    // One round = n_SM·k blocks; each block runs iters_per_thread
    // iterations of C_iter cycles on each of its threads.
    let lane_work = resident * lane.iters_per_thread * inv.c_iter;
    let compute_cycles = lane_work / issue_lanes;

    // Each SM streams its k resident blocks' footprints through its own
    // bandwidth slice (the memory system scales with n_SM; see
    // `MachineSpec::mem_bw_per_sm_gbs`).
    let sm_bytes = lane.k as f64 * lane.traffic;
    let mem_cycles = sm_bytes / inv.bytes_per_cycle;

    let round_cycles = compute_cycles.max(mem_cycles) + inv.sync_cycles;
    let bound = if compute_cycles >= mem_cycles {
        if occupancy < 1.0 {
            Bound::Latency
        } else {
            Bound::Compute
        }
    } else {
        Bound::Memory
    };

    let concurrent = (inv.n_sm * lane.k) as f64;
    let rounds_per_wavefront = (lane.blocks_per_wavefront / concurrent).ceil();
    let rounds = lane.n_wavefronts * rounds_per_wavefront;
    let cycles = rounds * round_cycles;
    let seconds = cycles / inv.clock_hz;
    let gflops = inv.total_flops / seconds / 1e9;

    TimeEstimate {
        cycles,
        seconds,
        gflops,
        m_tile_bytes: lane.m_tile,
        compute_cycles,
        mem_cycles,
        rounds,
        bound,
        occupancy,
    }
}

/// The model: machine constants + evaluation.
#[derive(Clone, Copy, Debug)]
pub struct TimeModel {
    pub machine: MachineSpec,
}

impl TimeModel {
    pub fn new(machine: MachineSpec) -> TimeModel {
        TimeModel { machine }
    }

    pub fn maxwell() -> TimeModel {
        TimeModel::new(MachineSpec::maxwell())
    }

    /// Check constraints (9)–(15) for `(stencil, hw, sw)`.
    ///
    /// Patterns enforced (§IV-A): `t_S1 ≥ 1`, `t_S2` a positive multiple of
    /// 32 (full warps), `t_T ≥ 2` and even (hybrid hexagonal requirement),
    /// `t_S3 ≥ 1` for 3-D, `k ≥ 1` integer; and the resource constraints
    /// (9)–(11) plus the architectural thread/warp limits.
    pub fn feasibility(
        &self,
        stencil: &Stencil,
        hw: &HwParams,
        sw: &SoftwareParams,
    ) -> Result<(), Infeasibility> {
        let m = &self.machine;
        let t = &sw.tiles;
        if t.t_s1 < 1 {
            return Err(Infeasibility::Pattern("t_S1 must be a positive integer"));
        }
        if t.t_s2 == 0 || t.t_s2 % m.warp as u64 != 0 {
            return Err(Infeasibility::Pattern("t_S2 must be a positive multiple of 32"));
        }
        if t.t_t < 2 || t.t_t % 2 != 0 {
            return Err(Infeasibility::Pattern("t_T must be even and >= 2"));
        }
        match (stencil.is_3d(), t.t_s3) {
            (true, Some(s3)) if s3 >= 1 => {}
            (true, _) => return Err(Infeasibility::Pattern("3-D stencil needs t_S3 >= 1")),
            (false, None) => {}
            (false, Some(_)) => return Err(Infeasibility::Pattern("2-D stencil with t_S3")),
        }
        if sw.k < 1 {
            return Err(Infeasibility::Pattern("k must be a positive integer"));
        }
        if sw.k > m.max_blocks_per_sm {
            return Err(Infeasibility::TooManyBlocks { k: sw.k, max: m.max_blocks_per_sm });
        }
        let threads = t.t_s2 * t.t_s3.unwrap_or(1);
        if threads > m.max_threads_per_block as u64 {
            return Err(Infeasibility::TooManyThreads { threads, max: m.max_threads_per_block });
        }
        let warps = sw.k as u64 * threads / m.warp as u64;
        if warps > m.max_warps_per_sm as u64 {
            return Err(Infeasibility::TooManyWarps { warps, max: m.max_warps_per_sm });
        }
        let m_tile = tiling::tile_footprint_bytes(stencil, t);
        let m_sm = hw.m_sm_kb * 1024.0;
        if sw.k as f64 * m_tile > m_sm {
            return Err(Infeasibility::SharedMemory {
                m_tile_bytes: m_tile,
                m_sm_bytes: m_sm,
                k: sw.k,
            });
        }
        Ok(())
    }

    /// Evaluate `T_alg` assuming feasibility has been established.
    ///
    /// Model structure (DESIGN.md §5):
    ///
    /// * Each wavefront's blocks are dispatched in `ceil(blocks / (n_SM·k))`
    ///   rounds of `n_SM·k` concurrent blocks.
    /// * Per round, an SM issues `n_V` lane-operations per cycle if it holds
    ///   enough resident threads to hide latency (`R ≥ λ·n_V`), else it is
    ///   latency-bound at `R/λ` lanes per cycle.
    /// * The round's global-memory phase moves `n_SM·k` tile footprints
    ///   through the fixed off-chip bandwidth; compute and memory overlap
    ///   (`max`), plus a fixed sync/dispatch overhead.
    pub fn evaluate(
        &self,
        stencil: &Stencil,
        size: &ProblemSize,
        hw: &HwParams,
        sw: &SoftwareParams,
    ) -> TimeEstimate {
        let geo = tiling::geometry(stencil, size, &sw.tiles);
        let m_tile = tiling::tile_footprint_bytes(stencil, &sw.tiles);
        let traffic = tiling::tile_traffic_bytes(stencil, &sw.tiles);
        self.evaluate_pre(stencil, size, hw, sw, &geo, m_tile, traffic)
    }

    /// Hoist every tile- and `k`-invariant subterm of the model for one
    /// `(stencil, size, hw)` instance — see [`EvalInvariants`]. The inner
    /// solver computes this once per solve; [`evaluate_pre`] recomputes it
    /// per call (the expressions are a handful of flops, and sharing one
    /// code path is what certifies the hoisting).
    ///
    /// [`evaluate_pre`]: TimeModel::evaluate_pre
    pub fn invariants(
        &self,
        stencil: &Stencil,
        size: &ProblemSize,
        hw: &HwParams,
    ) -> EvalInvariants {
        let m = &self.machine;
        let lam = m.latency_factor_for(hw.m_sm_kb);
        EvalInvariants {
            lam,
            needed: lam * hw.n_v as f64,
            n_v: hw.n_v as f64,
            c_iter: stencil.c_iter_cycles,
            bytes_per_cycle: m.bytes_per_cycle_per_sm(),
            sync_cycles: m.sync_cycles,
            clock_hz: m.clock_ghz * 1e9,
            total_flops: stencil.flops_per_point * size.points(),
            n_sm: hw.n_sm,
        }
    }

    /// Hot-path variant of [`TimeModel::evaluate`] with the tile-dependent
    /// (k-independent) quantities precomputed: the inner solver evaluates
    /// several `k` candidates per tile vector, and geometry + footprint +
    /// traffic are invariant across them (§Perf in EXPERIMENTS.md).
    ///
    /// Thin shim over [`eval_lane`]: the invariant hoisting + lane assembly
    /// here is exactly what the batched path does across whole SoA groups,
    /// so scalar and batched evaluation share one arithmetic kernel.
    pub fn evaluate_pre(
        &self,
        stencil: &Stencil,
        size: &ProblemSize,
        hw: &HwParams,
        sw: &SoftwareParams,
        geo: &tiling::TilingGeometry,
        m_tile: f64,
        traffic: f64,
    ) -> TimeEstimate {
        let inv = self.invariants(stencil, size, hw);
        let lane = EvalLane {
            k: sw.k,
            threads_per_block: geo.threads_per_block,
            iters_per_thread: geo.iters_per_thread,
            traffic,
            blocks_per_wavefront: geo.blocks_per_wavefront() as f64,
            n_wavefronts: geo.n_wavefronts() as f64,
            m_tile,
        };
        eval_lane(&inv, &lane)
    }

    /// Feasibility-checked evaluation.
    pub fn evaluate_checked(
        &self,
        stencil: &Stencil,
        size: &ProblemSize,
        hw: &HwParams,
        sw: &SoftwareParams,
    ) -> Result<TimeEstimate, Infeasibility> {
        self.feasibility(stencil, hw, sw)?;
        Ok(self.evaluate(stencil, size, hw, sw))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::defs::{Stencil, StencilId};

    fn jac() -> &'static Stencil {
        Stencil::get(StencilId::Jacobi2D)
    }

    fn heat3d() -> &'static Stencil {
        Stencil::get(StencilId::Heat3D)
    }

    fn model() -> TimeModel {
        TimeModel::maxwell()
    }

    fn gtx() -> HwParams {
        HwParams::gtx980()
    }

    fn sw2d() -> SoftwareParams {
        // Footprint: 2 buf × 4 B × (32+2·7+2) × (64+2) = 25 344 B; k = 2
        // fits comfortably in GTX 980's 96 kB.
        SoftwareParams::new(TileSizes::d2(32, 64, 8), 2)
    }

    #[test]
    fn feasible_baseline() {
        assert_eq!(model().feasibility(jac(), &gtx(), &sw2d()), Ok(()));
    }

    #[test]
    fn pattern_violations_rejected() {
        let m = model();
        // odd t_T
        let sw = SoftwareParams::new(TileSizes::d2(64, 128, 15), 4);
        assert!(matches!(m.feasibility(jac(), &gtx(), &sw), Err(Infeasibility::Pattern(_))));
        // t_S2 not multiple of 32
        let sw = SoftwareParams::new(TileSizes::d2(64, 100, 16), 4);
        assert!(matches!(m.feasibility(jac(), &gtx(), &sw), Err(Infeasibility::Pattern(_))));
        // k = 0
        let sw = SoftwareParams::new(TileSizes::d2(64, 128, 16), 0);
        assert!(matches!(m.feasibility(jac(), &gtx(), &sw), Err(Infeasibility::Pattern(_))));
        // 3-D tiles on a 2-D stencil
        let sw = SoftwareParams::new(TileSizes::d3(64, 32, 4, 16), 4);
        assert!(matches!(m.feasibility(jac(), &gtx(), &sw), Err(Infeasibility::Pattern(_))));
    }

    #[test]
    fn shared_memory_constraint_binds() {
        let m = model();
        // Huge tile: footprint over 96 kB.
        let sw = SoftwareParams::new(TileSizes::d2(4096, 512, 32), 1);
        assert!(matches!(
            m.feasibility(jac(), &gtx(), &sw),
            Err(Infeasibility::SharedMemory { .. })
        ));
        // Same tile fits with more shared memory.
        let mut big = gtx();
        big.m_sm_kb = 100_000.0;
        assert!(matches!(
            m.feasibility(jac(), &big, &sw),
            Ok(()) | Err(Infeasibility::TooManyWarps { .. })
        ));
    }

    #[test]
    fn block_and_warp_limits() {
        let m = model();
        let sw = SoftwareParams::new(TileSizes::d2(64, 128, 16), 33);
        assert!(matches!(m.feasibility(jac(), &gtx(), &sw), Err(Infeasibility::TooManyBlocks { .. })));
        let sw = SoftwareParams::new(TileSizes::d2(64, 2048, 16), 1);
        assert!(matches!(m.feasibility(jac(), &gtx(), &sw), Err(Infeasibility::TooManyThreads { .. })));
        let sw = SoftwareParams::new(TileSizes::d2(64, 256, 16), 16);
        assert!(matches!(m.feasibility(jac(), &gtx(), &sw), Err(Infeasibility::TooManyWarps { .. })));
    }

    #[test]
    fn estimate_internally_consistent() {
        let m = model();
        let size = ProblemSize::d2(4096, 1024);
        let e = m.evaluate(jac(), &size, &gtx(), &sw2d());
        assert!(e.cycles > 0.0 && e.seconds > 0.0 && e.gflops > 0.0);
        assert!((e.seconds - e.cycles / 1.2e9).abs() / e.seconds < 1e-12);
        let gflops = jac().flops_per_point * size.points() / e.seconds / 1e9;
        assert!((gflops - e.gflops).abs() / gflops < 1e-12);
    }

    #[test]
    fn gtx980_jacobi_gflops_plausible() {
        // Sanity scale check: a decent tiling on GTX 980 should land in the
        // hundreds-to-thousands of GFLOP/s — the paper's Fig 3 scale.
        let m = model();
        let e = m.evaluate(jac(), &ProblemSize::d2(8192, 4096), &gtx(), &sw2d());
        assert!(
            e.gflops > 100.0 && e.gflops < 6000.0,
            "GTX980 Jacobi2D = {} GFLOP/s",
            e.gflops
        );
    }

    #[test]
    fn more_cores_help_when_compute_bound() {
        let m = model();
        let size = ProblemSize::d2(8192, 4096);
        // High occupancy config.
        let sw = SoftwareParams::new(TileSizes::d2(64, 256, 16), 8);
        let base = m.evaluate(jac(), &size, &gtx(), &sw);
        let mut more = gtx();
        more.n_v = 256;
        let boosted = m.evaluate(jac(), &size, &more, &sw);
        assert!(boosted.gflops > base.gflops);
    }

    #[test]
    fn starved_sm_is_latency_bound() {
        let m = model();
        let size = ProblemSize::d2(8192, 4096);
        // One tiny block per SM on a very wide SM.
        let mut wide = gtx();
        wide.n_v = 1024;
        let sw = SoftwareParams::new(TileSizes::d2(64, 32, 8), 1);
        let e = m.evaluate(jac(), &size, &wide, &sw);
        assert_eq!(e.bound, Bound::Latency);
        assert!(e.occupancy < 1.0);
    }

    #[test]
    fn tiny_time_tiles_become_memory_bound() {
        let m = model();
        let size = ProblemSize::d2(8192, 4096);
        // t_T = 2 (minimum reuse) with wide spatial tiles: traffic-heavy.
        let sw = SoftwareParams::new(TileSizes::d2(512, 1024, 2), 1);
        let e = m.evaluate(jac(), &size, &gtx(), &sw);
        assert_eq!(e.bound, Bound::Memory, "bound={:?} cc={} mc={}", e.bound, e.compute_cycles, e.mem_cycles);
    }

    #[test]
    fn evaluate_checked_rejects_infeasible() {
        let m = model();
        let sw = SoftwareParams::new(TileSizes::d2(4096, 512, 32), 4);
        assert!(m
            .evaluate_checked(jac(), &ProblemSize::d2(4096, 1024), &gtx(), &sw)
            .is_err());
    }

    #[test]
    fn model_runs_higher_radius_families() {
        // The time model is radius-parametric end to end: a radius-2 star in
        // 3-D evaluates feasibly and is costlier per round than radius 1 at
        // equal software parameters (wider halo → bigger tiles → more
        // traffic).
        use crate::stencil::spec::{Dim, StencilSpec};
        let m = model();
        let r1 = *Stencil::get(StencilSpec::star(Dim::D3, 1).register());
        let r2 = *Stencil::get(StencilSpec::star(Dim::D3, 2).register());
        // Tiles sized so even the radius-2 footprint fits GTX 980's 96 kB:
        // r2: (8+2·2·7+4)·(32+4)·(4+4)·2 buf·4 B = 92 160 B.
        let sw = SoftwareParams::new(TileSizes::d3(8, 32, 4, 8), 1);
        let size = ProblemSize::d3(256, 64);
        let a = m.evaluate_checked(&r1, &size, &gtx(), &sw).unwrap();
        let b = m.evaluate_checked(&r2, &size, &gtx(), &sw).unwrap();
        assert!(a.gflops > 0.0 && b.gflops > 0.0);
        assert!(b.mem_cycles > a.mem_cycles, "wider halo must move more bytes");
    }

    #[test]
    fn lane_kernel_matches_evaluate_bit_exactly() {
        // The shared-kernel contract: assembling an EvalLane by hand from
        // the tiling helpers and running eval_lane must reproduce
        // evaluate()'s result to the bit — this is what makes the batched
        // solver path structurally identical to the scalar one.
        let m = model();
        let size = ProblemSize::d2(4096, 1024);
        for (tiles, k) in [
            (TileSizes::d2(32, 64, 8), 2u32),
            (TileSizes::d2(64, 128, 16), 4),
            (TileSizes::d2(1, 96, 12), 5),
        ] {
            let sw = SoftwareParams::new(tiles, k);
            let reference = m.evaluate(jac(), &size, &gtx(), &sw);
            let inv = m.invariants(jac(), &size, &gtx());
            let geo = tiling::geometry(jac(), &size, &tiles);
            let lane = EvalLane {
                k,
                threads_per_block: geo.threads_per_block,
                iters_per_thread: geo.iters_per_thread,
                traffic: tiling::tile_traffic_bytes(jac(), &tiles),
                blocks_per_wavefront: geo.blocks_per_wavefront() as f64,
                n_wavefronts: geo.n_wavefronts() as f64,
                m_tile: tiling::tile_footprint_bytes(jac(), &tiles),
            };
            let batched = eval_lane(&inv, &lane);
            assert_eq!(batched.seconds.to_bits(), reference.seconds.to_bits());
            assert_eq!(batched.cycles.to_bits(), reference.cycles.to_bits());
            assert_eq!(batched.gflops.to_bits(), reference.gflops.to_bits());
            assert_eq!(batched.compute_cycles.to_bits(), reference.compute_cycles.to_bits());
            assert_eq!(batched.mem_cycles.to_bits(), reference.mem_cycles.to_bits());
            assert_eq!(batched.rounds.to_bits(), reference.rounds.to_bits());
            assert_eq!(batched.occupancy.to_bits(), reference.occupancy.to_bits());
            assert_eq!(batched.bound, reference.bound);
        }
    }

    #[test]
    fn model_3d_runs() {
        let m = model();
        let sw = SoftwareParams::new(TileSizes::d3(16, 32, 4, 8), 1);
        let e = m
            .evaluate_checked(heat3d(), &ProblemSize::d3(256, 64), &gtx(), &sw)
            .unwrap();
        assert!(e.gflops > 0.0);
    }

    #[test]
    fn weak_monotonicity_fixed_sw_more_sms_compute_bound() {
        // With software fixed and the round compute-bound, doubling n_SM
        // must not hurt. (When memory-bound, more SMs genuinely do not help
        // under fixed off-chip bandwidth and ceil-quantization can even cost
        // a few percent — that behaviour is intentional and covered by
        // `tiny_time_tiles_become_memory_bound`.)
        let m = model();
        let size = ProblemSize::d2(8192, 4096);
        let sw = SoftwareParams::new(TileSizes::d2(32, 64, 16), 2);
        let a = m.evaluate(jac(), &size, &gtx(), &sw);
        assert_ne!(a.bound, Bound::Memory, "config must be compute/latency bound");
        let mut h2 = gtx();
        h2.n_sm = 32;
        let b = m.evaluate(jac(), &size, &h2, &sw);
        assert!(b.seconds <= a.seconds * 1.0001);
    }
}
