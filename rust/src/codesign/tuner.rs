//! §V-D: "The designer can choose to fix some parameters and optimize for
//! others" — partial-codesign tuning.
//!
//! Given any subset of {n_SM, n_V, M_SM} pinned (plus optionally the cache
//! configuration, for tuning *existing* cached parts) and an area budget,
//! search the free parameters for the workload-optimal completion. This is
//! the paper's compiler-only (`everything fixed` → tile sizes only) and
//! architect (`n_V and M_SM fixed` → tune n_SM) scenarios in one knob.

use crate::area::model::AreaModel;
use crate::area::params::HwParams;
use crate::codesign::space::{m_sm_grid, DesignPoint, SpaceSpec};
use crate::opt::problem::SolveOpts;
use crate::opt::separable::solve_hardware_point;
use crate::platform::spec::PlatformSpec;
use crate::stencil::workload::Workload;
use crate::timemodel::citer::CIterTable;

/// Which hardware parameters are pinned.
#[derive(Clone, Copy, Debug, Default)]
pub struct Pinned {
    pub n_sm: Option<u32>,
    pub n_v: Option<u32>,
    pub m_sm_kb: Option<f64>,
    /// Pin the cache configuration (e.g. tune around an existing cached
    /// part). `None` means cache-less candidates (the paper's default).
    pub caches: Option<(f64, f64)>, // (l1_smpair_kb, l2_kb)
}

impl Pinned {
    /// Everything fixed to an existing part: only tile sizes remain free —
    /// the paper's "optimize for compiler parameters" scenario.
    pub fn all_of(hw: &HwParams) -> Pinned {
        Pinned {
            n_sm: Some(hw.n_sm),
            n_v: Some(hw.n_v),
            m_sm_kb: Some(hw.m_sm_kb),
            caches: Some((hw.l1_smpair_kb, hw.l2_kb)),
        }
    }
}

/// Result of a tuning run.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub hw: HwParams,
    pub area_mm2: f64,
    pub gflops: f64,
    pub seconds: f64,
    /// Candidates examined (area-feasible grid completions — the bound-
    /// pruned ones included: they were examined, just not solved).
    pub candidates: usize,
    /// Candidates answered from their certified objective lower bound
    /// without a single model evaluation (0 with `--no-prune`).
    pub pruned: usize,
}

/// Enumerate the area-feasible completions of `pinned` within the budget, in
/// the deterministic (n_SM, n_V, M_SM) nested order the tuner searches. The
/// free dimensions run over `space`'s bounds (historically the paper grid
/// was hard-coded here; it is now the platform's [`SpaceSpec`]). The shared
/// grid behind [`tune`] and the session service's memoized tune path
/// (`service::session`), so both examine identical candidates.
pub fn candidate_grid(
    pinned: &Pinned,
    budget_mm2: f64,
    space: &SpaceSpec,
    area_model: &AreaModel,
) -> Vec<DesignPoint> {
    let n_sm_grid: Vec<u32> = match pinned.n_sm {
        Some(v) => vec![v],
        None => (2..=space.n_sm_max).step_by(2).collect(),
    };
    let n_v_grid: Vec<u32> = match pinned.n_v {
        Some(v) => vec![v],
        None => (32..=space.n_v_max).step_by(32).collect(),
    };
    let m_grid: Vec<f64> = match pinned.m_sm_kb {
        Some(v) => vec![v],
        None => m_sm_grid(space.m_sm_max_kb),
    };
    let (l1, l2) = pinned.caches.unwrap_or((0.0, 0.0));
    let mut out = Vec::new();
    for &n_sm in &n_sm_grid {
        for &n_v in &n_v_grid {
            for &m_sm_kb in &m_grid {
                let hw = HwParams {
                    n_sm,
                    n_v,
                    r_vu_kb: space.r_vu_kb,
                    m_sm_kb,
                    l1_smpair_kb: l1,
                    l2_kb: l2,
                };
                let area = area_model.area_mm2(&hw);
                if area <= budget_mm2 {
                    out.push(DesignPoint { hw, area_mm2: area });
                }
            }
        }
    }
    out
}

/// Search the unpinned dimensions for the best completion within the budget,
/// on one platform (grid bounds, area pricing and time model all come from
/// its [`PlatformSpec`]).
///
/// With pruning enabled (`opts.prune`, the default) candidates are visited
/// in ascending order of their certified objective lower bound
/// (`Σ wᵢ · lower_bound_entry(i)` — see [`crate::opt::bounds`]); once an
/// incumbent exists, any candidate whose bound already reaches the
/// incumbent's weighted seconds is skipped without a model evaluation. The
/// winner is **identical** to the unpruned scan's: the bound carries a
/// one-sided safety margin, so a skipped candidate is *strictly* worse than
/// the incumbent and could never have replaced it (replacement requires a
/// strict improvement) — certified by `integration_prune.rs`.
pub fn tune(
    pinned: &Pinned,
    budget_mm2: f64,
    workload: &Workload,
    platform: &PlatformSpec,
    citer: &CIterTable,
    opts: &SolveOpts,
) -> Option<TuneResult> {
    let candidates = candidate_grid(pinned, budget_mm2, &platform.space, &platform.area_model());
    let time_model = platform.time_model();
    // Evaluation order: bound-ascending under pruning (pure function of the
    // candidate set), the plain grid order otherwise.
    let mut order: Vec<usize> = (0..candidates.len()).collect();
    let mut lb_sums: Vec<f64> = Vec::new();
    if opts.prune {
        lb_sums = candidates
            .iter()
            .map(|c| {
                let mut sum = 0.0f64;
                for e in workload.entries.iter().filter(|e| e.weight > 0.0) {
                    sum += e.weight
                        * crate::opt::bounds::lower_bound_entry(&time_model, citer, &c.hw, e, opts);
                }
                sum
            })
            .collect();
        order.sort_by(|&a, &b| lb_sums[a].partial_cmp(&lb_sums[b]).unwrap().then(a.cmp(&b)));
    }
    let mut pruned = 0usize;
    let mut solved: Vec<(usize, f64, f64)> = Vec::new(); // (index, seconds, gflops)
    let mut best_seconds = f64::INFINITY;
    for &i in &order {
        let c = &candidates[i];
        if opts.prune && lb_sums[i] >= best_seconds {
            pruned += 1;
            continue;
        }
        let sol = solve_hardware_point(&time_model, workload, citer, &c.hw, opts);
        if let (Some(seconds), Some(gflops)) = (sol.weighted_seconds, sol.weighted_gflops) {
            solved.push((i, seconds, gflops));
            if seconds < best_seconds {
                best_seconds = seconds;
            }
        }
    }
    // Winner selection in grid order with a strict-improvement scan — the
    // exact tie semantics of the historical unpruned loop.
    solved.sort_by_key(|&(i, _, _)| i);
    let mut best: Option<TuneResult> = None;
    for &(i, seconds, gflops) in &solved {
        if best.as_ref().map_or(true, |b| gflops > b.gflops) {
            best = Some(TuneResult {
                hw: candidates[i].hw,
                area_mm2: candidates[i].area_mm2,
                gflops,
                seconds,
                candidates: 0,
                pruned: 0,
            });
        }
    }
    best.map(|b| TuneResult { candidates: candidates.len(), pruned, ..b })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::registry::Platform;
    use crate::stencil::defs::StencilId;

    fn small_workload() -> Workload {
        Workload::single(StencilId::Heat2D).reweighted(|e| {
            // Thin to 4 instances to keep the test fast.
            if e.size.s1 <= 8192 && e.size.t <= 2048 {
                1.0
            } else {
                0.0
            }
        })
    }

    fn setup() -> (&'static PlatformSpec, CIterTable, SolveOpts) {
        (Platform::default_spec(), CIterTable::paper(), SolveOpts::default())
    }

    #[test]
    fn fully_pinned_is_tile_selection_only() {
        let (p, ci, opts) = setup();
        let wl = small_workload();
        let gtx = HwParams::gtx980();
        let r = tune(&Pinned::all_of(&gtx), 1e9, &wl, p, &ci, &opts).unwrap();
        assert_eq!(r.candidates, 1);
        assert_eq!(r.hw, gtx);
        assert!(r.gflops > 100.0);
    }

    #[test]
    fn tuning_n_sm_with_rest_pinned() {
        // §V-D's example: n_V and memory sizes fixed, tune the SM count.
        let (p, ci, opts) = setup();
        let wl = small_workload();
        let pinned = Pinned {
            n_sm: None,
            n_v: Some(128),
            m_sm_kb: Some(96.0),
            caches: None,
        };
        let r = tune(&pinned, 430.0, &wl, p, &ci, &opts).unwrap();
        assert!(r.candidates > 5);
        assert_eq!(r.hw.n_v, 128);
        assert_eq!(r.hw.m_sm_kb, 96.0);
        assert!(r.area_mm2 <= 430.0);
        // With everything else equal and compute-bound workloads, the tuner
        // should push n_SM up to the budget.
        assert!(r.hw.n_sm >= 20, "n_sm = {}", r.hw.n_sm);
    }

    #[test]
    fn wider_budget_never_worse() {
        let (p, ci, opts) = setup();
        let wl = small_workload();
        let pinned = Pinned { n_v: Some(128), m_sm_kb: Some(96.0), ..Default::default() };
        let lo = tune(&pinned, 300.0, &wl, p, &ci, &opts).unwrap();
        let hi = tune(&pinned, 500.0, &wl, p, &ci, &opts).unwrap();
        assert!(hi.gflops >= lo.gflops);
    }

    #[test]
    fn candidate_grid_is_area_feasible_and_deterministic() {
        let am = AreaModel::paper();
        let space = Platform::default_spec().space;
        let pinned = Pinned { n_v: Some(128), m_sm_kb: Some(96.0), ..Default::default() };
        let a = candidate_grid(&pinned, 430.0, &space, &am);
        let b = candidate_grid(&pinned, 430.0, &space, &am);
        assert!(!a.is_empty());
        assert!(a.iter().all(|c| c.area_mm2 <= 430.0));
        assert!(a.iter().all(|c| c.hw.n_v == 128 && c.hw.m_sm_kb == 96.0));
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.hw == y.hw));
        // n_SM ascending — the tuner's historical search order.
        assert!(a.windows(2).all(|w| w[0].hw.n_sm <= w[1].hw.n_sm));
    }

    #[test]
    fn pruned_tune_matches_unpruned_winner_with_fewer_solves() {
        let (p, ci, opts) = setup();
        let wl = small_workload();
        let pinned = Pinned { n_v: Some(128), m_sm_kb: Some(96.0), ..Default::default() };
        let pruned = tune(&pinned, 430.0, &wl, p, &ci, &opts).unwrap();
        let full = tune(&pinned, 430.0, &wl, p, &ci, &opts.clone().without_prune()).unwrap();
        assert_eq!(pruned.hw, full.hw);
        assert_eq!(pruned.gflops.to_bits(), full.gflops.to_bits());
        assert_eq!(pruned.seconds.to_bits(), full.seconds.to_bits());
        assert_eq!(pruned.candidates, full.candidates);
        assert_eq!(full.pruned, 0, "--no-prune must not skip anything");
        assert!(pruned.pruned > 0, "bound ordering should skip most of the n_SM ladder");
    }

    #[test]
    fn impossible_budget_returns_none() {
        let (p, ci, opts) = setup();
        let wl = small_workload();
        assert!(tune(&Pinned::default(), 10.0, &wl, p, &ci, &opts).is_none());
    }

    #[test]
    fn grid_bounds_come_from_the_platform_space() {
        // A platform with a tighter space must bound the tuner's search.
        let am = AreaModel::paper();
        let tight = SpaceSpec { n_sm_max: 8, n_v_max: 256, ..Platform::default_spec().space };
        let pinned = Pinned { m_sm_kb: Some(96.0), ..Default::default() };
        let grid = candidate_grid(&pinned, 1e9, &tight, &am);
        assert!(!grid.is_empty());
        assert!(grid.iter().all(|c| c.hw.n_sm <= 8 && c.hw.n_v <= 256));
    }
}
