//! §V-D extension: energy/power-aware codesign.
//!
//! *"Our approach can be extended to consider energy/power consumption …
//! the objective function can be updated to be the argmin of the weighted
//! execution times and energy components … Such an optimization function can
//! be formulated to solve power-gating problems."*
//!
//! This module adds exactly that: a component-level power model over the
//! same hardware parameters the area model prices, an energy evaluation per
//! solved design point (energy = power × workload time), a weighted
//! time/energy objective, and the power-gating query (which fraction of the
//! SMs should be switched off for a given workload intensity).
//!
//! The coefficients are first-order CMOS scaling anchored on the GTX 980's
//! published 165 W TDP at 398 mm²: dynamic power proportional to active
//! compute (lanes × utilization) and memory traffic, leakage proportional
//! to powered silicon area. They are deliberately simple — the point is the
//! *objective structure*, as in the paper.

use crate::area::model::AreaBreakdown;
use crate::area::params::HwParams;
use crate::codesign::scenario::ScenarioResult;
use crate::platform::spec::PlatformSpec;
use crate::timemodel::machine::MachineSpec;
use crate::timemodel::talg::TimeEstimate;

/// Power model coefficients.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PowerModel {
    /// Dynamic energy per lane-cycle at full issue, W per (lane·GHz) —
    /// i.e. watts contributed by one vector lane busy at 1 GHz.
    pub w_per_lane_ghz: f64,
    /// Dynamic power per GB/s of off-chip traffic.
    pub w_per_gbs: f64,
    /// Leakage per mm² of powered silicon.
    pub leakage_w_per_mm2: f64,
    /// Fixed board/uncore power, W.
    pub base_w: f64,
}

impl PowerModel {
    /// Anchored on the GTX 980: 2048 lanes at 1.216 GHz boost, 224 GB/s,
    /// 398 mm², 165 W TDP. Split: ~60% dynamic compute, ~15% memory,
    /// ~15% leakage, ~10% base.
    pub fn maxwell() -> PowerModel {
        PowerModel {
            w_per_lane_ghz: 165.0 * 0.60 / (2048.0 * 1.216),
            w_per_gbs: 165.0 * 0.15 / 224.0,
            leakage_w_per_mm2: 165.0 * 0.15 / 398.0,
            base_w: 165.0 * 0.10,
        }
    }

    /// Average power of a design running one modelled workload phase.
    ///
    /// `est` supplies the utilization (occupancy and compute/memory balance);
    /// `machine` the clock rate and per-SM bandwidth (historically the
    /// Maxwell 14 GB/s was baked in here); `active_sm_frac` supports
    /// power-gating studies (gated SMs contribute no dynamic power and no
    /// leakage for their area share, but the chip-level overhead keeps
    /// leaking).
    pub fn power_w(
        &self,
        hw: &HwParams,
        breakdown: &AreaBreakdown,
        est: &TimeEstimate,
        machine: &MachineSpec,
        active_sm_frac: f64,
    ) -> f64 {
        assert!((0.0..=1.0).contains(&active_sm_frac));
        let lanes = (hw.n_sm * hw.n_v) as f64 * active_sm_frac;
        // Issue utilization: occupancy caps the issue rate; memory-bound
        // rounds idle the lanes for the balance of the round.
        let compute_frac = if est.mem_cycles > est.compute_cycles {
            est.compute_cycles / est.mem_cycles
        } else {
            1.0
        };
        let util = est.occupancy.min(1.0) * compute_frac;
        let dyn_compute = self.w_per_lane_ghz * lanes * machine.clock_ghz * util;

        // Memory traffic power from the achieved share of the platform's
        // per-SM bandwidth.
        let mem_frac = if est.compute_cycles > est.mem_cycles {
            est.mem_cycles / est.compute_cycles
        } else {
            1.0
        };
        let bw_gbs = machine.mem_bw_per_sm_gbs * hw.n_sm as f64 * active_sm_frac * mem_frac;
        let dyn_mem = self.w_per_gbs * bw_gbs;

        // Leakage: gated SMs are power-gated (their slice of SM-proportional
        // area stops leaking); chip-level L2 and base never gate.
        let sm_area = breakdown.total() - breakdown.l2_mm2;
        let leak = self.leakage_w_per_mm2 * (sm_area * active_sm_frac + breakdown.l2_mm2);

        dyn_compute + dyn_mem + leak + self.base_w
    }
}

/// Energy-aware view of one solved design point.
#[derive(Clone, Debug)]
pub struct EnergyEval {
    pub hw: HwParams,
    pub area_mm2: f64,
    pub gflops: f64,
    /// Average power over the workload, W.
    pub power_w: f64,
    /// Workload energy, J (weighted seconds × average power).
    pub energy_j: f64,
    /// Energy efficiency, GFLOP/s per W.
    pub gflops_per_w: f64,
}

/// Evaluate energy for every point of a scenario result, under the
/// platform's own area coefficients, power coefficients and machine
/// constants.
pub fn energy_evals(result: &ScenarioResult, platform: &PlatformSpec) -> Vec<EnergyEval> {
    let area_model = platform.area_model();
    result
        .points
        .iter()
        .map(|p| {
            let breakdown = area_model.breakdown(&p.hw);
            // Workload-weighted average power and energy via the shared
            // accumulation (`codesign::energy`) — the gated tri-objective
            // sweep runs the same function on the same inputs, which is
            // what keeps the two paths' energies bit-identical.
            let ep = crate::codesign::energy::energy_point(
                &p.hw,
                &breakdown,
                &p.per_entry,
                &platform.power,
                &platform.machine,
                p.seconds,
            );
            EnergyEval {
                hw: p.hw,
                area_mm2: p.area_mm2,
                gflops: p.gflops,
                power_w: ep.power_w,
                energy_j: ep.energy_j,
                gflops_per_w: p.gflops / ep.power_w,
            }
        })
        .collect()
}

/// The §V-D weighted objective: minimize `λ·T + (1−λ)·E` (normalized). With
/// λ = 1 this is the paper's pure-performance problem; with λ = 0 pure
/// energy. Returns the index of the best point.
pub fn best_weighted(evals: &[EnergyEval], result: &ScenarioResult, lambda: f64) -> Option<usize> {
    assert!((0.0..=1.0).contains(&lambda));
    if evals.is_empty() {
        return None;
    }
    let t_min = result.points.iter().map(|p| p.seconds).fold(f64::INFINITY, f64::min);
    let e_min = evals.iter().map(|e| e.energy_j).fold(f64::INFINITY, f64::min);
    (0..evals.len()).min_by(|&a, &b| {
        let score = |i: usize| {
            lambda * result.points[i].seconds / t_min + (1.0 - lambda) * evals[i].energy_j / e_min
        };
        score(a).partial_cmp(&score(b)).unwrap()
    })
}

/// Power-gating query (§V-D's closing suggestion): for a design point and a
/// per-SM power budget, how many SMs can stay on — and what fraction of
/// nominal throughput survives? Returns (active SMs, power W, relative
/// throughput) for each gating level.
pub fn gating_curve(
    hw: &HwParams,
    breakdown: &AreaBreakdown,
    est: &TimeEstimate,
    power_model: &PowerModel,
    machine: &MachineSpec,
) -> Vec<(u32, f64, f64)> {
    (1..=hw.n_sm)
        .map(|active| {
            let frac = active as f64 / hw.n_sm as f64;
            let p = power_model.power_w(hw, breakdown, est, machine, frac);
            // Throughput scales with active SMs (each carries its own
            // bandwidth slice in the time model).
            (active, p, frac)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::model::AreaModel;
    use crate::codesign::scenario::testfix;
    use crate::platform::registry::Platform;
    use crate::timemodel::talg::Bound;

    /// Maxwell machine constants at the published 1.216 GHz boost clock the
    /// 165 W TDP anchor assumes.
    fn boost() -> MachineSpec {
        MachineSpec { clock_ghz: 1.216, ..MachineSpec::maxwell() }
    }

    fn est(occ: f64, cc: f64, mc: f64) -> TimeEstimate {
        TimeEstimate {
            cycles: 1e9,
            seconds: 1.0,
            gflops: 1000.0,
            m_tile_bytes: 1e4,
            compute_cycles: cc,
            mem_cycles: mc,
            rounds: 100.0,
            bound: Bound::Compute,
            occupancy: occ,
        }
    }

    #[test]
    fn gtx980_full_tilt_lands_near_tdp() {
        let pm = PowerModel::maxwell();
        let hw = HwParams::gtx980();
        let b = AreaModel::paper().breakdown(&hw);
        let p = pm.power_w(&hw, &b, &est(1.0, 1.0, 1.0), &boost(), 1.0);
        assert!((140.0..190.0).contains(&p), "GTX980 busy power {p} W vs 165 W TDP");
    }

    #[test]
    fn idle_ish_power_below_busy() {
        let pm = PowerModel::maxwell();
        let hw = HwParams::gtx980();
        let b = AreaModel::paper().breakdown(&hw);
        let busy = pm.power_w(&hw, &b, &est(1.0, 1.0, 0.1), &boost(), 1.0);
        let starved = pm.power_w(&hw, &b, &est(0.2, 1.0, 0.1), &boost(), 1.0);
        assert!(starved < busy);
    }

    #[test]
    fn gating_reduces_power_monotonically() {
        let pm = PowerModel::maxwell();
        let hw = HwParams::gtx980();
        let b = AreaModel::paper().breakdown(&hw);
        let curve = gating_curve(&hw, &b, &est(1.0, 1.0, 0.5), &pm, &boost());
        assert_eq!(curve.len(), 16);
        for w in curve.windows(2) {
            assert!(w[0].1 < w[1].1, "power not monotone in active SMs");
            assert!(w[0].2 < w[1].2);
        }
        // Even fully gated to one SM, base + L2 leakage keeps power > base.
        assert!(curve[0].1 > pm.base_w);
    }

    #[test]
    fn energy_objective_interpolates() {
        let r = testfix::quick_2d();
        let evals = energy_evals(r, Platform::default_spec());
        assert_eq!(evals.len(), r.points.len());
        assert!(evals.iter().all(|e| e.power_w > 0.0 && e.energy_j > 0.0));
        let perf = best_weighted(&evals, r, 1.0).unwrap();
        let energy = best_weighted(&evals, r, 0.0).unwrap();
        // Pure-performance pick = the fastest point.
        let fastest = (0..r.points.len())
            .min_by(|&a, &b| r.points[a].seconds.partial_cmp(&r.points[b].seconds).unwrap())
            .unwrap();
        assert_eq!(perf, fastest);
        // Pure-energy pick minimizes energy.
        let frugalest = (0..evals.len())
            .min_by(|&a, &b| evals[a].energy_j.partial_cmp(&evals[b].energy_j).unwrap())
            .unwrap();
        assert_eq!(energy, frugalest);
        // And they are (almost certainly) different machines.
        assert_ne!(
            r.points[perf].hw, r.points[energy].hw,
            "perf- and energy-optimal designs coincide — suspicious"
        );
    }

    #[test]
    fn efficiency_metric_consistent() {
        let r = testfix::quick_2d();
        let evals = energy_evals(r, Platform::default_spec());
        for e in &evals {
            assert!((e.gflops_per_w - e.gflops / e.power_w).abs() < 1e-9);
        }
    }
}
