//! The codesign engine — the paper's contribution proper (§IV–§V).
//!
//! * [`space`] — enumerate the feasible hardware design space of §IV-B
//!   (cache-less candidate accelerators on the manufacturer grid).
//! * [`scenario`] — run a full design-space exploration for a workload on
//!   one platform: per-point eq. (18) solves, evaluations of the platform's
//!   reference architectures (stock GTX 980 / Titan X on the default
//!   baseline), and the improvement statistics quoted in the abstract and
//!   §V-A.
//! * [`pareto`] — Pareto-frontier extraction over (area, performance), and
//!   the tri-objective (area, performance, energy) fronts behind
//!   `ParetoEnergy` requests.
//! * [`energy`] — the energy objective: per-design joules from the power
//!   model × weighted execution time, shared by the reporting and gated
//!   sweep paths.
//! * [`sensitivity`] — §V-B / Table II: per-benchmark optimal architectures
//!   from re-weighted (memoized) results.
//! * [`allocation`] — §V-C / Fig 4: chip-area resource allocation of every
//!   design point.
//! * [`cacheless`] — §V-A's cache-deletion comparison (E5).
//! * [`tuner`] — §V-D's partial codesign: pin any subset of the hardware
//!   parameters and optimize the rest.
//! * [`power`] — §V-D's energy extension: power model, weighted time/energy
//!   objective, power-gating curves.

pub mod allocation;
pub mod cacheless;
pub mod energy;
pub mod pareto;
pub mod power;
pub mod scenario;
pub mod sensitivity;
pub mod space;
pub mod tuner;

pub use energy::{energy_point, weighted_power_w, EnergyPoint};
pub use pareto::{pareto_front, pareto_front3, ParetoFront, ParetoFront3};
pub use scenario::{DesignEval, Scenario, ScenarioResult};
pub use space::{enumerate_space, DesignPoint, SpaceSpec};
