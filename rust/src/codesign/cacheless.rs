//! The cache-deletion comparison (§V-A, E5, and the abstract's 28%/33%
//! claim): a large part of the Fig 3 gains come from candidate designs
//! carrying no caches (the HHC compiler moves data explicitly). To separate
//! "remove the caches" from "rebalance the architecture", the paper deletes
//! the caches from the GTX 980 / Titan X, recomputes their areas, and
//! compares the Pareto designs at those *reduced* budgets.

use crate::area::model::AreaModel;
use crate::codesign::scenario::ScenarioResult;

/// One row of the cache-less comparison.
#[derive(Clone, Debug)]
pub struct CachelessRow {
    pub reference: String,
    /// Reference area with caches (modelled), mm².
    pub full_area_mm2: f64,
    /// Reference area after deleting L1+L2, mm².
    pub reduced_area_mm2: f64,
    /// Reference performance (unchanged by cache deletion — the time model's
    /// code never uses caches), GFLOP/s.
    pub ref_gflops: f64,
    /// Best candidate design within the reduced budget, GFLOP/s.
    pub best_gflops: f64,
    /// Improvement at the reduced budget, %.
    pub improvement_pct: f64,
    /// Improvement at the full (cache-included) budget, % — Fig 3's headline.
    pub full_budget_improvement_pct: f64,
}

/// Compute the §V-A comparison for every reference in the scenario result.
pub fn cacheless_comparison(result: &ScenarioResult, area_model: &AreaModel) -> Vec<CachelessRow> {
    let xy = result.xy();
    result
        .references
        .iter()
        .map(|r| {
            let reduced_area = area_model.area_mm2(&r.hw.without_caches());
            let best_reduced = crate::codesign::pareto::best_within_area(&xy, reduced_area);
            let best_full = crate::codesign::pareto::best_within_area(&xy, r.area_mm2);
            let best_gflops = best_reduced.map(|i| xy[i].1).unwrap_or(f64::NAN);
            let full_gflops = best_full.map(|i| xy[i].1).unwrap_or(f64::NAN);
            CachelessRow {
                reference: r.name.to_string(),
                full_area_mm2: r.area_mm2,
                reduced_area_mm2: reduced_area,
                ref_gflops: r.gflops,
                best_gflops,
                improvement_pct: 100.0 * (best_gflops / r.gflops - 1.0),
                full_budget_improvement_pct: 100.0 * (full_gflops / r.gflops - 1.0),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codesign::scenario::testfix;

    #[test]
    fn cacheless_budgets_shrink_and_gains_shrink() {
        let r = testfix::quick_2d();
        let rows = cacheless_comparison(r, &AreaModel::paper());
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert!(
                row.reduced_area_mm2 < row.full_area_mm2,
                "{}: deleting caches must shrink area",
                row.reference
            );
            // A smaller budget can never improve more than a larger one.
            // (Strictness is asserted for the GTX 980 below; the Titan X's
            // full and reduced budgets both saturate the reduced *test*
            // space, so they may tie there.)
            assert!(
                row.improvement_pct <= row.full_budget_improvement_pct,
                "{}: {} !<= {}",
                row.reference,
                row.improvement_pct,
                row.full_budget_improvement_pct
            );
        }
        let g980 = rows.iter().find(|r| r.reference == "gtx980").unwrap();
        assert!(
            g980.improvement_pct < g980.full_budget_improvement_pct,
            "gtx980 reduced-budget gain should be strictly smaller"
        );
        // GTX980 cache-less area lands near the paper's 237 mm² (our exact
        // eq. (5) computation gives ~249; accept the ballpark).
        let g = rows.iter().find(|r| r.reference == "gtx980").unwrap();
        assert!((220.0..270.0).contains(&g.reduced_area_mm2), "{}", g.reduced_area_mm2);
    }
}
