//! Workload sensitivity (§V-B, Table II): because eq. (18) memoizes the
//! per-(hardware, stencil, size) optima, changing benchmark frequencies is a
//! re-aggregation — no new optimization. Setting frequency 1 for a single
//! benchmark yields the per-benchmark optimal architectures of Table II.

use crate::codesign::scenario::{DesignEval, ScenarioResult};
use crate::stencil::defs::{Stencil, StencilId};
use crate::stencil::workload::Workload;

/// One Table II row.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub stencil: StencilId,
    pub n_sm: u32,
    pub n_v: u32,
    pub m_sm_kb: f64,
    pub area_mm2: f64,
    pub gflops: f64,
}

/// Re-aggregate one design's per-entry results under new weights.
/// Returns `None` if any positively-weighted entry was infeasible.
pub fn reweighted_gflops(point: &DesignEval, workload: &Workload, weights: &[f64]) -> Option<f64> {
    assert_eq!(point.per_entry.len(), workload.entries.len());
    assert_eq!(weights.len(), workload.entries.len());
    let mut t = 0.0;
    let mut flops = 0.0;
    for ((entry, sol), &w) in workload.entries.iter().zip(&point.per_entry).zip(weights) {
        if w == 0.0 {
            continue;
        }
        let s = sol.as_ref()?;
        t += w * s.est.seconds;
        flops += w * Stencil::get(entry.stencil).flops_per_point * entry.size.points();
    }
    (t > 0.0).then(|| flops / t / 1e9)
}

/// Single-benchmark weights over a scenario workload (uniform across that
/// benchmark's sizes, zero elsewhere).
pub fn single_benchmark_weights(workload: &Workload, id: StencilId) -> Vec<f64> {
    let n = workload.entries.iter().filter(|e| e.stencil == id).count();
    assert!(n > 0, "stencil {id:?} not in workload");
    workload
        .entries
        .iter()
        .map(|e| if e.stencil == id { 1.0 / n as f64 } else { 0.0 })
        .collect()
}

/// Best architecture for one benchmark within an area band — one Table II
/// row. `result` must come from a scenario whose workload contains `id`.
pub fn best_for_benchmark(
    result: &ScenarioResult,
    workload: &Workload,
    id: StencilId,
    area_band: (f64, f64),
) -> Option<Table2Row> {
    let weights = single_benchmark_weights(workload, id);
    let mut best: Option<(f64, &DesignEval)> = None;
    for p in &result.points {
        if p.area_mm2 < area_band.0 || p.area_mm2 > area_band.1 {
            continue;
        }
        if let Some(g) = reweighted_gflops(p, workload, &weights) {
            if best.map_or(true, |(bg, _)| g > bg) {
                best = Some((g, p));
            }
        }
    }
    best.map(|(g, p)| Table2Row {
        stencil: id,
        n_sm: p.hw.n_sm,
        n_v: p.hw.n_v,
        m_sm_kb: p.hw.m_sm_kb,
        area_mm2: p.area_mm2,
        gflops: g,
    })
}

/// Assemble the full Table II from the 2-D and 3-D scenario results, with
/// the paper's 425–450 mm² band.
pub fn table2(
    res_2d: &ScenarioResult,
    wl_2d: &Workload,
    res_3d: &ScenarioResult,
    wl_3d: &Workload,
) -> Vec<Table2Row> {
    let band = (425.0, 450.0);
    let mut rows = Vec::new();
    for id in [StencilId::Jacobi2D, StencilId::Heat2D, StencilId::Gradient2D, StencilId::Laplacian2D]
    {
        if let Some(r) = best_for_benchmark(res_2d, wl_2d, id, band) {
            rows.push(r);
        }
    }
    for id in [StencilId::Heat3D, StencilId::Laplacian3D] {
        if let Some(r) = best_for_benchmark(res_3d, wl_3d, id, band) {
            rows.push(r);
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codesign::scenario::testfix;

    #[test]
    fn single_benchmark_weights_normalized() {
        let w = Workload::uniform_2d();
        let ws = single_benchmark_weights(&w, StencilId::Heat2D);
        assert!((ws.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(ws.iter().filter(|&&x| x > 0.0).count(), 16);
    }

    #[test]
    fn per_benchmark_optima_differ() {
        // Table II's point: the optimal architecture is benchmark-specific.
        let sc = testfix::quick_2d_scenario();
        let r = testfix::quick_2d();
        let band = (400.0, 460.0);
        let jac = best_for_benchmark(r, &sc.workload, StencilId::Jacobi2D, band).unwrap();
        let grad = best_for_benchmark(r, &sc.workload, StencilId::Gradient2D, band).unwrap();
        assert!(jac.gflops > 0.0 && grad.gflops > 0.0);
        assert!(jac.area_mm2 >= 400.0 && jac.area_mm2 <= 460.0);
        // Different stencils -> (usually) different best configs; at minimum
        // the achieved GFLOP/s must differ (operation counts differ).
        assert!((jac.gflops - grad.gflops).abs() > 1.0);
    }
}
