//! Full design-space exploration for one workload class (Fig 3's two
//! panels): enumerate hardware candidates, solve eq. (18) on each, evaluate
//! the platform's reference architectures under the same time model, and
//! derive the paper's improvement statistics.

use crate::area::params::HwParams;
use crate::codesign::pareto::{best_within_area, pareto_front};
use crate::codesign::space::{enumerate_space, SpaceSpec};
use crate::opt::inner::InnerSolution;
use crate::opt::problem::SolveOpts;
use crate::opt::separable::solve_hardware_point;
use crate::platform::spec::{PlatformSpec, ReferenceHw};
use crate::stencil::workload::Workload;
use crate::timemodel::citer::CIterTable;
use crate::util::threadpool::{default_threads, parallel_map};

/// One solved design point.
#[derive(Clone, Debug)]
pub struct DesignEval {
    pub hw: HwParams,
    pub area_mm2: f64,
    /// Workload-weighted GFLOP/s (Fig 3 y-axis).
    pub gflops: f64,
    /// Workload-weighted execution time, seconds (objective (17)).
    pub seconds: f64,
    /// Per-entry optima, aligned with the scenario workload's entries —
    /// kept so §V-B re-weighting needs no further model evaluations.
    pub per_entry: Vec<Option<InnerSolution>>,
}

/// A reference (existing) architecture evaluated under the same model.
#[derive(Clone, Debug)]
pub struct RefEval {
    pub name: String,
    pub hw: HwParams,
    /// Modelled area (eq. 5) and the published die area.
    pub area_mm2: f64,
    pub published_area_mm2: f64,
    pub gflops: f64,
    pub seconds: f64,
    pub per_entry: Vec<Option<InnerSolution>>,
}

/// Scenario definition.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub workload: Workload,
    pub space: SpaceSpec,
    pub solve_opts: SolveOpts,
    pub threads: usize,
    pub citer: CIterTable,
}

impl Scenario {
    /// Fig 3 left panel: the four 2-D stencils, uniform frequencies, the
    /// paper's full hardware grid.
    pub fn paper_2d() -> Scenario {
        Scenario {
            name: "2d".into(),
            workload: Workload::uniform_2d(),
            space: SpaceSpec::paper(),
            solve_opts: SolveOpts::default(),
            threads: default_threads(),
            citer: CIterTable::paper(),
        }
    }

    /// Fig 3 right panel: the two 3-D stencils.
    pub fn paper_3d() -> Scenario {
        Scenario { name: "3d".into(), workload: Workload::uniform_3d(), ..Scenario::paper_2d() }
    }

    /// This scenario under a new display name (batch outputs are keyed by
    /// name, so give every batched variant a distinct one).
    pub fn named(mut self, name: &str) -> Scenario {
        self.name = name.to_string();
        self
    }

    /// This scenario restricted to designs within `mm2` of silicon — a
    /// tighter budget enumerates a subset of the same grid, so a batch
    /// answers it from the shared sweep without new inner solves.
    pub fn with_area_budget(mut self, mm2: f64) -> Scenario {
        self.space = self.space.with_budget(mm2);
        self
    }

    /// This scenario under a different workload (re-weighting, per-stencil
    /// subset, …). Workloads over the same entry instances share all inner
    /// solutions in a batch.
    pub fn with_workload(mut self, workload: Workload) -> Scenario {
        self.workload = workload;
        self
    }

    /// This scenario with an explicit worker-thread count.
    pub fn with_threads(mut self, threads: usize) -> Scenario {
        self.threads = threads.max(1);
        self
    }

    /// Reduced scenario for tests / quick runs: small space, thinned
    /// workload (every `stride`-th size instance; `step_by` always keeps the
    /// first entry, so any stride leaves at least one entry of a non-empty
    /// workload). Falls back to uniform weights when the kept entries carry
    /// zero total weight — normalizing by zero would poison every downstream
    /// aggregate with NaN.
    pub fn quick(base: Scenario, stride: usize) -> Scenario {
        let mut workload = base.workload.clone();
        workload.entries =
            workload.entries.iter().copied().step_by(stride.max(1)).collect();
        let total: f64 = workload.entries.iter().map(|e| e.weight).sum();
        if total > 0.0 {
            for e in &mut workload.entries {
                e.weight /= total;
            }
        } else if !workload.entries.is_empty() {
            let uniform = 1.0 / workload.entries.len() as f64;
            for e in &mut workload.entries {
                e.weight = uniform;
            }
        }
        Scenario { workload, space: SpaceSpec::small(), ..base }
    }
}

/// Headline improvement statistics (§V-A / abstract).
#[derive(Clone, Debug)]
pub struct ImprovementStats {
    /// (reference name, best same-area design improvement %, best design hw).
    pub vs_reference: Vec<(String, f64, HwParams)>,
}

/// Everything a scenario run produces.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub scenario_name: String,
    pub points: Vec<DesignEval>,
    /// Indices into `points`, area-ascending (the blue points of Fig 3).
    pub pareto: Vec<usize>,
    pub references: Vec<RefEval>,
    pub stats: ImprovementStats,
    /// Total inner-solver model evaluations (solver-cost accounting, E8).
    pub total_evals: u64,
    /// Feasible-but-unsolvable hardware points (no feasible tiling).
    pub infeasible_points: usize,
}

impl ScenarioResult {
    /// (area, gflops) pairs of all solved points.
    pub fn xy(&self) -> Vec<(f64, f64)> {
        self.points.iter().map(|p| (p.area_mm2, p.gflops)).collect()
    }

    pub fn reference(&self, name: &str) -> Option<&RefEval> {
        self.references.iter().find(|r| r.name == name)
    }

    /// Best solved design within an area budget.
    pub fn best_within(&self, budget_mm2: f64) -> Option<&DesignEval> {
        best_within_area(&self.xy(), budget_mm2).map(|i| &self.points[i])
    }
}

/// Evaluate one of the platform's reference architectures (stock, caches and
/// all) under the scenario's workload. The time model sees its real `n_SM`,
/// `n_V`, `M_SM`; its caches contribute area but not performance (the
/// HHC-generated code the model describes stages data through shared memory
/// explicitly).
pub fn evaluate_reference(
    reference: &ReferenceHw,
    scenario: &Scenario,
    platform: &PlatformSpec,
) -> RefEval {
    let sol = solve_hardware_point(
        &platform.time_model(),
        &scenario.workload,
        &scenario.citer,
        &reference.hw,
        &scenario.solve_opts,
    );
    RefEval {
        name: reference.name.clone(),
        hw: reference.hw,
        area_mm2: platform.area_model().area_mm2(&reference.hw),
        published_area_mm2: reference.published_area_mm2,
        gflops: sol.weighted_gflops.expect("reference must be feasible"),
        seconds: sol.weighted_seconds.expect("reference must be feasible"),
        per_entry: sol.per_entry,
    }
}

/// Run the full exploration on one platform (area pricing, time model and
/// reference architectures all come from its [`PlatformSpec`]).
pub fn run(scenario: &Scenario, platform: &PlatformSpec) -> ScenarioResult {
    let area_model = platform.area_model();
    let time_model = platform.time_model();
    let space = enumerate_space(&area_model, &scenario.space);
    let solved = parallel_map(&space, scenario.threads, |pt| {
        let sol = solve_hardware_point(
            &time_model,
            &scenario.workload,
            &scenario.citer,
            &pt.hw,
            &scenario.solve_opts,
        );
        (pt.area_mm2, sol)
    });

    let mut points = Vec::new();
    let mut total_evals = 0u64;
    let mut infeasible_points = 0usize;
    for (pt, (area, sol)) in space.iter().zip(solved) {
        total_evals += sol.evals;
        match (sol.weighted_seconds, sol.weighted_gflops) {
            (Some(seconds), Some(gflops)) => points.push(DesignEval {
                hw: pt.hw,
                area_mm2: area,
                gflops,
                seconds,
                per_entry: sol.per_entry,
            }),
            _ => infeasible_points += 1,
        }
    }

    let xy: Vec<(f64, f64)> = points.iter().map(|p| (p.area_mm2, p.gflops)).collect();
    let pareto = pareto_front(&xy);

    let references: Vec<RefEval> = platform
        .references
        .iter()
        .map(|r| evaluate_reference(r, scenario, platform))
        .collect();

    let vs_reference = references
        .iter()
        .map(|r| {
            let best = best_within_area(&xy, r.area_mm2);
            let (impr, hw) = match best {
                Some(i) => {
                    (100.0 * (points[i].gflops / r.gflops - 1.0), points[i].hw)
                }
                None => (f64::NAN, r.hw),
            };
            (r.name.clone(), impr, hw)
        })
        .collect();

    ScenarioResult {
        scenario_name: scenario.name.clone(),
        points,
        pareto,
        references,
        stats: ImprovementStats { vs_reference },
        total_evals,
        infeasible_points,
    }
}

/// Shared quick scenario results for the test suite (a full quick run takes
/// seconds; several test modules consume the same one).
#[cfg(test)]
pub(crate) mod testfix {
    use super::*;
    use crate::platform::registry::Platform;
    use std::sync::OnceLock;

    pub fn quick_2d_scenario() -> Scenario {
        Scenario::quick(Scenario::paper_2d(), 8) // 8 of 64 entries
    }

    pub fn quick_2d() -> &'static ScenarioResult {
        static CELL: OnceLock<ScenarioResult> = OnceLock::new();
        CELL.get_or_init(|| run(&quick_2d_scenario(), Platform::default_spec()))
    }
}

#[cfg(test)]
mod tests {
    use super::testfix::quick_2d;
    use super::*;

    #[test]
    fn quick_scenario_produces_front_and_references() {
        let r = quick_2d();
        assert!(r.points.len() > 100, "points: {}", r.points.len());
        assert!(!r.pareto.is_empty());
        assert!(r.pareto.len() < r.points.len() / 10, "front should prune ~99%");
        assert_eq!(r.references.len(), 2);
        assert!(r.reference("gtx980").unwrap().gflops > 100.0);
        // Titan X has more SMs: at least as fast as GTX 980 on the same mix.
        assert!(r.reference("titanx").unwrap().gflops >= r.reference("gtx980").unwrap().gflops);
    }

    #[test]
    fn optimized_designs_beat_stock_at_same_area() {
        // The central claim (E3/E9): a same-area cache-less design
        // outperforms the stock GTX 980 under this workload.
        let r = quick_2d();
        let (name, impr, _) = &r.stats.vs_reference[0];
        assert_eq!(name, "gtx980");
        assert!(*impr > 20.0, "improvement over GTX980 = {impr}%");
    }

    #[test]
    fn quick_oversized_stride_keeps_one_normalized_entry() {
        // A stride beyond the entry count must not leave an empty workload
        // or normalize by a zero total.
        let sc = Scenario::quick(Scenario::paper_2d(), 10_000);
        assert_eq!(sc.workload.entries.len(), 1);
        assert!((sc.workload.total_weight() - 1.0).abs() < 1e-12);
        assert!(sc.workload.entries[0].weight.is_finite());
    }

    #[test]
    fn quick_zero_weight_survivors_get_uniform_weights() {
        // If thinning keeps only zero-weighted entries, quick() must fall
        // back to uniform weights instead of dividing by zero.
        let mut base = Scenario::paper_2d();
        for e in &mut base.workload.entries {
            e.weight = 0.0;
        }
        base.workload.entries[1].weight = 1.0; // dropped by any stride >= 2
        let sc = Scenario::quick(base, 10_000);
        assert!(!sc.workload.entries.is_empty());
        assert!((sc.workload.total_weight() - 1.0).abs() < 1e-12);
        assert!(sc.workload.entries.iter().all(|e| e.weight.is_finite()));
    }

    #[test]
    fn pareto_points_are_best_within_their_area() {
        let r = quick_2d();
        let xy = r.xy();
        for &i in &r.pareto {
            let b = best_within_area(&xy, xy[i].0).unwrap();
            assert!((xy[b].1 - xy[i].1).abs() < 1e-9);
        }
    }
}
