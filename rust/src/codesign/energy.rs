//! The energy objective: per-design energy points for tri-objective
//! (area × perf × energy) Pareto fronts.
//!
//! [`crate::codesign::power`] models per-phase power; this module turns that
//! into the third front axis. One accumulation path — [`weighted_power_w`] —
//! produces a design's workload-average power from its per-entry inner
//! solutions, and [`energy_point`] multiplies it by the design's weighted
//! execution time (`T_alg`, eq. 17) to get joules per sweep-unit. Both the
//! batch-derived reporting path (`power::energy_evals`) and the gated
//! tri-objective sweep (`Coordinator::run_pareto_energy_gated`) call this
//! exact function on the same inputs, so their energies are bit-identical
//! **structurally** — same IEEE-754 expressions in the same association
//! order, never two re-derivations that happen to agree.
//!
//! Determinism contract: per-entry solutions iterate in workload-entry
//! order (`per_entry.iter().flatten()`), the accumulators are plain `f64`
//! sums in that order, and nothing here depends on thread count, prune
//! state or evaluation path — an energy value is a pure function of the
//! design's solved entries.

use crate::area::model::AreaBreakdown;
use crate::area::params::HwParams;
use crate::codesign::power::PowerModel;
use crate::opt::inner::InnerSolution;
use crate::timemodel::machine::MachineSpec;

/// The energy view of one solved design point: the third objective of a
/// tri-objective front (area ↓, perf ↑, energy ↓).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyPoint {
    /// Workload-average power, W: each solved entry's
    /// [`PowerModel::power_w`] weighted by its share of the total modelled
    /// time. `NaN` when no entry contributed time (nothing solved).
    pub power_w: f64,
    /// Workload energy, J per sweep-unit: `power_w × weighted_seconds`
    /// (`T_alg`, eq. 17).
    pub energy_j: f64,
}

/// Workload-average power of one design: per-entry powers weighted by each
/// entry's share of the summed modelled seconds. Iterates `per_entry` in
/// entry order, skipping unsolved (`None`) slots — exactly the accumulation
/// `power::energy_evals` has always used, now shared.
///
/// Returns `NaN` when no entry contributed time (all slots `None`).
pub fn weighted_power_w(
    hw: &HwParams,
    breakdown: &AreaBreakdown,
    per_entry: &[Option<InnerSolution>],
    power: &PowerModel,
    machine: &MachineSpec,
) -> f64 {
    let mut acc_pw = 0.0;
    let mut acc_t = 0.0;
    for sol in per_entry.iter().flatten() {
        let pw = power.power_w(hw, breakdown, &sol.est, machine, 1.0);
        acc_pw += pw * sol.est.seconds;
        acc_t += sol.est.seconds;
    }
    if acc_t > 0.0 {
        acc_pw / acc_t
    } else {
        f64::NAN
    }
}

/// The per-design [`EnergyPoint`]: average power from [`weighted_power_w`],
/// energy as that power × the design's workload-weighted seconds.
pub fn energy_point(
    hw: &HwParams,
    breakdown: &AreaBreakdown,
    per_entry: &[Option<InnerSolution>],
    power: &PowerModel,
    machine: &MachineSpec,
    weighted_seconds: f64,
) -> EnergyPoint {
    let power_w = weighted_power_w(hw, breakdown, per_entry, power, machine);
    EnergyPoint { power_w, energy_j: power_w * weighted_seconds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::model::AreaModel;
    use crate::codesign::power::energy_evals;
    use crate::codesign::scenario::testfix;
    use crate::platform::registry::Platform;

    #[test]
    fn energy_point_is_bit_identical_to_energy_evals() {
        // The shared-function contract: recomputing every point of a
        // scenario result through `energy_point` reproduces
        // `power::energy_evals` bit-for-bit — same power, same energy.
        let r = testfix::quick_2d();
        let platform = Platform::default_spec();
        let area_model = platform.area_model();
        let evals = energy_evals(r, platform);
        assert_eq!(evals.len(), r.points.len());
        for (p, e) in r.points.iter().zip(&evals) {
            let breakdown = area_model.breakdown(&p.hw);
            let ep = energy_point(
                &p.hw,
                &breakdown,
                &p.per_entry,
                &platform.power,
                &platform.machine,
                p.seconds,
            );
            assert_eq!(ep.power_w.to_bits(), e.power_w.to_bits());
            assert_eq!(ep.energy_j.to_bits(), e.energy_j.to_bits());
        }
    }

    #[test]
    fn unsolved_slots_do_not_contribute() {
        // Zero-weight entries ride as `None` on the gated path; masking an
        // entry must change only the average's composition, never poison it.
        let r = testfix::quick_2d();
        let platform = Platform::default_spec();
        let breakdown = AreaModel::paper().breakdown(&r.points[0].hw);
        let full = weighted_power_w(
            &r.points[0].hw,
            &breakdown,
            &r.points[0].per_entry,
            &platform.power,
            &platform.machine,
        );
        assert!(full.is_finite() && full > 0.0);
        let mut masked = r.points[0].per_entry.clone();
        let n = masked.len();
        for slot in masked.iter_mut().take(n / 2) {
            *slot = None;
        }
        let half = weighted_power_w(
            &r.points[0].hw,
            &breakdown,
            &masked,
            &platform.power,
            &platform.machine,
        );
        assert!(half.is_finite() && half > 0.0);
        let none = weighted_power_w(
            &r.points[0].hw,
            &breakdown,
            &vec![None; n],
            &platform.power,
            &platform.machine,
        );
        assert!(none.is_nan(), "no solved entries must read as NaN, not 0");
    }

    #[test]
    fn energy_scales_linearly_with_weighted_seconds() {
        let r = testfix::quick_2d();
        let platform = Platform::default_spec();
        let p = &r.points[0];
        let breakdown = AreaModel::paper().breakdown(&p.hw);
        let e1 = energy_point(&p.hw, &breakdown, &p.per_entry, &platform.power, &platform.machine, 1.0);
        let e2 = energy_point(&p.hw, &breakdown, &p.per_entry, &platform.power, &platform.machine, 2.0);
        assert_eq!(e1.power_w.to_bits(), e2.power_w.to_bits());
        assert!((e2.energy_j - 2.0 * e1.energy_j).abs() < 1e-12 * e1.energy_j.abs());
    }
}
