//! Hardware design-space enumeration (§IV-B).
//!
//! The candidate accelerators are **cache-less** (the HHC compiler the time
//! model targets performs explicit shared-memory data movement, so the paper
//! spends no candidate area on caches — §V-A), on the manufacturer grid:
//!
//! * `2 ≤ n_SM ≤ 32`, even;
//! * `32 ≤ n_V ≤ 2048`, multiple of 32;
//! * `M_SM ∈ {12, 24, 36} ∪ {48, 96, …, 480}` kB (multiples of 48 plus the
//!   three small sizes the paper additionally explores);
//! * `R_VU` fixed at the Maxwell 2 kB per vector unit (register sizing is a
//!   stated limitation of the paper's model, §V-D).

use crate::area::model::AreaModel;
use crate::area::params::HwParams;

/// Enumeration bounds (defaults = the paper's; platform presets carry their
/// own — see [`crate::platform::PlatformSpec`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpaceSpec {
    pub n_sm_max: u32,
    pub n_v_max: u32,
    pub m_sm_max_kb: f64,
    /// Total-area budget ceiling, mm² (§V-A sweeps 200–650).
    pub max_area_mm2: f64,
    pub r_vu_kb: f64,
}

impl SpaceSpec {
    pub fn paper() -> SpaceSpec {
        SpaceSpec { n_sm_max: 32, n_v_max: 2048, m_sm_max_kb: 480.0, max_area_mm2: 650.0, r_vu_kb: 2.0 }
    }

    /// A reduced space for tests and quick runs.
    pub fn small() -> SpaceSpec {
        SpaceSpec { n_sm_max: 16, n_v_max: 512, m_sm_max_kb: 192.0, max_area_mm2: 650.0, r_vu_kb: 2.0 }
    }

    /// This space clamped to the quick-run grid: bounds are the minimum of
    /// this space's and [`SpaceSpec::small`]'s, so a platform's tighter
    /// bounds survive `--quick` while the paper space shrinks exactly as it
    /// always has (`SpaceSpec::paper().shrunk() == SpaceSpec::small()`).
    pub fn shrunk(&self) -> SpaceSpec {
        let s = SpaceSpec::small();
        SpaceSpec {
            n_sm_max: self.n_sm_max.min(s.n_sm_max),
            n_v_max: self.n_v_max.min(s.n_v_max),
            m_sm_max_kb: self.m_sm_max_kb.min(s.m_sm_max_kb),
            max_area_mm2: self.max_area_mm2,
            r_vu_kb: self.r_vu_kb,
        }
    }

    /// This space under a tighter (or looser) total-area budget. On the same
    /// grid bounds a smaller budget enumerates a subset of the points, which
    /// the batched coordinator serves without any new inner solves.
    pub fn with_budget(mut self, max_area_mm2: f64) -> SpaceSpec {
        self.max_area_mm2 = max_area_mm2;
        self
    }
}

/// One enumerated hardware candidate with its modelled area.
#[derive(Clone, Copy, Debug)]
pub struct DesignPoint {
    pub hw: HwParams,
    pub area_mm2: f64,
}

/// The `M_SM` grid: 12/24/36 kB plus multiples of 48 kB up to the cap.
pub fn m_sm_grid(max_kb: f64) -> Vec<f64> {
    let mut g: Vec<f64> = vec![12.0, 24.0, 36.0];
    let mut v = 48.0;
    while v <= max_kb {
        g.push(v);
        v += 48.0;
    }
    g.retain(|&x| x <= max_kb);
    g
}

/// Enumerate every grid point whose modelled area fits the budget.
pub fn enumerate_space(model: &AreaModel, spec: &SpaceSpec) -> Vec<DesignPoint> {
    let mut out = Vec::new();
    let m_grid = m_sm_grid(spec.m_sm_max_kb);
    for n_sm in (2..=spec.n_sm_max).step_by(2) {
        for n_v in (32..=spec.n_v_max).step_by(32) {
            // Cheapest memory config first: if even M_SM = 12 kB busts the
            // budget, larger n_V at this n_SM can't fit either.
            for &m_sm_kb in &m_grid {
                let hw = HwParams {
                    n_sm,
                    n_v,
                    r_vu_kb: spec.r_vu_kb,
                    m_sm_kb,
                    l1_smpair_kb: 0.0,
                    l2_kb: 0.0,
                };
                debug_assert!(hw.respects_manufacturer_patterns());
                let area = model.area_mm2(&hw);
                if area <= spec.max_area_mm2 {
                    out.push(DesignPoint { hw, area_mm2: area });
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn m_grid_matches_paper() {
        let g = m_sm_grid(480.0);
        assert_eq!(&g[..3], &[12.0, 24.0, 36.0]);
        assert!(g.contains(&48.0) && g.contains(&480.0));
        assert_eq!(g.len(), 13);
    }

    #[test]
    fn paper_space_has_thousands_of_points() {
        let pts = enumerate_space(&AreaModel::paper(), &SpaceSpec::paper());
        // Fig 3 reports ≈3000 feasible 2-D design points; the enumeration
        // (shared by both workload classes) must be the same order.
        assert!(
            (1500..8000).contains(&pts.len()),
            "feasible design points: {}",
            pts.len()
        );
        assert!(pts.iter().all(|p| p.area_mm2 <= 650.0));
        assert!(pts.iter().all(|p| p.hw.l1_smpair_kb == 0.0 && p.hw.l2_kb == 0.0));
    }

    #[test]
    fn all_points_on_manufacturer_grid() {
        let pts = enumerate_space(&AreaModel::paper(), &SpaceSpec::small());
        assert!(pts.iter().all(|p| p.hw.respects_manufacturer_patterns()));
    }

    #[test]
    fn shrunk_is_small_on_the_paper_space_and_respects_tighter_bounds() {
        assert_eq!(SpaceSpec::paper().shrunk(), SpaceSpec::small());
        let tight = SpaceSpec { n_sm_max: 8, n_v_max: 128, ..SpaceSpec::paper() };
        let q = tight.shrunk();
        assert_eq!((q.n_sm_max, q.n_v_max), (8, 128));
        assert_eq!(q.m_sm_max_kb, 192.0);
    }

    #[test]
    fn budget_monotone() {
        let model = AreaModel::paper();
        let lo = enumerate_space(&model, &SpaceSpec { max_area_mm2: 300.0, ..SpaceSpec::paper() });
        let hi = enumerate_space(&model, &SpaceSpec::paper());
        assert!(lo.len() < hi.len());
    }
}
