//! Resource allocation (§V-C, Fig 4): where does the chip area of each
//! design go? Pareto designs cluster in the (%-memory, %-cores) plane.

use crate::area::model::AreaModel;
use crate::codesign::scenario::ScenarioResult;

/// One design's allocation coordinates.
#[derive(Clone, Copy, Debug)]
pub struct AllocationPoint {
    /// % of chip area in explicitly-managed memory (register files + shm).
    pub pct_memory: f64,
    /// % of chip area in vector-unit core logic.
    pub pct_cores: f64,
    pub area_mm2: f64,
    pub gflops: f64,
    pub is_pareto: bool,
}

/// Compute Fig 4's point cloud from a scenario result.
pub fn allocation_points(result: &ScenarioResult, area_model: &AreaModel) -> Vec<AllocationPoint> {
    result
        .points
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let b = area_model.breakdown(&p.hw);
            let (pct_memory, pct_cores) = b.allocation_pcts();
            AllocationPoint {
                pct_memory,
                pct_cores,
                area_mm2: p.area_mm2,
                gflops: p.gflops,
                is_pareto: result.pareto.contains(&i),
            }
        })
        .collect()
}

/// Dispersion measure used to quantify the paper's "optimal designs cluster"
/// observation: mean Euclidean distance to the centroid in the
/// (%mem, %cores) plane.
pub fn dispersion(points: &[(f64, f64)]) -> f64 {
    if points.is_empty() {
        return f64::NAN;
    }
    let n = points.len() as f64;
    let cx = points.iter().map(|p| p.0).sum::<f64>() / n;
    let cy = points.iter().map(|p| p.1).sum::<f64>() / n;
    points.iter().map(|p| ((p.0 - cx).powi(2) + (p.1 - cy).powi(2)).sqrt()).sum::<f64>() / n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codesign::scenario::testfix;

    #[test]
    fn allocation_sums_below_100() {
        let r = testfix::quick_2d();
        let pts = allocation_points(r, &AreaModel::paper());
        assert_eq!(pts.len(), r.points.len());
        for p in &pts {
            assert!(p.pct_memory > 0.0 && p.pct_cores > 0.0);
            assert!(p.pct_memory + p.pct_cores < 100.0);
        }
        assert_eq!(pts.iter().filter(|p| p.is_pareto).count(), r.pareto.len());
    }

    #[test]
    fn pareto_designs_cluster_tighter_than_the_cloud() {
        // §V-C's qualitative observation, quantified.
        let r = testfix::quick_2d();
        let pts = allocation_points(r, &AreaModel::paper());
        let all: Vec<(f64, f64)> = pts.iter().map(|p| (p.pct_memory, p.pct_cores)).collect();
        let front: Vec<(f64, f64)> =
            pts.iter().filter(|p| p.is_pareto).map(|p| (p.pct_memory, p.pct_cores)).collect();
        assert!(front.len() > 2);
        assert!(
            dispersion(&front) < dispersion(&all),
            "front dispersion {} vs cloud {}",
            dispersion(&front),
            dispersion(&all)
        );
    }

    #[test]
    fn dispersion_edge_cases() {
        assert!(dispersion(&[]).is_nan());
        assert_eq!(dispersion(&[(1.0, 2.0)]), 0.0);
        assert!((dispersion(&[(0.0, 0.0), (2.0, 0.0)]) - 1.0).abs() < 1e-12);
    }
}
