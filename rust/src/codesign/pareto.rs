//! Pareto-frontier extraction over (area, performance).
//!
//! Fig 3's observation: of the thousands of feasible designs only ~1% are
//! Pareto-optimal — "a nearly 100-fold savings in design cost".

/// A design is Pareto-optimal iff no other design has `area ≤` **and**
/// `perf ≥` with at least one strict. Returns indices into `points`,
/// sorted by area ascending.
///
/// `O(n log n)`: sort by (area asc, perf desc), then a single max-scan.
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .0
            .partial_cmp(&points[b].0)
            .unwrap()
            .then(points[b].1.partial_cmp(&points[a].1).unwrap())
    });
    let mut front = Vec::new();
    let mut best_perf = f64::NEG_INFINITY;
    let mut last_area = f64::NEG_INFINITY;
    for &i in &idx {
        let (area, perf) = points[i];
        if perf > best_perf {
            // Equal-area ties: the sort put the best-perf one first; any
            // later equal-area point with lower perf is dominated, and an
            // equal-area equal-perf duplicate is redundant.
            if area == last_area && perf == best_perf {
                continue;
            }
            front.push(i);
            best_perf = perf;
            last_area = area;
        }
    }
    front
}

/// Best performance among points with `area ≤ budget`. Returns the index.
pub fn best_within_area(points: &[(f64, f64)], budget: f64) -> Option<usize> {
    points
        .iter()
        .enumerate()
        .filter(|(_, p)| p.0 <= budget)
        .max_by(|a, b| a.1 .1.partial_cmp(&b.1 .1).unwrap())
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_front() {
        // (area, perf)
        let pts = vec![(1.0, 1.0), (2.0, 3.0), (3.0, 2.0), (2.5, 3.5), (4.0, 4.0)];
        let f = pareto_front(&pts);
        // (3.0, 2.0) dominated by (2.5, 3.5); (2.0,3.0) on front.
        assert_eq!(f, vec![0, 1, 3, 4]);
    }

    #[test]
    fn dominated_duplicates_removed() {
        let pts = vec![(1.0, 1.0), (1.0, 1.0), (1.0, 2.0)];
        let f = pareto_front(&pts);
        assert_eq!(f.len(), 1);
        assert_eq!(pts[f[0]], (1.0, 2.0));
    }

    #[test]
    fn front_invariants() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(99);
        let pts: Vec<(f64, f64)> =
            (0..500).map(|_| (rng.f64() * 100.0, rng.f64() * 100.0)).collect();
        let f = pareto_front(&pts);
        // 1. No front point dominates another front point.
        for &a in &f {
            for &b in &f {
                if a != b {
                    let dom = pts[a].0 <= pts[b].0
                        && pts[a].1 >= pts[b].1
                        && (pts[a].0 < pts[b].0 || pts[a].1 > pts[b].1);
                    assert!(!dom, "front point dominates front point");
                }
            }
        }
        // 2. Every non-front point is dominated by some front point.
        for i in 0..pts.len() {
            if !f.contains(&i) {
                assert!(
                    f.iter().any(|&a| {
                        pts[a].0 <= pts[i].0
                            && pts[a].1 >= pts[i].1
                            && (pts[a].0 < pts[i].0 || pts[a].1 > pts[i].1)
                    }),
                    "non-front point {i} not dominated"
                );
            }
        }
        // 3. Sorted by area, strictly increasing perf.
        for w in f.windows(2) {
            assert!(pts[w[0]].0 <= pts[w[1]].0);
            assert!(pts[w[0]].1 < pts[w[1]].1);
        }
    }

    #[test]
    fn best_within_budget() {
        let pts = vec![(1.0, 1.0), (2.0, 3.0), (3.0, 9.0)];
        assert_eq!(best_within_area(&pts, 2.5), Some(1));
        assert_eq!(best_within_area(&pts, 0.5), None);
        assert_eq!(best_within_area(&pts, 10.0), Some(2));
    }
}
