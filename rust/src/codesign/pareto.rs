//! Pareto-frontier extraction over (area, performance).
//!
//! Fig 3's observation: of the thousands of feasible designs only ~1% are
//! Pareto-optimal — "a nearly 100-fold savings in design cost".

/// A design is Pareto-optimal iff no other design has `area ≤` **and**
/// `perf ≥` with at least one strict. Returns indices into `points`,
/// sorted by area ascending.
///
/// `O(n log n)`: sort by (area asc, perf desc), then a single max-scan.
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .0
            .partial_cmp(&points[b].0)
            .unwrap()
            .then(points[b].1.partial_cmp(&points[a].1).unwrap())
    });
    let mut front = Vec::new();
    let mut best_perf = f64::NEG_INFINITY;
    let mut last_area = f64::NEG_INFINITY;
    for &i in &idx {
        let (area, perf) = points[i];
        if perf > best_perf {
            // Equal-area ties: the sort put the best-perf one first; any
            // later equal-area point with lower perf is dominated, and an
            // equal-area equal-perf duplicate is redundant.
            if area == last_area && perf == best_perf {
                continue;
            }
            front.push(i);
            best_perf = perf;
            last_area = area;
        }
    }
    front
}

/// Incrementally maintained Pareto front over (area ↓ good, perf ↑ good).
///
/// The batched DSE engine streams candidate designs as they are aggregated
/// and keeps the front current after every insertion instead of re-running
/// [`pareto_front`] over the full point set per scenario. Entries are kept
/// strictly increasing in *both* area and perf, so an insert is a binary
/// search plus one contiguous splice — `O(n)` worst case in the front size
/// `n` (the splice shifts the tail). That's the right trade here because
/// fronts stay tiny (~1% of the points, Fig 3); don't reuse this for huge
/// fronts fed in descending-area order, which degenerates to `Θ(n²)`.
///
/// Feeding every point of a slice in index order yields exactly
/// [`pareto_front`]'s output, ties included (certified by the property test
/// `prop_incremental_pareto_front_matches_batch`). Coordinates must be
/// finite (no NaN).
#[derive(Clone, Debug, Default)]
pub struct ParetoFront {
    /// `(area, perf, caller index)`, area strictly ascending, perf strictly
    /// ascending.
    entries: Vec<(f64, f64, usize)>,
}

impl ParetoFront {
    pub fn new() -> ParetoFront {
        ParetoFront { entries: Vec::new() }
    }

    /// Offer one point. Returns `true` if it joined the front (possibly
    /// evicting now-dominated entries), `false` if an existing entry
    /// dominates or duplicates it.
    pub fn insert(&mut self, area: f64, perf: f64, index: usize) -> bool {
        // Loud like `pareto_front`'s `partial_cmp().unwrap()`: a NaN here
        // (e.g. an all-zero-weight workload aggregating to 0/0) would
        // otherwise corrupt the front silently.
        assert!(
            area.is_finite() && perf.is_finite(),
            "ParetoFront requires finite coordinates (got area {area}, perf {perf})"
        );
        // First entry with area strictly greater than the candidate's.
        let pos = self.entries.partition_point(|e| e.0 <= area);
        if pos > 0 && self.entries[pos - 1].1 >= perf {
            // The best entry at area ≤ `area` already performs at least as
            // well: the candidate is dominated (or an exact duplicate, where
            // the first-seen index is kept, matching `pareto_front`).
            return false;
        }
        // Evict the contiguous run the candidate dominates: an equal-area
        // predecessor with lower perf, plus every larger-area entry whose
        // perf does not exceed the candidate's.
        let start = if pos > 0 && self.entries[pos - 1].0 == area { pos - 1 } else { pos };
        let mut end = start;
        while end < self.entries.len() && self.entries[end].1 <= perf {
            end += 1;
        }
        self.entries.splice(start..end, std::iter::once((area, perf, index)));
        true
    }

    /// Caller indices of the current front, area-ascending — the same shape
    /// [`pareto_front`] returns.
    pub fn indices(&self) -> Vec<usize> {
        self.entries.iter().map(|e| e.2).collect()
    }

    /// Best performance among front entries with `area ≤ budget`, or `None`
    /// when nothing fits. Because entries ascend strictly in both area and
    /// perf, this is the last entry at or under the budget — an `O(log n)`
    /// probe the bound-gated sweep uses as its domination test (a candidate
    /// whose perf *upper bound* does not beat this cannot join the front).
    pub fn best_perf_within(&self, budget: f64) -> Option<f64> {
        let pos = self.entries.partition_point(|e| e.0 <= budget);
        (pos > 0).then(|| self.entries[pos - 1].1)
    }

    /// The `(area, perf, index)` entries, area-ascending.
    pub fn entries(&self) -> &[(f64, f64, usize)] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Best performance among points with `area ≤ budget`. Returns the index.
pub fn best_within_area(points: &[(f64, f64)], budget: f64) -> Option<usize> {
    points
        .iter()
        .enumerate()
        .filter(|(_, p)| p.0 <= budget)
        .max_by(|a, b| a.1 .1.partial_cmp(&b.1 .1).unwrap())
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_front() {
        // (area, perf)
        let pts = vec![(1.0, 1.0), (2.0, 3.0), (3.0, 2.0), (2.5, 3.5), (4.0, 4.0)];
        let f = pareto_front(&pts);
        // (3.0, 2.0) dominated by (2.5, 3.5); (2.0,3.0) on front.
        assert_eq!(f, vec![0, 1, 3, 4]);
    }

    #[test]
    fn dominated_duplicates_removed() {
        let pts = vec![(1.0, 1.0), (1.0, 1.0), (1.0, 2.0)];
        let f = pareto_front(&pts);
        assert_eq!(f.len(), 1);
        assert_eq!(pts[f[0]], (1.0, 2.0));
    }

    #[test]
    fn front_invariants() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(99);
        let pts: Vec<(f64, f64)> =
            (0..500).map(|_| (rng.f64() * 100.0, rng.f64() * 100.0)).collect();
        let f = pareto_front(&pts);
        // 1. No front point dominates another front point.
        for &a in &f {
            for &b in &f {
                if a != b {
                    let dom = pts[a].0 <= pts[b].0
                        && pts[a].1 >= pts[b].1
                        && (pts[a].0 < pts[b].0 || pts[a].1 > pts[b].1);
                    assert!(!dom, "front point dominates front point");
                }
            }
        }
        // 2. Every non-front point is dominated by some front point.
        for i in 0..pts.len() {
            if !f.contains(&i) {
                assert!(
                    f.iter().any(|&a| {
                        pts[a].0 <= pts[i].0
                            && pts[a].1 >= pts[i].1
                            && (pts[a].0 < pts[i].0 || pts[a].1 > pts[i].1)
                    }),
                    "non-front point {i} not dominated"
                );
            }
        }
        // 3. Sorted by area, strictly increasing perf.
        for w in f.windows(2) {
            assert!(pts[w[0]].0 <= pts[w[1]].0);
            assert!(pts[w[0]].1 < pts[w[1]].1);
        }
    }

    #[test]
    fn incremental_front_matches_batch_on_examples() {
        let cases: Vec<Vec<(f64, f64)>> = vec![
            vec![(1.0, 1.0), (2.0, 3.0), (3.0, 2.0), (2.5, 3.5), (4.0, 4.0)],
            vec![(1.0, 1.0), (1.0, 1.0), (1.0, 2.0)],
            vec![(1.0, 5.0), (1.0, 9.0), (1.0, 7.0)], // equal areas, mixed order
            vec![(3.0, 1.0), (2.0, 2.0), (1.0, 3.0)], // strictly improving inserts
            vec![(5.0, 5.0)],
        ];
        for pts in cases {
            let mut inc = ParetoFront::new();
            for (i, &(a, p)) in pts.iter().enumerate() {
                inc.insert(a, p, i);
            }
            assert_eq!(inc.indices(), pareto_front(&pts), "points {pts:?}");
            assert_eq!(inc.len(), inc.indices().len());
        }
    }

    #[test]
    fn incremental_front_stays_strictly_sorted() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(4242);
        let mut inc = ParetoFront::new();
        for i in 0..2000 {
            // Quantized coordinates force frequent area/perf ties.
            let a = rng.range_u64(0, 30) as f64;
            let p = rng.range_u64(0, 30) as f64;
            inc.insert(a, p, i);
            for w in inc.entries().windows(2) {
                assert!(w[0].0 < w[1].0, "area not strictly ascending");
                assert!(w[0].1 < w[1].1, "perf not strictly ascending");
            }
        }
        assert!(!inc.is_empty());
    }

    #[test]
    fn insert_reports_membership() {
        let mut inc = ParetoFront::new();
        assert!(inc.insert(2.0, 2.0, 0));
        assert!(!inc.insert(3.0, 1.0, 1), "dominated point must be rejected");
        assert!(!inc.insert(2.0, 2.0, 2), "duplicate keeps the first index");
        assert!(inc.insert(1.0, 3.0, 3), "dominating point evicts");
        assert_eq!(inc.indices(), vec![3]);
    }

    #[test]
    fn best_within_budget() {
        let pts = vec![(1.0, 1.0), (2.0, 3.0), (3.0, 9.0)];
        assert_eq!(best_within_area(&pts, 2.5), Some(1));
        assert_eq!(best_within_area(&pts, 0.5), None);
        assert_eq!(best_within_area(&pts, 10.0), Some(2));
    }

    #[test]
    fn incremental_best_perf_within_matches_point_scan() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(7);
        let pts: Vec<(f64, f64)> =
            (0..300).map(|_| (rng.range_u64(0, 40) as f64, rng.range_u64(0, 40) as f64)).collect();
        let mut inc = ParetoFront::new();
        for (i, &(a, p)) in pts.iter().enumerate() {
            inc.insert(a, p, i);
        }
        for budget in [0.0, 3.5, 17.0, 39.0, 100.0] {
            let scan = pts
                .iter()
                .filter(|p| p.0 <= budget)
                .map(|p| p.1)
                .fold(f64::NEG_INFINITY, f64::max);
            match inc.best_perf_within(budget) {
                None => assert!(scan.is_infinite(), "budget {budget}"),
                Some(b) => assert_eq!(b, scan, "budget {budget}"),
            }
        }
    }
}
