//! Pareto-frontier extraction over (area, performance).
//!
//! Fig 3's observation: of the thousands of feasible designs only ~1% are
//! Pareto-optimal — "a nearly 100-fold savings in design cost".

/// A design is Pareto-optimal iff no other design has `area ≤` **and**
/// `perf ≥` with at least one strict. Returns indices into `points`,
/// sorted by area ascending.
///
/// `O(n log n)`: sort by (area asc, perf desc), then a single max-scan.
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .0
            .partial_cmp(&points[b].0)
            .unwrap()
            .then(points[b].1.partial_cmp(&points[a].1).unwrap())
    });
    let mut front = Vec::new();
    let mut best_perf = f64::NEG_INFINITY;
    let mut last_area = f64::NEG_INFINITY;
    for &i in &idx {
        let (area, perf) = points[i];
        if perf > best_perf {
            // Equal-area ties: the sort put the best-perf one first; any
            // later equal-area point with lower perf is dominated, and an
            // equal-area equal-perf duplicate is redundant.
            if area == last_area && perf == best_perf {
                continue;
            }
            front.push(i);
            best_perf = perf;
            last_area = area;
        }
    }
    front
}

/// Incrementally maintained Pareto front over (area ↓ good, perf ↑ good).
///
/// The batched DSE engine streams candidate designs as they are aggregated
/// and keeps the front current after every insertion instead of re-running
/// [`pareto_front`] over the full point set per scenario. Entries are kept
/// strictly increasing in *both* area and perf, so an insert is a binary
/// search plus one contiguous splice — `O(n)` worst case in the front size
/// `n` (the splice shifts the tail). That's the right trade here because
/// fronts stay tiny (~1% of the points, Fig 3); don't reuse this for huge
/// fronts fed in descending-area order, which degenerates to `Θ(n²)`.
///
/// Feeding every point of a slice in index order yields exactly
/// [`pareto_front`]'s output, ties included (certified by the property test
/// `prop_incremental_pareto_front_matches_batch`). Coordinates must be
/// finite (no NaN).
#[derive(Clone, Debug, Default)]
pub struct ParetoFront {
    /// `(area, perf, caller index)`, area strictly ascending, perf strictly
    /// ascending.
    entries: Vec<(f64, f64, usize)>,
}

impl ParetoFront {
    pub fn new() -> ParetoFront {
        ParetoFront { entries: Vec::new() }
    }

    /// Offer one point. Returns `true` if it joined the front (possibly
    /// evicting now-dominated entries), `false` if an existing entry
    /// dominates or duplicates it.
    pub fn insert(&mut self, area: f64, perf: f64, index: usize) -> bool {
        // Loud like `pareto_front`'s `partial_cmp().unwrap()`: a NaN here
        // (e.g. an all-zero-weight workload aggregating to 0/0) would
        // otherwise corrupt the front silently.
        assert!(
            area.is_finite() && perf.is_finite(),
            "ParetoFront requires finite coordinates (got area {area}, perf {perf})"
        );
        // First entry with area strictly greater than the candidate's.
        let pos = self.entries.partition_point(|e| e.0 <= area);
        if pos > 0 && self.entries[pos - 1].1 >= perf {
            // The best entry at area ≤ `area` already performs at least as
            // well: the candidate is dominated (or an exact duplicate, where
            // the first-seen index is kept, matching `pareto_front`).
            return false;
        }
        // Evict the contiguous run the candidate dominates: an equal-area
        // predecessor with lower perf, plus every larger-area entry whose
        // perf does not exceed the candidate's.
        let start = if pos > 0 && self.entries[pos - 1].0 == area { pos - 1 } else { pos };
        let mut end = start;
        while end < self.entries.len() && self.entries[end].1 <= perf {
            end += 1;
        }
        self.entries.splice(start..end, std::iter::once((area, perf, index)));
        true
    }

    /// Caller indices of the current front, area-ascending — the same shape
    /// [`pareto_front`] returns.
    pub fn indices(&self) -> Vec<usize> {
        self.entries.iter().map(|e| e.2).collect()
    }

    /// Best performance among front entries with `area ≤ budget`, or `None`
    /// when nothing fits. Because entries ascend strictly in both area and
    /// perf, this is the last entry at or under the budget — an `O(log n)`
    /// probe the bound-gated sweep uses as its domination test (a candidate
    /// whose perf *upper bound* does not beat this cannot join the front).
    pub fn best_perf_within(&self, budget: f64) -> Option<f64> {
        let pos = self.entries.partition_point(|e| e.0 <= budget);
        (pos > 0).then(|| self.entries[pos - 1].1)
    }

    /// The `(area, perf, index)` entries, area-ascending.
    pub fn entries(&self) -> &[(f64, f64, usize)] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Brute-force tri-objective Pareto front over `(area ↓, perf ↑, energy ↓)`.
///
/// A point is kept iff no other point weakly dominates it with at least one
/// strict inequality, and — among exact all-equal duplicates — only the
/// first occurrence survives (matching [`pareto_front`]'s tie rule and
/// [`ParetoFront3`]'s first-seen-wins insert). Returns indices into
/// `points` in ascending index (enumeration) order: `O(n²)`, the oracle
/// the certification tier checks the incremental front against.
pub fn pareto_front3(points: &[(f64, f64, f64)]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| {
            let p = points[i];
            !points.iter().enumerate().any(|(j, &q)| {
                if j == i {
                    return false;
                }
                let weak = q.0 <= p.0 && q.1 >= p.1 && q.2 <= p.2;
                if !weak {
                    return false;
                }
                let strict = q.0 < p.0 || q.1 > p.1 || q.2 < p.2;
                // Strict domination kills `i`; an all-equal duplicate kills
                // it only when the duplicate came first.
                strict || j < i
            })
        })
        .collect()
}

/// Incrementally maintained tri-objective Pareto front over
/// `(area ↓ good, perf ↑ good, energy ↓ good)`.
///
/// The 3-D counterpart of [`ParetoFront`], with the same streaming contract:
/// feeding every point of a slice in index order yields exactly
/// [`pareto_front3`]'s output, ties included (certified by
/// `prop_incremental_pareto_front3_matches_batch` and the exhaustive-grid
/// oracle in `integration_energy.rs`). Unlike the 2-D front there is no
/// total order that keeps 3-D entries in one sorted run, so entries are
/// held in insertion order and both the insert scan and the eviction pass
/// are linear in the front size — still cheap, because tri-objective fronts
/// stay a small fraction of the enumerated space.
#[derive(Clone, Debug, Default)]
pub struct ParetoFront3 {
    /// `(area, perf, energy, caller index)` in insertion order; no entry
    /// weakly dominates another.
    entries: Vec<(f64, f64, f64, usize)>,
}

impl ParetoFront3 {
    pub fn new() -> ParetoFront3 {
        ParetoFront3 { entries: Vec::new() }
    }

    /// Offer one point. Returns `true` if it joined the front (evicting any
    /// entries it now dominates), `false` if an existing entry dominates or
    /// exactly duplicates it (first-seen index kept, matching
    /// [`pareto_front3`]).
    pub fn insert(&mut self, area: f64, perf: f64, energy: f64, index: usize) -> bool {
        assert!(
            area.is_finite() && perf.is_finite() && energy.is_finite(),
            "ParetoFront3 requires finite coordinates \
             (got area {area}, perf {perf}, energy {energy})"
        );
        // Weak domination-or-tie by any resident entry rejects the
        // candidate: strictly worse somewhere, or an exact duplicate.
        if self
            .entries
            .iter()
            .any(|e| e.0 <= area && e.1 >= perf && e.2 <= energy)
        {
            return false;
        }
        // No survivor of the check above can tie the candidate on all three
        // axes, so everything this retain drops is strictly dominated.
        self.entries.retain(|e| !(area <= e.0 && perf >= e.1 && energy <= e.2));
        self.entries.push((area, perf, energy, index));
        true
    }

    /// `true` iff some front entry weakly dominates the *optimistic* corner
    /// `(area, perf_ub, energy_lb)` of a candidate. Because `perf_ub` and
    /// `energy_lb` carry the bounds' one-sided safety margin (strictly above
    /// the true perf / strictly below the true energy of any feasible
    /// design), a `true` here means the entry **strictly** dominates the
    /// candidate's true point — it can never join the front, and skipping
    /// its solve cannot change the result. This is the gated sweep's 3-D
    /// domination probe, the tri-objective analogue of
    /// [`ParetoFront::best_perf_within`].
    pub fn dominates_bound(&self, area: f64, perf_ub: f64, energy_lb: f64) -> bool {
        self.entries
            .iter()
            .any(|e| e.0 <= area && e.1 >= perf_ub && e.2 <= energy_lb)
    }

    /// Caller indices of the current front, ascending (enumeration order) —
    /// the same shape [`pareto_front3`] returns.
    pub fn indices(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = self.entries.iter().map(|e| e.3).collect();
        idx.sort_unstable();
        idx
    }

    /// The `(area, perf, energy, index)` entries in insertion order.
    pub fn entries(&self) -> &[(f64, f64, f64, usize)] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Best performance among points with `area ≤ budget`. Returns the index.
pub fn best_within_area(points: &[(f64, f64)], budget: f64) -> Option<usize> {
    points
        .iter()
        .enumerate()
        .filter(|(_, p)| p.0 <= budget)
        .max_by(|a, b| a.1 .1.partial_cmp(&b.1 .1).unwrap())
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_front() {
        // (area, perf)
        let pts = vec![(1.0, 1.0), (2.0, 3.0), (3.0, 2.0), (2.5, 3.5), (4.0, 4.0)];
        let f = pareto_front(&pts);
        // (3.0, 2.0) dominated by (2.5, 3.5); (2.0,3.0) on front.
        assert_eq!(f, vec![0, 1, 3, 4]);
    }

    #[test]
    fn dominated_duplicates_removed() {
        let pts = vec![(1.0, 1.0), (1.0, 1.0), (1.0, 2.0)];
        let f = pareto_front(&pts);
        assert_eq!(f.len(), 1);
        assert_eq!(pts[f[0]], (1.0, 2.0));
    }

    #[test]
    fn front_invariants() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(99);
        let pts: Vec<(f64, f64)> =
            (0..500).map(|_| (rng.f64() * 100.0, rng.f64() * 100.0)).collect();
        let f = pareto_front(&pts);
        // 1. No front point dominates another front point.
        for &a in &f {
            for &b in &f {
                if a != b {
                    let dom = pts[a].0 <= pts[b].0
                        && pts[a].1 >= pts[b].1
                        && (pts[a].0 < pts[b].0 || pts[a].1 > pts[b].1);
                    assert!(!dom, "front point dominates front point");
                }
            }
        }
        // 2. Every non-front point is dominated by some front point.
        for i in 0..pts.len() {
            if !f.contains(&i) {
                assert!(
                    f.iter().any(|&a| {
                        pts[a].0 <= pts[i].0
                            && pts[a].1 >= pts[i].1
                            && (pts[a].0 < pts[i].0 || pts[a].1 > pts[i].1)
                    }),
                    "non-front point {i} not dominated"
                );
            }
        }
        // 3. Sorted by area, strictly increasing perf.
        for w in f.windows(2) {
            assert!(pts[w[0]].0 <= pts[w[1]].0);
            assert!(pts[w[0]].1 < pts[w[1]].1);
        }
    }

    #[test]
    fn incremental_front_matches_batch_on_examples() {
        let cases: Vec<Vec<(f64, f64)>> = vec![
            vec![(1.0, 1.0), (2.0, 3.0), (3.0, 2.0), (2.5, 3.5), (4.0, 4.0)],
            vec![(1.0, 1.0), (1.0, 1.0), (1.0, 2.0)],
            vec![(1.0, 5.0), (1.0, 9.0), (1.0, 7.0)], // equal areas, mixed order
            vec![(3.0, 1.0), (2.0, 2.0), (1.0, 3.0)], // strictly improving inserts
            vec![(5.0, 5.0)],
        ];
        for pts in cases {
            let mut inc = ParetoFront::new();
            for (i, &(a, p)) in pts.iter().enumerate() {
                inc.insert(a, p, i);
            }
            assert_eq!(inc.indices(), pareto_front(&pts), "points {pts:?}");
            assert_eq!(inc.len(), inc.indices().len());
        }
    }

    #[test]
    fn incremental_front_stays_strictly_sorted() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(4242);
        let mut inc = ParetoFront::new();
        for i in 0..2000 {
            // Quantized coordinates force frequent area/perf ties.
            let a = rng.range_u64(0, 30) as f64;
            let p = rng.range_u64(0, 30) as f64;
            inc.insert(a, p, i);
            for w in inc.entries().windows(2) {
                assert!(w[0].0 < w[1].0, "area not strictly ascending");
                assert!(w[0].1 < w[1].1, "perf not strictly ascending");
            }
        }
        assert!(!inc.is_empty());
    }

    #[test]
    fn insert_reports_membership() {
        let mut inc = ParetoFront::new();
        assert!(inc.insert(2.0, 2.0, 0));
        assert!(!inc.insert(3.0, 1.0, 1), "dominated point must be rejected");
        assert!(!inc.insert(2.0, 2.0, 2), "duplicate keeps the first index");
        assert!(inc.insert(1.0, 3.0, 3), "dominating point evicts");
        assert_eq!(inc.indices(), vec![3]);
    }

    #[test]
    fn best_within_budget() {
        let pts = vec![(1.0, 1.0), (2.0, 3.0), (3.0, 9.0)];
        assert_eq!(best_within_area(&pts, 2.5), Some(1));
        assert_eq!(best_within_area(&pts, 0.5), None);
        assert_eq!(best_within_area(&pts, 10.0), Some(2));
    }

    #[test]
    fn front3_simple() {
        // (area ↓, perf ↑, energy ↓)
        let pts = vec![
            (1.0, 1.0, 1.0), // on front
            (2.0, 3.0, 2.0), // on front
            (3.0, 2.0, 3.0), // dominated by index 1
            (2.0, 2.0, 1.5), // on front: cheaper energy than 1, better perf than 0
            (4.0, 4.0, 4.0), // on front: best perf
        ];
        assert_eq!(pareto_front3(&pts), vec![0, 1, 3, 4]);
        let mut inc = ParetoFront3::new();
        for (i, &(a, p, e)) in pts.iter().enumerate() {
            inc.insert(a, p, e, i);
        }
        assert_eq!(inc.indices(), vec![0, 1, 3, 4]);
    }

    #[test]
    fn front3_energy_axis_rescues_perf_dominated_points() {
        // Same area and worse perf, but lower energy → incomparable, kept.
        // This is exactly the case that makes a pure perf-gate unsound in 3-D.
        let pts = vec![(2.0, 5.0, 10.0), (2.0, 3.0, 4.0)];
        assert_eq!(pareto_front3(&pts), vec![0, 1]);
        let mut inc = ParetoFront3::new();
        for (i, &(a, p, e)) in pts.iter().enumerate() {
            inc.insert(a, p, e, i);
        }
        assert_eq!(inc.indices(), vec![0, 1]);
    }

    #[test]
    fn front3_duplicates_keep_first_index() {
        let pts = vec![(1.0, 2.0, 3.0), (1.0, 2.0, 3.0), (1.0, 2.0, 2.0)];
        // Index 0 beats its duplicate 1; index 2 strictly dominates both.
        assert_eq!(pareto_front3(&pts), vec![2]);
        let mut inc = ParetoFront3::new();
        assert!(inc.insert(1.0, 2.0, 3.0, 0));
        assert!(!inc.insert(1.0, 2.0, 3.0, 1), "duplicate keeps the first index");
        assert!(inc.insert(1.0, 2.0, 2.0, 2), "strict dominator evicts");
        assert_eq!(inc.indices(), vec![2]);
    }

    #[test]
    fn incremental_front3_matches_batch_on_quantized_random_points() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(0x3d0f);
        for case in 0..40 {
            // Heavy quantization forces ties on every axis.
            let n = 1 + (case % 7) * 30;
            let pts: Vec<(f64, f64, f64)> = (0..n)
                .map(|_| {
                    (
                        rng.range_u64(0, 8) as f64,
                        rng.range_u64(0, 8) as f64,
                        rng.range_u64(0, 8) as f64,
                    )
                })
                .collect();
            let mut inc = ParetoFront3::new();
            for (i, &(a, p, e)) in pts.iter().enumerate() {
                inc.insert(a, p, e, i);
            }
            assert_eq!(inc.indices(), pareto_front3(&pts), "case {case}: {pts:?}");
            assert_eq!(inc.len(), inc.indices().len());
        }
    }

    #[test]
    fn front3_no_entry_dominates_another() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(77);
        let mut inc = ParetoFront3::new();
        for i in 0..1500 {
            let a = rng.range_u64(0, 20) as f64;
            let p = rng.range_u64(0, 20) as f64;
            let e = rng.range_u64(0, 20) as f64;
            inc.insert(a, p, e, i);
        }
        assert!(!inc.is_empty());
        let entries = inc.entries();
        for x in entries {
            for y in entries {
                if x.3 != y.3 {
                    let weak = x.0 <= y.0 && x.1 >= y.1 && x.2 <= y.2;
                    assert!(!weak, "front entry {x:?} weakly dominates {y:?}");
                }
            }
        }
    }

    #[test]
    fn front3_dominates_bound_probe() {
        let mut inc = ParetoFront3::new();
        inc.insert(2.0, 5.0, 3.0, 0);
        // Optimistic corner worse-or-equal on all axes → prunable.
        assert!(inc.dominates_bound(2.0, 5.0, 3.0));
        assert!(inc.dominates_bound(3.0, 4.0, 4.0));
        // Any axis where the corner beats the entry → must solve.
        assert!(!inc.dominates_bound(1.5, 4.0, 4.0), "smaller area escapes");
        assert!(!inc.dominates_bound(3.0, 6.0, 4.0), "higher perf UB escapes");
        assert!(!inc.dominates_bound(3.0, 4.0, 2.0), "lower energy LB escapes");
    }

    #[test]
    fn incremental_best_perf_within_matches_point_scan() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(7);
        let pts: Vec<(f64, f64)> =
            (0..300).map(|_| (rng.range_u64(0, 40) as f64, rng.range_u64(0, 40) as f64)).collect();
        let mut inc = ParetoFront::new();
        for (i, &(a, p)) in pts.iter().enumerate() {
            inc.insert(a, p, i);
        }
        for budget in [0.0, 3.5, 17.0, 39.0, 100.0] {
            let scan = pts
                .iter()
                .filter(|p| p.0 <= budget)
                .map(|p| p.1)
                .fold(f64::NEG_INFINITY, f64::max);
            match inc.best_perf_within(budget) {
                None => assert!(scan.is_infinite(), "budget {budget}"),
                Some(b) => assert_eq!(b, scan, "budget {budget}"),
            }
        }
    }
}
