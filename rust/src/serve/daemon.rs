//! The long-running serve daemon: a streaming request loop over [`Session`].
//!
//! [`Daemon::run`] reads newline-delimited request frames ([`super::proto`])
//! continuously from any `BufRead` (stdin, a Unix-socket connection, an
//! in-process pipe in tests), answers them on worker threads, and streams
//! each response frame back the moment its request completes — tagged by the
//! client's `id`, **not** in arrival order.
//!
//! # Concurrency model
//!
//! The daemon keys every request to its compatible batch group — the
//! `(platform fingerprint, C_iter table, solver options)` partition triple
//! PR 2's session partitioning defined — and holds **one [`Session`] per
//! partition key**, each behind its own mutex. Requests for different
//! partitions run fully concurrently (their coordinators share nothing);
//! requests for the same partition serialize on its session, which is
//! exactly the batch-compatibility constraint. `Validate`/`SolverCost`
//! requests touch no coordinator and ride a separate direct-lane session.
//! A counting gate caps concurrently-running groups at
//! [`DaemonConfig::max_groups`]; inside a group, the coordinator's own
//! data-parallel sweep (the existing thread pool) is untouched.
//!
//! One deliberate cost: a `Sensitivity` request spans two scenarios but is
//! keyed by its 2-D scenario, so when its 3-D scenario names a different
//! platform the daemon may build a coordinator that duplicates one living
//! in another partition session. That duplicates *work*, never answers —
//! the memo stores can't alias, so results stay bit-identical to one-shot
//! serving either way.
//!
//! # Backpressure
//!
//! Admission is explicit: a bounded [`Mailbox`] caps **outstanding** work
//! (queued + in-flight). When full, the request is answered immediately
//! with a `rejected: "overloaded"` frame carrying the mailbox counters, and
//! in-flight work is untouched. A `{"type": "stats"}` probe is answered
//! synchronously by the reader thread — it bypasses the mailbox and never
//! blocks behind a running solve (its memory figures are the post-request
//! mirrors, not a live cache walk, for the same reason).
//!
//! # Bit-identity
//!
//! Answers equal one-shot `serve --requests` for the same request set: the
//! response payload is the same [`wire`](crate::service::wire) encoding of
//! the same [`Session`] answer, partitions can't alias each other's memo
//! stores, and a memo budget changes only *where* answers come from (cache
//! vs re-solve), never what they are. `integration_daemon.rs` certifies
//! this under 1 and 8 threads, including budgets small enough to evict.

use crate::artifact::{self, ArtifactError, LoadReport};
use crate::coordinator::{entry_footprint_bytes, EvictionSnapshot, MemoBudget, StatsSnapshot};
use crate::opt::problem::SolveOpts;
use crate::platform::registry::{Platform, PlatformId};
use crate::platform::spec::PlatformSpec;
use crate::serve::evict::{memory_telemetry, MemoryTelemetry};
use crate::serve::mailbox::{Mailbox, MailboxSnapshot};
use crate::serve::proto::{
    decode_frame, error_frame, read_frame_line, rejected_frame, response_frame, stats_frame,
    Frame, FrameLimits, ReadLine,
};
use crate::service::request::{CodesignRequest, CodesignResponse};
use crate::service::{Session, SubmitReport};
use crate::timemodel::citer::CIterTable;
use crate::util::json::Json;
use crate::util::stats::percentile;
use crate::util::threadpool::default_threads;
use std::io::{BufRead, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Force the `--no-prune` audit path onto every solver-option set a decoded
/// request carries: same answers, full evaluation. Shared by one-shot
/// `serve --requests` and the daemon (where it runs at admission, *before*
/// partition keying — pruned and unpruned option sets are distinct keys).
pub fn strip_prune(req: &mut CodesignRequest) {
    match req {
        CodesignRequest::Explore { scenario }
        | CodesignRequest::Pareto { scenario }
        | CodesignRequest::ParetoEnergy { scenario }
        | CodesignRequest::WhatIf { scenario, .. } => scenario.solve_opts.prune = false,
        CodesignRequest::Sensitivity { scenario_2d, scenario_3d, .. } => {
            scenario_2d.solve_opts.prune = false;
            scenario_3d.solve_opts.prune = false;
        }
        CodesignRequest::Tune(t) => t.solve_opts.prune = false,
        CodesignRequest::Validate | CodesignRequest::SolverCost { .. } => {}
    }
}

/// Force the `--scalar-eval` audit path onto every solver-option set a decoded
/// request carries: same answers, legacy point-at-a-time evaluation instead of
/// the batched SoA loop. Applied at the same admission point as
/// [`strip_prune`], and like it runs *before* partition keying — scalar and
/// batched option sets are distinct keys, so the two paths never share memo
/// stores.
pub fn force_scalar_eval(req: &mut CodesignRequest) {
    match req {
        CodesignRequest::Explore { scenario }
        | CodesignRequest::Pareto { scenario }
        | CodesignRequest::ParetoEnergy { scenario }
        | CodesignRequest::WhatIf { scenario, .. } => scenario.solve_opts.scalar_eval = true,
        CodesignRequest::Sensitivity { scenario_2d, scenario_3d, .. } => {
            scenario_2d.solve_opts.scalar_eval = true;
            scenario_3d.solve_opts.scalar_eval = true;
        }
        CodesignRequest::Tune(t) => t.solve_opts.scalar_eval = true,
        CodesignRequest::Validate | CodesignRequest::SolverCost { .. } => {}
    }
}

/// Daemon tuning knobs. Every field has a serving-sane default; the CLI maps
/// `--mailbox-depth`, `--max-groups`, `--memo-entries`/`--memo-mb` and
/// `--no-prune` onto it.
#[derive(Clone, Debug)]
pub struct DaemonConfig {
    /// The platform requests run on when they name none.
    pub default_platform: PlatformSpec,
    /// Outstanding-request bound (queued + in-flight) before admissions are
    /// answered `rejected`.
    pub mailbox_depth: usize,
    /// Concurrently-running batch groups (each group still parallelizes
    /// internally over the sweep pool).
    pub max_groups: usize,
    /// Per-partition memo-store budget; `None` = unbounded.
    pub memo_budget: Option<MemoBudget>,
    /// Strip pruning from every admitted request (the `--no-prune` audit
    /// knob).
    pub no_prune: bool,
    /// Route every admitted request down the legacy scalar evaluation loop
    /// (the `--scalar-eval` audit knob).
    pub scalar_eval: bool,
    /// Hostile-input bounds for the frame decoder.
    pub limits: FrameLimits,
}

impl DaemonConfig {
    pub fn new(default_platform: PlatformSpec) -> DaemonConfig {
        DaemonConfig {
            default_platform,
            mailbox_depth: 64,
            max_groups: default_threads().clamp(1, 8),
            memo_budget: None,
            no_prune: false,
            scalar_eval: false,
            limits: FrameLimits::default(),
        }
    }

    /// A daemon on the paper's default platform.
    pub fn paper() -> DaemonConfig {
        DaemonConfig::new(Platform::default_spec().clone())
    }
}

/// One partition: its key triple, its session, and post-request telemetry
/// mirrors the stats probe can read without touching the session lock.
struct Partition {
    fp: u64,
    citer: CIterTable,
    opts: SolveOpts,
    session: Mutex<Session>,
    resident: AtomicUsize,
    bounded: AtomicUsize,
    evicted: AtomicU64,
}

/// Per-run counters, all updated atomically from reader and worker threads.
#[derive(Default)]
struct RunCounters {
    lines_read: AtomicU64,
    responses: AtomicU64,
    error_lines: AtomicU64,
    rejected: AtomicU64,
    stats_probes: AtomicU64,
    error_responses: AtomicU64,
    write_errors: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    unique_instances: AtomicU64,
}

/// A counting semaphore bounding concurrently-running batch groups.
struct Gate {
    permits: Mutex<usize>,
    freed: Condvar,
}

impl Gate {
    fn new(n: usize) -> Gate {
        Gate { permits: Mutex::new(n.max(1)), freed: Condvar::new() }
    }

    fn acquire(&self) {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.freed.wait(p).unwrap();
        }
        *p -= 1;
    }

    fn release(&self) {
        *self.permits.lock().unwrap() += 1;
        self.freed.notify_one();
    }
}

/// An admitted request on its way to a worker.
struct Job {
    id: String,
    request: CodesignRequest,
    admitted: Instant,
}

enum Lane {
    /// A scenario/tune request, keyed to its compatible batch group.
    Partition(u64, CIterTable, SolveOpts),
    /// Validate / SolverCost: no coordinator state, separate session.
    Direct,
}

/// What one [`Daemon::run`] observed, plus the daemon's end-of-run memory
/// picture. `latencies_ms` is per answered request, admission to response
/// written.
pub struct DaemonReport {
    pub lines_read: u64,
    pub responses: u64,
    pub error_lines: u64,
    pub rejected: u64,
    pub stats_probes: u64,
    /// Answered requests whose response was a wire-level `error`.
    pub error_responses: u64,
    pub write_errors: u64,
    pub wall: Duration,
    pub latencies_ms: Vec<f64>,
    pub mailbox: MailboxSnapshot,
    pub cache: StatsSnapshot,
    pub unique_instances: u64,
    pub memory: MemoryTelemetry,
}

impl DaemonReport {
    pub fn throughput_rps(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s > 0.0 {
            self.responses as f64 / s
        } else {
            0.0
        }
    }

    /// The `BENCH_serve_daemon.json` payload: throughput, latency tails, hit
    /// rate, eviction and backpressure counters.
    pub fn bench_json(&self) -> Json {
        let (p50, p95) = if self.latencies_ms.is_empty() {
            (0.0, 0.0)
        } else {
            (percentile(&self.latencies_ms, 50.0), percentile(&self.latencies_ms, 95.0))
        };
        Json::obj(vec![
            ("mode", Json::str("daemon")),
            ("lines_read", Json::Num(self.lines_read as f64)),
            ("responses", Json::Num(self.responses as f64)),
            ("error_lines", Json::Num(self.error_lines as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("stats_probes", Json::Num(self.stats_probes as f64)),
            ("error_responses", Json::Num(self.error_responses as f64)),
            ("write_errors", Json::Num(self.write_errors as f64)),
            ("wall_ms", Json::Num(self.wall.as_secs_f64() * 1e3)),
            ("throughput_rps", Json::Num(self.throughput_rps())),
            ("latency_p50_ms", Json::Num(p50)),
            ("latency_p95_ms", Json::Num(p95)),
            ("cache_hit_rate", Json::Num(self.cache.hit_rate())),
            ("lookups", Json::Num(self.cache.lookups() as f64)),
            ("unique_instances", Json::Num(self.unique_instances as f64)),
            ("mailbox", self.mailbox.to_json()),
            ("memory", self.memory.to_json()),
        ])
    }
}

/// The persistent serve daemon. Construct once, [`Daemon::run`] per stream
/// (a Unix-socket accept loop reuses one daemon across connections, keeping
/// every partition warm).
pub struct Daemon {
    config: DaemonConfig,
    partitions: Mutex<Vec<Arc<Partition>>>,
    direct: Mutex<Session>,
}

impl Daemon {
    pub fn new(config: DaemonConfig) -> Daemon {
        let direct = Session::new(config.default_platform.clone());
        Daemon { config, partitions: Mutex::new(Vec::new()), direct: Mutex::new(direct) }
    }

    pub fn config(&self) -> &DaemonConfig {
        &self.config
    }

    fn resolve_platform(&self, id: Option<PlatformId>) -> PlatformSpec {
        match id {
            Some(id) => Platform::get(id).spec.clone(),
            None => self.config.default_platform.clone(),
        }
    }

    fn lane_of(&self, req: &CodesignRequest) -> Lane {
        match req {
            CodesignRequest::Explore { scenario }
            | CodesignRequest::Pareto { scenario }
            | CodesignRequest::ParetoEnergy { scenario }
            | CodesignRequest::WhatIf { scenario, .. } => Lane::Partition(
                self.resolve_platform(scenario.platform).fingerprint(),
                scenario.citer.clone(),
                scenario.solve_opts.clone(),
            ),
            CodesignRequest::Sensitivity { scenario_2d, .. } => Lane::Partition(
                self.resolve_platform(scenario_2d.platform).fingerprint(),
                scenario_2d.citer.clone(),
                scenario_2d.solve_opts.clone(),
            ),
            CodesignRequest::Tune(t) => Lane::Partition(
                self.resolve_platform(t.platform).fingerprint(),
                t.citer.clone(),
                t.solve_opts.clone(),
            ),
            CodesignRequest::Validate | CodesignRequest::SolverCost { .. } => Lane::Direct,
        }
    }

    /// Find or create the partition for a key triple. Lock order everywhere:
    /// the partitions list first, then (after the list lock is dropped) one
    /// partition's session — never a session inside the list lock.
    fn partition_for(&self, fp: u64, citer: &CIterTable, opts: &SolveOpts) -> Arc<Partition> {
        let mut parts = self.partitions.lock().unwrap();
        if let Some(p) =
            parts.iter().find(|p| p.fp == fp && p.citer == *citer && p.opts == *opts)
        {
            return Arc::clone(p);
        }
        let session = Session::new(self.config.default_platform.clone())
            .with_memo_budget(self.config.memo_budget);
        let p = Arc::new(Partition {
            fp,
            citer: citer.clone(),
            opts: opts.clone(),
            session: Mutex::new(session),
            resident: AtomicUsize::new(0),
            bounded: AtomicUsize::new(0),
            evicted: AtomicU64::new(0),
        });
        parts.push(Arc::clone(&p));
        p
    }

    /// Warm-start the daemon from a sweep artifact: every shard is decoded
    /// and integrity-checked up front ([`artifact::load_partitions`]), then
    /// routed to its own partition session. Call before serving begins — on
    /// a fresh daemon every receiving partition is new, so the per-shard
    /// provenance absorb cannot conflict partway.
    pub fn warm_start(&self, dir: &Path) -> Result<LoadReport, ArtifactError> {
        let decoded = artifact::load_partitions(dir)?;
        let mut report = LoadReport::default();
        for shard in decoded {
            let exact = shard
                .entries
                .iter()
                .filter(|(_, e)| matches!(e, crate::coordinator::CacheEntry::Exact(_)))
                .count();
            report.exact_entries += exact;
            report.bounded_entries += shard.entries.len() - exact;
            let part = self.partition_for(shard.platform.fingerprint(), &shard.citer, &shard.opts);
            let mut session = part.session.lock().unwrap();
            let installed = session
                .absorb_partition(&shard.platform, &shard.citer, &shard.opts, &shard.entries)
                .map_err(|e| ArtifactError::PartitionConflict { detail: format!("{e:#}") })?;
            part.resident.store(session.cache_entries(), Ordering::Relaxed);
            part.bounded.store(session.bounded_entries(), Ordering::Relaxed);
            report.entries_installed += installed;
            report.shards += 1;
        }
        Ok(report)
    }

    /// Answer one admitted request on its lane. Returns the wire response;
    /// telemetry lands in `counters` and the partition mirrors.
    fn answer(&self, request: &CodesignRequest, counters: &RunCounters) -> CodesignResponse {
        let absorb = |rep: &SubmitReport| {
            counters.hits.fetch_add(rep.cache.hits, Ordering::Relaxed);
            counters.misses.fetch_add(rep.cache.misses, Ordering::Relaxed);
            counters.unique_instances.fetch_add(rep.unique_instances as u64, Ordering::Relaxed);
        };
        match self.lane_of(request) {
            Lane::Direct => {
                let mut session = self.direct.lock().unwrap();
                let rep = session.submit_all(std::slice::from_ref(request));
                absorb(&rep);
                rep.into_responses().pop().expect("one request in, one response out")
            }
            Lane::Partition(fp, citer, opts) => {
                let part = self.partition_for(fp, &citer, &opts);
                let mut session = part.session.lock().unwrap();
                let rep = session.submit_all(std::slice::from_ref(request));
                absorb(&rep);
                part.resident.store(session.cache_entries(), Ordering::Relaxed);
                part.bounded.store(session.bounded_entries(), Ordering::Relaxed);
                part.evicted.store(session.eviction_total().evicted(), Ordering::Relaxed);
                rep.into_responses().pop().expect("one request in, one response out")
            }
        }
    }

    /// The live `stats` probe body: run counters, mailbox state, and the
    /// post-request memory mirrors — no session lock is taken, so a probe
    /// never waits behind an in-flight solve.
    fn live_stats(&self, mailbox: &Mailbox<Job>, c: &RunCounters) -> Json {
        let parts = self.partitions.lock().unwrap();
        let partitions = parts.len();
        let resident: usize = parts.iter().map(|p| p.resident.load(Ordering::Relaxed)).sum();
        let bounded: usize = parts.iter().map(|p| p.bounded.load(Ordering::Relaxed)).sum();
        let evicted: u64 = parts.iter().map(|p| p.evicted.load(Ordering::Relaxed)).sum();
        drop(parts);
        let (hits, misses) =
            (c.hits.load(Ordering::Relaxed), c.misses.load(Ordering::Relaxed));
        let cache = StatsSnapshot { hits, misses };
        Json::obj(vec![
            ("mailbox", mailbox.snapshot().to_json()),
            ("responses", Json::Num(c.responses.load(Ordering::Relaxed) as f64)),
            ("error_lines", Json::Num(c.error_lines.load(Ordering::Relaxed) as f64)),
            ("rejected", Json::Num(c.rejected.load(Ordering::Relaxed) as f64)),
            ("partitions", Json::Num(partitions as f64)),
            ("resident_entries", Json::Num(resident as f64)),
            ("bounded_entries", Json::Num(bounded as f64)),
            ("evicted", Json::Num(evicted as f64)),
            ("cache_hits", Json::Num(hits as f64)),
            ("cache_misses", Json::Num(misses as f64)),
            ("cache_hit_rate", Json::Num(cache.hit_rate())),
            (
                "memo_budget_entries",
                match self.config.memo_budget {
                    Some(b) => Json::Num(b.max_entries as f64),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Idle-time eviction sweep: called by a worker that just drained the
    /// mailbox (no queued or in-flight work), to pay eviction debt the last
    /// batches deferred while their pins suspended budget enforcement.
    /// Best-effort and non-blocking — a partition whose session lock is
    /// contended (new work just arrived) is skipped; the next idle moment or
    /// the on-insert trigger catches it. Returns the entries evicted.
    fn sweep_idle(&self) -> u64 {
        let parts: Vec<Arc<Partition>> =
            self.partitions.lock().unwrap().iter().map(Arc::clone).collect();
        let mut evicted = 0u64;
        for p in parts {
            let Ok(session) = p.session.try_lock() else { continue };
            evicted += session.sweep_idle();
            p.resident.store(session.cache_entries(), Ordering::Relaxed);
            p.bounded.store(session.bounded_entries(), Ordering::Relaxed);
            p.evicted.store(session.eviction_total().evicted(), Ordering::Relaxed);
        }
        evicted
    }

    /// End-of-run memory telemetry, summed over every partition session plus
    /// the direct lane (locks each session; call only when workers are done).
    fn memory_total(&self) -> MemoryTelemetry {
        let mut total = MemoryTelemetry {
            partitions: 0,
            resident_entries: 0,
            bounded_entries: 0,
            budget_entries: self.config.memo_budget.map(|b| b.max_entries),
            approx_resident_bytes: 0,
            eviction: EvictionSnapshot::default(),
        };
        let parts = self.partitions.lock().unwrap();
        for p in parts.iter() {
            let session = p.session.lock().unwrap();
            let t = memory_telemetry(&session);
            total.partitions += t.partitions;
            total.resident_entries += t.resident_entries;
            total.bounded_entries += t.bounded_entries;
            total.eviction.evicted_exact += t.eviction.evicted_exact;
            total.eviction.evicted_bounded += t.eviction.evicted_bounded;
            total.eviction.passes += t.eviction.passes;
            total.eviction.futile_passes += t.eviction.futile_passes;
        }
        total.approx_resident_bytes = total.resident_entries * entry_footprint_bytes();
        total
    }

    /// Serve one request stream to completion: read frames until EOF, answer
    /// concurrently, stream responses (in completion order) to `output`.
    ///
    /// Write failures never abort in-flight work — they are counted in
    /// [`DaemonReport::write_errors`] (a client that hung up mid-stream
    /// shouldn't kill work other clients of a shared daemon are waiting on).
    /// Read errors abort after draining what was already admitted.
    pub fn run<R: BufRead, W: Write + Send>(
        &self,
        mut input: R,
        output: &mut W,
    ) -> std::io::Result<DaemonReport> {
        let t0 = Instant::now();
        let mailbox: Mailbox<Job> = Mailbox::new(self.config.mailbox_depth);
        let gate = Gate::new(self.config.max_groups);
        let writer = Mutex::new(output);
        let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::new());
        let counters = RunCounters::default();
        let mut read_error: Option<std::io::Error> = None;

        std::thread::scope(|scope| {
            let dispatcher = {
                let (mailbox, gate, writer, latencies, counters) =
                    (&mailbox, &gate, &writer, &latencies, &counters);
                let daemon = self;
                scope.spawn(move || {
                    // Claim a group slot *before* spawning, so at most
                    // `max_groups` workers ever exist; the worker releases it.
                    while let Some(job) = mailbox.recv() {
                        gate.acquire();
                        scope.spawn(move || {
                            let response = daemon.answer(&job.request, counters);
                            if response.is_error() {
                                counters.error_responses.fetch_add(1, Ordering::Relaxed);
                            }
                            write_line(writer, &response_frame(&job.id, &response), counters);
                            counters.responses.fetch_add(1, Ordering::Relaxed);
                            latencies
                                .lock()
                                .unwrap()
                                .push(job.admitted.elapsed().as_secs_f64() * 1e3);
                            mailbox.complete();
                            gate.release();
                            // The worker that drains the mailbox pays any
                            // deferred eviction debt while the daemon idles,
                            // so the next request starts at budget instead
                            // of evicting on its own first inserts.
                            let snap = mailbox.snapshot();
                            if snap.queued == 0 && snap.in_flight == 0 {
                                daemon.sweep_idle();
                            }
                        });
                    }
                })
            };

            let mut line_no = 0u64;
            loop {
                let read = match read_frame_line(&mut input, self.config.limits.max_line_bytes) {
                    Ok(r) => r,
                    Err(e) => {
                        read_error = Some(e);
                        break;
                    }
                };
                match read {
                    ReadLine::Eof => break,
                    ReadLine::Oversized { consumed } => {
                        line_no += 1;
                        counters.lines_read.fetch_add(1, Ordering::Relaxed);
                        counters.error_lines.fetch_add(1, Ordering::Relaxed);
                        let msg = format!(
                            "line exceeds {} bytes (got {consumed})",
                            self.config.limits.max_line_bytes
                        );
                        write_line(&writer, &error_frame(line_no, None, &msg), &counters);
                    }
                    ReadLine::Line(bytes) => {
                        line_no += 1;
                        if bytes.iter().all(|b| b.is_ascii_whitespace()) {
                            continue; // blank lines are inter-frame padding
                        }
                        counters.lines_read.fetch_add(1, Ordering::Relaxed);
                        match decode_frame(&bytes, &self.config.limits) {
                            Err(fe) => {
                                counters.error_lines.fetch_add(1, Ordering::Relaxed);
                                write_line(
                                    &writer,
                                    &error_frame(line_no, fe.id.as_deref(), &fe.message),
                                    &counters,
                                );
                            }
                            Ok(Frame::Stats { id }) => {
                                counters.stats_probes.fetch_add(1, Ordering::Relaxed);
                                let body = self.live_stats(&mailbox, &counters);
                                write_line(&writer, &stats_frame(&id, body), &counters);
                            }
                            Ok(Frame::Request { id, mut request }) => {
                                if self.config.no_prune {
                                    strip_prune(&mut request);
                                }
                                if self.config.scalar_eval {
                                    force_scalar_eval(&mut request);
                                }
                                let job = Job { id, request, admitted: Instant::now() };
                                if let Err(job) = mailbox.try_send(job) {
                                    counters.rejected.fetch_add(1, Ordering::Relaxed);
                                    write_line(
                                        &writer,
                                        &rejected_frame(&job.id, mailbox.snapshot().to_json()),
                                        &counters,
                                    );
                                }
                            }
                        }
                    }
                }
            }
            // EOF (or a read error): stop admissions, drain what's in.
            mailbox.close();
            dispatcher.join().expect("daemon dispatcher panicked");
        });

        let load = |c: &AtomicU64| c.load(Ordering::Relaxed);
        let report = DaemonReport {
            lines_read: load(&counters.lines_read),
            responses: load(&counters.responses),
            error_lines: load(&counters.error_lines),
            rejected: load(&counters.rejected),
            stats_probes: load(&counters.stats_probes),
            error_responses: load(&counters.error_responses),
            write_errors: load(&counters.write_errors),
            wall: t0.elapsed(),
            latencies_ms: latencies.into_inner().unwrap(),
            mailbox: mailbox.snapshot(),
            cache: StatsSnapshot { hits: load(&counters.hits), misses: load(&counters.misses) },
            unique_instances: load(&counters.unique_instances),
            memory: self.memory_total(),
        };
        match read_error {
            Some(e) => Err(e),
            None => Ok(report),
        }
    }
}

/// Write one frame line and flush it out immediately (streaming contract:
/// a response is visible the moment it exists). Failures count, not abort.
fn write_line<W: Write>(writer: &Mutex<W>, line: &str, counters: &RunCounters) {
    let mut w = writer.lock().unwrap();
    let wrote = writeln!(w, "{line}").and_then(|_| w.flush());
    if wrote.is_err() {
        counters.write_errors.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::request::ScenarioSpec;
    use crate::service::wire;
    use crate::stencil::defs::StencilId;
    use crate::util::json::parse;

    fn frame_line(id: &str, req: &CodesignRequest) -> String {
        Json::obj(vec![("id", Json::str(id)), ("request", wire::request_to_json(req))])
            .to_string_compact()
    }

    fn run_daemon(config: DaemonConfig, input: &str) -> (DaemonReport, Vec<Json>) {
        let daemon = Daemon::new(config);
        let mut out: Vec<u8> = Vec::new();
        let report = daemon.run(input.as_bytes(), &mut out).expect("stream reads cleanly");
        let frames = String::from_utf8(out)
            .expect("frames are UTF-8")
            .lines()
            .map(|l| match parse(l) {
                Ok(j) => j,
                Err(e) => panic!("unparsable output line '{l}': {e}"),
            })
            .collect();
        (report, frames)
    }

    fn frame_id<'a>(f: &'a Json) -> Option<&'a str> {
        f.get("id").and_then(|v| v.as_str())
    }

    #[test]
    fn streams_a_response_frame_per_request() {
        let r1 = CodesignRequest::pareto(ScenarioSpec::two_d().quick(16));
        let r2 =
            CodesignRequest::pareto(ScenarioSpec::two_d().quick(16).with_area_budget(400.0));
        let input = format!("{}\n{}\n", frame_line("a", &r1), frame_line("b", &r2));
        let (report, frames) = run_daemon(DaemonConfig::paper(), &input);

        assert_eq!(report.responses, 2);
        assert_eq!(report.error_lines, 0);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.write_errors, 0);
        assert_eq!(report.latencies_ms.len(), 2);
        assert_eq!(report.mailbox.accepted, 2);
        assert_eq!(report.mailbox.completed, 2);
        assert_eq!(report.mailbox.queued, 0);
        assert_eq!(report.mailbox.in_flight, 0);
        assert!(report.memory.resident_entries > 0, "the sweep memoized something");
        assert!(report.cache.lookups() > 0);
        assert!(report.throughput_rps() > 0.0);

        let mut ids: Vec<&str> = frames.iter().filter_map(frame_id).collect();
        ids.sort_unstable();
        assert_eq!(ids, ["a", "b"]);
        for f in &frames {
            assert!(f.get("response").is_some(), "{f:?} is not a response frame");
            assert_eq!(
                f.get("schema").and_then(|v| v.as_f64()),
                Some(wire::SCHEMA_VERSION as f64)
            );
        }

        let bench = report.bench_json();
        for field in [
            "mode",
            "lines_read",
            "responses",
            "error_lines",
            "rejected",
            "wall_ms",
            "throughput_rps",
            "latency_p50_ms",
            "latency_p95_ms",
            "cache_hit_rate",
            "unique_instances",
            "mailbox",
            "memory",
        ] {
            assert!(bench.get(field).is_some(), "bench json missing '{field}'");
        }
    }

    #[test]
    fn hostile_and_stats_lines_do_not_disturb_serving() {
        let good = frame_line("ok", &CodesignRequest::pareto(ScenarioSpec::two_d().quick(16)));
        let input = format!(
            "\n{{\"id\":\"s1\",\"request\":{{\"type\":\"stats\"}}}}\nnot json\n{good}\n{{\"id\":7,\"request\":{{}}}}\n"
        );
        let (report, frames) = run_daemon(DaemonConfig::paper(), &input);

        assert_eq!(report.responses, 1);
        assert_eq!(report.stats_probes, 1);
        assert_eq!(report.error_lines, 2);
        assert_eq!(report.lines_read, 4, "blank lines are not counted");
        assert_eq!(report.error_responses, 0);

        let stats = frames.iter().find(|f| f.get("stats").is_some()).expect("a stats frame");
        assert_eq!(frame_id(stats), Some("s1"));
        for field in ["mailbox", "partitions", "resident_entries", "cache_hit_rate"] {
            assert!(
                stats.get("stats").unwrap().get(field).is_some(),
                "stats body missing '{field}'"
            );
        }

        let errors: Vec<&Json> = frames.iter().filter(|f| f.get("error").is_some()).collect();
        assert_eq!(errors.len(), 2);
        for e in &errors {
            assert!(e.get("line").and_then(|v| v.as_f64()).is_some(), "{e:?} lacks a line");
        }

        assert!(
            frames.iter().any(|f| frame_id(f) == Some("ok") && f.get("response").is_some()),
            "the well-formed request was still answered"
        );
    }

    #[test]
    fn daemon_answers_equal_a_oneshot_session() {
        let reqs = vec![
            CodesignRequest::pareto(ScenarioSpec::two_d().quick(16)),
            CodesignRequest::what_if(
                ScenarioSpec::two_d().quick(16),
                vec![(StencilId::Jacobi2D, 1.0)],
            ),
        ];
        let input: String = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| format!("{}\n", frame_line(&format!("r{i}"), r)))
            .collect();
        let (_, frames) = run_daemon(DaemonConfig::paper(), &input);

        let mut session = Session::new(Platform::default_spec().clone());
        let expect = session.submit_all(&reqs).into_responses();
        for (i, want) in expect.iter().enumerate() {
            let id = format!("r{i}");
            let got = frames
                .iter()
                .find(|f| frame_id(f) == Some(id.as_str()))
                .unwrap_or_else(|| panic!("no frame for id '{id}'"));
            assert_eq!(
                got.get("response").unwrap().to_string_compact(),
                wire::response_to_json(want).to_string_compact(),
                "daemon answer for '{id}' diverged from one-shot serving"
            );
        }
    }

    #[test]
    fn memo_budget_changes_cost_never_answers() {
        let reqs = vec![
            CodesignRequest::pareto(ScenarioSpec::two_d().quick(8)),
            CodesignRequest::pareto(ScenarioSpec::two_d().quick(8).with_area_budget(420.0)),
        ];
        let input: String = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| format!("{}\n", frame_line(&format!("r{i}"), r)))
            .collect();
        let mut config = DaemonConfig::paper();
        config.memo_budget = Some(MemoBudget::entries(16));
        let (report, frames) = run_daemon(config, &input);
        assert!(
            report.memory.resident_entries <= 16 || report.memory.eviction.futile_passes > 0,
            "budget enforced (or provably suspended): resident {} evicted {}",
            report.memory.resident_entries,
            report.memory.eviction.evicted()
        );

        let mut session = Session::new(Platform::default_spec().clone());
        let expect = session.submit_all(&reqs).into_responses();
        for (i, want) in expect.iter().enumerate() {
            let id = format!("r{i}");
            let got = frames.iter().find(|f| frame_id(f) == Some(id.as_str())).unwrap();
            assert_eq!(
                got.get("response").unwrap().to_string_compact(),
                wire::response_to_json(want).to_string_compact(),
            );
        }
    }

    #[test]
    fn strip_prune_covers_every_scenario_carrying_variant() {
        let spec = ScenarioSpec::two_d().quick(8);
        assert!(spec.solve_opts.prune, "pruning is the default this test relies on");
        let mut reqs = vec![
            CodesignRequest::explore(spec.clone()),
            CodesignRequest::pareto(spec.clone()),
            CodesignRequest::pareto_energy(spec.clone()),
            CodesignRequest::what_if(spec.clone(), vec![(StencilId::Jacobi2D, 1.0)]),
            CodesignRequest::sensitivity(spec.clone(), ScenarioSpec::three_d(), (400.0, 450.0)),
            CodesignRequest::tune(crate::service::request::TuneRequest::new(430.0)),
        ];
        for r in &mut reqs {
            strip_prune(r);
        }
        for r in &reqs {
            match r {
                CodesignRequest::Explore { scenario }
                | CodesignRequest::Pareto { scenario }
                | CodesignRequest::ParetoEnergy { scenario }
                | CodesignRequest::WhatIf { scenario, .. } => {
                    assert!(!scenario.solve_opts.prune)
                }
                CodesignRequest::Sensitivity { scenario_2d, scenario_3d, .. } => {
                    assert!(!scenario_2d.solve_opts.prune);
                    assert!(!scenario_3d.solve_opts.prune);
                }
                CodesignRequest::Tune(t) => assert!(!t.solve_opts.prune),
                CodesignRequest::Validate | CodesignRequest::SolverCost { .. } => {}
            }
        }
    }

    #[test]
    fn force_scalar_eval_covers_every_scenario_carrying_variant() {
        let spec = ScenarioSpec::two_d().quick(8);
        assert!(!spec.solve_opts.scalar_eval, "batched is the default this test relies on");
        let mut reqs = vec![
            CodesignRequest::explore(spec.clone()),
            CodesignRequest::pareto(spec.clone()),
            CodesignRequest::pareto_energy(spec.clone()),
            CodesignRequest::what_if(spec.clone(), vec![(StencilId::Jacobi2D, 1.0)]),
            CodesignRequest::sensitivity(spec.clone(), ScenarioSpec::three_d(), (400.0, 450.0)),
            CodesignRequest::tune(crate::service::request::TuneRequest::new(430.0)),
        ];
        for r in &mut reqs {
            force_scalar_eval(r);
        }
        for r in &reqs {
            match r {
                CodesignRequest::Explore { scenario }
                | CodesignRequest::Pareto { scenario }
                | CodesignRequest::ParetoEnergy { scenario }
                | CodesignRequest::WhatIf { scenario, .. } => {
                    assert!(scenario.solve_opts.scalar_eval)
                }
                CodesignRequest::Sensitivity { scenario_2d, scenario_3d, .. } => {
                    assert!(scenario_2d.solve_opts.scalar_eval);
                    assert!(scenario_3d.solve_opts.scalar_eval);
                }
                CodesignRequest::Tune(t) => assert!(t.solve_opts.scalar_eval),
                CodesignRequest::Validate | CodesignRequest::SolverCost { .. } => {}
            }
        }
    }

    #[test]
    fn idle_mailbox_triggers_eviction_sweep() {
        // A budget far smaller than one sweep's footprint: during the
        // request the batch pin defers enforcement (futile passes suspend
        // it), and no insert arrives afterwards to re-trigger it. The
        // worker that drains the mailbox must pay the debt itself, so the
        // end-of-run store sits at budget with zero in-flight work.
        let req = CodesignRequest::pareto(ScenarioSpec::two_d().quick(8));
        let input = format!("{}\n", frame_line("a", &req));
        let mut config = DaemonConfig::paper();
        config.memo_budget = Some(MemoBudget::entries(4));
        let (report, _) = run_daemon(config, &input);
        assert_eq!(report.responses, 1);
        assert_eq!(report.mailbox.queued, 0);
        assert_eq!(report.mailbox.in_flight, 0);
        assert!(
            report.memory.eviction.evicted() > 0,
            "the idle sweep evicted the over-budget slots"
        );
        assert!(
            report.memory.resident_entries <= 4,
            "store at budget after the idle sweep, got {}",
            report.memory.resident_entries
        );
    }

    #[test]
    fn partition_keying_separates_incompatible_groups() {
        let daemon = Daemon::new(DaemonConfig::paper());
        let a = CodesignRequest::pareto(ScenarioSpec::two_d().quick(16));
        let b = CodesignRequest::pareto(
            ScenarioSpec::two_d()
                .quick(16)
                .with_solve_opts(SolveOpts { max_t_t: 96, ..SolveOpts::default() }),
        );
        let (Lane::Partition(fa, ca, oa), Lane::Partition(fb, cb, ob)) =
            (daemon.lane_of(&a), daemon.lane_of(&b))
        else {
            panic!("scenario requests key to partitions");
        };
        assert_eq!(fa, fb, "same platform");
        assert_eq!(ca, cb, "same C_iter");
        assert_ne!(oa, ob, "solver options split the partition");
        assert!(matches!(daemon.lane_of(&CodesignRequest::validate()), Lane::Direct));
        let p1 = daemon.partition_for(fa, &ca, &oa);
        let p2 = daemon.partition_for(fa, &ca, &oa);
        assert!(Arc::ptr_eq(&p1, &p2), "same key reuses the partition");
        let p3 = daemon.partition_for(fb, &cb, &ob);
        assert!(!Arc::ptr_eq(&p1, &p3));
    }
}
