//! The persistent serve daemon (PR 7): a streaming, concurrent, bounded
//! front-end over the session service.
//!
//! One-shot `serve --requests` answers a fixed envelope and exits — every
//! caller pays process startup and (without an artifact) the full sweep.
//! This module turns the same [`Session`](crate::service::Session) machinery
//! into a long-running server:
//!
//! * [`proto`] — the newline-delimited frame grammar over the existing wire
//!   schema (one request envelope per line, client-supplied `id`, responses
//!   streamed back tagged by `id` in completion order), hardened against
//!   hostile input: oversized lines, truncated JSON, NUL bytes, pathological
//!   nesting and unknown request kinds each produce a per-line error frame,
//!   never a crash and never a stalled stream.
//! * [`mailbox`] — explicit admission control: a bounded queue over
//!   *outstanding* work with non-blocking sends, `rejected` answers when
//!   full, and backpressure telemetry (queued, in-flight, accepted,
//!   rejected, max-depth-seen).
//! * [`daemon`] — the request loop itself: one session per compatible batch
//!   group (the partition triple), independent groups dispatched
//!   concurrently under a group cap, a synchronous `stats` probe, artifact
//!   warm starts, and a `--bench-out` report (throughput, latency tails,
//!   hit rate, eviction + backpressure counters).
//! * [`evict`] — the memory story's serving-layer glue: `--memo-entries` /
//!   `--memo-mb` flag resolution into a
//!   [`MemoBudget`](crate::coordinator::MemoBudget), and the aggregated
//!   [`MemoryTelemetry`](evict::MemoryTelemetry) record both the `stats`
//!   probe and the bench report serialize.
//!
//! Everything here preserves the engine's core contract: serving mode,
//! concurrency, admission pressure and memory budgets change *cost* —
//! wall-clock, cache traffic, re-solves — never *answers*.
//! `integration_daemon.rs` certifies streamed daemon responses bit-identical
//! to one-shot serving under 1 and 8 threads, including memo budgets small
//! enough to force evictions mid-stream.

pub mod daemon;
pub mod evict;
pub mod mailbox;
pub mod proto;

pub use daemon::{force_scalar_eval, strip_prune, Daemon, DaemonConfig, DaemonReport};
pub use evict::{budget_from_flags, memory_telemetry, MemoryTelemetry};
pub use mailbox::{Mailbox, MailboxSnapshot};
pub use proto::{
    decode_frame, error_frame, read_frame_line, rejected_frame, response_frame, stats_frame,
    Frame, FrameError, FrameLimits, ReadLine,
};
