//! Newline-delimited JSON framing for the serve daemon.
//!
//! The daemon speaks one JSON object per line. A request frame is
//!
//! ```text
//! {"id": "r07", "schema": 6, "request": {"type": "pareto", …}}
//! ```
//!
//! where `id` is a required, client-chosen correlation string (responses
//! stream back in completion order, so the id is the only join key), `schema`
//! is the optional wire schema version (defaults to the current one; the
//! inner `request` object is exactly the wire format of
//! [`crate::service::wire`]), and `request` is either a wire request or the
//! daemon-local `{"type": "stats"}` probe. Response frames are
//!
//! ```text
//! {"id": "r07", "response": {…}, "schema": 6}           answered request
//! {"error": "…", "line": 12}                            malformed line
//! {"id": "r07", "mailbox": {…}, "rejected": "overloaded"} admission refusal
//! {"id": "s1", "stats": {…}}                            stats probe
//! ```
//!
//! all serialized compactly on one line. Framing is hardened against hostile
//! input: every malformed line — oversized, non-UTF-8, NUL bytes, truncated
//! JSON, nesting past [`FrameLimits::max_depth`], a non-object frame, a
//! missing/blank id, an unknown request kind — yields a per-line error frame
//! (with the offending line number, and the id when one could be recovered)
//! instead of killing the stream. The line reader consumes oversized lines
//! to their newline in O(1) memory, so one abusive line cannot desynchronize
//! or bloat the rest of the stream.

use crate::service::request::{CodesignRequest, CodesignResponse};
use crate::service::wire;
use crate::util::json::{parse, Json};
use std::io::BufRead;

/// Hard bounds the frame decoder enforces before any parsing happens.
#[derive(Clone, Copy, Debug)]
pub struct FrameLimits {
    /// Longest accepted line in bytes (excluding the newline). Longer lines
    /// are drained to their newline and answered with an error frame.
    pub max_line_bytes: usize,
    /// Deepest accepted `{`/`[` nesting. The JSON parser recurses, so this
    /// pre-parse scan is what keeps a `[[[[…` line from overflowing the
    /// daemon's stack.
    pub max_depth: usize,
}

impl Default for FrameLimits {
    fn default() -> FrameLimits {
        FrameLimits { max_line_bytes: 1 << 20, max_depth: 64 }
    }
}

/// One bounded read from the stream.
pub enum ReadLine {
    /// A complete line (newline stripped; the final line of the stream may
    /// arrive unterminated and is still delivered).
    Line(Vec<u8>),
    /// The line exceeded `max_line_bytes`; its content was discarded but the
    /// stream was consumed up to (and including) the newline, so the next
    /// read starts on the next line. `consumed` is the discarded length.
    Oversized { consumed: usize },
    Eof,
}

/// Read one newline-terminated line, never buffering more than
/// `max_line_bytes` of it: once the running length passes the limit the
/// partial content is dropped and the rest of the line is only counted.
pub fn read_frame_line(
    input: &mut impl BufRead,
    max_line_bytes: usize,
) -> std::io::Result<ReadLine> {
    let mut line: Vec<u8> = Vec::new();
    let mut total = 0usize;
    let mut overflowed = false;
    loop {
        let buf = input.fill_buf()?;
        if buf.is_empty() {
            return Ok(match (total, overflowed) {
                (0, _) => ReadLine::Eof,
                (_, true) => ReadLine::Oversized { consumed: total },
                (_, false) => ReadLine::Line(line),
            });
        }
        let (chunk, terminated) = match buf.iter().position(|&b| b == b'\n') {
            Some(i) => (i, true),
            None => (buf.len(), false),
        };
        if !overflowed {
            if total + chunk > max_line_bytes {
                overflowed = true;
                line.clear();
                line.shrink_to_fit();
            } else {
                line.extend_from_slice(&buf[..chunk]);
            }
        }
        total += chunk;
        input.consume(chunk + usize::from(terminated));
        if terminated {
            return Ok(if overflowed {
                ReadLine::Oversized { consumed: total }
            } else {
                ReadLine::Line(line)
            });
        }
    }
}

/// A successfully decoded request frame.
pub enum Frame {
    /// A wire request to admit and answer.
    Request { id: String, request: CodesignRequest },
    /// The daemon-local `{"type": "stats"}` probe: answered synchronously by
    /// the reader thread, bypassing the mailbox.
    Stats { id: String },
}

/// Why a line failed to decode. The id is carried when it was recovered
/// before the failure, so clients can still correlate the error.
pub struct FrameError {
    pub id: Option<String>,
    pub message: String,
}

impl FrameError {
    fn new(message: impl Into<String>) -> FrameError {
        FrameError { id: None, message: message.into() }
    }
}

/// Maximum bracket nesting depth, counted outside string literals. Malformed
/// byte streams (unbalanced closers, unterminated strings) still get *some*
/// depth — they fail JSON parsing right after, so only well-formed prefixes
/// need an accurate count here.
fn max_nesting_depth(bytes: &[u8]) -> usize {
    let (mut depth, mut max, mut in_string, mut escaped) = (0usize, 0usize, false, false);
    for &b in bytes {
        if in_string {
            match (escaped, b) {
                (true, _) => escaped = false,
                (false, b'\\') => escaped = true,
                (false, b'"') => in_string = false,
                _ => {}
            }
            continue;
        }
        match b {
            b'"' => in_string = true,
            b'{' | b'[' => {
                depth += 1;
                max = max.max(depth);
            }
            b'}' | b']' => depth = depth.saturating_sub(1),
            _ => {}
        }
    }
    max
}

/// Decode one non-empty line into a [`Frame`]. Every hostile-input class is
/// rejected with a message naming what was wrong; nothing here panics on any
/// byte sequence (see the randomized test below).
pub fn decode_frame(line: &[u8], limits: &FrameLimits) -> Result<Frame, FrameError> {
    if line.contains(&0) {
        return Err(FrameError::new("frame contains a NUL byte"));
    }
    let text = std::str::from_utf8(line)
        .map_err(|e| FrameError::new(format!("frame is not valid UTF-8: {e}")))?;
    let depth = max_nesting_depth(line);
    if depth > limits.max_depth {
        return Err(FrameError::new(format!(
            "frame nests {depth} levels deep (limit {})",
            limits.max_depth
        )));
    }
    let j = parse(text).map_err(|e| FrameError::new(format!("bad JSON: {e}")))?;
    if j.as_obj().is_none() {
        return Err(FrameError::new("frame must be a JSON object"));
    }
    let id = match j.get("id") {
        Some(Json::Str(s)) if !s.is_empty() => s.clone(),
        Some(Json::Str(_)) => return Err(FrameError::new("frame 'id' must be non-empty")),
        Some(_) => return Err(FrameError::new("frame 'id' must be a string")),
        None => return Err(FrameError::new("frame is missing required field 'id'")),
    };
    let fail = |message: String| FrameError { id: Some(id.clone()), message };
    if let Some(v) = j.get("schema") {
        match v.as_f64() {
            Some(s)
                if s.fract() == 0.0
                    && s >= wire::MIN_SCHEMA_VERSION as f64
                    && s <= wire::SCHEMA_VERSION as f64 => {}
            _ => {
                return Err(fail(format!(
                    "unsupported schema version (this build speaks {}..={})",
                    wire::MIN_SCHEMA_VERSION,
                    wire::SCHEMA_VERSION
                )))
            }
        }
    }
    let req = j.get("request").ok_or_else(|| fail("frame is missing 'request'".into()))?;
    if req.get("type").and_then(Json::as_str) == Some("stats") {
        return Ok(Frame::Stats { id });
    }
    match wire::request_from_json(req) {
        Ok(request) => Ok(Frame::Request { id, request }),
        Err(e) => Err(fail(format!("bad request: {e:#}"))),
    }
}

// ---------------------------------------------------------------------------
// Response frames
// ---------------------------------------------------------------------------

/// `{"id": …, "response": …, "schema": N}` on one line (no newline).
pub fn response_frame(id: &str, response: &CodesignResponse) -> String {
    Json::obj(vec![
        ("id", Json::str(id)),
        ("response", wire::response_to_json(response)),
        ("schema", Json::Num(wire::SCHEMA_VERSION as f64)),
    ])
    .to_string_compact()
}

/// `{"error": …, "line": N}` plus the id when one was recovered.
pub fn error_frame(line_no: u64, id: Option<&str>, message: &str) -> String {
    let mut pairs = vec![
        ("error", Json::str(message)),
        ("line", Json::Num(line_no as f64)),
    ];
    if let Some(id) = id {
        pairs.push(("id", Json::str(id)));
    }
    Json::obj(pairs).to_string_compact()
}

/// `{"id": …, "mailbox": …, "rejected": "overloaded"}` — the admission
/// refusal. The mailbox snapshot rides along so a client can see how far
/// over capacity it pushed.
pub fn rejected_frame(id: &str, mailbox: Json) -> String {
    Json::obj(vec![
        ("id", Json::str(id)),
        ("mailbox", mailbox),
        ("rejected", Json::str("overloaded")),
    ])
    .to_string_compact()
}

/// `{"id": …, "stats": …}` — the answer to a stats probe.
pub fn stats_frame(id: &str, stats: Json) -> String {
    Json::obj(vec![("id", Json::str(id)), ("stats", stats)]).to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::request::ScenarioSpec;
    use crate::util::prng::Rng;
    use std::io::BufReader;

    fn limits() -> FrameLimits {
        FrameLimits::default()
    }

    fn decode(text: &str) -> Result<Frame, FrameError> {
        decode_frame(text.as_bytes(), &limits())
    }

    fn expect_err(text: &str, needle: &str) {
        let e = decode(text).err().unwrap_or_else(|| panic!("'{text}' must fail"));
        assert!(
            e.message.contains(needle),
            "error for '{text}' should mention '{needle}', got '{}'",
            e.message
        );
    }

    fn valid_line() -> String {
        let req = CodesignRequest::pareto(ScenarioSpec::two_d().quick(8));
        Json::obj(vec![
            ("id", Json::str("r0")),
            ("schema", Json::Num(wire::SCHEMA_VERSION as f64)),
            ("request", wire::request_to_json(&req)),
        ])
        .to_string_compact()
    }

    #[test]
    fn good_frame_roundtrips() {
        let line = valid_line();
        match decode(&line) {
            Ok(Frame::Request { id, request }) => {
                assert_eq!(id, "r0");
                assert_eq!(request.kind(), "pareto");
            }
            Ok(Frame::Stats { .. }) => panic!("not a stats frame"),
            Err(e) => panic!("valid frame must decode: {}", e.message),
        }
    }

    #[test]
    fn schema_is_optional_and_bounded() {
        let req = r#"{"id": "a", "request": {"type": "validate"}}"#;
        assert!(decode(req).is_ok(), "schema field is optional");
        expect_err(
            r#"{"id": "a", "schema": 99, "request": {"type": "validate"}}"#,
            "unsupported schema",
        );
        expect_err(
            r#"{"id": "a", "schema": 1.5, "request": {"type": "validate"}}"#,
            "unsupported schema",
        );
    }

    #[test]
    fn stats_probe_decodes() {
        match decode(r#"{"id": "s1", "request": {"type": "stats"}}"#) {
            Ok(Frame::Stats { id }) => assert_eq!(id, "s1"),
            Ok(Frame::Request { .. }) => panic!("stats must not reach the wire decoder"),
            Err(e) => panic!("stats probe must decode: {}", e.message),
        }
    }

    #[test]
    fn truncated_json_is_an_error() {
        expect_err(r#"{"id": "a", "request": {"type": "par"#, "bad JSON");
        expect_err("", "bad JSON");
    }

    #[test]
    fn nul_bytes_are_rejected_before_parsing() {
        let e = decode_frame(b"{\"id\": \"a\0b\"}", &limits()).err().unwrap();
        assert!(e.message.contains("NUL"), "{}", e.message);
    }

    #[test]
    fn invalid_utf8_is_rejected() {
        let e = decode_frame(&[b'{', 0xff, 0xfe, b'}'], &limits()).err().unwrap();
        assert!(e.message.contains("UTF-8"), "{}", e.message);
    }

    #[test]
    fn deep_nesting_is_rejected_without_recursing() {
        let mut hostile = String::new();
        for _ in 0..100_000 {
            hostile.push('[');
        }
        expect_err(&hostile, "levels deep");
        // Brackets inside strings don't count toward nesting.
        let fake = format!(r#"{{"id": "{}", "request": {{"type": "validate"}}}}"#, "[".repeat(200));
        assert!(decode(&fake).is_ok(), "brackets inside strings are content, not nesting");
    }

    #[test]
    fn id_is_required_string() {
        expect_err(r#"{"request": {"type": "validate"}}"#, "missing required field 'id'");
        expect_err(r#"{"id": 7, "request": {"type": "validate"}}"#, "must be a string");
        expect_err(r#"{"id": "", "request": {"type": "validate"}}"#, "non-empty");
        expect_err(r#"[1, 2]"#, "must be a JSON object");
    }

    #[test]
    fn unknown_request_kind_keeps_the_id() {
        let e = decode(r#"{"id": "r9", "request": {"type": "frobnicate"}}"#).err().unwrap();
        assert_eq!(e.id.as_deref(), Some("r9"));
        assert!(e.message.contains("unknown request type"), "{}", e.message);
    }

    #[test]
    fn bounded_reader_splits_and_drains() {
        let text = b"short\n".repeat(3);
        let mut r = BufReader::with_capacity(4, &text[..]);
        for _ in 0..3 {
            match read_frame_line(&mut r, 64).unwrap() {
                ReadLine::Line(l) => assert_eq!(l, b"short"),
                _ => panic!("expected a line"),
            }
        }
        assert!(matches!(read_frame_line(&mut r, 64).unwrap(), ReadLine::Eof));

        // An oversized line is drained to its newline; the next line is
        // intact — one abusive client line can't desynchronize the stream.
        let mut bytes = vec![b'x'; 1000];
        bytes.push(b'\n');
        bytes.extend_from_slice(b"next\n");
        let mut r = BufReader::with_capacity(16, &bytes[..]);
        match read_frame_line(&mut r, 100).unwrap() {
            ReadLine::Oversized { consumed } => assert_eq!(consumed, 1000),
            _ => panic!("expected oversize"),
        }
        match read_frame_line(&mut r, 100).unwrap() {
            ReadLine::Line(l) => assert_eq!(l, b"next"),
            _ => panic!("expected the next line"),
        }
    }

    #[test]
    fn bounded_reader_delivers_final_unterminated_line() {
        let mut r = BufReader::new(&b"tail-no-newline"[..]);
        match read_frame_line(&mut r, 64).unwrap() {
            ReadLine::Line(l) => assert_eq!(l, b"tail-no-newline"),
            _ => panic!("final line must be delivered"),
        }
        assert!(matches!(read_frame_line(&mut r, 64).unwrap(), ReadLine::Eof));
    }

    #[test]
    fn randomized_hostile_bytes_never_panic() {
        // Fuzz-style coverage (cargo-fuzz is unavailable offline; the
        // detached `fuzz/` crate reuses this generator): random mutations of
        // a valid frame plus raw byte noise must always decode to Ok or a
        // clean FrameError — never a panic — and valid frames keep decoding.
        let mut rng = Rng::new(0x5e2e_dae2);
        let template = valid_line().into_bytes();
        let lim = limits();
        for round in 0..2000 {
            let mut line = if rng.bernoulli(0.7) {
                let mut t = template.clone();
                for _ in 0..rng.range_u64(1, 8) {
                    if t.is_empty() {
                        break;
                    }
                    let i = rng.index(t.len());
                    match rng.index(3) {
                        0 => t[i] = rng.range_u64(0, 255) as u8,
                        1 => {
                            t.truncate(i);
                        }
                        _ => t.insert(i, rng.range_u64(0, 255) as u8),
                    }
                }
                t
            } else {
                (0..rng.range_u64(0, 300)).map(|_| rng.range_u64(0, 255) as u8).collect()
            };
            line.retain(|&b| b != b'\n');
            let _ = decode_frame(&line, &lim); // must not panic
            assert!(
                decode_frame(&template, &lim).is_ok(),
                "round {round}: the pristine template must still decode"
            );
        }
    }
}
