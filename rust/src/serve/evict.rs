//! Memory-budget policy glue: CLI flag parsing and session-level telemetry.
//!
//! The eviction mechanism itself lives in the memo store
//! ([`MemoCache`](crate::coordinator::MemoCache): generation-stamped LRU with
//! pinned in-flight batches, `BoundedOut` marks evicted before `Exact`
//! solutions, hysteresis, and the guarantee that eviction changes *cost*,
//! never *answers*). This module is the serving layer's view of it: turn
//! `--memo-entries` / `--memo-mb` flags into a [`MemoBudget`], and aggregate
//! per-partition residency + eviction counters into the one
//! [`MemoryTelemetry`] record the daemon's `stats` probe and `--bench-out`
//! report.
//!
//! Interaction with artifacts (PR 6), documented here because this is where
//! both meet operationally:
//!
//! * a warm-started session under budget evicts **lazily** — importing an
//!   artifact never triggers an eviction pass, so a budget smaller than the
//!   artifact only bites when live inserts land;
//! * `save_artifact` snapshots only what is **resident** — entries already
//!   evicted under budget are simply absent from the shard, which re-solves
//!   them on demand after a warm start (cost, not answers).
//!
//! Budget enforcement is insert-triggered, so a batch whose pin suspended
//! it can leave the store over budget with nothing left to re-arm it. The
//! idle path pays that debt: [`Session::sweep_idle`] sweeps every partition
//! back to budget, and the daemon calls it whenever its mailbox drains.

use crate::coordinator::{entry_footprint_bytes, EvictionSnapshot, MemoBudget};
use crate::service::Session;
use crate::util::json::Json;

/// Resolve the two budget flags into at most one budget. `entries` wins the
/// tie by being rejected: passing both is an operator error, not a merge.
pub fn budget_from_flags(
    entries: Option<usize>,
    megabytes: Option<f64>,
) -> anyhow::Result<Option<MemoBudget>> {
    match (entries, megabytes) {
        (Some(_), Some(_)) => {
            anyhow::bail!("--memo-entries and --memo-mb are mutually exclusive")
        }
        (Some(n), None) => {
            anyhow::ensure!(n > 0, "--memo-entries must be at least 1");
            Ok(Some(MemoBudget::entries(n)))
        }
        (None, Some(mb)) => {
            anyhow::ensure!(
                mb.is_finite() && mb > 0.0,
                "--memo-mb must be a positive number (got {mb})"
            );
            Ok(Some(MemoBudget::bytes((mb * 1024.0 * 1024.0) as usize)))
        }
        (None, None) => Ok(None),
    }
}

/// Session-wide memory picture: residency, approximate footprint, budget and
/// eviction telemetry summed over every partition.
#[derive(Clone, Copy, Debug)]
pub struct MemoryTelemetry {
    pub partitions: usize,
    pub resident_entries: usize,
    pub bounded_entries: usize,
    /// Per-partition entry cap, when the session runs under budget.
    pub budget_entries: Option<usize>,
    /// `resident_entries` × the accounting footprint per slot.
    pub approx_resident_bytes: usize,
    pub eviction: EvictionSnapshot,
}

pub fn memory_telemetry(session: &Session) -> MemoryTelemetry {
    let resident = session.cache_entries();
    MemoryTelemetry {
        partitions: session.partitions(),
        resident_entries: resident,
        bounded_entries: session.bounded_entries(),
        budget_entries: session.memo_budget().map(|b| b.max_entries),
        approx_resident_bytes: resident * entry_footprint_bytes(),
        eviction: session.eviction_total(),
    }
}

impl MemoryTelemetry {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("partitions", Json::Num(self.partitions as f64)),
            ("resident_entries", Json::Num(self.resident_entries as f64)),
            ("bounded_entries", Json::Num(self.bounded_entries as f64)),
            (
                "budget_entries",
                match self.budget_entries {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            ),
            ("approx_resident_bytes", Json::Num(self.approx_resident_bytes as f64)),
            ("evicted_exact", Json::Num(self.eviction.evicted_exact as f64)),
            ("evicted_bounded", Json::Num(self.eviction.evicted_bounded as f64)),
            ("eviction_passes", Json::Num(self.eviction.passes as f64)),
            ("futile_passes", Json::Num(self.eviction.futile_passes as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_resolution_rules() {
        assert!(budget_from_flags(None, None).unwrap().is_none());
        assert_eq!(
            budget_from_flags(Some(500), None).unwrap().map(|b| b.max_entries),
            Some(500)
        );
        let by_mb = budget_from_flags(None, Some(1.0)).unwrap().unwrap();
        assert_eq!(by_mb.max_entries, (1 << 20) / entry_footprint_bytes());
        assert!(budget_from_flags(Some(1), Some(1.0)).is_err(), "mutually exclusive");
        assert!(budget_from_flags(Some(0), None).is_err());
        for bad in [0.0, -2.0, f64::NAN, f64::INFINITY] {
            assert!(budget_from_flags(None, Some(bad)).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn tiny_byte_budget_floors_at_one_entry() {
        let b = budget_from_flags(None, Some(0.0001)).unwrap().unwrap();
        assert_eq!(b.max_entries, 1);
    }

    #[test]
    fn fresh_session_telemetry_is_zero() {
        let t = memory_telemetry(&Session::paper());
        assert_eq!(t.partitions, 0);
        assert_eq!(t.resident_entries, 0);
        assert_eq!(t.bounded_entries, 0);
        assert_eq!(t.approx_resident_bytes, 0);
        assert_eq!(t.budget_entries, None);
        assert_eq!(t.eviction.evicted(), 0);
    }

    #[test]
    fn budgeted_session_reports_its_cap() {
        let s = Session::paper().with_memo_budget(Some(MemoBudget::entries(64)));
        assert_eq!(memory_telemetry(&s).budget_entries, Some(64));
    }

    #[test]
    fn session_idle_sweep_pays_deferred_debt() {
        use crate::service::{CodesignRequest, ScenarioSpec};
        let mut s = Session::paper().with_memo_budget(Some(MemoBudget::entries(4)));
        s.submit(&CodesignRequest::pareto(ScenarioSpec::two_d().quick(8)));
        // The sweep's pin deferred enforcement and no insert follows it, so
        // the store sits over budget until something sweeps.
        let before = memory_telemetry(&s);
        assert!(before.resident_entries > 4, "sweep left deferred debt");
        let evicted = s.sweep_idle();
        assert!(evicted > 0, "idle sweep pays the debt");
        let after = memory_telemetry(&s);
        assert!(after.resident_entries <= 4, "store back at budget, got {}", after.resident_entries);
        // A second sweep finds nothing to do.
        assert_eq!(s.sweep_idle(), 0);
    }

    #[test]
    fn telemetry_serializes_every_field() {
        let j = memory_telemetry(&Session::paper()).to_json();
        for field in [
            "partitions",
            "resident_entries",
            "bounded_entries",
            "budget_entries",
            "approx_resident_bytes",
            "evicted_exact",
            "evicted_bounded",
            "eviction_passes",
            "futile_passes",
        ] {
            assert!(j.get(field).is_some(), "missing '{field}'");
        }
        assert_eq!(j.get("budget_entries"), Some(&Json::Null));
    }
}
