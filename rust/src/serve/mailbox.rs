//! Bounded admission queue for the serve daemon.
//!
//! The mailbox bounds **outstanding** work — queued *plus* in-flight — not
//! just queue length. The daemon's dispatcher drains the queue eagerly (a
//! job leaves the queue the moment a worker picks it up), so a queue-only
//! bound would admit unbounded work as fast as workers could claim it; the
//! outstanding bound is what actually caps the daemon's concurrent memory
//! and CPU exposure. [`Mailbox::try_send`] never blocks: when the bound is
//! hit the item comes straight back and the daemon answers `rejected` —
//! explicit backpressure the client can see, instead of an invisible stall.
//!
//! Backpressure telemetry (accepted, rejected, completed, max-depth-seen)
//! lives inside the same mutex as the queue, so a [`MailboxSnapshot`] is
//! always internally consistent — counters can't be observed mid-transition.

use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct State<T> {
    queue: VecDeque<T>,
    in_flight: usize,
    closed: bool,
    accepted: u64,
    rejected: u64,
    completed: u64,
    max_depth_seen: usize,
}

/// A bounded MPMC mailbox: non-blocking send, blocking receive.
pub struct Mailbox<T> {
    state: Mutex<State<T>>,
    available: Condvar,
    depth: usize,
}

/// One consistent observation of the mailbox (the `stats` request kind and
/// `--bench-out` both serialize this).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MailboxSnapshot {
    pub depth: usize,
    pub queued: usize,
    pub in_flight: usize,
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub max_depth_seen: usize,
}

impl MailboxSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("depth", Json::Num(self.depth as f64)),
            ("queued", Json::Num(self.queued as f64)),
            ("in_flight", Json::Num(self.in_flight as f64)),
            ("accepted", Json::Num(self.accepted as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("max_depth_seen", Json::Num(self.max_depth_seen as f64)),
        ])
    }
}

impl<T> Mailbox<T> {
    /// A mailbox admitting at most `depth` outstanding items (floored at 1).
    pub fn new(depth: usize) -> Mailbox<T> {
        Mailbox {
            state: Mutex::new(State {
                queue: VecDeque::new(),
                in_flight: 0,
                closed: false,
                accepted: 0,
                rejected: 0,
                completed: 0,
                max_depth_seen: 0,
            }),
            available: Condvar::new(),
            depth: depth.max(1),
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Admit `item`, or hand it back when the outstanding bound is hit (or
    /// the mailbox is closed). Never blocks.
    pub fn try_send(&self, item: T) -> Result<(), T> {
        let mut s = self.state.lock().unwrap();
        let outstanding = s.queue.len() + s.in_flight;
        if s.closed || outstanding >= self.depth {
            s.rejected += 1;
            return Err(item);
        }
        s.queue.push_back(item);
        s.accepted += 1;
        s.max_depth_seen = s.max_depth_seen.max(outstanding + 1);
        drop(s);
        self.available.notify_one();
        Ok(())
    }

    /// Block until an item is available (it moves to in-flight — pair every
    /// `Some` with a [`Mailbox::complete`]) or the mailbox is closed *and*
    /// drained, which returns `None` forever after.
    pub fn recv(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(item) = s.queue.pop_front() {
                s.in_flight += 1;
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.available.wait(s).unwrap();
        }
    }

    /// Mark one received item finished, freeing its slot of the outstanding
    /// bound.
    pub fn complete(&self) {
        let mut s = self.state.lock().unwrap();
        debug_assert!(s.in_flight > 0, "complete() without a matching recv()");
        s.in_flight = s.in_flight.saturating_sub(1);
        s.completed += 1;
    }

    /// Stop admissions; receivers drain what's queued, then see `None`.
    pub fn close(&self) {
        let mut s = self.state.lock().unwrap();
        s.closed = true;
        drop(s);
        self.available.notify_all();
    }

    pub fn snapshot(&self) -> MailboxSnapshot {
        let s = self.state.lock().unwrap();
        MailboxSnapshot {
            depth: self.depth,
            queued: s.queue.len(),
            in_flight: s.in_flight,
            accepted: s.accepted,
            rejected: s.rejected,
            completed: s.completed,
            max_depth_seen: s.max_depth_seen,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    #[test]
    fn bounds_outstanding_not_queue_length() {
        let mb = Mailbox::new(2);
        assert!(mb.try_send(1).is_ok());
        assert!(mb.try_send(2).is_ok());
        assert!(mb.try_send(3).is_err(), "queue full");
        // Draining the queue does NOT free capacity: the items are now
        // in-flight, still outstanding.
        assert_eq!(mb.recv(), Some(1));
        assert_eq!(mb.recv(), Some(2));
        assert!(mb.try_send(4).is_err(), "in-flight work still counts");
        // Completion is what frees a slot.
        mb.complete();
        assert!(mb.try_send(5).is_ok());
        let snap = mb.snapshot();
        assert_eq!(snap.accepted, 3);
        assert_eq!(snap.rejected, 2);
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.max_depth_seen, 2);
        assert_eq!(snap.queued, 1);
        assert_eq!(snap.in_flight, 1);
    }

    #[test]
    fn close_drains_then_ends() {
        let mb = Mailbox::new(8);
        mb.try_send("a").unwrap();
        mb.try_send("b").unwrap();
        mb.close();
        assert!(mb.try_send("c").is_err(), "closed mailbox admits nothing");
        assert_eq!(mb.recv(), Some("a"));
        assert_eq!(mb.recv(), Some("b"));
        assert_eq!(mb.recv(), None);
        assert_eq!(mb.recv(), None, "None is sticky");
    }

    #[test]
    fn depth_floors_at_one() {
        let mb = Mailbox::new(0);
        assert_eq!(mb.depth(), 1);
        assert!(mb.try_send(1).is_ok());
        assert!(mb.try_send(2).is_err());
    }

    #[test]
    fn blocked_receivers_wake_on_close() {
        let mb = Arc::new(Mailbox::<u32>::new(4));
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let mb = Arc::clone(&mb);
                std::thread::spawn(move || while mb.recv().is_some() {})
            })
            .collect();
        mb.close();
        for h in handles {
            h.join().unwrap(); // hangs forever if close doesn't wake them
        }
    }

    #[test]
    fn concurrent_producers_and_consumers_conserve_items() {
        let mb = Arc::new(Mailbox::<u64>::new(16));
        let consumed = Arc::new(AtomicU64::new(0));
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let mb = Arc::clone(&mb);
                let consumed = Arc::clone(&consumed);
                std::thread::spawn(move || {
                    while let Some(v) = mb.recv() {
                        consumed.fetch_add(v, Ordering::Relaxed);
                        mb.complete();
                    }
                })
            })
            .collect();
        let mut sent = 0u64;
        let mut delivered = 0u64;
        for v in 1..=500u64 {
            if mb.try_send(v).is_ok() {
                sent += v;
                delivered += 1;
            }
            if v % 7 == 0 {
                std::thread::yield_now();
            }
        }
        mb.close();
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(consumed.load(Ordering::Relaxed), sent, "every accepted item is consumed");
        let snap = mb.snapshot();
        assert_eq!(snap.accepted, delivered);
        assert_eq!(snap.completed, delivered);
        assert_eq!(snap.accepted + snap.rejected, 500);
        assert_eq!(snap.queued, 0);
        assert_eq!(snap.in_flight, 0);
        assert!(snap.max_depth_seen <= 16);
    }

    #[test]
    fn snapshot_serializes_every_counter() {
        let mb = Mailbox::new(3);
        mb.try_send(1).unwrap();
        let j = mb.snapshot().to_json();
        for field in
            ["depth", "queued", "in_flight", "accepted", "rejected", "completed", "max_depth_seen"]
        {
            assert!(j.get(field).is_some(), "missing '{field}'");
        }
        assert_eq!(j.get("accepted").and_then(|v| v.as_f64()), Some(1.0));
    }
}
