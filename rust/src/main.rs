//! `codesign` — the leader binary: a thin CLI adapter over the session
//! service.
//!
//! Every subcommand that evaluates scenarios (`explore`, `sensitivity`,
//! `report`, `tune`, `validate`, `solver-cost`) builds typed
//! [`CodesignRequest`]s and routes them through one [`Session::submit`]
//! path, so all of them share the warm memo store and the batched sweep
//! engine; `serve --requests` answers a JSON request file through the same
//! session, and `serve --listen <stdin|socket>` runs the persistent daemon
//! ([`codesign::serve`]): newline-delimited request frames in, response
//! frames streamed back in completion order, with bounded admission
//! (`--mailbox-depth`), concurrent batch groups (`--max-groups`) and
//! memo-memory budgets (`--memo-entries` / `--memo-mb`).
//! Subcommands map onto the experiments DESIGN.md catalogues; `report --all`
//! regenerates every paper table/figure under `reports/`. The session's
//! memoized sweeps persist across processes via `artifact save/load/inspect`
//! and `--warm-start` / `--save-artifact` on `explore`, `tune` and `serve`
//! (see DESIGN.md §6 for the format and the refuse-to-alias contract).

use codesign::platform::{Platform, DEFAULT_PLATFORM};
use codesign::report;
use codesign::runtime::{measure_citer, Engine};
use codesign::serve::{
    budget_from_flags, force_scalar_eval, strip_prune, Daemon, DaemonConfig, DaemonReport,
};
use codesign::service::{
    wire, CodesignRequest, CodesignResponse, ResponseDetail, ScenarioSpec, Session,
    SubmitReport, TuneRequest, WorkloadClass,
};
use codesign::stencil::defs::ALL_STENCILS;
use codesign::timemodel::CIterTable;
use codesign::util::cli::{Args, Cli, Command, OptSpec, Parsed};
use codesign::util::json::Json;
use std::path::Path;

fn cli() -> Cli {
    let out = OptSpec { name: "out", takes_value: true, default: Some("reports"), help: "output directory" };
    let quick =
        OptSpec { name: "quick", takes_value: false, default: None, help: "reduced space/workload" };
    let threads = OptSpec { name: "threads", takes_value: true, default: None, help: "worker threads" };
    let platform = OptSpec {
        name: "platform",
        takes_value: true,
        default: None,
        help: "hardware baseline: preset (maxwell, maxwell+, maxwell-nocache) or override name (maxwell:bw20:clk1.4)",
    };
    let no_prune = OptSpec {
        name: "no-prune",
        takes_value: false,
        default: None,
        help: "disable bound-and-prune: evaluate every instance in full (bit-identical results, more model evaluations)",
    };
    let scalar_eval = OptSpec {
        name: "scalar-eval",
        takes_value: false,
        default: None,
        help: "use the legacy point-at-a-time evaluation loop instead of batched SoA groups (bit-identical results; audit/bench knob)",
    };
    let warm_start = OptSpec {
        name: "warm-start",
        takes_value: true,
        default: None,
        help: "load a sweep artifact directory before answering (refuses stale/corrupt artifacts)",
    };
    let save_artifact = OptSpec {
        name: "save-artifact",
        takes_value: true,
        default: None,
        help: "persist the session's memoized sweeps to this artifact directory afterwards",
    };
    Cli {
        bin: "codesign",
        about: "Accelerator codesign as non-linear optimization — paper reproduction",
        commands: vec![
            Command {
                name: "calibrate",
                about: "E1/E2: calibrate the area model, validate on Titan X (Fig 2)",
                opts: vec![out.clone()],
            },
            Command {
                name: "explore",
                about: "E3/E4/E5/E7: full design-space exploration (Fig 3, Fig 4)",
                opts: vec![
                    out.clone(),
                    quick.clone(),
                    threads.clone(),
                    platform.clone(),
                    no_prune.clone(),
                    scalar_eval.clone(),
                    warm_start.clone(),
                    save_artifact.clone(),
                    OptSpec { name: "class", takes_value: true, default: Some("both"), help: "2d | 3d | both | <stencil>" },
                    OptSpec { name: "stencil", takes_value: true, default: None, help: "single stencil: preset (jacobi2d), family (star3d:r2) or fused chain (fuse:heat2d+laplacian2d:t4)" },
                    OptSpec { name: "objective", takes_value: true, default: Some("perf"), help: "perf (best-throughput exploration) | area-perf (2-objective Pareto front) | energy (tri-objective area x perf x energy front)" },
                    OptSpec { name: "measured-citer", takes_value: false, default: None, help: "use PJRT-measured C_iter" },
                ],
            },
            Command {
                name: "sensitivity",
                about: "E6: per-benchmark optimal architectures (Table II)",
                opts: vec![out.clone(), quick.clone(), threads.clone(), platform.clone()],
            },
            Command {
                name: "solver-cost",
                about: "E8: inner-solver cost vs bonmin + joint annealing baseline",
                opts: vec![out.clone()],
            },
            Command {
                name: "validate",
                about: "E10: time model vs cycle-approximate simulator",
                opts: vec![],
            },
            Command {
                name: "citer",
                about: "measure C_iter on the PJRT CPU substrate (needs `make artifacts`)",
                opts: vec![OptSpec { name: "repeats", takes_value: true, default: Some("3"), help: "runs per artifact" }],
            },
            Command {
                name: "run-stencil",
                about: "E11: execute one AOT stencil artifact end to end via PJRT",
                opts: vec![
                    OptSpec { name: "artifact", takes_value: true, default: Some("heat2d_256x256_t8"), help: "artifact name (see artifacts/manifest.json)" },
                    OptSpec { name: "seed", takes_value: true, default: Some("42"), help: "input seed" },
                ],
            },
            Command {
                name: "tune",
                about: "§V-D: pin a subset of {n-sm, n-v, m-sm} and optimize the rest under a budget",
                opts: vec![
                    threads.clone(),
                    platform.clone(),
                    no_prune.clone(),
                    scalar_eval.clone(),
                    warm_start.clone(),
                    save_artifact.clone(),
                    OptSpec { name: "budget", takes_value: true, default: Some("450"), help: "area budget, mm²" },
                    OptSpec { name: "n-sm", takes_value: true, default: None, help: "pin the SM count" },
                    OptSpec { name: "n-v", takes_value: true, default: None, help: "pin vector units per SM" },
                    OptSpec { name: "m-sm", takes_value: true, default: None, help: "pin shared memory (kB)" },
                    OptSpec { name: "stencil", takes_value: true, default: None, help: "single-stencil workload: preset, family or fused-chain name (default: 2d mix)" },
                ],
            },
            Command {
                name: "report",
                about: "regenerate paper tables/figures (use --all for everything)",
                opts: vec![
                    out.clone(),
                    quick.clone(),
                    threads.clone(),
                    platform.clone(),
                    OptSpec { name: "all", takes_value: false, default: None, help: "all experiments" },
                    OptSpec { name: "power-gating", takes_value: false, default: None, help: "print the §V-D power-gating curve for the platform's reference hardware and exit" },
                ],
            },
            Command {
                name: "serve",
                about: "answer a JSON request file (--requests) or run as a streaming daemon (--listen) through one warm session (wire schema v6; v1-v5 accepted)",
                opts: vec![
                    platform.clone(),
                    no_prune.clone(),
                    scalar_eval.clone(),
                    warm_start.clone(),
                    save_artifact.clone(),
                    OptSpec { name: "requests", takes_value: true, default: None, help: "one-shot mode: request file path" },
                    OptSpec { name: "listen", takes_value: true, default: None, help: "daemon mode: 'stdin' or a Unix socket path; newline-delimited request frames in, response frames streamed out in completion order" },
                    OptSpec { name: "mailbox-depth", takes_value: true, default: None, help: "daemon: max outstanding requests before admissions are rejected (default 64)" },
                    OptSpec { name: "max-groups", takes_value: true, default: None, help: "daemon: concurrently running batch groups (default: worker threads, capped at 8)" },
                    OptSpec { name: "memo-entries", takes_value: true, default: None, help: "per-partition memo-store budget in entries (evicts beyond it; answers unchanged)" },
                    OptSpec { name: "memo-mb", takes_value: true, default: None, help: "per-partition memo-store budget in megabytes (exclusive with --memo-entries)" },
                    OptSpec { name: "out", takes_value: true, default: Some("-"), help: "one-shot: response file path ('-' = stdout)" },
                    OptSpec { name: "pretty", takes_value: false, default: None, help: "one-shot: indent the response JSON" },
                    OptSpec { name: "bench-out", takes_value: true, default: None, help: "write wall/cache/eval stats JSON here (daemon: throughput, latency tails, backpressure and eviction counters)" },
                ],
            },
            Command {
                name: "artifact",
                about: "save / load / inspect persisted sweep artifacts (warm-start state)",
                opts: vec![
                    platform,
                    no_prune,
                    scalar_eval,
                    threads,
                    OptSpec { name: "dir", takes_value: true, default: None, help: "artifact directory (required)" },
                    OptSpec { name: "requests", takes_value: true, default: None, help: "request file whose sweeps to persist (save)" },
                ],
            },
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match cli().parse(&argv) {
        Parsed::Help(h) => println!("{h}"),
        Parsed::Error(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
        Parsed::Run(cmd, args) => {
            if let Err(e) = dispatch(&cmd, &args) {
                eprintln!("error: {e:#}");
                std::process::exit(1);
            }
        }
    }
}

/// A scenario spec from the shared CLI options (`--quick`, `--threads`,
/// `--no-prune`, `--scalar-eval`).
fn spec_from_args(spec: ScenarioSpec, args: &Args, citer: &CIterTable) -> ScenarioSpec {
    let mut spec = spec.with_citer(citer.clone());
    if args.flag("quick") {
        spec = spec.quick(4);
    }
    if let Some(t) = args.opt_usize("threads") {
        spec = spec.with_threads(t);
    }
    if args.flag("no-prune") {
        let opts = spec.solve_opts.clone().without_prune();
        spec = spec.with_solve_opts(opts);
    }
    if args.flag("scalar-eval") {
        let opts = spec.solve_opts.clone().with_scalar_eval();
        spec = spec.with_solve_opts(opts);
    }
    spec
}

/// The platform a request's work is attributed to in bench stats: the
/// request's own `platform` field, else the serving session's default. A
/// Sensitivity request whose two scenarios name different platforms is
/// attributed to the combined " & "-joined label (its evals span both
/// sweeps; '+' would be ambiguous — it is valid inside platform names).
fn request_platform_name(req: &CodesignRequest, default_name: &str) -> String {
    let (first, second) = req.platforms();
    let a = first.map(|i| i.name()).unwrap_or(default_name);
    let b = second.map(|i| i.name()).unwrap_or(default_name);
    if matches!(req, CodesignRequest::Sensitivity { .. }) && a != b {
        format!("{a} & {b}")
    } else {
        a.to_string()
    }
}

fn session_stats_line(session: &Session, rep: &SubmitReport) {
    eprintln!(
        "[service] {} request(s) answered in {:?}: {} unique instances swept, \
         {} lookups ({:.1}% cache hits), {} cached entries across {} partition(s); \
         prune: {} bounds, {} subtrees cut, {} instances bounded out",
        rep.answers.len(),
        rep.wall,
        rep.unique_instances,
        rep.lookups(),
        100.0 * rep.cache_hit_rate(),
        session.cache_entries(),
        session.partitions(),
        rep.prune.bounds_computed,
        rep.prune.subtrees_cut,
        rep.prune.bounded_out,
    );
}

/// `--warm-start <dir>`: load a sweep artifact into the session before any
/// request runs. Fatal on any staleness or corruption — a warm start either
/// aliases certified-identical state or nothing at all.
fn warm_start_from_args(session: &mut Session, args: &Args) -> anyhow::Result<()> {
    if let Some(dir) = args.opt("warm-start") {
        let rep = session.warm_start(Path::new(dir))?;
        eprintln!(
            "[artifact] warm start from {dir}: {} shard(s), {} slot(s) installed \
             ({} exact, {} bounded)",
            rep.shards, rep.entries_installed, rep.exact_entries, rep.bounded_entries
        );
    }
    Ok(())
}

/// `--save-artifact <dir>`: persist the session's memoized sweeps after the
/// command's requests are answered.
fn save_artifact_from_args(session: &Session, args: &Args) -> anyhow::Result<()> {
    if let Some(dir) = args.opt("save-artifact") {
        let manifest = session.save_artifact(Path::new(dir))?;
        let entries: u64 =
            manifest.shards.iter().map(|s| s.exact_entries + s.bounded_entries).sum();
        eprintln!(
            "[artifact] saved {} shard(s), {entries} entr(ies) to {dir}",
            manifest.shards.len()
        );
    }
    Ok(())
}

/// `serve --listen`: run the persistent daemon over stdin or a Unix socket.
/// Stdin serves one stream and exits at EOF; a socket path accepts
/// connections sequentially forever — one warm daemon, so every partition's
/// memo store stays hot across connections.
fn serve_daemon(
    listen: &str,
    platform: &'static Platform,
    memo_budget: Option<codesign::coordinator::MemoBudget>,
    args: &Args,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        args.opt("save-artifact").is_none(),
        "--save-artifact is not supported in daemon mode (the daemon holds one session \
         per batch group; snapshot sweeps via one-shot serve or `artifact save`)"
    );
    let mut config = DaemonConfig::new(platform.spec.clone());
    config.no_prune = args.flag("no-prune");
    config.scalar_eval = args.flag("scalar-eval");
    config.memo_budget = memo_budget;
    if let Some(d) = args.opt_usize("mailbox-depth") {
        config.mailbox_depth = d;
    }
    if let Some(g) = args.opt_usize("max-groups") {
        config.max_groups = g;
    }
    let daemon = Daemon::new(config);
    if let Some(dir) = args.opt("warm-start") {
        let rep = daemon.warm_start(Path::new(dir))?;
        eprintln!(
            "[artifact] warm start from {dir}: {} shard(s), {} slot(s) installed \
             ({} exact, {} bounded)",
            rep.shards, rep.entries_installed, rep.exact_entries, rep.bounded_entries
        );
    }
    match listen {
        "stdin" => {
            let stdin = std::io::stdin();
            let stdout = std::io::stdout();
            let mut out = stdout.lock();
            let report = daemon
                .run(stdin.lock(), &mut out)
                .map_err(|e| anyhow::anyhow!("daemon stream error: {e}"))?;
            drop(out);
            daemon_stats_line(&report);
            bench_out_daemon(&report, args)?;
        }
        path => {
            let sock = Path::new(path);
            if sock.exists() {
                std::fs::remove_file(sock)
                    .map_err(|e| anyhow::anyhow!("cannot replace stale socket '{path}': {e}"))?;
            }
            let listener = std::os::unix::net::UnixListener::bind(sock)
                .map_err(|e| anyhow::anyhow!("cannot bind '{path}': {e}"))?;
            eprintln!(
                "[serve] listening on {path} (sequential connections, one warm daemon; \
                 ^C to stop)"
            );
            for stream in listener.incoming() {
                let stream = stream?;
                let reader = std::io::BufReader::new(stream.try_clone()?);
                let mut writer = stream;
                match daemon.run(reader, &mut writer) {
                    Ok(report) => {
                        daemon_stats_line(&report);
                        bench_out_daemon(&report, args)?;
                    }
                    // A dropped connection must not kill the daemon.
                    Err(e) => eprintln!("[serve] connection error: {e}"),
                }
            }
        }
    }
    Ok(())
}

fn daemon_stats_line(report: &DaemonReport) {
    eprintln!(
        "[serve] {} response(s) streamed in {:?} ({:.1} req/s): {} line(s) read, \
         {} malformed, {} rejected, {} stats probe(s), {} error answer(s); \
         cache {:.1}% hits over {} lookups; {} resident entr(ies) across \
         {} partition(s), {} evicted",
        report.responses,
        report.wall,
        report.throughput_rps(),
        report.lines_read,
        report.error_lines,
        report.rejected,
        report.stats_probes,
        report.error_responses,
        100.0 * report.cache.hit_rate(),
        report.cache.lookups(),
        report.memory.resident_entries,
        report.memory.partitions,
        report.memory.eviction.evicted(),
    );
}

/// Daemon-mode `--bench-out`: written once per served stream (a socket
/// daemon overwrites it per connection, leaving the latest figures).
fn bench_out_daemon(report: &DaemonReport, args: &Args) -> anyhow::Result<()> {
    if let Some(path) = args.opt("bench-out") {
        std::fs::write(path, report.bench_json().to_string_pretty())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// `report --power-gating`: the §V-D closing query — sweep the number of
/// powered SMs on the platform's reference hardware and print the average
/// power and surviving relative throughput at each gating level. The
/// workload estimate comes from the inner solver's optimum for jacobi2d at
/// a paper-scale grid, so the utilization entering the power model
/// (occupancy, compute/memory balance) is the modelled one, not an assumed
/// constant.
fn power_gating_report(platform: &'static Platform) -> anyhow::Result<()> {
    use codesign::codesign::power::gating_curve;
    use codesign::opt::inner::solve_inner;
    use codesign::opt::problem::{InnerProblem, SolveOpts};
    use codesign::stencil::defs::Stencil;
    use codesign::stencil::workload::ProblemSize;

    let spec = &platform.spec;
    let (ref_name, hw) = match spec.references.first() {
        Some(r) => (r.name.clone(), r.hw),
        None => ("gtx980".to_string(), codesign::area::params::HwParams::gtx980()),
    };
    let stencil = *Stencil::by_name_err("jacobi2d").map_err(|msg| anyhow::anyhow!("{msg}"))?;
    let size = ProblemSize::d2(8192, 4096);
    let sol =
        solve_inner(&spec.time_model(), &InnerProblem { stencil, size, hw }, &SolveOpts::default())
            .ok_or_else(|| {
                anyhow::anyhow!("no feasible jacobi2d tiling on reference '{ref_name}'")
            })?;
    let breakdown = spec.area_model().breakdown(&hw);
    let curve = gating_curve(&hw, &breakdown, &sol.est, &spec.power, &spec.machine);
    println!(
        "power-gating curve on {} ({ref_name}, {} SMs): jacobi2d {}x{}, T={}",
        platform.name, hw.n_sm, size.s1, size.s2, size.t
    );
    println!("  {:>9}  {:>9}  {:>9}", "active", "power W", "rel perf");
    for (active, watts, rel) in &curve {
        println!("  {active:>6} SM  {watts:>9.1}  {:>8.0}%", rel * 100.0);
    }
    let full = curve.last().expect("gating curve covers 1..=n_sm");
    println!(
        "  (gated floor {:.1} W at 1 SM; full tilt {:.1} W at {} SMs)",
        curve[0].1, full.1, hw.n_sm
    );
    Ok(())
}

fn dispatch(cmd: &str, args: &Args) -> anyhow::Result<()> {
    let out = args.opt_or("out", "reports");
    let out = Path::new(&out);
    // `--platform` selects the session's hardware baseline; commands without
    // the option (and omissions) run on the default platform. Parsing may
    // register a new override-derived platform as a side effect.
    let platform = match args.opt("platform") {
        Some(name) => Platform::by_name_err(name).map_err(|msg| anyhow::anyhow!("{msg}"))?,
        None => Platform::get(DEFAULT_PLATFORM),
    };
    match cmd {
        "calibrate" => {
            let rep = report::fig2::generate_default();
            print!("{}", rep.summary);
            for f in rep.save(out)? {
                println!("wrote {}", f.display());
            }
        }
        "explore" | "sensitivity" | "report" => {
            if cmd == "report" && args.flag("power-gating") {
                return power_gating_report(platform);
            }
            let class = args.opt_or("class", "both");
            // `--class both` fans out to the two paper panels; anything else
            // (2d, 3d, a preset name, a parametric family like star3d:r2)
            // resolves through `WorkloadClass::parse`, whose rejection lists
            // every valid option. `--stencil NAME` is shorthand for
            // `--class NAME` restricted to single-stencil classes.
            let single_class = match (cmd, args.opt("stencil"), class.as_str()) {
                ("explore", Some(name), _) => {
                    anyhow::ensure!(
                        class == "both",
                        "--stencil {name} conflicts with --class {class}; pass one or the other"
                    );
                    let st = codesign::stencil::defs::Stencil::by_name_err(name)
                        .map_err(|msg| anyhow::anyhow!("{msg}"))?;
                    Some(WorkloadClass::Single(st.id))
                }
                ("explore", None, "both" | "2d" | "3d") => None,
                ("explore", None, other) => Some(WorkloadClass::parse(other)?),
                _ => {
                    anyhow::ensure!(
                        class == "both",
                        "--class is only selectable for explore (got '{class}')"
                    );
                    None
                }
            };
            let citer = if args.flag("measured-citer") {
                let mut engine = Engine::from_default_artifacts()?;
                measure_citer(&mut engine, 3)?
            } else {
                CIterTable::paper()
            };
            // `--class` filters *before* any scenario is constructed: only
            // the requested specs are ever built.
            let want_2d = single_class.is_none() && (cmd != "explore" || class != "3d");
            let want_3d = single_class.is_none() && (cmd != "explore" || class != "2d");
            let spec_2d = want_2d.then(|| spec_from_args(ScenarioSpec::two_d(), args, &citer));
            let spec_3d = want_3d.then(|| spec_from_args(ScenarioSpec::three_d(), args, &citer));

            // `--objective` picks the request family explore submits:
            // `perf` keeps the paper's best-throughput exploration,
            // `area-perf` asks for the 2-objective Pareto front, `energy`
            // for the tri-objective (area, perf, energy) front certified by
            // the energy roofline bound. Only explore has the option; the
            // other commands always take the perf path.
            let objective = args.opt_or("objective", "perf");
            anyhow::ensure!(
                matches!(objective.as_str(), "perf" | "area-perf" | "energy"),
                "unknown --objective '{objective}' (choose: perf | area-perf | energy)"
            );
            let to_request = |spec: ScenarioSpec| match objective.as_str() {
                "area-perf" => CodesignRequest::pareto(spec),
                "energy" => CodesignRequest::pareto_energy(spec),
                _ => CodesignRequest::explore(spec),
            };

            let mut requests = Vec::new();
            if let Some(c) = single_class {
                let spec = spec_from_args(ScenarioSpec::new(c), args, &citer);
                requests.push(to_request(spec));
            }
            for spec in [&spec_2d, &spec_3d].into_iter().flatten() {
                requests.push(to_request(spec.clone()));
            }
            if cmd != "explore" {
                if let (Some(s2), Some(s3)) = (&spec_2d, &spec_3d) {
                    requests.push(CodesignRequest::sensitivity(
                        s2.clone(),
                        s3.clone(),
                        (425.0, 450.0),
                    ));
                }
            }
            if cmd == "report" && args.flag("all") {
                requests.push(CodesignRequest::SolverCost {
                    anneal_iters: 20_000,
                    citer: CIterTable::paper(),
                });
            }

            let mut session = Session::new(platform.spec.clone()).with_progress(500);
            warm_start_from_args(&mut session, args)?;
            let rep = session.submit_all(&requests);
            session_stats_line(&session, &rep);
            save_artifact_from_args(&session, args)?;
            for answer in &rep.answers {
                match (&answer.response, &answer.detail) {
                    (CodesignResponse::Explore(_), ResponseDetail::Scenarios(details)) => {
                        for d in details {
                            let fig3 = report::fig3::generate(&d.result, &d.platform.area_model());
                            print!("{}", fig3.summary);
                            fig3.save(out)?;
                            let fig4 = report::fig4::generate(&d.result, &d.platform.area_model());
                            print!("{}", fig4.summary);
                            fig4.save(out)?;
                        }
                    }
                    (CodesignResponse::Sensitivity(_), ResponseDetail::Scenarios(details)) => {
                        let [d2, d3] = &details[..] else {
                            anyhow::bail!("sensitivity answer must carry two scenarios");
                        };
                        let t2 = report::table2::generate(
                            &d2.result,
                            &d2.scenario.workload,
                            &d3.result,
                            &d3.scenario.workload,
                            &d2.platform,
                            &d2.scenario.citer,
                            (425.0, 450.0),
                        );
                        print!("{}", t2.summary);
                        t2.save(out)?;
                    }
                    (CodesignResponse::SolverCost(_), ResponseDetail::Report(r)) => {
                        print!("{}", r.summary);
                        r.save(out)?;
                    }
                    (CodesignResponse::Pareto(p), _) => {
                        println!(
                            "{}: area/perf Pareto front — {} design(s) evaluated \
                             ({} infeasible, {} bounded out), {} on the front:",
                            p.scenario,
                            p.designs,
                            p.infeasible,
                            p.bounded_out,
                            p.pareto.len()
                        );
                        for d in &p.pareto {
                            println!(
                                "  {:<36} {:>8.1} mm²  {:>8.0} GFLOP/s",
                                d.label(),
                                d.area_mm2,
                                d.gflops
                            );
                        }
                    }
                    (CodesignResponse::ParetoEnergy(p), _) => {
                        println!(
                            "{}: tri-objective (area, perf, energy) Pareto front — \
                             {} design(s) evaluated ({} infeasible, {} bounded out), \
                             {} on the front:",
                            p.scenario,
                            p.designs,
                            p.infeasible,
                            p.bounded_out,
                            p.pareto.len()
                        );
                        for d in &p.pareto {
                            println!(
                                "  {:<36} {:>8.1} mm²  {:>8.0} GFLOP/s  {:>7.1} W  {:>10.4} J",
                                d.label(),
                                d.area_mm2,
                                d.gflops,
                                d.power_w,
                                d.energy_j
                            );
                        }
                    }
                    (CodesignResponse::Error(e), _) => {
                        anyhow::bail!("{} request failed: {}", e.request, e.message)
                    }
                    _ => {}
                }
            }
            if cmd == "report" && args.flag("all") {
                let fig2 = report::fig2::generate_default();
                print!("{}", fig2.summary);
                fig2.save(out)?;
            }
        }
        "solver-cost" => {
            let mut session = Session::new(platform.spec.clone());
            let answer = session.submit(&CodesignRequest::solver_cost(50_000));
            match (&answer.response, &answer.detail) {
                (CodesignResponse::SolverCost(_), ResponseDetail::Report(r)) => {
                    print!("{}", r.summary);
                    r.save(out)?;
                }
                (other, _) => anyhow::bail!("unexpected response '{}'", other.kind()),
            }
        }
        "validate" => {
            let mut session = Session::new(platform.spec.clone());
            let answer = session.submit(&CodesignRequest::validate());
            let (CodesignResponse::Validate(v), ResponseDetail::Validation(full)) =
                (&answer.response, &answer.detail)
            else {
                anyhow::bail!("unexpected response '{}'", answer.response.kind());
            };
            println!(
                "model vs simulator over {} configurations: MAPE {:.1}%, Kendall tau {:.3}",
                v.cases, v.mape_pct, v.kendall_tau
            );
            for c in full.cases.iter().take(8) {
                println!(
                    "  {:<64} model {:>10.4} ms  sim {:>10.4} ms  ({:+.1}%)",
                    c.label,
                    c.model_seconds * 1e3,
                    c.sim_seconds * 1e3,
                    c.rel_err_pct()
                );
            }
        }
        "citer" => {
            let repeats = args.opt_usize("repeats").unwrap_or(3);
            let mut engine = Engine::from_default_artifacts()?;
            println!("PJRT platform: {}", engine.platform());
            let table = measure_citer(&mut engine, repeats)?;
            let paper = CIterTable::paper();
            for s in &ALL_STENCILS {
                println!(
                    "  {:<12} measured {:>7.2} cycles (paper mode {:>5.1})",
                    s.name(),
                    table.get(s.id),
                    paper.get(s.id)
                );
            }
        }
        "run-stencil" => {
            let name = args.opt_or("artifact", "heat2d_256x256_t8");
            let seed = args.opt_usize("seed").unwrap_or(42) as u64;
            let mut engine = Engine::from_default_artifacts()?;
            let entry = engine
                .manifest()
                .get(&name)
                .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))?
                .clone();
            let input = Engine::random_input(&entry, seed);
            let run = engine.run_sweep(&name, &input)?;
            let ns_pt = run.elapsed.as_nanos() as f64 / entry.points_per_sweep;
            println!(
                "{name}: {} points x {} steps in {:?} ({ns_pt:.2} ns/point-update) on {}",
                entry.points_per_sweep / entry.t_steps as f64,
                entry.t_steps,
                run.elapsed,
                engine.platform()
            );
            let mean: f32 = run.output.iter().sum::<f32>() / run.output.len() as f32;
            println!("output mean {mean:.6}, first interior value {}", run.output[entry.shape[1] + 3]);
        }
        "tune" => {
            let budget = args.opt_f64("budget").unwrap_or(450.0);
            let mut req = TuneRequest::new(budget);
            req.n_sm = args.opt_usize("n-sm").map(|v| v as u32);
            req.n_v = args.opt_usize("n-v").map(|v| v as u32);
            req.m_sm_kb = args.opt_f64("m-sm");
            req.threads = args.opt_usize("threads");
            if args.flag("no-prune") {
                req.solve_opts.prune = false;
            }
            if args.flag("scalar-eval") {
                req.solve_opts.scalar_eval = true;
            }
            if let Some(name) = args.opt("stencil") {
                let st = codesign::stencil::defs::Stencil::by_name_err(name)
                    .map_err(|msg| anyhow::anyhow!("{msg}"))?;
                req.stencil = Some(st.id);
            }
            let mut session = Session::new(platform.spec.clone());
            warm_start_from_args(&mut session, args)?;
            let answer = session.submit(&CodesignRequest::Tune(req));
            save_artifact_from_args(&session, args)?;
            let CodesignResponse::Tune(t) = &answer.response else {
                anyhow::bail!("unexpected response '{}'", answer.response.kind());
            };
            let best = t
                .best
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("no feasible design within {budget} mm²"))?;
            println!(
                "best completion within {budget} mm² over {} candidates:\n  {} -> {:.0} GFLOP/s at {:.0} mm²",
                t.candidates,
                best.label(),
                best.gflops,
                best.area_mm2
            );
        }
        "serve" => {
            let memo_budget =
                budget_from_flags(args.opt_usize("memo-entries"), args.opt_f64("memo-mb"))?;
            if let Some(listen) = args.opt("listen") {
                anyhow::ensure!(
                    args.opt("requests").is_none(),
                    "--listen and --requests are mutually exclusive (daemon vs one-shot)"
                );
                return serve_daemon(listen, platform, memo_budget, args);
            }
            let path = args.opt("requests").ok_or_else(|| {
                anyhow::anyhow!("serve needs --requests <file.json> or --listen <stdin|socket>")
            })?;
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("cannot read '{path}': {e}"))?;
            let mut requests = wire::decode_requests(&text)?;
            if args.flag("no-prune") {
                for req in &mut requests {
                    strip_prune(req);
                }
            }
            if args.flag("scalar-eval") {
                for req in &mut requests {
                    force_scalar_eval(req);
                }
            }
            let mut session = Session::new(platform.spec.clone()).with_memo_budget(memo_budget);
            warm_start_from_args(&mut session, args)?;
            let rep = session.submit_all(&requests);
            session_stats_line(&session, &rep);
            save_artifact_from_args(&session, args)?;
            let mut failed = 0usize;
            for (i, a) in rep.answers.iter().enumerate() {
                if let CodesignResponse::Error(e) = &a.response {
                    eprintln!("[service] request {i} ({}) failed: {}", e.request, e.message);
                    failed += 1;
                }
            }
            let responses: Vec<CodesignResponse> =
                rep.answers.iter().map(|a| a.response.clone()).collect();
            let envelope = wire::encode_responses(&responses);
            let rendered = if args.flag("pretty") {
                envelope.to_string_pretty()
            } else {
                envelope.to_string_compact()
            };
            let dest = args.opt_or("out", "-");
            if dest == "-" {
                println!("{rendered}");
            } else {
                std::fs::write(&dest, &rendered)?;
                eprintln!("wrote {dest}");
            }
            if let Some(bench_path) = args.opt("bench-out") {
                let total_evals: u64 =
                    responses.iter().map(CodesignResponse::total_evals).sum();
                // Per-platform entries so the perf trajectory distinguishes
                // baselines: requests and model evaluations attributed to
                // the platform each request ran on.
                let mut per: Vec<(String, u64, u64)> = Vec::new();
                for (req, resp) in requests.iter().zip(&responses) {
                    let name = request_platform_name(req, platform.name);
                    match per.iter_mut().find(|(n, _, _)| *n == name) {
                        Some(e) => {
                            e.1 += 1;
                            e.2 += resp.total_evals();
                        }
                        None => per.push((name, 1, resp.total_evals())),
                    }
                }
                let platforms = Json::Arr(
                    per.into_iter()
                        .map(|(name, reqs, evals)| {
                            Json::obj(vec![
                                ("platform", Json::str(&name)),
                                ("requests", Json::num(reqs as f64)),
                                ("total_evals", Json::num(evals as f64)),
                            ])
                        })
                        .collect(),
                );
                let bench = Json::obj(vec![
                    ("requests", Json::num(requests.len() as f64)),
                    ("wall_ms", Json::num(rep.wall.as_secs_f64() * 1e3)),
                    ("cache_hit_rate", Json::num(rep.cache_hit_rate())),
                    ("lookups", Json::num(rep.lookups() as f64)),
                    ("unique_instances", Json::num(rep.unique_instances as f64)),
                    ("total_evals", Json::num(total_evals as f64)),
                    (
                        "prune",
                        Json::obj(vec![
                            ("enabled", Json::Bool(!args.flag("no-prune"))),
                            ("bounds_computed", Json::num(rep.prune.bounds_computed as f64)),
                            ("subtrees_cut", Json::num(rep.prune.subtrees_cut as f64)),
                            ("bounded_out", Json::num(rep.prune.bounded_out as f64)),
                            ("groups_evaluated", Json::num(rep.prune.groups_evaluated as f64)),
                            ("lanes_evaluated", Json::num(rep.prune.lanes_evaluated as f64)),
                        ]),
                    ),
                    ("scalar_eval", Json::Bool(args.flag("scalar-eval"))),
                    ("default_platform", Json::str(platform.name)),
                    ("platforms", platforms),
                ]);
                std::fs::write(bench_path, bench.to_string_pretty())?;
                eprintln!("wrote {bench_path}");
            }
            // Responses (and bench stats) are written above even on failure;
            // the nonzero exit keeps CI honest about error answers.
            anyhow::ensure!(
                failed == 0,
                "{failed} of {} request(s) answered with an error",
                requests.len()
            );
        }
        "artifact" => {
            let action = args.positional.first().map(String::as_str).ok_or_else(|| {
                anyhow::anyhow!("artifact needs an action: save | load | inspect")
            })?;
            let dir_of = || -> anyhow::Result<&str> {
                args.opt("dir")
                    .ok_or_else(|| anyhow::anyhow!("artifact {action} needs --dir <directory>"))
            };
            match action {
                "save" => {
                    // Run a request file through a fresh session, then persist
                    // the sweeps it memoized.
                    let dir = dir_of()?;
                    let path = args.opt("requests").ok_or_else(|| {
                        anyhow::anyhow!(
                            "artifact save needs --requests <file.json> \
                             (the workload whose sweeps to persist)"
                        )
                    })?;
                    let text = std::fs::read_to_string(path)
                        .map_err(|e| anyhow::anyhow!("cannot read '{path}': {e}"))?;
                    let mut requests = wire::decode_requests(&text)?;
                    if args.flag("no-prune") {
                        for req in &mut requests {
                            strip_prune(req);
                        }
                    }
                    if args.flag("scalar-eval") {
                        for req in &mut requests {
                            force_scalar_eval(req);
                        }
                    }
                    let mut session = Session::new(platform.spec.clone());
                    let rep = session.submit_all(&requests);
                    session_stats_line(&session, &rep);
                    let manifest = session.save_artifact(Path::new(dir))?;
                    let entries: u64 = manifest
                        .shards
                        .iter()
                        .map(|s| s.exact_entries + s.bounded_entries)
                        .sum();
                    println!(
                        "saved {} shard(s), {entries} entr(ies) to {dir}",
                        manifest.shards.len()
                    );
                }
                "load" => {
                    // Certify an artifact by loading it into a fresh session:
                    // every integrity and staleness gate runs; failure exits
                    // nonzero with the precise mismatch.
                    let dir = dir_of()?;
                    let mut session = Session::new(platform.spec.clone());
                    let rep = session.warm_start(Path::new(dir))?;
                    println!(
                        "loaded {} shard(s) from {dir}: {} slot(s) installed \
                         ({} exact, {} bounded) across {} partition(s)",
                        rep.shards,
                        rep.entries_installed,
                        rep.exact_entries,
                        rep.bounded_entries,
                        session.partitions()
                    );
                }
                "inspect" => {
                    let dir = dir_of()?;
                    let info = codesign::artifact::inspect(Path::new(dir))?;
                    println!(
                        "artifact at {dir}: schema {} (wire {}), {} shard(s), {} entr(ies), \
                         checksums verified",
                        info.artifact_schema,
                        info.wire_schema,
                        info.shards.len(),
                        info.total_entries()
                    );
                    for s in &info.shards {
                        println!(
                            "  {}  platform {} (fp {:016x})  prune={}  {} exact + {} bounded  \
                             {} bytes",
                            s.file,
                            s.platform,
                            s.platform_fp,
                            s.prune,
                            s.exact_entries,
                            s.bounded_entries,
                            s.bytes
                        );
                    }
                }
                other => anyhow::bail!("unknown artifact action '{other}' (save | load | inspect)"),
            }
        }
        other => anyhow::bail!("unhandled command {other}"),
    }
    Ok(())
}
