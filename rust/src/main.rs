//! `codesign` — the leader binary: CLI over the full reproduction.
//!
//! Subcommands map 1:1 onto the experiments of DESIGN.md §6; `report --all`
//! regenerates every paper table/figure under `reports/`.

use codesign::area::AreaModel;
use codesign::codesign::scenario::Scenario;
use codesign::coordinator::Coordinator;
use codesign::report;
use codesign::runtime::{measure_citer, Engine};
use codesign::sim::validate_sweep;
use codesign::stencil::defs::StencilId;
use codesign::timemodel::{CIterTable, TimeModel};
use codesign::util::cli::{Args, Cli, Command, OptSpec, Parsed};
use std::path::Path;

fn cli() -> Cli {
    let out = OptSpec { name: "out", takes_value: true, default: Some("reports"), help: "output directory" };
    let quick =
        OptSpec { name: "quick", takes_value: false, default: None, help: "reduced space/workload" };
    let threads = OptSpec { name: "threads", takes_value: true, default: None, help: "worker threads" };
    Cli {
        bin: "codesign",
        about: "Accelerator codesign as non-linear optimization — paper reproduction",
        commands: vec![
            Command {
                name: "calibrate",
                about: "E1/E2: calibrate the area model, validate on Titan X (Fig 2)",
                opts: vec![out.clone()],
            },
            Command {
                name: "explore",
                about: "E3/E4/E5/E7: full design-space exploration (Fig 3, Fig 4)",
                opts: vec![
                    out.clone(),
                    quick.clone(),
                    threads.clone(),
                    OptSpec { name: "class", takes_value: true, default: Some("both"), help: "2d | 3d | both" },
                    OptSpec { name: "measured-citer", takes_value: false, default: None, help: "use PJRT-measured C_iter" },
                ],
            },
            Command {
                name: "sensitivity",
                about: "E6: per-benchmark optimal architectures (Table II)",
                opts: vec![out.clone(), quick.clone(), threads.clone()],
            },
            Command {
                name: "solver-cost",
                about: "E8: inner-solver cost vs bonmin + joint annealing baseline",
                opts: vec![out.clone()],
            },
            Command {
                name: "validate",
                about: "E10: time model vs cycle-approximate simulator",
                opts: vec![],
            },
            Command {
                name: "citer",
                about: "measure C_iter on the PJRT CPU substrate (needs `make artifacts`)",
                opts: vec![OptSpec { name: "repeats", takes_value: true, default: Some("3"), help: "runs per artifact" }],
            },
            Command {
                name: "run-stencil",
                about: "E11: execute one AOT stencil artifact end to end via PJRT",
                opts: vec![
                    OptSpec { name: "artifact", takes_value: true, default: Some("heat2d_256x256_t8"), help: "artifact name (see artifacts/manifest.json)" },
                    OptSpec { name: "seed", takes_value: true, default: Some("42"), help: "input seed" },
                ],
            },
            Command {
                name: "tune",
                about: "§V-D: pin a subset of {n-sm, n-v, m-sm} and optimize the rest under a budget",
                opts: vec![
                    OptSpec { name: "budget", takes_value: true, default: Some("450"), help: "area budget, mm²" },
                    OptSpec { name: "n-sm", takes_value: true, default: None, help: "pin the SM count" },
                    OptSpec { name: "n-v", takes_value: true, default: None, help: "pin vector units per SM" },
                    OptSpec { name: "m-sm", takes_value: true, default: None, help: "pin shared memory (kB)" },
                    OptSpec { name: "stencil", takes_value: true, default: None, help: "single-benchmark workload (default: 2d mix)" },
                ],
            },
            Command {
                name: "report",
                about: "regenerate paper tables/figures (use --all for everything)",
                opts: vec![
                    out.clone(),
                    quick.clone(),
                    threads,
                    OptSpec { name: "all", takes_value: false, default: None, help: "all experiments" },
                ],
            },
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match cli().parse(&argv) {
        Parsed::Help(h) => println!("{h}"),
        Parsed::Error(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
        Parsed::Run(cmd, args) => {
            if let Err(e) = dispatch(&cmd, &args) {
                eprintln!("error: {e:#}");
                std::process::exit(1);
            }
        }
    }
}

fn scenario(base: Scenario, args: &Args) -> Scenario {
    let mut sc = if args.flag("quick") { Scenario::quick(base, 4) } else { base };
    if let Some(t) = args.opt_usize("threads") {
        sc.threads = t.max(1);
    }
    sc
}

fn dispatch(cmd: &str, args: &Args) -> anyhow::Result<()> {
    let out = args.opt_or("out", "reports");
    let out = Path::new(&out);
    let area_model = AreaModel::paper();
    let time_model = TimeModel::maxwell();
    match cmd {
        "calibrate" => {
            let rep = report::fig2::generate_default();
            print!("{}", rep.summary);
            for f in rep.save(out)? {
                println!("wrote {}", f.display());
            }
        }
        "explore" | "sensitivity" | "report" => {
            let class = args.opt_or("class", "both");
            let citer = if args.flag("measured-citer") {
                let mut engine = Engine::from_default_artifacts()?;
                measure_citer(&mut engine, 3)?
            } else {
                CIterTable::paper()
            };
            let coord = Coordinator::new(area_model, time_model).with_progress(500);
            let mut results = Vec::new();
            for base in [Scenario::paper_2d(), Scenario::paper_3d()] {
                if cmd == "explore" && class != "both" && base.name != class {
                    continue;
                }
                let mut sc = scenario(base, args);
                sc.citer = citer.clone();
                eprintln!("[explore] running {} scenario…", sc.name);
                let rep = coord.run_scenario(&sc);
                eprintln!(
                    "[explore] {}: {} points, {:?}, cache {} entries ({:.0}% hits)",
                    sc.name,
                    rep.result.points.len(),
                    rep.wall,
                    rep.cache_entries,
                    100.0 * rep.cache_hit_rate
                );
                results.push((sc, rep));
            }
            for (_, rep) in &results {
                let fig3 = report::fig3::generate(&rep.result, &area_model);
                print!("{}", fig3.summary);
                fig3.save(out)?;
                let fig4 = report::fig4::generate(&rep.result, &area_model);
                print!("{}", fig4.summary);
                fig4.save(out)?;
            }
            if (cmd != "explore") && results.len() == 2 {
                let t2 = report::table2::generate(
                    &results[0].1.result,
                    &results[0].0.workload,
                    &results[1].1.result,
                    &results[1].0.workload,
                    &time_model,
                    &results[0].0.citer,
                    (425.0, 450.0),
                );
                print!("{}", t2.summary);
                t2.save(out)?;
            }
            if cmd == "report" && args.flag("all") {
                let fig2 = report::fig2::generate_default();
                print!("{}", fig2.summary);
                fig2.save(out)?;
                let sc = report::solver_cost::generate(&time_model, &CIterTable::paper(), 20_000);
                print!("{}", sc.summary);
                sc.save(out)?;
            }
        }
        "solver-cost" => {
            let rep = report::solver_cost::generate(&time_model, &CIterTable::paper(), 50_000);
            print!("{}", rep.summary);
            rep.save(out)?;
        }
        "validate" => {
            let rep = validate_sweep(&time_model);
            println!(
                "model vs simulator over {} configurations: MAPE {:.1}%, Kendall tau {:.3}",
                rep.cases.len(),
                rep.mape_pct,
                rep.kendall_tau
            );
            for c in rep.cases.iter().take(8) {
                println!(
                    "  {:<64} model {:>10.4} ms  sim {:>10.4} ms  ({:+.1}%)",
                    c.label,
                    c.model_seconds * 1e3,
                    c.sim_seconds * 1e3,
                    c.rel_err_pct()
                );
            }
        }
        "citer" => {
            let repeats = args.opt_usize("repeats").unwrap_or(3);
            let mut engine = Engine::from_default_artifacts()?;
            println!("PJRT platform: {}", engine.platform());
            let table = measure_citer(&mut engine, repeats)?;
            let paper = CIterTable::paper();
            for id in [
                StencilId::Jacobi2D,
                StencilId::Heat2D,
                StencilId::Laplacian2D,
                StencilId::Gradient2D,
                StencilId::Heat3D,
                StencilId::Laplacian3D,
            ] {
                println!(
                    "  {:<12} measured {:>7.2} cycles (paper mode {:>5.1})",
                    id.name(),
                    table.get(id),
                    paper.get(id)
                );
            }
        }
        "run-stencil" => {
            let name = args.opt_or("artifact", "heat2d_256x256_t8");
            let seed = args.opt_usize("seed").unwrap_or(42) as u64;
            let mut engine = Engine::from_default_artifacts()?;
            let entry = engine
                .manifest()
                .get(&name)
                .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))?
                .clone();
            let input = Engine::random_input(&entry, seed);
            let run = engine.run_sweep(&name, &input)?;
            let ns_pt = run.elapsed.as_nanos() as f64 / entry.points_per_sweep;
            println!(
                "{name}: {} points x {} steps in {:?} ({ns_pt:.2} ns/point-update) on {}",
                entry.points_per_sweep / entry.t_steps as f64,
                entry.t_steps,
                run.elapsed,
                engine.platform()
            );
            let mean: f32 = run.output.iter().sum::<f32>() / run.output.len() as f32;
            println!("output mean {mean:.6}, first interior value {}", run.output[entry.shape[1] + 3]);
        }
        "tune" => {
            use codesign::codesign::tuner::{tune, Pinned};
            use codesign::opt::problem::SolveOpts;
            use codesign::stencil::workload::Workload;
            let budget = args.opt_f64("budget").unwrap_or(450.0);
            let pinned = Pinned {
                n_sm: args.opt_usize("n-sm").map(|v| v as u32),
                n_v: args.opt_usize("n-v").map(|v| v as u32),
                m_sm_kb: args.opt_f64("m-sm"),
                caches: None,
            };
            let workload = match args.opt("stencil") {
                Some(name) => {
                    let id = StencilId::from_name(name)
                        .ok_or_else(|| anyhow::anyhow!("unknown stencil '{name}'"))?;
                    Workload::single(id)
                }
                None => Workload::uniform_2d(),
            };
            let r = tune(
                &pinned,
                budget,
                &workload,
                &area_model,
                &time_model,
                &CIterTable::paper(),
                &SolveOpts::default(),
            )
            .ok_or_else(|| anyhow::anyhow!("no feasible design within {budget} mm²"))?;
            println!(
                "best completion within {budget} mm² over {} candidates:\n  {} -> {:.0} GFLOP/s at {:.0} mm²",
                r.candidates,
                r.hw.label(),
                r.gflops,
                r.area_mm2
            );
        }
        other => anyhow::bail!("unhandled command {other}"),
    }
    Ok(())
}
