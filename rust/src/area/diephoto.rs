//! Die-photomicrograph "measurements" of the GTX 980.
//!
//! The paper derives two coefficients that Cacti cannot give — the
//! per-vector-unit core-logic area `β_VU` and the per-SM common overhead
//! `α_oh` — by annotating functional blocks on published GTX 980 die photos
//! (Fritzchens Fritz's photographs + NVIDIA's official die shots), measuring
//! block areas in pixels, and normalizing by the known total die area.
//!
//! No die photos ship with this repo, so the *pixel measurements themselves*
//! are the substitution point (DESIGN.md §2): we store the pixel-space block
//! annotation that reproduces the paper's published mm² numbers and run the
//! same normalization pipeline over it. The paper's §III-B reports the mm²
//! results of that pipeline (L2 105 mm², L1 7.34 mm², shm 1.27 mm²/SM-slice,
//! β_VU 0.04282 mm², overhead region 102.65 mm²), which pins the synthetic
//! annotation exactly.

/// One annotated rectangular block on the die photo, measured in pixels.
#[derive(Clone, Copy, Debug)]
pub struct BlockPx {
    pub name: &'static str,
    pub pixels: f64,
}

/// A die photograph annotation: total die pixels, known die area, and the
/// measured functional blocks.
#[derive(Clone, Debug)]
pub struct DiePhoto {
    /// Chip name for reporting.
    pub chip: &'static str,
    /// Published total die area, mm² (GTX 980: 398 mm²).
    pub die_mm2: f64,
    /// Total die size in the photograph, pixels.
    pub die_px: f64,
    pub blocks: Vec<BlockPx>,
}

/// Paper-reported GTX 980 die-photo measurements (mm²), used to synthesize
/// the pixel annotation and to cross-check the normalization below.
pub const GTX980_MEASURED_MM2: [(&str, f64); 5] = [
    ("l2_total", 105.0),
    ("l1_total", 7.34),
    ("shm_per_sm", 1.27),
    ("vu_core_logic_per_v", 0.04282),
    ("overhead_region", 102.65),
];

impl DiePhoto {
    /// The synthetic GTX 980 annotation. We fix an arbitrary photograph
    /// resolution (4000×4000 px for a 398 mm² die → 40.2 kpx/mm²) and place
    /// each paper-reported block at the pixel count that normalizes back to
    /// its published mm² figure — i.e. the annotation *is* the paper's
    /// measurement, re-expressed in the pixel domain so the full
    /// pixels→mm² pipeline is exercised.
    pub fn gtx980() -> DiePhoto {
        let die_mm2 = 398.0;
        let die_px = 4000.0 * 4000.0;
        let px_per_mm2 = die_px / die_mm2;
        let blocks = GTX980_MEASURED_MM2
            .iter()
            .map(|&(name, mm2)| BlockPx { name, pixels: mm2 * px_per_mm2 })
            .collect();
        DiePhoto { chip: "gtx980", die_mm2, die_px, blocks }
    }

    /// Pixels-per-mm² normalization factor of this photograph.
    pub fn px_per_mm2(&self) -> f64 {
        self.die_px / self.die_mm2
    }

    /// Normalized area of a named block, mm².
    pub fn block_mm2(&self, name: &str) -> Option<f64> {
        self.blocks
            .iter()
            .find(|b| b.name == name)
            .map(|b| b.pixels / self.px_per_mm2())
    }

    /// β_VU: per-vector-unit core logic area (excluding the register file),
    /// mm². On GTX 980 the measured block is already per vector unit.
    pub fn beta_vu(&self) -> f64 {
        self.block_mm2("vu_core_logic_per_v").expect("annotation missing vu block")
    }

    /// α_oh: common overhead area amortized per SM, mm² — the I/O pads,
    /// buffers, memory controllers, gigathread + raster engines and PCI
    /// controller region divided by the SM count (§III-A's design choice
    /// that overhead scales with `n_SM`).
    pub fn alpha_oh(&self, n_sm: u32) -> f64 {
        self.block_mm2("overhead_region").expect("annotation missing overhead block")
            / n_sm as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization_roundtrips_published_numbers() {
        let p = DiePhoto::gtx980();
        for &(name, mm2) in &GTX980_MEASURED_MM2 {
            let got = p.block_mm2(name).unwrap();
            assert!((got - mm2).abs() < 1e-9, "{name}: {got} vs {mm2}");
        }
    }

    #[test]
    fn beta_vu_matches_paper() {
        assert!((DiePhoto::gtx980().beta_vu() - 0.04282).abs() < 1e-9);
    }

    #[test]
    fn alpha_oh_matches_paper() {
        // 102.65 mm² / 16 SMs = 6.4156 mm² per SM.
        let a = DiePhoto::gtx980().alpha_oh(16);
        assert!((a - 6.4156).abs() < 1e-3, "alpha_oh={a}");
    }

    #[test]
    fn unknown_block_is_none() {
        assert!(DiePhoto::gtx980().block_mm2("nope").is_none());
    }
}
