//! Analytical GPU silicon-area model (§III of the paper).
//!
//! `A_tot(n_SM, n_V, R_VU, M_SM, L1, L2)` per eq. (3)–(6): per-SM vector-unit
//! and memory terms, chip-level caches, and a per-SM overhead for I/O, global
//! routing, gigathread scheduler, PCI and memory controllers. Coefficients
//! come from two sources, exactly as in the paper:
//!
//! 1. the four memory linear fits out of the Cacti-like estimator
//!    ([`crate::cacti`], Fig 2), and
//! 2. die-photomicrograph measurements of the GTX 980 ([`diephoto`]):
//!    per-vector-unit core logic area β_VU and per-SM overhead α_oh.
//!
//! Calibrated on the GTX 980, validated on the Titan X (§III-C; ≤ 2% error).

pub mod calibrate;
pub mod diephoto;
pub mod model;
pub mod params;

pub use calibrate::{calibrate, Calibration};
pub use diephoto::DiePhoto;
pub use model::{AreaBreakdown, AreaCoeffs, AreaModel};
pub use params::HwParams;
