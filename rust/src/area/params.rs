//! Hardware parameter vectors (the h-vector of the codesign problem) and the
//! two reference Maxwell configurations used throughout the paper.

/// An accelerator hardware configuration.
///
/// Fields mirror Table I's elementary parameters. Cache-less design points
/// (the paper's proposed architectures, §V-A) set `l1_smpair_kb` and `l2_kb`
/// to zero.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HwParams {
    /// Number of streaming multiprocessors, `n_SM`.
    pub n_sm: u32,
    /// Vector units (cores) per SM, `n_V`.
    pub n_v: u32,
    /// Register file per vector unit, kB (`R_VU`; GTX 980: 512 × 32-bit = 2 kB).
    pub r_vu_kb: f64,
    /// Shared (scratchpad) memory per SM, kB (`M_SM`).
    pub m_sm_kb: f64,
    /// L1 cache per SM-pair, kB (`L1_SMpair`).
    pub l1_smpair_kb: f64,
    /// Total chip-level L2 cache, kB (`L2`); not scaled by `n_SM` (§III-A).
    pub l2_kb: f64,
}

impl HwParams {
    /// NVIDIA GeForce GTX 980 (Maxwell GM204): 16 SMs × 128 cores, 96 kB
    /// shared memory per SM, 48 kB L1 per SM-pair, 2 MB L2, 2 kB registers
    /// per vector unit. Published die area: 398 mm².
    pub fn gtx980() -> HwParams {
        HwParams {
            n_sm: 16,
            n_v: 128,
            r_vu_kb: 2.0,
            m_sm_kb: 96.0,
            l1_smpair_kb: 48.0,
            l2_kb: 2048.0,
        }
    }

    /// NVIDIA GeForce GTX Titan X (Maxwell GM200): 24 SMs × 128 cores, 3 MB
    /// L2, otherwise GTX 980-like. Published die area: 601 mm².
    pub fn titanx() -> HwParams {
        HwParams { n_sm: 24, n_v: 128, r_vu_kb: 2.0, m_sm_kb: 96.0, l1_smpair_kb: 48.0, l2_kb: 3072.0 }
    }

    /// This configuration with all caches removed (§V-A's "delete the
    /// caches" scenario). Register file and shared memory are kept.
    pub fn without_caches(&self) -> HwParams {
        HwParams { l1_smpair_kb: 0.0, l2_kb: 0.0, ..*self }
    }

    /// Total vector units on the chip.
    pub fn total_cores(&self) -> u32 {
        self.n_sm * self.n_v
    }

    /// Total shared memory on the chip, kB.
    pub fn total_shared_kb(&self) -> f64 {
        self.m_sm_kb * self.n_sm as f64
    }

    /// Manufacturer-pattern feasibility per constraints (12)–(15) and §IV-B:
    /// `n_SM` even, `n_V` a positive multiple of 32, `M_SM` positive.
    pub fn respects_manufacturer_patterns(&self) -> bool {
        self.n_sm >= 2
            && self.n_sm % 2 == 0
            && self.n_v >= 32
            && self.n_v % 32 == 0
            && self.m_sm_kb > 0.0
            && self.r_vu_kb > 0.0
            && self.l1_smpair_kb >= 0.0
            && self.l2_kb >= 0.0
    }

    /// Short human-readable identifier, e.g. `16sm x 128v, 96kB shm`.
    pub fn label(&self) -> String {
        let caches = if self.l1_smpair_kb == 0.0 && self.l2_kb == 0.0 {
            ", cacheless".to_string()
        } else {
            String::new()
        };
        format!("{}sm x {}v, {}kB shm{}", self.n_sm, self.n_v, self.m_sm_kb, caches)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_configs_are_feasible() {
        assert!(HwParams::gtx980().respects_manufacturer_patterns());
        assert!(HwParams::titanx().respects_manufacturer_patterns());
    }

    #[test]
    fn gtx980_headline_numbers() {
        let g = HwParams::gtx980();
        assert_eq!(g.total_cores(), 2048);
        assert_eq!(g.total_shared_kb(), 1536.0);
    }

    #[test]
    fn cacheless_strips_only_caches() {
        let g = HwParams::gtx980().without_caches();
        assert_eq!(g.l1_smpair_kb, 0.0);
        assert_eq!(g.l2_kb, 0.0);
        assert_eq!(g.m_sm_kb, 96.0);
        assert_eq!(g.n_sm, 16);
        assert!(g.respects_manufacturer_patterns());
    }

    #[test]
    fn pattern_checks_reject_odd_configs() {
        let mut p = HwParams::gtx980();
        p.n_sm = 15;
        assert!(!p.respects_manufacturer_patterns());
        let mut p = HwParams::gtx980();
        p.n_v = 100;
        assert!(!p.respects_manufacturer_patterns());
    }

    #[test]
    fn label_mentions_cacheless() {
        assert!(HwParams::gtx980().without_caches().label().contains("cacheless"));
        assert!(!HwParams::gtx980().label().contains("cacheless"));
    }
}
