//! The area model proper: eq. (3)–(6).

use crate::area::params::HwParams;

/// Calibrated coefficients of eq. (5). Units: mm² and mm²/kB.
///
/// The four (β, α) memory pairs come from the Cacti-like sweeps (Fig 2);
/// `beta_vu` and `alpha_oh` from die-photo measurements ([`super::diephoto`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AreaCoeffs {
    /// Core-logic area per vector unit (mm²), excluding its register file.
    pub beta_vu: f64,
    /// Register file: mm² per kB per vector unit / fixed per vector unit.
    pub beta_r: f64,
    pub alpha_r: f64,
    /// Shared memory: mm² per kB per SM / fixed per SM.
    pub beta_m: f64,
    pub alpha_m: f64,
    /// L1 cache: mm² per kB per SM-pair / fixed per SM-pair.
    pub beta_l1: f64,
    pub alpha_l1: f64,
    /// L2 cache: mm² per kB / fixed, chip-level.
    pub beta_l2: f64,
    pub alpha_l2: f64,
    /// Common overhead per SM (I/O, routing, controllers…), mm².
    pub alpha_oh: f64,
}

impl AreaCoeffs {
    /// The paper's published calibration (§III-B): Cacti fits + GTX 980 die
    /// measurements. These are the exact constants behind eq. (6).
    pub fn paper() -> AreaCoeffs {
        AreaCoeffs {
            beta_vu: 0.04282,
            beta_r: 0.004305,
            alpha_r: 0.001947,
            beta_m: 0.01565,
            alpha_m: 0.09281,
            beta_l1: 0.1604,
            alpha_l1: 0.08204,
            beta_l2: 0.04197,
            alpha_l2: 0.7685,
            alpha_oh: 6.4156,
        }
    }
}

/// Per-component area decomposition of a design (drives Fig 4).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AreaBreakdown {
    /// Vector-unit core logic: `n_SM · n_V · β_VU`.
    pub cores_mm2: f64,
    /// Register files: `n_SM · n_V · (β_R·R_VU + α_R)`.
    pub registers_mm2: f64,
    /// Shared memory: `n_SM · (β_M·M_SM + α_M)`.
    pub shared_mm2: f64,
    /// L1: `(n_SM/2) · (β_L1·L1 + α_L1)`; zero for cache-less designs.
    pub l1_mm2: f64,
    /// L2: `β_L2·L2 + α_L2`; zero for cache-less designs.
    pub l2_mm2: f64,
    /// Common overhead: `n_SM · α_oh`.
    pub overhead_mm2: f64,
}

impl AreaBreakdown {
    pub fn total(&self) -> f64 {
        self.cores_mm2
            + self.registers_mm2
            + self.shared_mm2
            + self.l1_mm2
            + self.l2_mm2
            + self.overhead_mm2
    }

    /// All caches (L1 + L2).
    pub fn caches_mm2(&self) -> f64 {
        self.l1_mm2 + self.l2_mm2
    }

    /// All explicitly-managed memory (register files + shared memory) —
    /// Fig 4's "memory" axis.
    pub fn memory_mm2(&self) -> f64 {
        self.registers_mm2 + self.shared_mm2
    }

    /// Fig 4 axes: (% of chip area in memory, % in vector units).
    pub fn allocation_pcts(&self) -> (f64, f64) {
        let t = self.total();
        (100.0 * self.memory_mm2() / t, 100.0 * self.cores_mm2 / t)
    }
}

/// The analytical area model, eq. (5).
#[derive(Clone, Copy, Debug)]
pub struct AreaModel {
    pub coeffs: AreaCoeffs,
}

impl AreaModel {
    pub fn new(coeffs: AreaCoeffs) -> AreaModel {
        AreaModel { coeffs }
    }

    /// Model with the paper's published coefficients.
    pub fn paper() -> AreaModel {
        AreaModel::new(AreaCoeffs::paper())
    }

    /// Full per-component decomposition for a design point.
    ///
    /// Cache terms are dropped entirely (including their α fixed costs) when
    /// the corresponding capacity is zero — a cache-less design has no cache
    /// periphery either.
    pub fn breakdown(&self, h: &HwParams) -> AreaBreakdown {
        let c = &self.coeffs;
        let n_sm = h.n_sm as f64;
        let n_v = h.n_v as f64;
        let l1 = if h.l1_smpair_kb > 0.0 {
            (n_sm / 2.0) * (c.beta_l1 * h.l1_smpair_kb + c.alpha_l1)
        } else {
            0.0
        };
        let l2 = if h.l2_kb > 0.0 { c.beta_l2 * h.l2_kb + c.alpha_l2 } else { 0.0 };
        AreaBreakdown {
            cores_mm2: n_sm * n_v * c.beta_vu,
            registers_mm2: n_sm * n_v * (c.beta_r * h.r_vu_kb + c.alpha_r),
            shared_mm2: n_sm * (c.beta_m * h.m_sm_kb + c.alpha_m),
            l1_mm2: l1,
            l2_mm2: l2,
            overhead_mm2: n_sm * c.alpha_oh,
        }
    }

    /// Total die area, mm² — `A_tot` of eq. (5).
    pub fn area_mm2(&self, h: &HwParams) -> f64 {
        self.breakdown(h).total()
    }

    /// The paper's simplified published form, eq. (6):
    ///
    /// ```text
    /// A_tot = 0.0447·n_SM·n_V + 0.0043·R_VU·n_SM·n_V + 0.015·M_SM·n_SM
    ///       + 0.08·L1_SMpair·n_SM + 0.041·L2_kB + 7.317·n_SM
    /// ```
    ///
    /// Note eq. (6) folds `β_VU + α_R` into 0.0447, halves β_L1 (per-pair →
    /// per-SM), and folds `α_M + α_L1/2 + α_L2/… + α_oh` into the 7.317·n_SM
    /// term (which slightly re-attributes the chip-level constant `α_L2` to
    /// SMs). Kept verbatim for comparison against [`AreaModel::area_mm2`].
    pub fn paper_eq6(h: &HwParams) -> f64 {
        0.0447 * (h.n_sm * h.n_v) as f64
            + 0.0043 * h.r_vu_kb * (h.n_sm * h.n_v) as f64
            + 0.015 * h.m_sm_kb * h.n_sm as f64
            + 0.08 * h.l1_smpair_kb * h.n_sm as f64
            + 0.041 * h.l2_kb
            + 7.317 * h.n_sm as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gtx980_close_to_published_die_area() {
        // Calibration target: the GTX 980 die is 398 mm².
        let a = AreaModel::paper().area_mm2(&HwParams::gtx980());
        let err = (a - 398.0).abs() / 398.0 * 100.0;
        assert!(err < 3.0, "GTX980 area {a} mm² ({err:.2}% off 398)");
    }

    #[test]
    fn titanx_validation_eq6_within_two_pct() {
        // §III-C: the paper predicts 589.2 mm² vs published 601 mm² (1.96%).
        // That prediction comes from the published eq. (6) (whose folded
        // 7.317·n_SM term re-attributes α_L2 per SM); reproduce it there.
        let a = AreaModel::paper_eq6(&HwParams::titanx());
        let err = (a - 601.0).abs() / 601.0 * 100.0;
        assert!(err < 2.0, "TitanX eq6 area {a} mm² ({err:.2}% off 601)");
    }

    #[test]
    fn titanx_validation_eq5_within_four_pct() {
        // The exact eq. (5) decomposition (no folding) is slightly farther
        // off the published die area; document the envelope.
        let a = AreaModel::paper().area_mm2(&HwParams::titanx());
        let err = (a - 601.0).abs() / 601.0 * 100.0;
        assert!(err < 4.0, "TitanX eq5 area {a} mm² ({err:.2}% off 601)");
    }

    #[test]
    fn gtx980_validation_eq6_within_one_pct() {
        let a = AreaModel::paper_eq6(&HwParams::gtx980());
        let err = (a - 398.0).abs() / 398.0 * 100.0;
        assert!(err < 1.0, "GTX980 eq6 area {a} mm² ({err:.2}% off 398)");
    }

    #[test]
    fn eq5_and_eq6_agree_roughly() {
        // eq. (6) folds α_L2 into the per-SM overhead term, so the two forms
        // differ by ~α_L2·(n_SM−1) ≈ 2–3%.
        let m = AreaModel::paper();
        for h in [HwParams::gtx980(), HwParams::titanx()] {
            let a5 = m.area_mm2(&h);
            let a6 = AreaModel::paper_eq6(&h);
            assert!(
                ((a5 - a6) / a6).abs() < 0.04,
                "eq5={a5} eq6={a6} for {}",
                h.label()
            );
        }
    }

    #[test]
    fn breakdown_sums_to_total() {
        let m = AreaModel::paper();
        let h = HwParams::gtx980();
        let b = m.breakdown(&h);
        assert!((b.total() - m.area_mm2(&h)).abs() < 1e-12);
    }

    #[test]
    fn cacheless_drops_cache_area_entirely() {
        let m = AreaModel::paper();
        let g = HwParams::gtx980();
        let b = m.breakdown(&g);
        let bc = m.breakdown(&g.without_caches());
        assert_eq!(bc.caches_mm2(), 0.0);
        assert!((b.total() - bc.total() - b.caches_mm2()).abs() < 1e-9);
        // The paper says deleting GTX 980 caches lands near 237 mm²; our
        // exact-coefficient computation gives ~249 mm². Assert the ballpark.
        assert!(
            (230.0..265.0).contains(&bc.total()),
            "cacheless GTX980 = {}",
            bc.total()
        );
    }

    #[test]
    fn area_monotone_in_every_parameter() {
        let m = AreaModel::paper();
        let base = HwParams::gtx980();
        let a0 = m.area_mm2(&base);
        for (i, h) in [
            HwParams { n_sm: base.n_sm + 2, ..base },
            HwParams { n_v: base.n_v + 32, ..base },
            HwParams { r_vu_kb: base.r_vu_kb + 1.0, ..base },
            HwParams { m_sm_kb: base.m_sm_kb + 48.0, ..base },
            HwParams { l1_smpair_kb: base.l1_smpair_kb + 16.0, ..base },
            HwParams { l2_kb: base.l2_kb + 512.0, ..base },
        ]
        .iter()
        .enumerate()
        {
            assert!(m.area_mm2(h) > a0, "not monotone in param {i}");
        }
    }

    #[test]
    fn allocation_pcts_sane() {
        let b = AreaModel::paper().breakdown(&HwParams::gtx980());
        let (mem, cores) = b.allocation_pcts();
        assert!(mem > 0.0 && cores > 0.0 && mem + cores < 100.0);
    }
}
