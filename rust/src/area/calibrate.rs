//! End-to-end calibration pipeline (§III-B): Cacti-like sweeps → memory
//! coefficients; die photo → core-logic and overhead coefficients; then
//! validation against the Titan X (§III-C).

use crate::area::diephoto::DiePhoto;
use crate::area::model::{AreaCoeffs, AreaModel};
use crate::area::params::HwParams;
use crate::cacti::estimator::SramEstimator;
use crate::cacti::sweep::{run_paper_sweeps, SweepFit};

/// Everything the calibration run produces, for reporting (Fig 2 + §III-B/C).
#[derive(Clone, Debug)]
pub struct Calibration {
    /// The four memory sweeps with their fitted linear models.
    pub sweeps: Vec<SweepFit>,
    /// The assembled coefficient set.
    pub coeffs: AreaCoeffs,
    /// Cross-check of the memory model vs the die-photo block measurements
    /// (§III-B's "measured vs predicted" table): (name, measured, predicted).
    pub memory_crosscheck: Vec<(&'static str, f64, f64)>,
    /// GTX 980 total predicted by the calibrated model, mm².
    pub gtx980_pred_mm2: f64,
    /// Titan X total predicted by the calibrated model, mm² (validation).
    pub titanx_pred_mm2: f64,
    /// Titan X relative error vs the published 601 mm², %.
    pub titanx_err_pct: f64,
}

/// Published die areas used for calibration/validation targets.
pub const GTX980_DIE_MM2: f64 = 398.0;
pub const TITANX_DIE_MM2: f64 = 601.0;

/// Run the full §III-B pipeline with a given estimator and die photo.
pub fn calibrate(est: &SramEstimator, photo: &DiePhoto) -> Calibration {
    let sweeps = run_paper_sweeps(est);
    let get = |name: &str| {
        sweeps
            .iter()
            .find(|f| f.name == name)
            .unwrap_or_else(|| panic!("missing sweep {name}"))
    };
    let rf = get("register_file");
    let shm = get("shared_memory");
    let l1 = get("l1_cache");
    let l2 = get("l2_cache");

    let gtx = HwParams::gtx980();
    let coeffs = AreaCoeffs {
        beta_vu: photo.beta_vu(),
        beta_r: rf.beta(),
        alpha_r: rf.alpha(),
        beta_m: shm.beta(),
        alpha_m: shm.alpha(),
        beta_l1: l1.beta(),
        alpha_l1: l1.alpha(),
        beta_l2: l2.beta(),
        alpha_l2: l2.alpha(),
        alpha_oh: photo.alpha_oh(gtx.n_sm),
    };

    // §III-B cross-check: predict the die-photo memory blocks from the fits.
    // The measured blocks are the chip-level L2 (2 MB), one SM-pair's L1
    // (48 kB) and one SM's shared memory (96 kB) — this is the reading under
    // which the paper's own stated predictions (98.25 / 7.78 / 1.59 mm²)
    // follow from its published coefficients.
    let memory_crosscheck = vec![
        (
            "l2_total",
            photo.block_mm2("l2_total").unwrap(),
            coeffs.beta_l2 * gtx.l2_kb + coeffs.alpha_l2,
        ),
        (
            "l1_total",
            photo.block_mm2("l1_total").unwrap(),
            coeffs.beta_l1 * gtx.l1_smpair_kb + coeffs.alpha_l1,
        ),
        (
            "shm_per_sm",
            photo.block_mm2("shm_per_sm").unwrap(),
            coeffs.beta_m * gtx.m_sm_kb + coeffs.alpha_m,
        ),
    ];

    let model = AreaModel::new(coeffs);
    let gtx980_pred = model.area_mm2(&gtx);
    let titanx_pred = model.area_mm2(&HwParams::titanx());
    Calibration {
        sweeps,
        coeffs,
        memory_crosscheck,
        gtx980_pred_mm2: gtx980_pred,
        titanx_pred_mm2: titanx_pred,
        titanx_err_pct: 100.0 * (titanx_pred - TITANX_DIE_MM2).abs() / TITANX_DIE_MM2,
    }
}

/// Convenience: calibrate with the default Maxwell estimator + GTX 980 photo.
pub fn calibrate_maxwell() -> Calibration {
    calibrate(&SramEstimator::maxwell(), &DiePhoto::gtx980())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_coeffs_close_to_paper() {
        let cal = calibrate_maxwell();
        let p = AreaCoeffs::paper();
        let close = |a: f64, b: f64, tol: f64, what: &str| {
            assert!(((a - b) / b).abs() < tol, "{what}: got {a}, paper {b}");
        };
        close(cal.coeffs.beta_r, p.beta_r, 0.05, "beta_r");
        close(cal.coeffs.beta_m, p.beta_m, 0.05, "beta_m");
        close(cal.coeffs.beta_l1, p.beta_l1, 0.05, "beta_l1");
        close(cal.coeffs.beta_l2, p.beta_l2, 0.05, "beta_l2");
        // Die-photo-derived coefficients are exact by construction.
        close(cal.coeffs.beta_vu, p.beta_vu, 1e-6, "beta_vu");
        close(cal.coeffs.alpha_oh, p.alpha_oh, 1e-3, "alpha_oh");
    }

    #[test]
    fn gtx980_and_titanx_predictions() {
        let cal = calibrate_maxwell();
        // The un-folded eq. (5) decomposition sits ~3–4% from the published
        // die areas (the paper's headline 1.96% comes from the folded eq. (6)
        // form — see `area::model::tests::titanx_validation_eq6_within_two_pct`).
        let e980 = 100.0 * (cal.gtx980_pred_mm2 - GTX980_DIE_MM2).abs() / GTX980_DIE_MM2;
        assert!(e980 < 4.0, "GTX980 {} mm² ({e980:.2}%)", cal.gtx980_pred_mm2);
        assert!(
            cal.titanx_err_pct < 4.5,
            "TitanX {} mm² ({:.2}%)",
            cal.titanx_pred_mm2,
            cal.titanx_err_pct
        );
    }

    #[test]
    fn crosscheck_same_order_of_magnitude() {
        // The paper's own cross-check has errors up to ~25% (shm 1.27 vs
        // 1.59); require the same looseness, not more.
        let cal = calibrate_maxwell();
        for (name, measured, predicted) in &cal.memory_crosscheck {
            let ratio = predicted / measured;
            assert!(
                (0.5..2.0).contains(&ratio),
                "{name}: measured {measured} vs predicted {predicted}"
            );
        }
    }
}
