//! Parametric stencil families — the open workload space beyond the paper's
//! six kernels.
//!
//! The codesign model never consumes a stencil's *code*; it consumes a small
//! analytical characterization (§II): space dimensionality, halo width per
//! time step (σ), flops per updated point, live buffers per tile, and bytes
//! per cell. A [`StencilSpec`] describes a whole *family* of such kernels —
//! star or box stencils of arbitrary radius in 2-D or 3-D — and derives that
//! characterization analytically, so any member can be explored, batched,
//! cached and served exactly like the six paper presets.
//!
//! Derivations (DESIGN.md §3 documents the math):
//!
//! * **support** — taps read per updated point: star `2·d·r + 1`,
//!   box `(2r+1)^d`;
//! * **flops/point** — one multiply per tap plus the adds that combine them,
//!   `2·support − 1` (a fully-weighted scheme; exact loop-body counts can
//!   override);
//! * **σ (halo)** — the dependence-cone slope equals the radius, `σ = r`;
//! * **C_iter** — paper-scale heuristic pending silicon measurement:
//!   `8 + flops/2` cycles in 2-D, `11 + flops/2` in 3-D (presets pin the
//!   paper's measured values instead).
//!
//! Every spec has a **canonical name** that encodes all of its parameters
//! (`star3d:r2`, `box2d:r1:f20`) and round-trips through [`StencilSpec::parse`]
//! bit-exactly — the wire format (schema v2) carries specs as these names.
//!
//! A [`FusedChain`] composes several same-dimension specs into one fused
//! ghost-zone workload (`fuse:heat2d+laplacian2d:t4`, schema v7) whose
//! *derived* characterization — deepened halo, redundancy-inflated flops and
//! `C_iter`, shared plane buffers — registers and caches exactly like any
//! single spec (DESIGN.md §10 has the derivation).
//!
//! # Examples
//!
//! ```no_run
//! use codesign::stencil::spec::{Dim, StencilSpec};
//!
//! // A radius-2 star in 3-D: 13-point support, halo 2 per time step.
//! let spec = StencilSpec::star(Dim::D3, 2);
//! assert_eq!(spec.support_points(), 13);
//! assert_eq!(spec.canonical_name(), "star3d:r2");
//!
//! // Register it and it behaves exactly like a built-in benchmark.
//! let id = spec.register();
//! let st = codesign::stencil::defs::Stencil::get(id);
//! assert_eq!(st.sigma, 2);
//! ```

use crate::stencil::defs::{self, StencilId};

/// Space dimensionality of a stencil family (every benchmark adds one time
/// dimension on top).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dim {
    D2,
    D3,
}

impl Dim {
    /// Number of space dimensions (2 or 3).
    pub fn space_dims(&self) -> u32 {
        match self {
            Dim::D2 => 2,
            Dim::D3 => 3,
        }
    }

    /// The `2d` / `3d` name fragment.
    pub fn token(&self) -> &'static str {
        match self {
            Dim::D2 => "2d",
            Dim::D3 => "3d",
        }
    }
}

/// Neighborhood shape of a stencil family.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Shape {
    /// Axis-aligned cross: `2·d·r` neighbors plus the center.
    Star,
    /// Full hypercube: `(2r+1)^d` taps.
    Box,
}

impl Shape {
    /// The `star` / `box` name fragment.
    pub fn token(&self) -> &'static str {
        match self {
            Shape::Star => "star",
            Shape::Box => "box",
        }
    }
}

/// Maximum supported radius. The hybrid-hexagonal time model stays valid for
/// any σ, but radii beyond this are outside the calibrated regime (the halo
/// dominates every realistic tile footprint).
pub const MAX_RADIUS: u32 = 8;

/// A parametric stencil family member: shape × dimensionality × radius, plus
/// optional characterization overrides for exact loop bodies.
///
/// Defaults describe a fully-weighted scheme in fp32 with double-buffered
/// time planes — override `flops`/`c_iter` when a concrete kernel's operation
/// count is known (the six paper presets do exactly that).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StencilSpec {
    pub dim: Dim,
    pub shape: Shape,
    /// Halo width per time step, `1..=MAX_RADIUS` (σ in the tiling model).
    pub radius: u32,
    /// Live arrays a tile stages in shared memory (default 2: in/out planes).
    pub n_buffers: f64,
    /// Bytes per cell (default 4: fp32).
    pub bytes_per_cell: f64,
    /// Exact flops per updated point, overriding the derived count.
    pub flops: Option<f64>,
    /// Measured `C_iter` cycles, overriding the derived heuristic.
    pub c_iter: Option<f64>,
}

impl StencilSpec {
    /// A star (axis-aligned cross) family member with default
    /// characterization.
    pub fn star(dim: Dim, radius: u32) -> StencilSpec {
        StencilSpec {
            dim,
            shape: Shape::Star,
            radius,
            n_buffers: 2.0,
            bytes_per_cell: 4.0,
            flops: None,
            c_iter: None,
        }
    }

    /// A box (full hypercube) family member with default characterization.
    pub fn boxed(dim: Dim, radius: u32) -> StencilSpec {
        StencilSpec { shape: Shape::Box, ..StencilSpec::star(dim, radius) }
    }

    /// Override the flops-per-point count (exact loop bodies).
    pub fn with_flops(mut self, flops: f64) -> StencilSpec {
        self.flops = Some(flops);
        self
    }

    /// Override the `C_iter` cycle cost (measured values).
    pub fn with_c_iter(mut self, cycles: f64) -> StencilSpec {
        self.c_iter = Some(cycles);
        self
    }

    /// Override the live-buffer count.
    pub fn with_buffers(mut self, n: f64) -> StencilSpec {
        self.n_buffers = n;
        self
    }

    /// Override the bytes-per-cell word size.
    pub fn with_bytes_per_cell(mut self, bytes: f64) -> StencilSpec {
        self.bytes_per_cell = bytes;
        self
    }

    /// Validate every parameter; `Err` carries a human-readable reason.
    pub fn validate(&self) -> Result<(), String> {
        if self.radius < 1 || self.radius > MAX_RADIUS {
            return Err(format!("radius must be 1..={MAX_RADIUS} (got {})", self.radius));
        }
        if !(self.n_buffers.is_finite() && self.n_buffers >= 1.0) {
            return Err(format!("n_buffers must be finite and >= 1 (got {})", self.n_buffers));
        }
        if !(self.bytes_per_cell.is_finite() && self.bytes_per_cell > 0.0) {
            return Err(format!(
                "bytes_per_cell must be finite and positive (got {})",
                self.bytes_per_cell
            ));
        }
        if let Some(f) = self.flops {
            if !(f.is_finite() && f > 0.0) {
                return Err(format!("flops override must be finite and positive (got {f})"));
            }
        }
        if let Some(c) = self.c_iter {
            if !(c.is_finite() && c > 0.0) {
                return Err(format!("c_iter override must be finite and positive (got {c})"));
            }
        }
        Ok(())
    }

    /// Taps read per updated point: star `2·d·r + 1`, box `(2r+1)^d`.
    pub fn support_points(&self) -> u64 {
        let d = self.dim.space_dims() as u64;
        let r = self.radius as u64;
        match self.shape {
            Shape::Star => 2 * d * r + 1,
            Shape::Box => (2 * r + 1).pow(d as u32),
        }
    }

    /// Formal order of accuracy of the centered finite-difference scheme this
    /// halo supports: `2·radius`.
    pub fn order(&self) -> u32 {
        2 * self.radius
    }

    /// Derived flops per point for a fully-weighted scheme: one multiply per
    /// tap plus `support − 1` adds, `2·support − 1`.
    pub fn derived_flops(&self) -> f64 {
        2.0 * self.support_points() as f64 - 1.0
    }

    /// Effective flops per point (override, else derived).
    pub fn flops_per_point(&self) -> f64 {
        self.flops.unwrap_or_else(|| self.derived_flops())
    }

    /// Derived `C_iter` heuristic: per-iteration loop overhead plus half a
    /// cycle per flop on the paper's GTX 980 scale (`8 + flops/2` in 2-D,
    /// `11 + flops/2` in 3-D — anchored so the measured presets land within
    /// a few cycles).
    pub fn derived_c_iter(&self) -> f64 {
        let base = match self.dim {
            Dim::D2 => 8.0,
            Dim::D3 => 11.0,
        };
        base + self.flops_per_point() / 2.0
    }

    /// Effective `C_iter` cycles (override, else derived).
    pub fn c_iter_cycles(&self) -> f64 {
        self.c_iter.unwrap_or_else(|| self.derived_c_iter())
    }

    /// The canonical name: `<shape><dim>:r<radius>` plus `:b`/`:w`/`:f`/`:c`
    /// suffixes for every non-default parameter, in that order. Floats use
    /// Rust's shortest round-trip formatting, so
    /// `parse(canonical_name()) == self` bit-exactly.
    pub fn canonical_name(&self) -> String {
        let mut name = format!("{}{}:r{}", self.shape.token(), self.dim.token(), self.radius);
        if self.n_buffers != 2.0 {
            name.push_str(&format!(":b{}", self.n_buffers));
        }
        if self.bytes_per_cell != 4.0 {
            name.push_str(&format!(":w{}", self.bytes_per_cell));
        }
        if let Some(f) = self.flops {
            name.push_str(&format!(":f{f}"));
        }
        if let Some(c) = self.c_iter {
            name.push_str(&format!(":c{c}"));
        }
        name
    }

    /// Parse a family name. Grammar (suffixes accepted in any order; a
    /// repeated suffix takes its last value):
    ///
    /// ```text
    /// <shape><dim> ":r" <radius> [":b" <f64>] [":w" <f64>] [":f" <f64>] [":c" <f64>]
    /// shape  = "star" | "box"
    /// dim    = "2d" | "3d"
    /// radius = 1..=8
    /// ```
    ///
    /// `b` = live buffers, `w` = bytes per cell (word size), `f` = flops per
    /// point override, `c` = `C_iter` cycles override.
    pub fn parse(name: &str) -> Result<StencilSpec, String> {
        let mut parts = name.split(':');
        let head = parts.next().unwrap_or_default();
        let (shape, dim_tok) = if let Some(rest) = head.strip_prefix("star") {
            (Shape::Star, rest)
        } else if let Some(rest) = head.strip_prefix("box") {
            (Shape::Box, rest)
        } else {
            return Err(format!("'{head}' is not a stencil family (want star… or box…)"));
        };
        let dim = match dim_tok {
            "2d" => Dim::D2,
            "3d" => Dim::D3,
            other => return Err(format!("'{other}' is not a dimensionality (want 2d or 3d)")),
        };
        let mut spec = StencilSpec::star(dim, 0);
        spec.shape = shape;
        let mut seen_r = false;
        for part in parts {
            if !part.is_ascii() {
                return Err(format!("unknown parameter in '{part}'"));
            }
            let (tag, value) = part.split_at(1.min(part.len()));
            let parse_f64 = |what: &str| -> Result<f64, String> {
                value.parse::<f64>().map_err(|_| format!("bad {what} value '{value}'"))
            };
            match tag {
                "r" => {
                    spec.radius = value
                        .parse::<u32>()
                        .map_err(|_| format!("bad radius '{value}'"))?;
                    seen_r = true;
                }
                "b" => spec.n_buffers = parse_f64("buffer-count (b)")?,
                "w" => spec.bytes_per_cell = parse_f64("word-size (w)")?,
                "f" => spec.flops = Some(parse_f64("flops (f)")?),
                "c" => spec.c_iter = Some(parse_f64("c_iter (c)")?),
                other => return Err(format!("unknown parameter '{other}' in '{part}'")),
            }
        }
        if !seen_r {
            return Err(format!("'{name}' is missing the radius (e.g. {head}:r2)"));
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Intern this spec in the global stencil registry (idempotent: equal
    /// canonical names return the same id) and get its [`StencilId`], usable
    /// everywhere a preset id is — workloads, scenarios, requests, the wire.
    ///
    /// Panics on an invalid spec or a full registry (u16 id space); untrusted
    /// inputs should go through the fallible
    /// [`Stencil::by_name_err`](crate::stencil::defs::Stencil::by_name_err)
    /// name path instead.
    pub fn register(&self) -> StencilId {
        defs::register_spec(self)
    }
}

/// Maximum stages in a fused chain (the six paper presets set the scale).
pub const MAX_FUSE_STAGES: usize = 6;

/// Maximum chain passes per fused block (`:t` in the grammar). The Python
/// fused kernels are exercised at `t_steps ≤ 8`; beyond that the ghost zone
/// dominates any realistic block.
pub const MAX_FUSE_STEPS: u32 = 8;

/// Maximum fused halo `h = t·Σσᵢ`. The hybrid-hexagonal model stays valid
/// for any σ, but a halo beyond this swallows every calibrated tile footprint.
pub const MAX_FUSE_HALO: u32 = 32;

/// Reference square-tile edge at which the redundant-compute factor is
/// frozen into the characterization — the Python kernels' default block edge
/// (`common.choose_tile` prefers 64).
pub const FUSE_REF_TILE: u64 = 64;

/// A fused multi-stencil chain: `1..=MAX_FUSE_STAGES` same-dimension stages
/// applied in sequence, the whole sequence repeated `t_steps` times per
/// fused block (the ghost-zone / redundant-computation scheme of Meng &
/// Skadron realized by `python/compile/kernels/fused.py`).
///
/// One chain application is one *macro time step*: a block stages once with
/// an `h = t_steps·Σσᵢ`-deep halo, advances all `t_steps·K` stage
/// applications in shared memory (the valid region shrinking by the stage's
/// σ per application), and writes back once. A workload's `T` counts macro
/// steps, so per *stage application* the staged traffic drops by the fusion
/// depth while the halo trapezoid adds `O(h·σ/t)` redundant compute per tile
/// edge — both captured by the derived characterization
/// ([`FusedChain::effective_spec`]), which registers and cache-keys exactly
/// like a plain spec. DESIGN.md §10 derives every term.
///
/// Canonical grammar (round-trips bit-exactly):
///
/// ```text
/// "fuse:" <stage> ("+" <stage>)* [":t" <1-8>]
/// stage = preset name | StencilSpec family name
/// ```
///
/// # Examples
///
/// ```no_run
/// use codesign::stencil::spec::FusedChain;
///
/// let chain = FusedChain::parse("fuse:heat2d+laplacian2d:t4").unwrap();
/// assert_eq!(chain.halo(), 8);                  // 4 passes × (σ=1 + σ=1)
/// assert_eq!(chain.canonical_name(), "fuse:heat2d+laplacian2d:t4");
/// let id = chain.register();                    // behaves like any stencil
/// assert_eq!(codesign::stencil::defs::Stencil::get(id).sigma, 8);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct FusedChain {
    /// Stage specs, applied in order within each chain pass. All stages
    /// share the dimensionality and word size (validated).
    pub stages: Vec<StencilSpec>,
    /// Chain passes per fused block (`t` in `h = t·Σσᵢ`), `1..=MAX_FUSE_STEPS`.
    pub t_steps: u32,
}

impl FusedChain {
    pub fn new(stages: Vec<StencilSpec>, t_steps: u32) -> Result<FusedChain, String> {
        let chain = FusedChain { stages, t_steps };
        chain.validate()?;
        Ok(chain)
    }

    /// Validate the composition; `Err` carries a human-readable reason.
    pub fn validate(&self) -> Result<(), String> {
        if self.stages.is_empty() {
            return Err("a fused chain needs at least one stage".to_string());
        }
        if self.stages.len() > MAX_FUSE_STAGES {
            return Err(format!(
                "a fused chain carries at most {MAX_FUSE_STAGES} stages (got {})",
                self.stages.len()
            ));
        }
        if self.t_steps < 1 || self.t_steps > MAX_FUSE_STEPS {
            return Err(format!(
                "fuse steps must be 1..={MAX_FUSE_STEPS} (got t{})",
                self.t_steps
            ));
        }
        for (i, stage) in self.stages.iter().enumerate() {
            stage.validate().map_err(|e| format!("stage {}: {e}", i + 1))?;
            if stage.dim != self.stages[0].dim {
                return Err(format!(
                    "all stages must share one dimensionality (stage {} is {}, stage 1 is {})",
                    i + 1,
                    stage.dim.token(),
                    self.stages[0].dim.token()
                ));
            }
            if stage.bytes_per_cell != self.stages[0].bytes_per_cell {
                return Err(format!(
                    "all stages must share one word size (stage {} stages {} B cells, \
                     stage 1 stages {} B)",
                    i + 1,
                    stage.bytes_per_cell,
                    self.stages[0].bytes_per_cell
                ));
            }
        }
        if self.halo() > MAX_FUSE_HALO {
            return Err(format!(
                "fused halo t·Σσ = {} exceeds {MAX_FUSE_HALO} (deeper ghost zones swallow \
                 every calibrated tile)",
                self.halo()
            ));
        }
        if self.effective_buffers() < 1.0 {
            return Err(format!(
                "stages stage too few buffers to share the fused time planes \
                 (Σbᵢ − 2(K−1) = {} < 1)",
                self.effective_buffers()
            ));
        }
        Ok(())
    }

    /// Common space dimensionality of all stages.
    pub fn dim(&self) -> Dim {
        self.stages[0].dim
    }

    /// Fused halo depth `h = t_steps · Σᵢ σᵢ` — ghost-zone cells staged per
    /// block face, and the macro step's dependence-cone slope (the chain's
    /// effective σ in the tiling model).
    pub fn halo(&self) -> u32 {
        self.t_steps * self.stages.iter().map(|s| s.radius).sum::<u32>()
    }

    /// Stage applications per macro step: `t_steps · K`.
    pub fn applications(&self) -> u32 {
        self.t_steps * self.stages.len() as u32
    }

    /// Ghost-zone redundant-compute factor over a `t1 × t2 (× t3)` tile:
    /// total stencil applications (the halo trapezoid shrinking by the
    /// stage's σ per application) over the useful `t1·t2(·t3)·n`. Mirrors
    /// `python/compile/kernels/fused.redundancy_factor` exactly for a
    /// single-stage chain; `1.0` exactly when `applications() == 1`.
    pub fn redundancy_factor(&self, t1: u64, t2: u64, t3: Option<u64>) -> f64 {
        let h = self.halo() as f64;
        let mut cum = 0u32;
        let mut total = 0.0;
        for _pass in 0..self.t_steps {
            for stage in &self.stages {
                cum += stage.radius;
                let rem = h - cum as f64; // halo left after this application
                let w1 = t1 as f64 + 2.0 * rem;
                let w2 = t2 as f64 + 2.0 * rem;
                let w3 = t3.map_or(1.0, |t| t as f64 + 2.0 * rem);
                total += w1 * w2 * w3;
            }
        }
        let useful = t1 as f64
            * t2 as f64
            * t3.unwrap_or(1) as f64
            * self.applications() as f64;
        total / useful
    }

    /// Bytes a fused grid step stages over a `t1 × t2` block: input block
    /// plus `h`-deep halo, output block — the exact
    /// `python/compile/kernels/fused.vmem_footprint_bytes` formula (2-D
    /// parity helper; the tiling model's own hexagonal footprint is
    /// `timemodel::tiling::tile_footprint_bytes`).
    pub fn vmem_footprint_bytes(&self, t1: u64, t2: u64) -> f64 {
        let h = self.halo() as u64;
        self.stages[0].bytes_per_cell
            * (((t1 + 2 * h) * (t2 + 2 * h) + t1 * t2) as f64)
    }

    /// The redundancy factor frozen at the reference tile
    /// ([`FUSE_REF_TILE`] per space dimension) — the factor baked into the
    /// effective flops and `C_iter`.
    pub fn reference_redundancy(&self) -> f64 {
        let t3 = match self.dim() {
            Dim::D2 => None,
            Dim::D3 => Some(FUSE_REF_TILE),
        };
        self.redundancy_factor(FUSE_REF_TILE, FUSE_REF_TILE, t3)
    }

    /// Flops per macro-step point: the useful `t·Σfᵢ` inflated by the
    /// reference redundancy (redundant halo applications execute real
    /// flops). Bit-equal to the lone stage's flops when
    /// `applications() == 1`.
    pub fn effective_flops(&self) -> f64 {
        self.reference_redundancy()
            * self.t_steps as f64
            * self.stages.iter().map(|s| s.flops_per_point()).sum::<f64>()
    }

    /// `C_iter` cycles per macro iteration: every stage application a thread
    /// issues per macro step, inflated by the same reference redundancy.
    pub fn effective_c_iter(&self) -> f64 {
        self.reference_redundancy()
            * self.t_steps as f64
            * self.stages.iter().map(|s| s.c_iter_cycles()).sum::<f64>()
    }

    /// Combined live buffers: the stages run sequentially inside one block,
    /// so the double-buffered in/out planes are shared — one pair total —
    /// while every stage's extra arrays (coefficients, derived fields) stay
    /// live across the whole macro step: `Σbᵢ − 2(K−1)`.
    pub fn effective_buffers(&self) -> f64 {
        self.stages.iter().map(|s| s.n_buffers).sum::<f64>()
            - 2.0 * (self.stages.len() as f64 - 1.0)
    }

    /// The derived single-stencil characterization the whole model stack
    /// consumes, as a synthetic spec: radius = fused halo, flops / `C_iter`
    /// pinned to the effective values. It re-derives the chain
    /// characterization exactly, but is *not* a registrable family of its
    /// own (the halo may exceed [`MAX_RADIUS`]) — it only rides inside the
    /// chain's registry entry.
    pub fn effective_spec(&self) -> StencilSpec {
        StencilSpec {
            dim: self.dim(),
            shape: self.stages[0].shape,
            radius: self.halo(),
            n_buffers: self.effective_buffers(),
            bytes_per_cell: self.stages[0].bytes_per_cell,
            flops: Some(self.effective_flops()),
            c_iter: Some(self.effective_c_iter()),
        }
    }

    /// The canonical name: `fuse:` + stage names joined with `+`, plus `:t`
    /// when `t_steps != 1`. A stage whose spec is bit-equal to a preset's
    /// prints the preset name (`heat2d`), otherwise its family canonical
    /// name — so `parse(canonical_name()) == self` bit-exactly.
    pub fn canonical_name(&self) -> String {
        let stages: Vec<String> = self
            .stages
            .iter()
            .map(|s| match defs::ALL_STENCILS.iter().find(|p| p.spec == *s) {
                Some(p) => p.name.to_string(),
                None => s.canonical_name(),
            })
            .collect();
        let mut name = format!("fuse:{}", stages.join("+"));
        if self.t_steps != 1 {
            name.push_str(&format!(":t{}", self.t_steps));
        }
        name
    }

    /// Parse a chain name. Grammar:
    ///
    /// ```text
    /// "fuse:" <stage> ("+" <stage>)* [":t" <steps>]
    /// stage = preset name (heat2d) | family name (star2d:r2:f20)
    /// steps = 1..=8 (default 1)
    /// ```
    ///
    /// The trailing `:t` segment is unambiguous: `t` is not a stage suffix
    /// tag, and stage names never contain `+`. Chains do not nest.
    pub fn parse(name: &str) -> Result<FusedChain, String> {
        let Some(body) = name.strip_prefix("fuse:") else {
            return Err(format!("'{name}' is not a fused chain (want fuse:…)"));
        };
        let (head, t_steps) = match body.rsplit_once(':') {
            Some((head, last)) if last.starts_with('t') => {
                let steps = last[1..]
                    .parse::<u32>()
                    .map_err(|_| format!("bad fuse steps '{last}' (want t<count>)"))?;
                (head, steps)
            }
            _ => (body, 1),
        };
        let mut stages = Vec::new();
        for tok in head.split('+') {
            if tok.is_empty() {
                return Err(format!("empty stage in '{name}'"));
            }
            stages.push(Self::stage_spec(tok)?);
        }
        FusedChain::new(stages, t_steps)
    }

    /// Resolve one stage token: a preset name yields the preset's pinned
    /// spec, anything else must parse as a family name. Deliberately *not*
    /// `Stencil::by_name_err`, so chains cannot nest and stage parsing never
    /// touches the registry.
    fn stage_spec(tok: &str) -> Result<StencilSpec, String> {
        if let Some(p) = defs::ALL_STENCILS.iter().find(|p| p.name == tok) {
            return Ok(p.spec);
        }
        StencilSpec::parse(tok).map_err(|e| format!("stage '{tok}': {e}"))
    }

    /// Intern this chain in the global stencil registry under its canonical
    /// name (idempotent) and get its [`StencilId`] — from there workloads,
    /// scenarios, cache keys, the wire and the daemon treat it as just
    /// another characterized stencil.
    ///
    /// Panics on an invalid chain or a full registry; untrusted inputs go
    /// through [`Stencil::by_name_err`](crate::stencil::defs::Stencil::by_name_err).
    pub fn register(&self) -> StencilId {
        defs::register_chain(self, None).unwrap_or_else(|e| panic!("{e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn support_counts() {
        assert_eq!(StencilSpec::star(Dim::D2, 1).support_points(), 5);
        assert_eq!(StencilSpec::star(Dim::D3, 1).support_points(), 7);
        assert_eq!(StencilSpec::star(Dim::D3, 2).support_points(), 13);
        assert_eq!(StencilSpec::boxed(Dim::D2, 1).support_points(), 9);
        assert_eq!(StencilSpec::boxed(Dim::D3, 1).support_points(), 27);
        assert_eq!(StencilSpec::boxed(Dim::D3, 2).support_points(), 125);
    }

    #[test]
    fn derived_characterization_scales_with_radius() {
        for dim in [Dim::D2, Dim::D3] {
            let mut last_flops = 0.0;
            for r in 1..=MAX_RADIUS {
                let s = StencilSpec::star(dim, r);
                assert!(s.validate().is_ok());
                assert_eq!(s.order(), 2 * r);
                assert!(s.flops_per_point() > last_flops, "flops must grow with radius");
                assert!(s.c_iter_cycles() > 0.0);
                last_flops = s.flops_per_point();
            }
        }
    }

    #[test]
    fn canonical_name_roundtrips() {
        let cases = [
            StencilSpec::star(Dim::D3, 2),
            StencilSpec::boxed(Dim::D2, 4),
            StencilSpec::star(Dim::D2, 1).with_flops(4.0).with_c_iter(11.0),
            StencilSpec::boxed(Dim::D3, 3).with_buffers(3.0).with_bytes_per_cell(8.0),
            StencilSpec::star(Dim::D2, 2).with_flops(1.0 / 3.0),
        ];
        for spec in cases {
            let name = spec.canonical_name();
            let back = StencilSpec::parse(&name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(spec, back, "{name}");
            assert_eq!(back.canonical_name(), name);
        }
    }

    #[test]
    fn parse_accepts_any_suffix_order() {
        let a = StencilSpec::parse("star2d:r2:f20:b3").unwrap();
        let b = StencilSpec::parse("star2d:b3:f20:r2").unwrap();
        assert_eq!(a, b);
        assert_eq!(a.canonical_name(), "star2d:r2:b3:f20");
    }

    #[test]
    fn parse_rejects_garbage_with_reasons() {
        for (name, needle) in [
            ("sphere2d:r1", "not a stencil family"),
            ("star4d:r1", "not a dimensionality"),
            ("star2d", "missing the radius"),
            ("star2d:r0", "radius must be"),
            ("star2d:r9", "radius must be"),
            ("star2d:rtwo", "bad radius"),
            ("star2d:r2:q7", "unknown parameter"),
            ("star2d:r2:f-1", "finite and positive"),
            ("star2d:r2:b0.5", ">= 1"),
        ] {
            let err = StencilSpec::parse(name).unwrap_err();
            assert!(err.contains(needle), "{name}: '{err}' should mention '{needle}'");
        }
    }

    #[test]
    fn registration_is_idempotent() {
        let a = StencilSpec::star(Dim::D3, 2).register();
        let b = StencilSpec::parse("star3d:r2").unwrap().register();
        assert_eq!(a, b);
        assert_eq!(a.name(), "star3d:r2");
    }

    #[test]
    fn chain_halo_sums_stage_depths() {
        let chain = FusedChain::parse("fuse:heat2d+laplacian2d:t4").unwrap();
        assert_eq!(chain.stages.len(), 2);
        assert_eq!(chain.t_steps, 4);
        assert_eq!(chain.halo(), 8, "4 passes × (σ=1 + σ=1)");
        assert_eq!(chain.applications(), 8);
        let deep = FusedChain::new(
            vec![StencilSpec::star(Dim::D2, 2), StencilSpec::star(Dim::D2, 1)],
            3,
        )
        .unwrap();
        assert_eq!(deep.halo(), 9, "3 passes × (σ=2 + σ=1)");
    }

    #[test]
    fn chain_canonical_name_roundtrips() {
        let cases = [
            "fuse:heat2d",
            "fuse:heat2d:t4",
            "fuse:heat2d+laplacian2d:t4",
            "fuse:jacobi2d+heat2d+laplacian2d:t2",
            "fuse:heat3d+laplacian3d:t3",
            "fuse:star2d:r2+box2d:r1:t2",
            "fuse:star2d:r2:b3:f20+heat2d:t2",
            "fuse:box3d:r1:c25.5+star3d:r2:t2",
        ];
        for name in cases {
            let chain = FusedChain::parse(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(chain.canonical_name(), name, "canonical");
            let back = FusedChain::parse(&chain.canonical_name()).unwrap();
            assert_eq!(chain, back, "{name}");
        }
        // A family spelling of a preset canonicalizes to the preset name.
        let chain = FusedChain::parse("fuse:star2d:r1:f10:c13+laplacian2d:t4").unwrap();
        assert_eq!(chain.canonical_name(), "fuse:heat2d+laplacian2d:t4");
    }

    #[test]
    fn chain_parse_rejects_garbage_with_reasons() {
        for (name, needle) in [
            ("fuse:", "empty stage"),
            ("fuse:heat2d++laplacian2d", "empty stage"),
            ("fuse:frobnicate:t2", "stage 'frobnicate'"),
            ("fuse:heat2d:tmany", "bad fuse steps"),
            ("fuse:heat2d:t0", "fuse steps must be"),
            ("fuse:heat2d:t99", "fuse steps must be"),
            ("fuse:heat2d+heat3d:t2", "share one dimensionality"),
            ("fuse:heat2d+star2d:r1:w8:t2", "share one word size"),
            ("fuse:star2d:r8+star2d:r8+star2d:r8+star2d:r8+star2d:r8:t8", "exceeds"),
            (
                "fuse:jacobi2d+heat2d+laplacian2d+gradient2d+jacobi2d+heat2d+laplacian2d",
                "at most",
            ),
            ("fuse:star2d:r1:b1+star2d:r1:b1:t2", "too few buffers"),
            ("heat2d", "not a fused chain"),
        ] {
            let err = FusedChain::parse(name).unwrap_err();
            assert!(err.contains(needle), "{name}: '{err}' should mention '{needle}'");
        }
    }

    #[test]
    fn single_application_chain_characterizes_as_its_stage() {
        // K = 1, t = 1: the redundancy factor is exactly 1 and every
        // effective field is bit-equal to the lone stage's — the identity
        // the property tier certifies across random stages.
        for stage in [
            StencilSpec::star(Dim::D2, 1).with_flops(10.0).with_c_iter(13.0),
            StencilSpec::boxed(Dim::D3, 2).with_buffers(3.0),
        ] {
            let chain = FusedChain::new(vec![stage], 1).unwrap();
            assert_eq!(chain.reference_redundancy().to_bits(), 1.0_f64.to_bits());
            let eff = chain.effective_spec();
            assert_eq!(eff.radius, stage.radius);
            assert_eq!(eff.flops_per_point().to_bits(), stage.flops_per_point().to_bits());
            assert_eq!(eff.c_iter_cycles().to_bits(), stage.c_iter_cycles().to_bits());
            assert_eq!(eff.n_buffers.to_bits(), stage.n_buffers.to_bits());
            assert_eq!(eff.bytes_per_cell.to_bits(), stage.bytes_per_cell.to_bits());
        }
    }

    #[test]
    fn chain_redundancy_matches_python_fused_kernels() {
        // python/compile/kernels/fused.redundancy_factor(16, 24, 4) with
        // σ = 1: Σ_{s=0}^{3} (16+2(3−s))·(24+2(3−s)) / (16·24·4).
        let chain = FusedChain::new(vec![StencilSpec::star(Dim::D2, 1)], 4).unwrap();
        let expect = (22.0 * 30.0 + 20.0 * 28.0 + 18.0 * 26.0 + 16.0 * 24.0)
            / (16.0 * 24.0 * 4.0);
        assert_eq!(chain.redundancy_factor(16, 24, None).to_bits(), expect.to_bits());
        // And the footprint formula: 4 B · [(t1+2h)(t2+2h) + t1·t2] at
        // 64×64, h = 4 — the module docstring's 21.6 kB example.
        assert_eq!(chain.vmem_footprint_bytes(64, 64), 4.0 * ((72 * 72 + 64 * 64) as f64));
        assert_eq!(chain.halo(), 4);
    }

    #[test]
    fn chain_characterization_scales_with_depth() {
        // Deeper fusion: more halo, more redundant compute per macro point,
        // same staged word — the traffic amortization lives in the macro
        // step carrying `applications()` real stage applications.
        let per_pass: f64 = 10.0 + 6.0; // heat2d + laplacian2d flops
        let mut last_r = 0.0;
        for t in 1..=4u32 {
            let chain = FusedChain::parse(&format!("fuse:heat2d+laplacian2d:t{t}")).unwrap();
            let r = chain.reference_redundancy();
            assert!(r >= 1.0 && r > last_r || t == 1, "redundancy grows with depth");
            assert!(
                chain.effective_flops() >= t as f64 * per_pass,
                "effective flops carry the useful work plus the edge term"
            );
            assert_eq!(chain.effective_buffers(), 2.0, "default stages share one plane pair");
            last_r = r;
        }
    }

    #[test]
    fn chain_registers_like_a_stencil() {
        let chain = FusedChain::parse("fuse:heat2d+laplacian2d:t4").unwrap();
        let id = chain.register();
        assert_eq!(id, chain.register(), "idempotent");
        let st = defs::Stencil::get(id);
        assert_eq!(st.name(), "fuse:heat2d+laplacian2d:t4");
        assert_eq!(st.sigma, 8);
        assert_eq!(st.space_dims, 2);
        assert_eq!(st.flops_per_point.to_bits(), chain.effective_flops().to_bits());
        assert_eq!(st.c_iter_cycles.to_bits(), chain.effective_c_iter().to_bits());
        assert_eq!(st.n_buffers, 2.0);
        assert_eq!(st.bytes_per_cell, 4.0);
    }
}
