//! Parametric stencil families — the open workload space beyond the paper's
//! six kernels.
//!
//! The codesign model never consumes a stencil's *code*; it consumes a small
//! analytical characterization (§II): space dimensionality, halo width per
//! time step (σ), flops per updated point, live buffers per tile, and bytes
//! per cell. A [`StencilSpec`] describes a whole *family* of such kernels —
//! star or box stencils of arbitrary radius in 2-D or 3-D — and derives that
//! characterization analytically, so any member can be explored, batched,
//! cached and served exactly like the six paper presets.
//!
//! Derivations (DESIGN.md §3 documents the math):
//!
//! * **support** — taps read per updated point: star `2·d·r + 1`,
//!   box `(2r+1)^d`;
//! * **flops/point** — one multiply per tap plus the adds that combine them,
//!   `2·support − 1` (a fully-weighted scheme; exact loop-body counts can
//!   override);
//! * **σ (halo)** — the dependence-cone slope equals the radius, `σ = r`;
//! * **C_iter** — paper-scale heuristic pending silicon measurement:
//!   `8 + flops/2` cycles in 2-D, `11 + flops/2` in 3-D (presets pin the
//!   paper's measured values instead).
//!
//! Every spec has a **canonical name** that encodes all of its parameters
//! (`star3d:r2`, `box2d:r1:f20`) and round-trips through [`StencilSpec::parse`]
//! bit-exactly — the wire format (schema v2) carries specs as these names.
//!
//! # Examples
//!
//! ```no_run
//! use codesign::stencil::spec::{Dim, StencilSpec};
//!
//! // A radius-2 star in 3-D: 13-point support, halo 2 per time step.
//! let spec = StencilSpec::star(Dim::D3, 2);
//! assert_eq!(spec.support_points(), 13);
//! assert_eq!(spec.canonical_name(), "star3d:r2");
//!
//! // Register it and it behaves exactly like a built-in benchmark.
//! let id = spec.register();
//! let st = codesign::stencil::defs::Stencil::get(id);
//! assert_eq!(st.sigma, 2);
//! ```

use crate::stencil::defs::{self, StencilId};

/// Space dimensionality of a stencil family (every benchmark adds one time
/// dimension on top).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dim {
    D2,
    D3,
}

impl Dim {
    /// Number of space dimensions (2 or 3).
    pub fn space_dims(&self) -> u32 {
        match self {
            Dim::D2 => 2,
            Dim::D3 => 3,
        }
    }

    /// The `2d` / `3d` name fragment.
    pub fn token(&self) -> &'static str {
        match self {
            Dim::D2 => "2d",
            Dim::D3 => "3d",
        }
    }
}

/// Neighborhood shape of a stencil family.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Shape {
    /// Axis-aligned cross: `2·d·r` neighbors plus the center.
    Star,
    /// Full hypercube: `(2r+1)^d` taps.
    Box,
}

impl Shape {
    /// The `star` / `box` name fragment.
    pub fn token(&self) -> &'static str {
        match self {
            Shape::Star => "star",
            Shape::Box => "box",
        }
    }
}

/// Maximum supported radius. The hybrid-hexagonal time model stays valid for
/// any σ, but radii beyond this are outside the calibrated regime (the halo
/// dominates every realistic tile footprint).
pub const MAX_RADIUS: u32 = 8;

/// A parametric stencil family member: shape × dimensionality × radius, plus
/// optional characterization overrides for exact loop bodies.
///
/// Defaults describe a fully-weighted scheme in fp32 with double-buffered
/// time planes — override `flops`/`c_iter` when a concrete kernel's operation
/// count is known (the six paper presets do exactly that).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StencilSpec {
    pub dim: Dim,
    pub shape: Shape,
    /// Halo width per time step, `1..=MAX_RADIUS` (σ in the tiling model).
    pub radius: u32,
    /// Live arrays a tile stages in shared memory (default 2: in/out planes).
    pub n_buffers: f64,
    /// Bytes per cell (default 4: fp32).
    pub bytes_per_cell: f64,
    /// Exact flops per updated point, overriding the derived count.
    pub flops: Option<f64>,
    /// Measured `C_iter` cycles, overriding the derived heuristic.
    pub c_iter: Option<f64>,
}

impl StencilSpec {
    /// A star (axis-aligned cross) family member with default
    /// characterization.
    pub fn star(dim: Dim, radius: u32) -> StencilSpec {
        StencilSpec {
            dim,
            shape: Shape::Star,
            radius,
            n_buffers: 2.0,
            bytes_per_cell: 4.0,
            flops: None,
            c_iter: None,
        }
    }

    /// A box (full hypercube) family member with default characterization.
    pub fn boxed(dim: Dim, radius: u32) -> StencilSpec {
        StencilSpec { shape: Shape::Box, ..StencilSpec::star(dim, radius) }
    }

    /// Override the flops-per-point count (exact loop bodies).
    pub fn with_flops(mut self, flops: f64) -> StencilSpec {
        self.flops = Some(flops);
        self
    }

    /// Override the `C_iter` cycle cost (measured values).
    pub fn with_c_iter(mut self, cycles: f64) -> StencilSpec {
        self.c_iter = Some(cycles);
        self
    }

    /// Override the live-buffer count.
    pub fn with_buffers(mut self, n: f64) -> StencilSpec {
        self.n_buffers = n;
        self
    }

    /// Override the bytes-per-cell word size.
    pub fn with_bytes_per_cell(mut self, bytes: f64) -> StencilSpec {
        self.bytes_per_cell = bytes;
        self
    }

    /// Validate every parameter; `Err` carries a human-readable reason.
    pub fn validate(&self) -> Result<(), String> {
        if self.radius < 1 || self.radius > MAX_RADIUS {
            return Err(format!("radius must be 1..={MAX_RADIUS} (got {})", self.radius));
        }
        if !(self.n_buffers.is_finite() && self.n_buffers >= 1.0) {
            return Err(format!("n_buffers must be finite and >= 1 (got {})", self.n_buffers));
        }
        if !(self.bytes_per_cell.is_finite() && self.bytes_per_cell > 0.0) {
            return Err(format!(
                "bytes_per_cell must be finite and positive (got {})",
                self.bytes_per_cell
            ));
        }
        if let Some(f) = self.flops {
            if !(f.is_finite() && f > 0.0) {
                return Err(format!("flops override must be finite and positive (got {f})"));
            }
        }
        if let Some(c) = self.c_iter {
            if !(c.is_finite() && c > 0.0) {
                return Err(format!("c_iter override must be finite and positive (got {c})"));
            }
        }
        Ok(())
    }

    /// Taps read per updated point: star `2·d·r + 1`, box `(2r+1)^d`.
    pub fn support_points(&self) -> u64 {
        let d = self.dim.space_dims() as u64;
        let r = self.radius as u64;
        match self.shape {
            Shape::Star => 2 * d * r + 1,
            Shape::Box => (2 * r + 1).pow(d as u32),
        }
    }

    /// Formal order of accuracy of the centered finite-difference scheme this
    /// halo supports: `2·radius`.
    pub fn order(&self) -> u32 {
        2 * self.radius
    }

    /// Derived flops per point for a fully-weighted scheme: one multiply per
    /// tap plus `support − 1` adds, `2·support − 1`.
    pub fn derived_flops(&self) -> f64 {
        2.0 * self.support_points() as f64 - 1.0
    }

    /// Effective flops per point (override, else derived).
    pub fn flops_per_point(&self) -> f64 {
        self.flops.unwrap_or_else(|| self.derived_flops())
    }

    /// Derived `C_iter` heuristic: per-iteration loop overhead plus half a
    /// cycle per flop on the paper's GTX 980 scale (`8 + flops/2` in 2-D,
    /// `11 + flops/2` in 3-D — anchored so the measured presets land within
    /// a few cycles).
    pub fn derived_c_iter(&self) -> f64 {
        let base = match self.dim {
            Dim::D2 => 8.0,
            Dim::D3 => 11.0,
        };
        base + self.flops_per_point() / 2.0
    }

    /// Effective `C_iter` cycles (override, else derived).
    pub fn c_iter_cycles(&self) -> f64 {
        self.c_iter.unwrap_or_else(|| self.derived_c_iter())
    }

    /// The canonical name: `<shape><dim>:r<radius>` plus `:b`/`:w`/`:f`/`:c`
    /// suffixes for every non-default parameter, in that order. Floats use
    /// Rust's shortest round-trip formatting, so
    /// `parse(canonical_name()) == self` bit-exactly.
    pub fn canonical_name(&self) -> String {
        let mut name = format!("{}{}:r{}", self.shape.token(), self.dim.token(), self.radius);
        if self.n_buffers != 2.0 {
            name.push_str(&format!(":b{}", self.n_buffers));
        }
        if self.bytes_per_cell != 4.0 {
            name.push_str(&format!(":w{}", self.bytes_per_cell));
        }
        if let Some(f) = self.flops {
            name.push_str(&format!(":f{f}"));
        }
        if let Some(c) = self.c_iter {
            name.push_str(&format!(":c{c}"));
        }
        name
    }

    /// Parse a family name. Grammar (suffixes accepted in any order; a
    /// repeated suffix takes its last value):
    ///
    /// ```text
    /// <shape><dim> ":r" <radius> [":b" <f64>] [":w" <f64>] [":f" <f64>] [":c" <f64>]
    /// shape  = "star" | "box"
    /// dim    = "2d" | "3d"
    /// radius = 1..=8
    /// ```
    ///
    /// `b` = live buffers, `w` = bytes per cell (word size), `f` = flops per
    /// point override, `c` = `C_iter` cycles override.
    pub fn parse(name: &str) -> Result<StencilSpec, String> {
        let mut parts = name.split(':');
        let head = parts.next().unwrap_or_default();
        let (shape, dim_tok) = if let Some(rest) = head.strip_prefix("star") {
            (Shape::Star, rest)
        } else if let Some(rest) = head.strip_prefix("box") {
            (Shape::Box, rest)
        } else {
            return Err(format!("'{head}' is not a stencil family (want star… or box…)"));
        };
        let dim = match dim_tok {
            "2d" => Dim::D2,
            "3d" => Dim::D3,
            other => return Err(format!("'{other}' is not a dimensionality (want 2d or 3d)")),
        };
        let mut spec = StencilSpec::star(dim, 0);
        spec.shape = shape;
        let mut seen_r = false;
        for part in parts {
            if !part.is_ascii() {
                return Err(format!("unknown parameter in '{part}'"));
            }
            let (tag, value) = part.split_at(1.min(part.len()));
            let parse_f64 = |what: &str| -> Result<f64, String> {
                value.parse::<f64>().map_err(|_| format!("bad {what} value '{value}'"))
            };
            match tag {
                "r" => {
                    spec.radius = value
                        .parse::<u32>()
                        .map_err(|_| format!("bad radius '{value}'"))?;
                    seen_r = true;
                }
                "b" => spec.n_buffers = parse_f64("buffer-count (b)")?,
                "w" => spec.bytes_per_cell = parse_f64("word-size (w)")?,
                "f" => spec.flops = Some(parse_f64("flops (f)")?),
                "c" => spec.c_iter = Some(parse_f64("c_iter (c)")?),
                other => return Err(format!("unknown parameter '{other}' in '{part}'")),
            }
        }
        if !seen_r {
            return Err(format!("'{name}' is missing the radius (e.g. {head}:r2)"));
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Intern this spec in the global stencil registry (idempotent: equal
    /// canonical names return the same id) and get its [`StencilId`], usable
    /// everywhere a preset id is — workloads, scenarios, requests, the wire.
    ///
    /// Panics on an invalid spec or a full registry (u16 id space); untrusted
    /// inputs should go through the fallible
    /// [`Stencil::by_name_err`](crate::stencil::defs::Stencil::by_name_err)
    /// name path instead.
    pub fn register(&self) -> StencilId {
        defs::register_spec(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn support_counts() {
        assert_eq!(StencilSpec::star(Dim::D2, 1).support_points(), 5);
        assert_eq!(StencilSpec::star(Dim::D3, 1).support_points(), 7);
        assert_eq!(StencilSpec::star(Dim::D3, 2).support_points(), 13);
        assert_eq!(StencilSpec::boxed(Dim::D2, 1).support_points(), 9);
        assert_eq!(StencilSpec::boxed(Dim::D3, 1).support_points(), 27);
        assert_eq!(StencilSpec::boxed(Dim::D3, 2).support_points(), 125);
    }

    #[test]
    fn derived_characterization_scales_with_radius() {
        for dim in [Dim::D2, Dim::D3] {
            let mut last_flops = 0.0;
            for r in 1..=MAX_RADIUS {
                let s = StencilSpec::star(dim, r);
                assert!(s.validate().is_ok());
                assert_eq!(s.order(), 2 * r);
                assert!(s.flops_per_point() > last_flops, "flops must grow with radius");
                assert!(s.c_iter_cycles() > 0.0);
                last_flops = s.flops_per_point();
            }
        }
    }

    #[test]
    fn canonical_name_roundtrips() {
        let cases = [
            StencilSpec::star(Dim::D3, 2),
            StencilSpec::boxed(Dim::D2, 4),
            StencilSpec::star(Dim::D2, 1).with_flops(4.0).with_c_iter(11.0),
            StencilSpec::boxed(Dim::D3, 3).with_buffers(3.0).with_bytes_per_cell(8.0),
            StencilSpec::star(Dim::D2, 2).with_flops(1.0 / 3.0),
        ];
        for spec in cases {
            let name = spec.canonical_name();
            let back = StencilSpec::parse(&name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(spec, back, "{name}");
            assert_eq!(back.canonical_name(), name);
        }
    }

    #[test]
    fn parse_accepts_any_suffix_order() {
        let a = StencilSpec::parse("star2d:r2:f20:b3").unwrap();
        let b = StencilSpec::parse("star2d:b3:f20:r2").unwrap();
        assert_eq!(a, b);
        assert_eq!(a.canonical_name(), "star2d:r2:b3:f20");
    }

    #[test]
    fn parse_rejects_garbage_with_reasons() {
        for (name, needle) in [
            ("sphere2d:r1", "not a stencil family"),
            ("star4d:r1", "not a dimensionality"),
            ("star2d", "missing the radius"),
            ("star2d:r0", "radius must be"),
            ("star2d:r9", "radius must be"),
            ("star2d:rtwo", "bad radius"),
            ("star2d:r2:q7", "unknown parameter"),
            ("star2d:r2:f-1", "finite and positive"),
            ("star2d:r2:b0.5", ">= 1"),
        ] {
            let err = StencilSpec::parse(name).unwrap_err();
            assert!(err.contains(needle), "{name}: '{err}' should mention '{needle}'");
        }
    }

    #[test]
    fn registration_is_idempotent() {
        let a = StencilSpec::star(Dim::D3, 2).register();
        let b = StencilSpec::parse("star3d:r2").unwrap().register();
        assert_eq!(a, b);
        assert_eq!(a.name(), "star3d:r2");
    }
}
