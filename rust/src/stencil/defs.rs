//! The six dense stencils of the paper's workload: four 2-D (Jacobi, Heat,
//! Laplacian, Gradient — all first order, two space dimensions + time) and
//! two 3-D (Heat, Laplacian — three space dimensions + time).
//!
//! Per-point operation counts are derived from the canonical loop bodies (the
//! same bodies implemented by the Pallas kernels in `python/compile/kernels/`
//! and by the pure-jnp oracle `ref.py`). `C_iter` — the per-iteration,
//! per-thread issue cost in cycles that the paper measures on real silicon —
//! is carried per stencil with *paper-mode* defaults calibrated against the
//! paper's reported GFLOP/s scale (see `timemodel::citer`), and can be
//! overridden by measurements from the PJRT runtime.

/// Identity of a benchmark stencil.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum StencilId {
    Jacobi2D,
    Heat2D,
    Laplacian2D,
    Gradient2D,
    Heat3D,
    Laplacian3D,
}

impl StencilId {
    pub fn name(&self) -> &'static str {
        match self {
            StencilId::Jacobi2D => "jacobi2d",
            StencilId::Heat2D => "heat2d",
            StencilId::Laplacian2D => "laplacian2d",
            StencilId::Gradient2D => "gradient2d",
            StencilId::Heat3D => "heat3d",
            StencilId::Laplacian3D => "laplacian3d",
        }
    }

    pub fn from_name(name: &str) -> Option<StencilId> {
        ALL_STENCILS.iter().find(|s| s.id.name() == name).map(|s| s.id)
    }
}

/// Static description of one stencil benchmark.
#[derive(Clone, Copy, Debug)]
pub struct Stencil {
    pub id: StencilId,
    /// Space dimensions (2 or 3); every benchmark adds one time dimension.
    pub space_dims: u32,
    /// Halo width per time step (all six are first-order: σ = 1).
    pub sigma: u32,
    /// Floating-point operations per updated point.
    pub flops_per_point: f64,
    /// Live arrays a tile must stage in shared memory (double-buffered
    /// time planes for in/out, plus coefficient arrays where applicable).
    pub n_buffers: f64,
    /// Bytes per cell (all benchmarks are fp32).
    pub bytes_per_cell: f64,
    /// Paper-mode per-iteration single-thread cost, cycles (see
    /// `timemodel::citer` for calibration).
    pub c_iter_cycles: f64,
}

impl Stencil {
    pub fn name(&self) -> &'static str {
        self.id.name()
    }

    pub fn is_3d(&self) -> bool {
        self.space_dims == 3
    }

    /// Look up a stencil by id.
    pub fn get(id: StencilId) -> &'static Stencil {
        ALL_STENCILS.iter().find(|s| s.id == id).expect("unknown stencil")
    }

    /// Look up a stencil by `name()`.
    pub fn by_name(name: &str) -> Option<&'static Stencil> {
        ALL_STENCILS.iter().find(|s| s.id.name() == name)
    }
}

/// All six benchmarks.
///
/// Operation counts (per output point, fp32):
/// * **Jacobi-2D** `o = 0.25·(N+S+E+W)`: 3 add + 1 mul = 4 flops.
/// * **Heat-2D** `o = c·C + a·(N+S+E+W)` (explicit 5-point heat step written
///   as 2 mul + 5 add/sub in the canonical body): 10 flops.
/// * **Laplacian-2D** `o = N+S+E+W − 4·C`: 4 add/sub + 1 mul = 6 flops
///   (counting the fused scale-subtract as 2).
/// * **Gradient-2D** `o = sqrt(gx² + gy²)`, `gx = (E−W)/2`, `gy = (N−S)/2`:
///   2 sub + 2 mul + 2 mul + 1 add + sqrt(≈4) = 14 flops.
/// * **Heat-3D** 7-point explicit heat step: 14 flops.
/// * **Laplacian-3D** `o = Σ₆ neighbors − 6·C`: 6 add + 2 = 8 flops.
///
/// `n_buffers`: Jacobi/Heat/Laplacian sweep in/out planes (2); Gradient reads
/// one plane and writes a derived field (2); none carry coefficient arrays.
pub const ALL_STENCILS: [Stencil; 6] = [
    Stencil {
        id: StencilId::Jacobi2D,
        space_dims: 2,
        sigma: 1,
        flops_per_point: 4.0,
        n_buffers: 2.0,
        bytes_per_cell: 4.0,
        c_iter_cycles: 11.0,
    },
    Stencil {
        id: StencilId::Heat2D,
        space_dims: 2,
        sigma: 1,
        flops_per_point: 10.0,
        n_buffers: 2.0,
        bytes_per_cell: 4.0,
        c_iter_cycles: 13.0,
    },
    Stencil {
        id: StencilId::Laplacian2D,
        space_dims: 2,
        sigma: 1,
        flops_per_point: 6.0,
        n_buffers: 2.0,
        bytes_per_cell: 4.0,
        c_iter_cycles: 10.0,
    },
    Stencil {
        id: StencilId::Gradient2D,
        space_dims: 2,
        sigma: 1,
        flops_per_point: 14.0,
        n_buffers: 2.0,
        bytes_per_cell: 4.0,
        c_iter_cycles: 12.0,
    },
    Stencil {
        id: StencilId::Heat3D,
        space_dims: 3,
        sigma: 1,
        flops_per_point: 14.0,
        n_buffers: 2.0,
        bytes_per_cell: 4.0,
        c_iter_cycles: 16.0,
    },
    Stencil {
        id: StencilId::Laplacian3D,
        space_dims: 3,
        sigma: 1,
        flops_per_point: 8.0,
        n_buffers: 2.0,
        bytes_per_cell: 4.0,
        c_iter_cycles: 15.0,
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_benchmarks_four_2d_two_3d() {
        assert_eq!(ALL_STENCILS.len(), 6);
        assert_eq!(ALL_STENCILS.iter().filter(|s| s.space_dims == 2).count(), 4);
        assert_eq!(ALL_STENCILS.iter().filter(|s| s.space_dims == 3).count(), 2);
    }

    #[test]
    fn all_first_order_fp32() {
        for s in &ALL_STENCILS {
            assert_eq!(s.sigma, 1, "{}", s.name());
            assert_eq!(s.bytes_per_cell, 4.0, "{}", s.name());
            assert!(s.flops_per_point > 0.0 && s.c_iter_cycles > 0.0);
        }
    }

    #[test]
    fn lookup_roundtrip() {
        for s in &ALL_STENCILS {
            assert_eq!(Stencil::by_name(s.name()).unwrap().id, s.id);
            assert_eq!(StencilId::from_name(s.name()), Some(s.id));
            assert_eq!(Stencil::get(s.id).name(), s.name());
        }
        assert!(Stencil::by_name("bogus").is_none());
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<&str> = ALL_STENCILS.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 6);
    }
}
