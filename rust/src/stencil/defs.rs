//! The stencil registry: the paper's six benchmark presets plus any number
//! of registered parametric family members (see [`crate::stencil::spec`]).
//!
//! The paper's workload is four 2-D stencils (Jacobi, Heat, Laplacian,
//! Gradient — all first order, two space dimensions + time) and two 3-D
//! (Heat, Laplacian). Their per-point operation counts are derived from the
//! canonical loop bodies (the same bodies implemented by the Pallas kernels
//! in `python/compile/kernels/` and by the pure-jnp oracle `ref.py`), and
//! their [`ALL_STENCILS`] characterizations are **bit-identical to the
//! original hard-coded tables** — certified by `integration_stencil.rs`.
//!
//! `C_iter` — the per-iteration, per-thread issue cost in cycles that the
//! paper measures on real silicon — is carried per stencil with *paper-mode*
//! defaults calibrated against the paper's reported GFLOP/s scale (see
//! `timemodel::citer`), and can be overridden by measurements from the PJRT
//! runtime.
//!
//! A [`StencilId`] is a small copyable handle into the registry: ids `0..6`
//! are the presets (exposed as the familiar `StencilId::Jacobi2D`-style
//! constants), higher ids are interned parametric specs. [`Stencil::by_name`]
//! resolves preset names *and* parses family names like `star3d:r2`,
//! registering them on first sight.

use crate::stencil::spec::{Dim, FusedChain, Shape, StencilSpec};
use std::collections::HashMap;
use std::sync::{OnceLock, RwLock};

/// Identity of a registered stencil: presets `0..6`, then interned
/// parametric specs in registration order.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StencilId(u16);

#[allow(non_upper_case_globals)] // named after the former enum variants
impl StencilId {
    pub const Jacobi2D: StencilId = StencilId(0);
    pub const Heat2D: StencilId = StencilId(1);
    pub const Laplacian2D: StencilId = StencilId(2);
    pub const Gradient2D: StencilId = StencilId(3);
    pub const Heat3D: StencilId = StencilId(4);
    pub const Laplacian3D: StencilId = StencilId(5);

    pub fn name(&self) -> &'static str {
        Stencil::get(*self).name
    }

    /// Resolve a preset name or parse-and-register a parametric family name.
    pub fn from_name(name: &str) -> Option<StencilId> {
        Stencil::by_name(name).map(|s| s.id)
    }
}

impl std::fmt::Debug for StencilId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Static description of one stencil: the analytical characterization the
/// whole model stack consumes, plus the [`StencilSpec`] it derives from.
#[derive(Clone, Copy, Debug)]
pub struct Stencil {
    pub id: StencilId,
    /// Registry name (`jacobi2d`, `star3d:r2`, `fuse:heat2d+laplacian2d:t4`, …).
    pub name: &'static str,
    /// The generating family spec (presets pin exact loop-body counts).
    /// Fused chains carry their synthetic *effective* spec — it re-derives
    /// the characterization below exactly, but its radius is the fused halo
    /// and may exceed `MAX_RADIUS`, so it is not a registrable family.
    pub spec: StencilSpec,
    /// Space dimensions (2 or 3); every benchmark adds one time dimension.
    pub space_dims: u32,
    /// Halo width per time step (σ — the stencil radius).
    pub sigma: u32,
    /// Floating-point operations per updated point.
    pub flops_per_point: f64,
    /// Live arrays a tile must stage in shared memory (double-buffered
    /// time planes for in/out, plus coefficient arrays where applicable).
    pub n_buffers: f64,
    /// Bytes per cell (the presets are fp32).
    pub bytes_per_cell: f64,
    /// Paper-mode per-iteration single-thread cost, cycles (see
    /// `timemodel::citer` for calibration).
    pub c_iter_cycles: f64,
}

impl Stencil {
    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn is_3d(&self) -> bool {
        self.space_dims == 3
    }

    /// Look up a stencil by id. Preset lookups are lock-free.
    pub fn get(id: StencilId) -> &'static Stencil {
        let i = id.0 as usize;
        if i < ALL_STENCILS.len() {
            return &ALL_STENCILS[i];
        }
        registry().read().unwrap().defs[i - ALL_STENCILS.len()]
    }

    /// Look up by preset name or by parametric family name (`star3d:r2`,
    /// `box2d:r1:f20`, …), registering parsed specs on first sight.
    pub fn by_name(name: &str) -> Option<&'static Stencil> {
        Stencil::by_name_err(name).ok()
    }

    /// [`Stencil::by_name`] with a diagnosable error: unknown names report
    /// the valid presets and the family-name grammar instead of a bare
    /// rejection.
    pub fn by_name_err(name: &str) -> Result<&'static Stencil, String> {
        if let Some(s) = ALL_STENCILS.iter().find(|s| s.name == name) {
            return Ok(s);
        }
        // Copy the id out before the read guard drops: `Stencil::get`
        // re-locks, and a nested read while a writer queues can deadlock.
        let registered = registry().read().unwrap().by_name.get(name).copied();
        if let Some(id) = registered {
            return Ok(Stencil::get(id));
        }
        if name.starts_with("fuse:") {
            return match FusedChain::parse(name) {
                Ok(chain) => register_chain(&chain, Some(name)).map(Stencil::get),
                Err(reason) => Err(unknown_stencil_msg(name, &reason)),
            };
        }
        match StencilSpec::parse(name) {
            Ok(spec) => register_named(&spec, Some(name)).map(Stencil::get),
            Err(reason) => Err(unknown_stencil_msg(name, &reason)),
        }
    }
}

/// The "unknown stencil" diagnostic: what failed, the valid presets, and the
/// parametric grammar.
pub fn unknown_stencil_msg(name: &str, reason: &str) -> String {
    let presets: Vec<&str> = ALL_STENCILS.iter().map(|s| s.name).collect();
    format!(
        "unknown stencil '{name}' ({reason}); valid presets: {}; or a parametric family \
         '<star|box><2d|3d>:r<1-8>' with optional ':b<bufs>', ':w<bytes>', ':f<flops>', \
         ':c<cycles>' overrides (e.g. star3d:r2, box2d:r1:f20); or a fused chain \
         'fuse:<stage>(+<stage>)*[:t<1-8>]' of same-dimension stages \
         (e.g. fuse:heat2d+laplacian2d:t4)",
        presets.join(", ")
    )
}

struct Registry {
    /// Non-preset definitions; `StencilId(6 + i)` indexes `defs[i]`.
    /// Entries are leaked so `Stencil::get` can keep returning `&'static`.
    defs: Vec<&'static Stencil>,
    /// Canonical names *and* accepted aliases, presets included.
    by_name: HashMap<String, StencilId>,
}

fn registry() -> &'static RwLock<Registry> {
    static REGISTRY: OnceLock<RwLock<Registry>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        let by_name = ALL_STENCILS.iter().map(|s| (s.name.to_string(), s.id)).collect();
        RwLock::new(Registry { defs: Vec::new(), by_name })
    })
}

/// Intern a spec under its canonical name (idempotent). Called via
/// [`StencilSpec::register`].
pub(crate) fn register_spec(spec: &StencilSpec) -> StencilId {
    register_named(spec, None).unwrap_or_else(|e| panic!("{e}"))
}

/// Intern a spec, optionally under an alias spelling too. Each distinct
/// canonical name leaks one small definition (that is what makes
/// `Stencil::get` return `&'static`), bounded by the u16 id space — a full
/// registry is a clean error, not a panic, because this is reachable from
/// untrusted wire input (`stencil_from_json` → `by_name_err`).
fn register_named(spec: &StencilSpec, alias: Option<&str>) -> Result<StencilId, String> {
    if let Err(e) = spec.validate() {
        return Err(format!("invalid StencilSpec: {e}"));
    }
    intern(spec.canonical_name(), spec, alias)
}

/// Intern a fused chain under its canonical name (idempotent; the alias is
/// the as-written spelling). The registry entry carries the chain's
/// *effective* spec, so every downstream consumer — cache keys, time model,
/// bounds, workloads, the wire — sees a plain characterized stencil. Two
/// chains with identical characterizations but different names still share
/// sweeps: `CacheKey` is built from the characterization bits, not the id.
pub(crate) fn register_chain(
    chain: &FusedChain,
    alias: Option<&str>,
) -> Result<StencilId, String> {
    if let Err(e) = chain.validate() {
        return Err(format!("invalid fused chain: {e}"));
    }
    intern(chain.canonical_name(), &chain.effective_spec(), alias)
}

fn intern(
    canonical: String,
    spec: &StencilSpec,
    alias: Option<&str>,
) -> Result<StencilId, String> {
    let mut reg = registry().write().unwrap();
    let id = match reg.by_name.get(&canonical) {
        Some(&id) => id,
        None => {
            let index = ALL_STENCILS.len() + reg.defs.len();
            if index >= u16::MAX as usize {
                return Err(format!(
                    "stencil registry full ({index} registered); refusing '{canonical}'"
                ));
            }
            let id = StencilId(index as u16);
            let name: &'static str = Box::leak(canonical.clone().into_boxed_str());
            let st: &'static Stencil = Box::leak(Box::new(Stencil {
                id,
                name,
                spec: *spec,
                space_dims: spec.dim.space_dims(),
                sigma: spec.radius,
                flops_per_point: spec.flops_per_point(),
                n_buffers: spec.n_buffers,
                bytes_per_cell: spec.bytes_per_cell,
                c_iter_cycles: spec.c_iter_cycles(),
            }));
            reg.defs.push(st);
            reg.by_name.insert(canonical, id);
            id
        }
    };
    if let Some(alias) = alias {
        reg.by_name.entry(alias.to_string()).or_insert(id);
    }
    Ok(id)
}

/// All six paper presets.
///
/// Operation counts (per output point, fp32):
/// * **Jacobi-2D** `o = 0.25·(N+S+E+W)`: 3 add + 1 mul = 4 flops.
/// * **Heat-2D** `o = c·C + a·(N+S+E+W)` (explicit 5-point heat step written
///   as 2 mul + 5 add/sub in the canonical body): 10 flops.
/// * **Laplacian-2D** `o = N+S+E+W − 4·C`: 4 add/sub + 1 mul = 6 flops
///   (counting the fused scale-subtract as 2).
/// * **Gradient-2D** `o = sqrt(gx² + gy²)`, `gx = (E−W)/2`, `gy = (N−S)/2`:
///   2 sub + 2 mul + 2 mul + 1 add + sqrt(≈4) = 14 flops.
/// * **Heat-3D** 7-point explicit heat step: 14 flops.
/// * **Laplacian-3D** `o = Σ₆ neighbors − 6·C`: 6 add + 2 = 8 flops.
///
/// `n_buffers`: Jacobi/Heat/Laplacian sweep in/out planes (2); Gradient reads
/// one plane and writes a derived field (2); none carry coefficient arrays.
///
/// Every preset is the corresponding radius-1 star family member with its
/// exact loop-body flop count and measured `C_iter` pinned as overrides, so
/// the derived characterization is bit-identical to the historical table.
pub const ALL_STENCILS: [Stencil; 6] = [
    preset(StencilId::Jacobi2D, "jacobi2d", Dim::D2, 4.0, 11.0),
    preset(StencilId::Heat2D, "heat2d", Dim::D2, 10.0, 13.0),
    preset(StencilId::Laplacian2D, "laplacian2d", Dim::D2, 6.0, 10.0),
    preset(StencilId::Gradient2D, "gradient2d", Dim::D2, 14.0, 12.0),
    preset(StencilId::Heat3D, "heat3d", Dim::D3, 14.0, 16.0),
    preset(StencilId::Laplacian3D, "laplacian3d", Dim::D3, 8.0, 15.0),
];

/// Const constructor for the preset table: a first-order star with pinned
/// loop-body flops and measured `C_iter`.
const fn preset(
    id: StencilId,
    name: &'static str,
    dim: Dim,
    flops: f64,
    c_iter: f64,
) -> Stencil {
    Stencil {
        id,
        name,
        spec: StencilSpec {
            dim,
            shape: Shape::Star,
            radius: 1,
            n_buffers: 2.0,
            bytes_per_cell: 4.0,
            flops: Some(flops),
            c_iter: Some(c_iter),
        },
        space_dims: match dim {
            Dim::D2 => 2,
            Dim::D3 => 3,
        },
        sigma: 1,
        flops_per_point: flops,
        n_buffers: 2.0,
        bytes_per_cell: 4.0,
        c_iter_cycles: c_iter,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_benchmarks_four_2d_two_3d() {
        assert_eq!(ALL_STENCILS.len(), 6);
        assert_eq!(ALL_STENCILS.iter().filter(|s| s.space_dims == 2).count(), 4);
        assert_eq!(ALL_STENCILS.iter().filter(|s| s.space_dims == 3).count(), 2);
    }

    #[test]
    fn all_first_order_fp32() {
        for s in &ALL_STENCILS {
            assert_eq!(s.sigma, 1, "{}", s.name());
            assert_eq!(s.bytes_per_cell, 4.0, "{}", s.name());
            assert!(s.flops_per_point > 0.0 && s.c_iter_cycles > 0.0);
        }
    }

    #[test]
    fn preset_spec_rederives_the_table() {
        // The pinned spec must reproduce every characterization field —
        // the data-driven path and the const table cannot drift apart.
        for s in &ALL_STENCILS {
            assert_eq!(s.spec.dim.space_dims(), s.space_dims, "{}", s.name());
            assert_eq!(s.spec.radius, s.sigma, "{}", s.name());
            assert_eq!(s.spec.flops_per_point().to_bits(), s.flops_per_point.to_bits());
            assert_eq!(s.spec.c_iter_cycles().to_bits(), s.c_iter_cycles.to_bits());
            assert_eq!(s.spec.n_buffers.to_bits(), s.n_buffers.to_bits());
            assert_eq!(s.spec.bytes_per_cell.to_bits(), s.bytes_per_cell.to_bits());
        }
    }

    #[test]
    fn lookup_roundtrip() {
        for s in &ALL_STENCILS {
            assert_eq!(Stencil::by_name(s.name()).unwrap().id, s.id);
            assert_eq!(StencilId::from_name(s.name()), Some(s.id));
            assert_eq!(Stencil::get(s.id).name(), s.name());
        }
        assert!(Stencil::by_name("bogus").is_none());
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<&str> = ALL_STENCILS.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 6);
    }

    #[test]
    fn parametric_lookup_registers_and_interns() {
        let a = Stencil::by_name("star3d:r2").expect("family name must parse");
        assert_eq!(a.space_dims, 3);
        assert_eq!(a.sigma, 2);
        assert!(a.is_3d());
        assert_eq!(a.flops_per_point, 2.0 * 13.0 - 1.0);
        // Interned: same id on re-lookup, alias and canonical both resolve.
        let b = Stencil::by_name("star3d:r2").unwrap();
        assert_eq!(a.id, b.id);
        assert_eq!(StencilId::from_name("star3d:r2"), Some(a.id));
        assert_eq!(format!("{:?}", a.id), "star3d:r2");
    }

    #[test]
    fn unknown_names_list_presets_and_grammar() {
        let err = Stencil::by_name_err("frobnicate").unwrap_err();
        for needle in
            ["jacobi2d", "laplacian3d", "star|box", "r<1-8>", "fuse:", "frobnicate"]
        {
            assert!(err.contains(needle), "'{err}' should mention '{needle}'");
        }
        // A near-miss family name reports the specific parse failure too.
        let err = Stencil::by_name_err("star3d:r99").unwrap_err();
        assert!(err.contains("radius must be"), "{err}");
        // So does a near-miss chain name.
        let err = Stencil::by_name_err("fuse:heat2d+heat3d:t2").unwrap_err();
        assert!(err.contains("share one dimensionality"), "{err}");
    }

    #[test]
    fn fused_chain_lookup_registers_and_interns() {
        let a = Stencil::by_name("fuse:heat3d+laplacian3d:t2").expect("chain must parse");
        assert_eq!(a.space_dims, 3);
        assert!(a.is_3d());
        assert_eq!(a.sigma, 4, "2 passes × (σ=1 + σ=1)");
        let b = Stencil::by_name("fuse:heat3d+laplacian3d:t2").unwrap();
        assert_eq!(a.id, b.id, "interned under the canonical name");
        assert_eq!(format!("{:?}", a.id), "fuse:heat3d+laplacian3d:t2");
        // The effective spec re-derives the registered characterization,
        // preset-table style.
        assert_eq!(a.spec.radius, a.sigma);
        assert_eq!(a.spec.flops_per_point().to_bits(), a.flops_per_point.to_bits());
        assert_eq!(a.spec.c_iter_cycles().to_bits(), a.c_iter_cycles.to_bits());
        // A non-canonical spelling aliases to the same entry.
        let c = Stencil::by_name("fuse:star3d:r1:f14:c16+laplacian3d:t2").unwrap();
        assert_eq!(a.id, c.id, "preset-equal stage spec canonicalizes to the preset");
    }

    #[test]
    fn preset_ids_are_stable_and_ordered() {
        let ids = [
            StencilId::Jacobi2D,
            StencilId::Heat2D,
            StencilId::Laplacian2D,
            StencilId::Gradient2D,
            StencilId::Heat3D,
            StencilId::Laplacian3D,
        ];
        for (i, id) in ids.iter().enumerate() {
            assert_eq!(Stencil::get(*id).id, *id);
            assert_eq!(ALL_STENCILS[i].id, *id);
        }
        let mut sorted = ids;
        sorted.sort();
        assert_eq!(sorted, ids, "preset order is the historical enum order");
    }
}
