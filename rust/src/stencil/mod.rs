//! The workload layer: stencil characterization (§II) and the SZ size grids
//! (§IV-A).
//!
//! * [`spec`] — parametric stencil families (star/box × 2-D/3-D × radius)
//!   whose characterization is derived analytically;
//! * [`defs`] — the stencil registry: the paper's six presets plus interned
//!   family members, addressed by copyable [`StencilId`]s;
//! * [`workload`] — frequency-weighted sets of (stencil, size) program
//!   instances, the input of the codesign objective (17).

pub mod defs;
pub mod spec;
pub mod workload;

pub use defs::{Stencil, StencilId, ALL_STENCILS};
pub use spec::{Dim, FusedChain, Shape, StencilSpec};
pub use workload::{ProblemSize, Workload, WorkloadEntry};
