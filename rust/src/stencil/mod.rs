//! The paper's benchmark stencils and the workload characterization
//! (§II "Workload characterization", §IV-A's SZ size grids).

pub mod defs;
pub mod workload;

pub use defs::{Stencil, StencilId, ALL_STENCILS};
pub use workload::{ProblemSize, Workload, WorkloadEntry};
