//! Workload characterization (§II, §IV-A): the SZ grids of problem sizes and
//! the frequency-weighted benchmark mix that the codesign objective (17)
//! averages over.
//!
//! Workloads are built over [`StencilId`]s, so any registered stencil —
//! preset or parametric family member — participates on equal footing:
//! [`Workload::single`] and [`Workload::uniform_over`] pick the
//! dimension-appropriate size grid per stencil automatically.

use crate::stencil::defs::{Stencil, StencilId, ALL_STENCILS};

/// Problem-size vector `p` of one program instance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ProblemSize {
    pub s1: u64,
    pub s2: u64,
    /// `None` for 2-D stencils.
    pub s3: Option<u64>,
    pub t: u64,
}

impl ProblemSize {
    pub fn d2(s: u64, t: u64) -> ProblemSize {
        ProblemSize { s1: s, s2: s, s3: None, t }
    }

    pub fn d3(s: u64, t: u64) -> ProblemSize {
        ProblemSize { s1: s, s2: s, s3: Some(s), t }
    }

    /// Total updated points `S1·S2(·S3)·T`.
    pub fn points(&self) -> f64 {
        self.s1 as f64 * self.s2 as f64 * self.s3.unwrap_or(1) as f64 * self.t as f64
    }

    pub fn label(&self) -> String {
        match self.s3 {
            Some(s3) => format!("{}x{}x{}xT{}", self.s1, self.s2, s3, self.t),
            None => format!("{}x{}xT{}", self.s1, self.s2, self.t),
        }
    }
}

/// §IV-A's 2-D grid: `S ∈ {4096, 8192, 12288, 16384}`,
/// `T ∈ {1024, 2048, 4096, 8192, 16384}`, restricted to `T ≤ S`
/// ("no more than S iterations are needed for convergence"); |SZ| = 16.
///
/// (The paper prints 12228, an evident typo for 12288 = 3·4096.)
pub fn sz_2d() -> Vec<ProblemSize> {
    let ss = [4096u64, 8192, 12288, 16384];
    let ts = [1024u64, 2048, 4096, 8192, 16384];
    let mut out = Vec::new();
    for &s in &ss {
        for &t in &ts {
            if t <= s {
                out.push(ProblemSize::d2(s, t));
            }
        }
    }
    out
}

/// 3-D grid. The paper does not print its 3-D SZ set; we use cubes whose
/// *total footprint* spans the same range of working sets as the 2-D grid
/// (256³–512³ fp32 ≈ 64 MB–512 MB) with `T ≤ S`, giving |SZ| = 9 instances.
pub fn sz_3d() -> Vec<ProblemSize> {
    let ss = [256u64, 384, 512];
    let ts = [64u64, 128, 256];
    let mut out = Vec::new();
    for &s in &ss {
        for &t in &ts {
            if t <= s {
                out.push(ProblemSize::d3(s, t));
            }
        }
    }
    out
}

/// One `(stencil, size, frequency)` instance of the workload mix.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadEntry {
    pub stencil: StencilId,
    pub size: ProblemSize,
    /// `fr(c) · fr(c, Sz)` — the combined weight in objective (17).
    pub weight: f64,
}

/// A frequency-weighted set of program instances.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub entries: Vec<WorkloadEntry>,
}

impl Workload {
    /// §V-A's uniform 2-D workload: the four 2-D stencils × the 16 sizes,
    /// all equally likely.
    pub fn uniform_2d() -> Workload {
        Workload::uniform("2d", ALL_STENCILS.iter().filter(|s| !s.is_3d()), &sz_2d())
    }

    /// §V-A's uniform 3-D workload: the two 3-D stencils × the 3-D grid.
    pub fn uniform_3d() -> Workload {
        Workload::uniform("3d", ALL_STENCILS.iter().filter(|s| s.is_3d()), &sz_3d())
    }

    /// A single-benchmark workload over the dimension-appropriate size grid
    /// (Table II's "frequency one for one benchmark, zero elsewhere").
    /// Works for any registered stencil, parametric families included.
    pub fn single(id: StencilId) -> Workload {
        let st = Stencil::get(id);
        let sizes = if st.is_3d() { sz_3d() } else { sz_2d() };
        Workload::uniform(st.name(), std::iter::once(st), &sizes)
    }

    /// A uniform workload over an arbitrary stencil set — e.g. a whole
    /// radius family. Each stencil contributes its dimension-appropriate
    /// size grid (so 2-D and 3-D members can mix); every (stencil, size)
    /// instance is equally likely. An empty id set is an `Err` (this is
    /// reachable from request-assembly code, so no panic).
    pub fn uniform_over(name: &str, ids: &[StencilId]) -> Result<Workload, String> {
        if ids.is_empty() {
            return Err(format!(
                "workload '{name}': uniform_over needs at least one stencil \
                 (got an empty id list)"
            ));
        }
        let grid_2d = sz_2d();
        let grid_3d = sz_3d();
        let mut entries = Vec::new();
        for &id in ids {
            let sizes = if Stencil::get(id).is_3d() { &grid_3d } else { &grid_2d };
            for &size in sizes {
                entries.push(WorkloadEntry { stencil: id, size, weight: 0.0 });
            }
        }
        let w = 1.0 / entries.len() as f64;
        for e in &mut entries {
            e.weight = w;
        }
        Ok(Workload { name: name.to_string(), entries })
    }

    fn uniform<'a>(
        name: &str,
        stencils: impl Iterator<Item = &'a Stencil>,
        sizes: &[ProblemSize],
    ) -> Workload {
        let stencils: Vec<&Stencil> = stencils.collect();
        let n = (stencils.len() * sizes.len()) as f64;
        let entries = stencils
            .iter()
            .flat_map(|s| {
                sizes.iter().map(move |&size| WorkloadEntry {
                    stencil: s.id,
                    size,
                    weight: 1.0 / n,
                })
            })
            .collect();
        Workload { name: name.to_string(), entries }
    }

    /// Re-weight this workload with an arbitrary frequency function — the
    /// "workload sensitivity for free" knob of §V-B. Weights are
    /// re-normalized; entries weighted zero are kept (their memoized results
    /// remain addressable).
    pub fn reweighted(&self, f: impl Fn(&WorkloadEntry) -> f64) -> Workload {
        let raw: Vec<f64> = self.entries.iter().map(&f).collect();
        let total: f64 = raw.iter().sum();
        assert!(total > 0.0, "reweighting zeroed the whole workload");
        Workload {
            name: format!("{}-reweighted", self.name),
            entries: self
                .entries
                .iter()
                .zip(raw)
                .map(|(e, w)| WorkloadEntry { weight: w / total, ..*e })
                .collect(),
        }
    }

    /// Sum of weights (1.0 after construction / reweighting).
    pub fn total_weight(&self) -> f64 {
        self.entries.iter().map(|e| e.weight).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sz_2d_matches_paper_count() {
        let sz = sz_2d();
        assert_eq!(sz.len(), 16, "|SZ| must be 16 (§IV-A)");
        assert!(sz.iter().all(|p| p.t <= p.s1 && p.s3.is_none()));
    }

    #[test]
    fn sz_3d_cubes() {
        let sz = sz_3d();
        assert_eq!(sz.len(), 9);
        assert!(sz.iter().all(|p| p.s3 == Some(p.s1)));
    }

    #[test]
    fn uniform_workloads_normalized() {
        for w in [Workload::uniform_2d(), Workload::uniform_3d()] {
            assert!((w.total_weight() - 1.0).abs() < 1e-9, "{}", w.name);
        }
        assert_eq!(Workload::uniform_2d().entries.len(), 4 * 16);
        assert_eq!(Workload::uniform_3d().entries.len(), 2 * 9);
    }

    #[test]
    fn single_workload_has_one_stencil() {
        let w = Workload::single(StencilId::Heat3D);
        assert!(w.entries.iter().all(|e| e.stencil == StencilId::Heat3D));
        assert_eq!(w.entries.len(), 9);
        assert!((w.total_weight() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn reweighting_targets_one_benchmark() {
        let w = Workload::uniform_2d()
            .reweighted(|e| if e.stencil == StencilId::Jacobi2D { 1.0 } else { 0.0 });
        assert!((w.total_weight() - 1.0).abs() < 1e-9);
        let jac_w: f64 = w
            .entries
            .iter()
            .filter(|e| e.stencil == StencilId::Jacobi2D)
            .map(|e| e.weight)
            .sum();
        assert!((jac_w - 1.0).abs() < 1e-9);
    }

    #[test]
    fn uniform_over_mixes_dimensions_and_families() {
        let star3d_r2 = crate::stencil::spec::StencilSpec::star(
            crate::stencil::spec::Dim::D3,
            2,
        )
        .register();
        let w = Workload::uniform_over("family", &[StencilId::Jacobi2D, star3d_r2]).unwrap();
        assert_eq!(w.entries.len(), 16 + 9, "2-D grid + 3-D grid");
        assert!((w.total_weight() - 1.0).abs() < 1e-9);
        assert!(w
            .entries
            .iter()
            .filter(|e| e.stencil == star3d_r2)
            .all(|e| e.size.s3.is_some()));
    }

    #[test]
    fn uniform_over_empty_set_is_a_clean_error() {
        // Reachable from request assembly, so an Err naming the failing
        // input — not a panic.
        let err = Workload::uniform_over("empty-mix", &[]).unwrap_err();
        assert!(err.contains("empty-mix"), "{err}");
        assert!(err.contains("at least one stencil"), "{err}");
    }

    #[test]
    fn fused_chains_join_workloads_like_presets() {
        let chain =
            crate::stencil::spec::FusedChain::parse("fuse:heat2d+laplacian2d:t4")
                .unwrap()
                .register();
        let w = Workload::single(chain);
        assert_eq!(w.entries.len(), 16, "2-D chain gets the 2-D size grid");
        assert!((w.total_weight() - 1.0).abs() < 1e-9);
        let mixed = Workload::uniform_over("mixed", &[StencilId::Heat2D, chain]).unwrap();
        assert_eq!(mixed.entries.len(), 32);
    }

    #[test]
    fn points_product() {
        assert_eq!(ProblemSize::d2(8, 2).points(), 128.0);
        assert_eq!(ProblemSize::d3(4, 2).points(), 128.0);
    }

    #[test]
    #[should_panic]
    fn reweight_to_zero_panics() {
        Workload::uniform_2d().reweighted(|_| 0.0);
    }
}
