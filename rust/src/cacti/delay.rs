//! Access-time (delay) estimation — the other half of what Cacti gives.
//!
//! The paper configures Cacti with delay objectives ("minimize area, with a
//! secondary objective of minimizing propagation delays", "tailored for
//! speed"); our substitute needs a delay model for one purpose: grounding
//! the time model's shared-memory latency scaling
//! ([`crate::timemodel::MachineSpec::latency_factor_for`], ablated in E12).
//!
//! Model: an optimally-banked SRAM. Unbanked, word/bit-line RC delay grows
//! linearly with the array side (≈ √capacity with distributed-RC partial
//! compensation); splitting into `b` banks cuts the in-bank side by √b but
//! adds an H-tree traversal growing with the chip-side of the bank grid.
//! Balancing the two at the optimal bank count leaves the classic
//! **capacity^(1/4)** envelope — which is exactly the exponent the machine
//! model uses.

use crate::cacti::estimator::MemConfig;
use crate::cacti::tech::TechNode;

/// Delay-model constants for a technology node.
#[derive(Clone, Copy, Debug)]
pub struct DelayModel {
    /// Fixed decode + sense overhead, ns.
    pub t_fixed_ns: f64,
    /// In-bank RC delay per µm of array side, ns/µm.
    pub t_wire_ns_per_um: f64,
    /// H-tree routing delay per µm, ns/µm (repeated wires are faster).
    pub t_htree_ns_per_um: f64,
    /// Per-extra-port delay penalty (longer lines through fatter cells).
    pub port_penalty: f64,
}

impl DelayModel {
    /// TSMC 28 nm-class constants (sub-ns SRAM at small capacities).
    pub fn tsmc28() -> DelayModel {
        DelayModel {
            t_fixed_ns: 0.3,
            t_wire_ns_per_um: 0.004,
            t_htree_ns_per_um: 0.0004,
            port_penalty: 0.12,
        }
    }

    /// Access time of an optimally-banked array, ns.
    pub fn access_ns(&self, tech: &TechNode, cfg: &MemConfig) -> f64 {
        let bits = cfg.data_bits() + cfg.tag_bits();
        let p = cfg.ports.total().max(1) as f64;
        let cell_side_um = tech.bitcell_um2.sqrt() * (1.0 + self.port_penalty * (p - 1.0));
        // Try bank counts 1..=256 (powers of two) and keep the fastest.
        let mut best = f64::INFINITY;
        let mut b = 1.0f64;
        while b <= 256.0 {
            let bank_side_um = (bits / b).sqrt() * cell_side_um;
            let htree_um = (b.sqrt() - 1.0) * bank_side_um * 2.0;
            let t = self.t_fixed_ns
                + self.t_wire_ns_per_um * bank_side_um
                + self.t_htree_ns_per_um * htree_um;
            best = best.min(t);
            b *= 2.0;
        }
        best
    }

    /// Latency of a capacity relative to the 96 kB Maxwell reference, for a
    /// shared-memory-like configuration.
    pub fn shm_relative_latency(&self, tech: &TechNode, capacity_kb: f64) -> f64 {
        let at = |kb: f64| self.access_ns(tech, &MemConfig::shared_memory(kb));
        at(capacity_kb) / at(96.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timemodel::machine::MachineSpec;

    #[test]
    fn delay_grows_with_capacity() {
        let d = DelayModel::tsmc28();
        let t = TechNode::tsmc28();
        let mut last = 0.0;
        for kb in [12.0, 48.0, 96.0, 192.0, 480.0] {
            let a = d.access_ns(&t, &MemConfig::shared_memory(kb));
            assert!(a > last, "not monotone at {kb} kB");
            last = a;
        }
    }

    #[test]
    fn absolute_delays_plausible_for_28nm() {
        // 28 nm SRAMs are sub-ns small, ~1–2 ns at hundreds of kB.
        let d = DelayModel::tsmc28();
        let t = TechNode::tsmc28();
        let small = d.access_ns(&t, &MemConfig::register_file(2.0));
        let big = d.access_ns(&t, &MemConfig::shared_memory(480.0));
        assert!((0.2..0.8).contains(&small), "RF access {small} ns");
        assert!((0.5..4.0).contains(&big), "480 kB access {big} ns");
    }

    #[test]
    fn banked_envelope_matches_machine_latency_exponent() {
        // The machine model scales λ as (M_SM/96)^0.25; the banked delay
        // model must produce the same envelope within ~20% over the design
        // space's M_SM range — this is the E12 assumption's grounding.
        let d = DelayModel::tsmc28();
        let t = TechNode::tsmc28();
        let m = MachineSpec::maxwell();
        for kb in [24.0, 48.0, 192.0, 384.0, 480.0] {
            let from_delay = d.shm_relative_latency(&t, kb);
            let from_machine = m.latency_factor_for(kb) / m.latency_factor_for(96.0);
            let ratio = from_delay / from_machine;
            assert!(
                (0.8..1.25).contains(&ratio),
                "{kb} kB: delay-model rel {from_delay:.3} vs machine rel {from_machine:.3}"
            );
        }
    }

    #[test]
    fn more_ports_slower() {
        let d = DelayModel::tsmc28();
        let t = TechNode::tsmc28();
        let a1 = d.access_ns(&t, &MemConfig::register_file(2.0));
        let a2 = d.access_ns(&t, &MemConfig::l1_cache(2.0));
        assert!(a2 > a1);
    }
}
