//! Cacti-like SRAM / cache silicon-area estimator.
//!
//! The paper (§III-B) calibrates its four memory-area linear models — register
//! file, shared memory, L1 and L2 — by running HP Labs' **Cacti 6.5** over a
//! sweep of bank sizes and fitting `area = β·size + α` per memory type. Cacti
//! is not available in this offline image, so this module implements a
//! simplified analytical estimator with the same *interface* (a memory
//! configuration in, an area estimate out) and the same *usage pattern*
//! (sweep sizes → linear fit → α/β coefficients).
//!
//! The estimator is physically structured (bit cells scaled by a quadratic
//! multi-port growth law, √-shaped row/column periphery, tag arrays and
//! associativity overheads for caches) and its handful of free constants are
//! **calibrated once against the coefficients the paper published from its
//! Cacti runs** (β_R, α_R, β_M, α_M, β_L1, α_L1, β_L2, α_L2) — see
//! [`calibrate`] and DESIGN.md §2 for why this substitution preserves the
//! downstream behaviour (the area model consumes only the fitted
//! coefficients, never raw Cacti output).

pub mod calibrate;
pub mod delay;
pub mod estimator;
pub mod sweep;
pub mod tech;

pub use calibrate::{calibrate_to_paper, CalibrationReport, PAPER_TARGETS};
pub use estimator::{Associativity, MemConfig, MemKind, Ports, SramEstimator};
pub use sweep::{paper_sweeps, MemorySweep, SweepFit};
pub use tech::{Knobs, TechNode};
