//! Calibration of the estimator's free constants against the paper's
//! published Cacti fit coefficients (§III-B).
//!
//! The paper reports, per memory type, the linear model `area = β·kB + α`
//! extracted from Cacti 6.5 sweeps. We treat those eight numbers as ground
//! truth and fit our estimator's eight knobs to reproduce them: a coordinate-
//! descent search in log-space minimizing the summed squared relative error
//! of (β, α) across the four memory types. The intercepts are weighted less
//! than the slopes because the downstream area model (§III-A, eq. 5–6) is
//! dominated by the β terms at realistic capacities.

use crate::cacti::estimator::SramEstimator;
use crate::cacti::sweep::{paper_sweeps, run_sweep};
use crate::cacti::tech::{Knobs, TechNode};

/// The paper's published fit coefficients, in sweep order
/// (register_file, shared_memory, l1_cache, l2_cache): `(β mm²/kB, α mm²)`.
pub const PAPER_TARGETS: [(&str, f64, f64); 4] = [
    ("register_file", 0.004305, 0.001947),
    ("shared_memory", 0.01565, 0.09281),
    ("l1_cache", 0.1604, 0.08204),
    ("l2_cache", 0.04197, 0.7685),
];

/// Outcome of a calibration run.
#[derive(Clone, Debug)]
pub struct CalibrationReport {
    pub knobs: Knobs,
    /// Final objective (weighted sum of squared relative coefficient errors).
    pub objective: f64,
    /// Per-memory-type relative errors in % for (β, α).
    pub errors_pct: Vec<(&'static str, f64, f64)>,
    pub iterations: usize,
}

impl CalibrationReport {
    /// Largest |error| across all eight coefficients, %.
    pub fn worst_error_pct(&self) -> f64 {
        self.errors_pct
            .iter()
            .flat_map(|&(_, b, a)| [b.abs(), a.abs()])
            .fold(0.0, f64::max)
    }

    /// Largest |β error| across the four memory types, %.
    pub fn worst_beta_error_pct(&self) -> f64 {
        self.errors_pct.iter().map(|&(_, b, _)| b.abs()).fold(0.0, f64::max)
    }
}

const SLOPE_WEIGHT: f64 = 1.0;
const INTERCEPT_WEIGHT: f64 = 0.15;

fn objective(knobs: &Knobs) -> f64 {
    let est = SramEstimator::new(TechNode::tsmc28(), *knobs);
    let mut acc = 0.0;
    for (sweep, &(_, beta_t, alpha_t)) in paper_sweeps().iter().zip(PAPER_TARGETS.iter()) {
        let fit = run_sweep(&est, sweep);
        let eb = (fit.beta() - beta_t) / beta_t;
        let ea = (fit.alpha() - alpha_t) / alpha_t;
        acc += SLOPE_WEIGHT * eb * eb + INTERCEPT_WEIGHT * ea * ea;
    }
    acc
}

fn report_for(knobs: Knobs, iterations: usize) -> CalibrationReport {
    let est = SramEstimator::new(TechNode::tsmc28(), knobs);
    let errors: Vec<(&'static str, f64, f64)> = paper_sweeps()
        .iter()
        .zip(PAPER_TARGETS.iter())
        .map(|(sweep, &(name, beta_t, alpha_t))| {
            let fit = run_sweep(&est, sweep);
            (
                name,
                100.0 * (fit.beta() - beta_t) / beta_t,
                100.0 * (fit.alpha() - alpha_t) / alpha_t,
            )
        })
        .collect();
    CalibrationReport { knobs, objective: objective(&knobs), errors_pct: errors, iterations }
}

/// Coordinate descent in log-space from `start`, shrinking the step factor
/// until convergence. Deterministic; ~10⁴ objective evaluations.
pub fn calibrate_to_paper(start: Knobs) -> CalibrationReport {
    let mut x = start.as_vec();
    let mut best = objective(&Knobs::from_vec(&x));
    let mut step = 0.30; // multiplicative step
    let mut iters = 0usize;
    while step > 1e-4 {
        let mut improved = false;
        for dim in 0..x.len() {
            for dir in [1.0 + step, 1.0 / (1.0 + step)] {
                let mut cand = x;
                cand[dim] *= dir;
                // Keep knobs in physically sensible ranges.
                if !knob_ok(dim, cand[dim]) {
                    continue;
                }
                let obj = objective(&Knobs::from_vec(&cand));
                iters += 1;
                if obj < best {
                    best = obj;
                    x = cand;
                    improved = true;
                }
            }
        }
        if !improved {
            step *= 0.5;
        }
    }
    report_for(Knobs::from_vec(&x), iters)
}

fn knob_ok(dim: usize, v: f64) -> bool {
    match dim {
        0 => (0.05..=0.8).contains(&v),   // port_growth
        1 => (1.0..=3.0).contains(&v),    // base_periph
        2 => (1.0..=6.0).contains(&v),    // cache_factor
        3 => (1.0..=4.0).contains(&v),    // fa_factor
        4 => (0.0..=50.0).contains(&v),   // row_cost_um
        5 => (0.0..=500.0).contains(&v),  // col_cost_um2
        6 => (0.0..=1e5).contains(&v),    // fixed_per_port_um2
        7 => (0.0..=1e4).contains(&v),    // fixed_per_bit_width_um2
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_converges_tightly_on_slopes() {
        let rep = calibrate_to_paper(Knobs::initial());
        assert!(
            rep.worst_beta_error_pct() < 5.0,
            "worst β error {}% (errors {:?})",
            rep.worst_beta_error_pct(),
            rep.errors_pct
        );
    }

    #[test]
    fn calibration_intercepts_reasonable() {
        let rep = calibrate_to_paper(Knobs::initial());
        // Intercepts are second-order for the downstream model (they change
        // chip totals by < 1.5 mm² out of ~400 mm²): our periphery law cannot
        // simultaneously match Cacti's four α values, and the calibration
        // deliberately weights slopes over intercepts. Require the right
        // order of magnitude only.
        for &(name, _, ea) in &rep.errors_pct {
            assert!(ea.abs() < 95.0, "{name} α error {ea}%");
        }
    }

    #[test]
    fn stored_defaults_match_fresh_calibration() {
        // `Knobs::tsmc28_calibrated()` must be the converged output of
        // `calibrate_to_paper(Knobs::initial())` (paste-updated when the
        // estimator changes). Tolerate small drift.
        let fresh = calibrate_to_paper(Knobs::initial()).knobs.as_vec();
        let stored = Knobs::tsmc28_calibrated().as_vec();
        for (i, (f, s)) in fresh.iter().zip(stored.iter()).enumerate() {
            let denom = f.abs().max(1e-9);
            assert!(
                ((f - s) / denom).abs() < 0.05,
                "knob {i} drifted: fresh={f} stored={s}"
            );
        }
    }

    #[test]
    fn calibrated_estimator_matches_paper_coefficients() {
        let rep = report_for(Knobs::tsmc28_calibrated(), 0);
        assert!(
            rep.worst_beta_error_pct() < 5.0,
            "stored knobs β error {}%: {:?}",
            rep.worst_beta_error_pct(),
            rep.errors_pct
        );
    }
}
