//! The area estimator proper: one memory configuration in, mm² out.

use crate::cacti::tech::{Knobs, TechNode};

/// Read/write port configuration of a bank.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Ports {
    /// Exclusive read ports.
    pub read: u32,
    /// Exclusive write ports.
    pub write: u32,
    /// Shared read-write ports.
    pub rw: u32,
}

impl Ports {
    pub fn new(read: u32, write: u32, rw: u32) -> Ports {
        Ports { read, write, rw }
    }

    /// Total physical ports (each rw port wires one wordline + bitline pair,
    /// like a single-direction port).
    pub fn total(&self) -> u32 {
        self.read + self.write + self.rw
    }
}

/// Set associativity for cache-type memories.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Associativity {
    DirectMapped,
    SetAssociative(u32),
    Full,
}

/// RAM vs cache organization.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MemKind {
    /// Plain scratchpad / register-file array: no tags.
    Ram,
    /// Cache: adds a tag array (with CAM cells when fully associative),
    /// comparators and line state.
    Cache { line_bytes: u32, assoc: Associativity },
}

/// A memory bank configuration, mirroring the fields one gives Cacti.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemConfig {
    pub capacity_kb: f64,
    pub data_width_bits: u32,
    pub ports: Ports,
    pub kind: MemKind,
}

impl MemConfig {
    /// The paper's register-file config: direct-mapped 'ram', 32-bit bus,
    /// 2 exclusive read + 1 exclusive write ports (§III-B).
    pub fn register_file(capacity_kb: f64) -> MemConfig {
        MemConfig {
            capacity_kb,
            data_width_bits: 32,
            ports: Ports::new(2, 1, 0),
            kind: MemKind::Ram,
        }
    }

    /// The paper's shared-memory config: direct-mapped 'ram', 32-bit bus on
    /// each of 8 read-write ports (§III-B).
    pub fn shared_memory(capacity_kb: f64) -> MemConfig {
        MemConfig {
            capacity_kb,
            data_width_bits: 32,
            ports: Ports::new(0, 0, 8),
            kind: MemKind::Ram,
        }
    }

    /// The paper's L1 config: 'cache', 128-byte lines, fully associative,
    /// 32-bit data width, 8 exclusive read + 8 exclusive write ports.
    pub fn l1_cache(capacity_kb: f64) -> MemConfig {
        MemConfig {
            capacity_kb,
            data_width_bits: 32,
            ports: Ports::new(8, 8, 0),
            kind: MemKind::Cache { line_bytes: 128, assoc: Associativity::Full },
        }
    }

    /// The paper's L2 config: 'cache', 128-byte lines, 256-bit bus on 8
    /// exclusive read ports plus one read-write port upstream.
    pub fn l2_cache(capacity_kb: f64) -> MemConfig {
        MemConfig {
            capacity_kb,
            data_width_bits: 256,
            ports: Ports::new(8, 0, 1),
            kind: MemKind::Cache { line_bytes: 128, assoc: Associativity::SetAssociative(16) },
        }
    }

    /// Data bits stored (excluding tags).
    pub fn data_bits(&self) -> f64 {
        self.capacity_kb * 1024.0 * 8.0
    }

    /// Tag bits for cache organizations (40-bit physical address assumed,
    /// plus valid+dirty state per line).
    pub fn tag_bits(&self) -> f64 {
        match self.kind {
            MemKind::Ram => 0.0,
            MemKind::Cache { line_bytes, .. } => {
                let lines = self.capacity_kb * 1024.0 / line_bytes as f64;
                let tag_width = 40.0 - (line_bytes as f64).log2() + 2.0;
                lines * tag_width
            }
        }
    }
}

/// The estimator: a technology node plus calibrated knobs.
#[derive(Clone, Debug)]
pub struct SramEstimator {
    pub tech: TechNode,
    pub knobs: Knobs,
}

impl SramEstimator {
    /// Estimator at TSMC 28 nm with paper-calibrated knobs — the
    /// configuration every downstream module uses.
    pub fn maxwell() -> SramEstimator {
        SramEstimator { tech: TechNode::tsmc28(), knobs: Knobs::tsmc28_calibrated() }
    }

    pub fn new(tech: TechNode, knobs: Knobs) -> SramEstimator {
        SramEstimator { tech, knobs }
    }

    /// Effective area of one stored bit, µm², after port replication and
    /// organization overheads.
    fn cell_um2(&self, cfg: &MemConfig) -> f64 {
        let k = &self.knobs;
        let p = cfg.ports.total().max(1) as f64;
        let port_factor = {
            let lin = 1.0 + k.port_growth * (p - 1.0);
            lin * lin
        };
        let mut a = self.tech.bitcell_um2 * k.base_periph * port_factor;
        if let MemKind::Cache { assoc, .. } = cfg.kind {
            a *= k.cache_factor;
            if assoc == Associativity::Full {
                a *= k.fa_factor;
            }
        }
        a
    }

    /// Total bank area in mm².
    ///
    /// Structure: data array + tag array (cache) + √-shaped row/column
    /// periphery + fixed per-port and per-bus-bit overheads. The √ terms are
    /// what give the paper's linear fits their positive intercepts.
    pub fn area_mm2(&self, cfg: &MemConfig) -> f64 {
        assert!(cfg.capacity_kb > 0.0, "capacity must be positive");
        let k = &self.knobs;
        let bits = cfg.data_bits() + cfg.tag_bits();
        let cell = self.cell_um2(cfg);
        let array_um2 = bits * cell;

        // Square-ish subarray: rows = cols = sqrt(bits). Periphery rows carry
        // wordline drivers/decoder slices, columns carry sense amps and write
        // drivers; both replicate per port.
        let p = cfg.ports.total().max(1) as f64;
        let side = bits.sqrt();
        let cell_pitch_um = cell.sqrt();
        let row_periph_um2 = k.row_cost_um * side * cell_pitch_um * p;
        let col_periph_um2 = k.col_cost_um2 * side * p;

        let fixed_um2 =
            k.fixed_per_port_um2 * p + k.fixed_per_bit_width_um2 * cfg.data_width_bits as f64;

        (array_um2 + row_periph_um2 + col_periph_um2 + fixed_um2) / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est() -> SramEstimator {
        SramEstimator::maxwell()
    }

    #[test]
    fn area_monotone_in_capacity() {
        let e = est();
        let mut last = 0.0;
        for kb in [0.5, 1.0, 2.0, 4.0, 8.0, 16.0] {
            let a = e.area_mm2(&MemConfig::register_file(kb));
            assert!(a > last, "area not monotone at {kb} kB");
            last = a;
        }
    }

    #[test]
    fn area_monotone_in_ports() {
        let e = est();
        let mut cfg = MemConfig::shared_memory(96.0);
        let a8 = e.area_mm2(&cfg);
        cfg.ports = Ports::new(0, 0, 16);
        let a16 = e.area_mm2(&cfg);
        assert!(a16 > a8 * 1.5, "port scaling too weak: {a8} -> {a16}");
    }

    #[test]
    fn cache_costs_more_than_ram() {
        let e = est();
        let ram = MemConfig {
            capacity_kb: 48.0,
            data_width_bits: 32,
            ports: Ports::new(8, 8, 0),
            kind: MemKind::Ram,
        };
        let cache = MemConfig::l1_cache(48.0);
        assert!(e.area_mm2(&cache) > e.area_mm2(&ram));
    }

    #[test]
    fn fully_associative_costs_more_than_set_assoc() {
        let e = est();
        let mut fa = MemConfig::l1_cache(48.0);
        let area_fa = e.area_mm2(&fa);
        fa.kind = MemKind::Cache { line_bytes: 128, assoc: Associativity::SetAssociative(8) };
        let area_sa = e.area_mm2(&fa);
        assert!(area_fa > area_sa);
    }

    #[test]
    fn tag_bits_zero_for_ram() {
        assert_eq!(MemConfig::register_file(1.0).tag_bits(), 0.0);
        assert!(MemConfig::l1_cache(48.0).tag_bits() > 0.0);
    }

    #[test]
    fn ports_total() {
        assert_eq!(Ports::new(2, 1, 0).total(), 3);
        assert_eq!(Ports::new(8, 0, 1).total(), 9);
    }

    #[test]
    fn bigger_node_bigger_area() {
        let small = SramEstimator::new(TechNode::tsmc28(), Knobs::tsmc28_calibrated());
        let big = SramEstimator::new(
            TechNode::tsmc28().shrunk(2.0, "fat"),
            Knobs::tsmc28_calibrated(),
        );
        let cfg = MemConfig::shared_memory(96.0);
        assert!(big.area_mm2(&cfg) > small.area_mm2(&cfg));
    }
}
