//! The paper's §III-B sweep-and-fit procedure: estimate bank areas over the
//! exact size grids the paper fed Cacti, then least-squares a linear model
//! per memory type. These fits are the only thing the downstream area model
//! consumes (Fig 2).

use crate::cacti::estimator::{MemConfig, SramEstimator};
use crate::util::regression::{linear_fit, LinearFit};

/// One memory type's sweep definition.
#[derive(Clone, Debug)]
pub struct MemorySweep {
    pub name: &'static str,
    /// Sizes in kB, exactly as listed in §III-B.
    pub sizes_kb: Vec<f64>,
    /// Builds the Cacti-equivalent configuration for a given size.
    pub config: fn(f64) -> MemConfig,
}

/// Result of sweeping one memory type and fitting the linear model.
#[derive(Clone, Debug)]
pub struct SweepFit {
    pub name: &'static str,
    pub sizes_kb: Vec<f64>,
    pub areas_mm2: Vec<f64>,
    pub fit: LinearFit,
}

impl SweepFit {
    /// β (mm²/kB).
    pub fn beta(&self) -> f64 {
        self.fit.slope
    }

    /// α (mm²).
    pub fn alpha(&self) -> f64 {
        self.fit.intercept
    }
}

/// The four sweeps of §III-B with the paper's exact size points.
pub fn paper_sweeps() -> Vec<MemorySweep> {
    vec![
        MemorySweep {
            name: "register_file",
            // "per vector-unit register file banks of 512, 1024, 2048, 4096
            // and 8192 bytes each"
            sizes_kb: vec![0.5, 1.0, 2.0, 4.0, 8.0],
            config: MemConfig::register_file,
        },
        MemorySweep {
            name: "shared_memory",
            // "per SM shared memory banks of 24, 48, 96, 192 and 384 kB"
            sizes_kb: vec![24.0, 48.0, 96.0, 192.0, 384.0],
            config: MemConfig::shared_memory,
        },
        MemorySweep {
            name: "l1_cache",
            // "per SM-pair sizes of 3, 6, 12, 24, 48 and 96 kB"
            sizes_kb: vec![3.0, 6.0, 12.0, 24.0, 48.0, 96.0],
            config: MemConfig::l1_cache,
        },
        MemorySweep {
            name: "l2_cache",
            // "per SM sizes of 32, 64, 128, 256 and 512 kB"
            sizes_kb: vec![32.0, 64.0, 128.0, 256.0, 512.0],
            config: MemConfig::l2_cache,
        },
    ]
}

/// Run one sweep through the estimator and fit the linear model.
pub fn run_sweep(est: &SramEstimator, sweep: &MemorySweep) -> SweepFit {
    let areas: Vec<f64> = sweep.sizes_kb.iter().map(|&kb| est.area_mm2(&(sweep.config)(kb))).collect();
    let fit = linear_fit(&sweep.sizes_kb, &areas);
    SweepFit { name: sweep.name, sizes_kb: sweep.sizes_kb.clone(), areas_mm2: areas, fit }
}

/// Run all four paper sweeps.
pub fn run_paper_sweeps(est: &SramEstimator) -> Vec<SweepFit> {
    paper_sweeps().iter().map(|s| run_sweep(est, s)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweeps_have_paper_grids() {
        let s = paper_sweeps();
        assert_eq!(s.len(), 4);
        assert_eq!(s[0].sizes_kb, vec![0.5, 1.0, 2.0, 4.0, 8.0]);
        assert_eq!(s[2].sizes_kb.len(), 6);
    }

    #[test]
    fn fits_are_near_linear() {
        let est = SramEstimator::maxwell();
        for fit in run_paper_sweeps(&est) {
            assert!(fit.fit.r2 > 0.99, "{}: r2={}", fit.name, fit.fit.r2);
            assert!(fit.beta() > 0.0 && fit.alpha() > 0.0, "{}", fit.name);
        }
    }

    #[test]
    fn l1_slope_much_steeper_than_shared_memory() {
        // 16-ported fully-associative cache bits are far more expensive than
        // 8-ported scratchpad bits — the structural fact behind the paper's
        // "delete the caches" conclusion.
        let est = SramEstimator::maxwell();
        let fits = run_paper_sweeps(&est);
        let shm = fits.iter().find(|f| f.name == "shared_memory").unwrap();
        let l1 = fits.iter().find(|f| f.name == "l1_cache").unwrap();
        assert!(l1.beta() > 5.0 * shm.beta());
    }
}
