//! Save / load / inspect for sweep artifacts.
//!
//! `save` serializes every non-empty session partition to one shard plus a
//! manifest; `load` is all-or-nothing — every shard is read, checksummed and
//! fully decoded **before** the first cache slot is written, so a failed load
//! leaves the receiving session untouched (the corruption test matrix holds
//! this as a property over cache statistics); `inspect` verifies integrity
//! without decoding payloads.

use crate::artifact::manifest::{Manifest, ShardMeta, ARTIFACT_SCHEMA_VERSION, MANIFEST_FILE};
use crate::artifact::payload::{
    characterization_from_json, characterization_to_json, entry_from_json, entry_to_json,
    hex64, hex64_parse, key_from_json, key_to_json, Characterization,
};
use crate::artifact::ArtifactError;
use crate::coordinator::cache::{CacheEntry, CacheKey};
use crate::opt::problem::SolveOpts;
use crate::platform::spec::PlatformSpec;
use crate::service::session::Session;
use crate::service::wire;
use crate::timemodel::citer::CIterTable;
use crate::util::fnv::fnv64;
use crate::util::json::{parse, Json};
use std::collections::BTreeSet;
use std::path::Path;

/// What [`load`] installed.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadReport {
    /// Shards validated and absorbed.
    pub shards: usize,
    /// Cache slots actually installed (existing slots are never downgraded,
    /// so a warm session absorbing an older artifact may install fewer).
    pub entries_installed: usize,
    /// `Exact` entries carried by the artifact.
    pub exact_entries: usize,
    /// `BoundedOut` entries carried by the artifact.
    pub bounded_entries: usize,
}

/// What [`inspect`] verified: the parsed manifest after every shard's byte
/// length and checksum have been re-checked against disk.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub artifact_schema: u64,
    pub wire_schema: u64,
    pub shards: Vec<ShardMeta>,
}

impl ArtifactInfo {
    pub fn total_entries(&self) -> u64 {
        self.shards.iter().map(|s| s.exact_entries + s.bounded_entries).sum()
    }
}

fn io_err(path: &Path, e: std::io::Error) -> ArtifactError {
    ArtifactError::Io { path: path.display().to_string(), detail: e.to_string() }
}

// ---------------------------------------------------------------------------
// Save
// ---------------------------------------------------------------------------

/// Serialize every non-empty partition of `session` into `dir` (created if
/// missing), returning the manifest that was written. Deterministic: saving
/// the same session state twice produces byte-identical files, and so does
/// saving a session that was itself warm-started from this artifact.
pub fn save(session: &Session, dir: &Path) -> Result<Manifest, ArtifactError> {
    std::fs::create_dir_all(dir).map_err(|e| io_err(dir, e))?;
    let mut shards: Vec<ShardMeta> = Vec::new();
    for snap in session.partition_snapshots() {
        if snap.entries.is_empty() {
            continue;
        }
        let platform_fp = snap.platform.fingerprint();
        let citer_json = wire::citer_to_json(&snap.citer);
        let solve_json = wire::solve_opts_to_json(&snap.opts);
        // The file name pins the partition identity: platform fingerprint
        // plus a digest of its (C_iter, SolveOpts) provenance, so a fleet
        // can pick shards by name without opening them.
        let digest = fnv64(
            Json::Arr(vec![citer_json.clone(), solve_json.clone()])
                .to_string_compact()
                .as_bytes(),
        );
        let file = format!("shard-{}-{}.json", hex64(platform_fp), hex64(digest));

        let mut characterizations: BTreeSet<Characterization> = BTreeSet::new();
        let mut exact_entries = 0u64;
        let mut bounded_entries = 0u64;
        for (key, entry) in &snap.entries {
            characterizations.insert(Characterization::of_key(key));
            match entry {
                CacheEntry::Exact(_) => exact_entries += 1,
                CacheEntry::BoundedOut { .. } => bounded_entries += 1,
            }
        }
        let body = Json::obj(vec![
            ("artifact_schema", Json::Num(ARTIFACT_SCHEMA_VERSION as f64)),
            ("wire_schema", Json::Num(wire::SCHEMA_VERSION as f64)),
            ("platform", Json::str(snap.platform.canonical_name())),
            ("platform_fp", Json::str(hex64(platform_fp))),
            ("solve", solve_json),
            ("citer", citer_json),
            (
                "characterizations",
                Json::Arr(characterizations.iter().map(characterization_to_json).collect()),
            ),
            (
                "entries",
                Json::Arr(
                    snap.entries
                        .iter()
                        .map(|(k, e)| {
                            Json::obj(vec![("key", key_to_json(k)), ("entry", entry_to_json(e))])
                        })
                        .collect(),
                ),
            ),
        ]);
        let bytes = body.to_string_compact().into_bytes();
        let path = dir.join(&file);
        std::fs::write(&path, &bytes).map_err(|e| io_err(&path, e))?;
        shards.push(ShardMeta {
            file,
            bytes: bytes.len() as u64,
            checksum: fnv64(&bytes),
            platform: snap.platform.canonical_name(),
            platform_fp,
            prune: snap.opts.prune,
            exact_entries,
            bounded_entries,
        });
    }
    shards.sort_by(|a, b| a.file.cmp(&b.file));
    let manifest = Manifest {
        artifact_schema: ARTIFACT_SCHEMA_VERSION,
        wire_schema: wire::SCHEMA_VERSION,
        shards,
    };
    let path = dir.join(MANIFEST_FILE);
    std::fs::write(&path, manifest.to_json().to_string_pretty())
        .map_err(|e| io_err(&path, e))?;
    Ok(manifest)
}

// ---------------------------------------------------------------------------
// Load
// ---------------------------------------------------------------------------

/// One fully validated, fully decoded shard, ready to absorb — the exact
/// provenance triple plus entry payload [`Session::absorb_partition`] takes.
/// Public so consumers that hold *several* sessions (the serve daemon keeps
/// one per partition key) can route each shard to the right one instead of
/// funnelling everything through a single [`load`] target.
pub struct DecodedPartition {
    pub platform: PlatformSpec,
    pub citer: CIterTable,
    pub opts: SolveOpts,
    pub entries: Vec<(CacheKey, CacheEntry)>,
}

fn read_manifest(dir: &Path) -> Result<Manifest, ArtifactError> {
    let path = dir.join(MANIFEST_FILE);
    let text = std::fs::read_to_string(&path).map_err(|e| io_err(&path, e))?;
    let json = parse(&text).map_err(|e| ArtifactError::BadManifest {
        path: path.display().to_string(),
        detail: format!("{e:?}"),
    })?;
    let manifest = Manifest::from_json(&json, &path.display().to_string())?;
    if manifest.artifact_schema != ARTIFACT_SCHEMA_VERSION {
        return Err(ArtifactError::SchemaMismatch {
            found: manifest.artifact_schema,
            supported: ARTIFACT_SCHEMA_VERSION,
        });
    }
    if manifest.wire_schema < wire::MIN_SCHEMA_VERSION
        || manifest.wire_schema > wire::SCHEMA_VERSION
    {
        return Err(ArtifactError::WireSchemaMismatch {
            found: manifest.wire_schema,
            min: wire::MIN_SCHEMA_VERSION,
            max: wire::SCHEMA_VERSION,
        });
    }
    Ok(manifest)
}

/// Read one shard's bytes and check them against the manifest record.
fn read_shard_bytes(dir: &Path, meta: &ShardMeta) -> Result<Vec<u8>, ArtifactError> {
    let path = dir.join(&meta.file);
    let bytes = std::fs::read(&path).map_err(|e| io_err(&path, e))?;
    if bytes.len() as u64 != meta.bytes {
        return Err(ArtifactError::TruncatedShard {
            file: meta.file.clone(),
            manifest_bytes: meta.bytes,
            actual_bytes: bytes.len() as u64,
        });
    }
    let actual = fnv64(&bytes);
    if actual != meta.checksum {
        return Err(ArtifactError::ChecksumMismatch {
            file: meta.file.clone(),
            manifest_checksum: meta.checksum,
            actual_checksum: actual,
        });
    }
    Ok(bytes)
}

/// Validate and decode one shard against its manifest record. Pure: no
/// session state is touched.
fn decode_shard(dir: &Path, meta: &ShardMeta) -> Result<DecodedPartition, ArtifactError> {
    let bad = |detail: String| ArtifactError::BadShard { file: meta.file.clone(), detail };
    let bytes = read_shard_bytes(dir, meta)?;
    let text = String::from_utf8(bytes).map_err(|e| bad(e.to_string()))?;
    let json = parse(&text).map_err(|e| bad(format!("{e:?}")))?;

    let num = |key: &str| -> Result<u64, ArtifactError> {
        match json.get(key) {
            Some(Json::Num(x)) if x.is_finite() && *x >= 0.0 && x.fract() == 0.0 => {
                Ok(*x as u64)
            }
            _ => Err(bad(format!("missing integer field '{key}'"))),
        }
    };
    let string = |key: &str| -> Result<&str, ArtifactError> {
        json.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| bad(format!("missing string field '{key}'")))
    };

    // Schema gates first: an incompatible shard must not be interpreted.
    let artifact_schema = num("artifact_schema")?;
    if artifact_schema != ARTIFACT_SCHEMA_VERSION {
        return Err(ArtifactError::SchemaMismatch {
            found: artifact_schema,
            supported: ARTIFACT_SCHEMA_VERSION,
        });
    }
    let wire_schema = num("wire_schema")?;
    if wire_schema < wire::MIN_SCHEMA_VERSION || wire_schema > wire::SCHEMA_VERSION {
        return Err(ArtifactError::WireSchemaMismatch {
            found: wire_schema,
            min: wire::MIN_SCHEMA_VERSION,
            max: wire::SCHEMA_VERSION,
        });
    }

    // Manifest-vs-shard provenance: both copies were written at save time,
    // so any disagreement means one of them was edited afterwards.
    let platform_name = string("platform")?;
    if platform_name != meta.platform {
        return Err(ArtifactError::ManifestShardMismatch {
            file: meta.file.clone(),
            field: "platform",
            manifest: meta.platform.clone(),
            shard: platform_name.to_string(),
        });
    }
    let shard_fp = hex64_parse(string("platform_fp")?, "platform_fp").map_err(&bad)?;
    if shard_fp != meta.platform_fp {
        return Err(ArtifactError::ManifestShardMismatch {
            file: meta.file.clone(),
            field: "platform_fp",
            manifest: hex64(meta.platform_fp),
            shard: hex64(shard_fp),
        });
    }
    let opts = wire::solve_opts_from_json(
        json.get("solve").ok_or_else(|| bad("missing field 'solve'".into()))?,
    )
    .map_err(|e| bad(format!("bad solver options: {e:#}")))?;
    if opts.prune != meta.prune {
        return Err(ArtifactError::PruneMismatch {
            file: meta.file.clone(),
            manifest_prune: meta.prune,
            shard_prune: opts.prune,
        });
    }

    // Staleness: the named platform must fingerprint today to the value the
    // keys were minted under, else the cached solutions describe a model
    // this build doesn't run.
    let platform = PlatformSpec::parse(platform_name).map_err(|e| {
        ArtifactError::BadManifest {
            path: meta.file.clone(),
            detail: format!("unparsable platform '{platform_name}': {e}"),
        }
    })?;
    let current = platform.fingerprint();
    if current != meta.platform_fp {
        return Err(ArtifactError::StaleFingerprint {
            platform: platform_name.to_string(),
            recorded: meta.platform_fp,
            current,
        });
    }

    let citer = wire::citer_from_json(
        json.get("citer").ok_or_else(|| bad("missing field 'citer'".into()))?,
    )
    .map_err(|e| bad(format!("bad C_iter table: {e:#}")))?;

    let declared: BTreeSet<Characterization> = match json.get("characterizations") {
        Some(Json::Arr(items)) => items
            .iter()
            .map(|j| characterization_from_json(j).map_err(&bad))
            .collect::<Result<_, _>>()?,
        _ => return Err(bad("missing array field 'characterizations'".into())),
    };

    let entry_items = match json.get("entries") {
        Some(Json::Arr(items)) => items,
        _ => return Err(bad("missing array field 'entries'".into())),
    };
    let mut entries = Vec::with_capacity(entry_items.len());
    let (mut exact, mut bounded) = (0u64, 0u64);
    for item in entry_items {
        let key = key_from_json(
            item.get("key").ok_or_else(|| bad("entry record missing 'key'".into()))?,
            meta.platform_fp,
        )
        .map_err(&bad)?;
        let entry = entry_from_json(
            item.get("entry").ok_or_else(|| bad("entry record missing 'entry'".into()))?,
        )
        .map_err(&bad)?;
        if !declared.contains(&Characterization::of_key(&key)) {
            return Err(ArtifactError::CharacterizationMismatch {
                file: meta.file.clone(),
                detail: format!(
                    "entry key (dims={}, sigma={}, s=({},{},{}), t={}) uses a stencil \
                     characterization outside the shard's declared set",
                    key.space_dims, key.sigma, key.s1, key.s2, key.s3, key.t
                ),
            });
        }
        match entry {
            CacheEntry::Exact(_) => exact += 1,
            CacheEntry::BoundedOut { .. } => bounded += 1,
        }
        entries.push((key, entry));
    }
    // The manifest's entry counts are informational but must still agree —
    // an edited count is provenance skew like any other.
    if exact != meta.exact_entries {
        return Err(ArtifactError::ManifestShardMismatch {
            file: meta.file.clone(),
            field: "exact_entries",
            manifest: meta.exact_entries.to_string(),
            shard: exact.to_string(),
        });
    }
    if bounded != meta.bounded_entries {
        return Err(ArtifactError::ManifestShardMismatch {
            file: meta.file.clone(),
            field: "bounded_entries",
            manifest: meta.bounded_entries.to_string(),
            shard: bounded.to_string(),
        });
    }
    Ok(DecodedPartition { platform, citer, opts, entries })
}

/// Read, checksum and fully decode every shard of the artifact in `dir`,
/// without touching any session. This is [`load`]'s validation front half,
/// exposed so a multi-session consumer (the serve daemon) can absorb each
/// partition into its own session; all integrity and staleness gates of the
/// refuse-to-alias contract run here — only the receiving-session provenance
/// checks remain for the caller's absorb step.
pub fn load_partitions(dir: &Path) -> Result<Vec<DecodedPartition>, ArtifactError> {
    let manifest = read_manifest(dir)?;
    let mut decoded = Vec::with_capacity(manifest.shards.len());
    for meta in &manifest.shards {
        decoded.push(decode_shard(dir, meta)?);
    }
    Ok(decoded)
}

/// Warm-start `session` from the artifact in `dir`.
///
/// All-or-nothing: every shard is read, checksummed and fully decoded before
/// anything is absorbed, and absorption itself validates each partition's
/// provenance against the receiving coordinator before mutating it — so on
/// `Err`, the session's caches and their statistics are exactly as before.
pub fn load(session: &mut Session, dir: &Path) -> Result<LoadReport, ArtifactError> {
    let decoded = load_partitions(dir)?;
    let mut report = LoadReport::default();
    for shard in &decoded {
        report.exact_entries +=
            shard.entries.iter().filter(|(_, e)| matches!(e, CacheEntry::Exact(_))).count();
        report.bounded_entries += shard
            .entries
            .iter()
            .filter(|(_, e)| matches!(e, CacheEntry::BoundedOut { .. }))
            .count();
    }
    // Dry-run the partition provenance checks against the session before any
    // absorb mutates it: a conflict on shard k must not leave shards 0..k
    // installed.
    for shard in &decoded {
        session
            .check_partition(&shard.platform, &shard.citer, &shard.opts)
            .map_err(|e| ArtifactError::PartitionConflict { detail: format!("{e:#}") })?;
    }
    for shard in decoded {
        let installed = session
            .absorb_partition(&shard.platform, &shard.citer, &shard.opts, &shard.entries)
            .map_err(|e| ArtifactError::PartitionConflict { detail: format!("{e:#}") })?;
        report.entries_installed += installed;
        report.shards += 1;
    }
    Ok(report)
}

// ---------------------------------------------------------------------------
// Inspect
// ---------------------------------------------------------------------------

/// Parse the manifest and re-verify every shard's byte length and checksum
/// against disk, without decoding payloads or touching any session.
pub fn inspect(dir: &Path) -> Result<ArtifactInfo, ArtifactError> {
    let manifest = read_manifest(dir)?;
    for meta in &manifest.shards {
        read_shard_bytes(dir, meta)?;
    }
    Ok(ArtifactInfo {
        artifact_schema: manifest.artifact_schema,
        wire_schema: manifest.wire_schema,
        shards: manifest.shards,
    })
}
