//! Persisted, sharded, checksummed sweep artifacts.
//!
//! Every cold process re-pays the full hardware sweep before it can answer a
//! single scenario, even though the memo store is already deduplicated,
//! fingerprinted and prune-partitioned — exactly the provenance needed to
//! persist it safely. This module serializes a [`Session`]'s memoized sweep
//! state to a versioned on-disk artifact and loads it back **certified
//! bit-identical**: a warm-started session produces the same points, fronts,
//! tune winners and telemetry-visible results as a cold recompute
//! (`integration_artifact.rs` certifies against the shipped request files).
//!
//! # Layout
//!
//! ```text
//! <dir>/
//!   manifest.json                      schema + per-shard integrity/provenance
//!   shard-<fp16>-<digest16>.json       one payload per session partition
//! ```
//!
//! One shard per session partition — a `(platform fingerprint, C_iter table,
//! solver options)` triple — named by the platform fingerprint and a digest
//! of the partition's `(C_iter, SolveOpts)` provenance, so a fleet can load
//! only the shards a request mixture needs. The manifest carries, per shard:
//! file name, byte length, FNV-1a checksum over the file bytes
//! ([`util::fnv`](crate::util::fnv)), platform canonical name + recorded
//! fingerprint, the prune partition flag, and entry counts. Each shard
//! repeats its own provenance header (platform, fingerprint, `C_iter`,
//! solver options, the stencil characterization set its keys draw from) plus
//! the entry payload in deterministic key order — floats ride the wire
//! format's shortest-round-trip JSON path, with `-0.0` and non-finite values
//! escaping to explicit bit literals ([`payload`]), so save→load→save is
//! **byte-identical**.
//!
//! # The refuse-to-alias contract
//!
//! A load either installs every validated entry or touches nothing: all
//! shards are read, checksummed and fully decoded **before** the first cache
//! slot is written, so a failed load provably leaves session cache statistics
//! unchanged. Every staleness or corruption mode is a distinct
//! [`ArtifactError`] naming the mismatched field:
//!
//! * unsupported artifact schema version → [`ArtifactError::SchemaMismatch`]
//! * wire-schema skew → [`ArtifactError::WireSchemaMismatch`]
//! * shorter/longer file than the manifest recorded →
//!   [`ArtifactError::TruncatedShard`]
//! * any byte flip (same length) → [`ArtifactError::ChecksumMismatch`]
//! * an edited manifest field that no longer matches the shard's own header
//!   → [`ArtifactError::ManifestShardMismatch`] (the `prune` flag gets its
//!   own [`ArtifactError::PruneMismatch`] — mixing prune partitions is the
//!   one staleness mode the live engine also guards against)
//! * a recorded platform fingerprint that no longer matches the named
//!   platform's current fingerprint → [`ArtifactError::StaleFingerprint`]
//! * a key whose characterization is outside the shard's declared set →
//!   [`ArtifactError::CharacterizationMismatch`]
//!
//! Never a silent partial load.
//!
//! [`Session`]: crate::service::Session

pub mod manifest;
pub mod payload;
pub mod store;

pub use manifest::{Manifest, ShardMeta, ARTIFACT_SCHEMA_VERSION, MANIFEST_FILE};
pub use store::{
    inspect, load, load_partitions, save, ArtifactInfo, DecodedPartition, LoadReport,
};

/// Everything that can go wrong saving, inspecting or loading an artifact.
/// Load-side variants are deliberately fine-grained: the corruption test
/// matrix asserts each staleness mode maps to its own variant, and the
/// Display text names the mismatched field so an operator can see *what*
/// diverged, not just that something did.
#[derive(Debug)]
pub enum ArtifactError {
    /// Filesystem trouble (missing directory, unreadable file, write
    /// failure).
    Io { path: String, detail: String },
    /// The manifest is unparsable or structurally invalid; `detail` names
    /// the offending field.
    BadManifest { path: String, detail: String },
    /// The artifact schema version is not one this build writes.
    SchemaMismatch { found: u64, supported: u64 },
    /// The artifact was written under a wire schema this build does not
    /// speak (f64 formatting and codec semantics ride the wire contract).
    WireSchemaMismatch { found: u64, min: u64, max: u64 },
    /// Shard file length differs from the manifest record — a truncated
    /// (or padded) payload.
    TruncatedShard { file: String, manifest_bytes: u64, actual_bytes: u64 },
    /// Shard bytes hash differently than the manifest recorded.
    ChecksumMismatch { file: String, manifest_checksum: u64, actual_checksum: u64 },
    /// A manifest field contradicts the shard's own provenance header —
    /// one of the two was edited after save.
    ManifestShardMismatch { file: String, field: &'static str, manifest: String, shard: String },
    /// The named platform's *current* fingerprint no longer matches the one
    /// the artifact was saved under: the platform definition has changed,
    /// so the cached solutions belong to a model this process doesn't run.
    StaleFingerprint { platform: String, recorded: u64, current: u64 },
    /// The manifest and shard disagree on the prune partition — pruned and
    /// unpruned sweeps may never share a store.
    PruneMismatch { file: String, manifest_prune: bool, shard_prune: bool },
    /// The shard payload is unparsable or structurally invalid.
    BadShard { file: String, detail: String },
    /// An entry key's stencil characterization is not in the shard's
    /// declared characterization set.
    CharacterizationMismatch { file: String, detail: String },
    /// The receiving session refused a partition (e.g. its coordinator was
    /// already populated under different provenance).
    PartitionConflict { detail: String },
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::Io { path, detail } => {
                write!(f, "artifact I/O error at '{path}': {detail}")
            }
            ArtifactError::BadManifest { path, detail } => {
                write!(f, "bad artifact manifest '{path}': {detail}")
            }
            ArtifactError::SchemaMismatch { found, supported } => write!(
                f,
                "unsupported artifact schema version {found} (this build speaks {supported})"
            ),
            ArtifactError::WireSchemaMismatch { found, min, max } => write!(
                f,
                "artifact wire schema {found} outside this build's supported range {min}..={max}"
            ),
            ArtifactError::TruncatedShard { file, manifest_bytes, actual_bytes } => write!(
                f,
                "shard '{file}' is {actual_bytes} bytes but the manifest recorded \
                 {manifest_bytes} (truncated or padded payload)"
            ),
            ArtifactError::ChecksumMismatch { file, manifest_checksum, actual_checksum } => {
                write!(
                    f,
                    "shard '{file}' checksum mismatch: manifest recorded \
                     {manifest_checksum:016x}, file hashes to {actual_checksum:016x}"
                )
            }
            ArtifactError::ManifestShardMismatch { file, field, manifest, shard } => write!(
                f,
                "manifest/shard provenance mismatch on field '{field}' for '{file}': \
                 manifest says '{manifest}', shard says '{shard}'"
            ),
            ArtifactError::StaleFingerprint { platform, recorded, current } => write!(
                f,
                "stale platform fingerprint for '{platform}': artifact was saved under \
                 {recorded:016x} but the platform now fingerprints to {current:016x} — \
                 refusing to alias cached solutions across model definitions"
            ),
            ArtifactError::PruneMismatch { file, manifest_prune, shard_prune } => write!(
                f,
                "prune partition mismatch for '{file}': manifest field 'prune' says \
                 {manifest_prune}, shard solver options say {shard_prune} — pruned and \
                 unpruned sweeps may never share a store"
            ),
            ArtifactError::BadShard { file, detail } => {
                write!(f, "bad artifact shard '{file}': {detail}")
            }
            ArtifactError::CharacterizationMismatch { file, detail } => write!(
                f,
                "characterization mismatch in shard '{file}': {detail}"
            ),
            ArtifactError::PartitionConflict { detail } => {
                write!(f, "artifact partition conflict: {detail}")
            }
        }
    }
}

impl std::error::Error for ArtifactError {}
