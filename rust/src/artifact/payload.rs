//! Bit-exact JSON codecs for persisted cache entries.
//!
//! The artifact payload must satisfy a stronger contract than the wire
//! format: **every** `f64` bit pattern round-trips, including `-0.0`
//! (whose shortest decimal repr `0` would decode to `+0.0`) and non-finite
//! values (which the wire maps to `null`/NaN, erasing NaN payloads). The
//! codec here rides the same shortest-round-trip path as the wire for the
//! common case — a finite, non-negative-zero value is a plain JSON number,
//! written with Rust's shortest representation and re-parsed by the strict
//! correctly-rounding `str::parse::<f64>` — and escapes everything else to
//! an explicit `"bits:<16 hex digits>"` literal. Unsigned 64-bit fields
//! that could exceed 2^53 (where `f64` stops being exact) escape to
//! `"u64:<decimal>"` the same way.

use crate::coordinator::cache::{CacheEntry, CacheKey};
use crate::opt::inner::InnerSolution;
use crate::timemodel::talg::{Bound, SoftwareParams, TimeEstimate};
use crate::timemodel::tiling::TileSizes;
use crate::util::json::Json;

/// Encode an `f64` preserving its exact bit pattern: finite non-negative-zero
/// values as plain numbers (shortest-repr round-trip), everything else —
/// `-0.0`, infinities, any NaN payload — as a `"bits:…"` literal.
pub fn exact_f64_to_json(x: f64) -> Json {
    if x.is_finite() && !(x == 0.0 && x.is_sign_negative()) {
        Json::Num(x)
    } else {
        Json::Str(format!("bits:{:016x}", x.to_bits()))
    }
}

/// Decode [`exact_f64_to_json`]. `what` names the field in error messages.
pub fn exact_f64_from_json(j: &Json, what: &str) -> Result<f64, String> {
    match j {
        Json::Num(x) => Ok(*x),
        Json::Str(s) => match s.strip_prefix("bits:") {
            Some(hex) if hex.len() == 16 => u64::from_str_radix(hex, 16)
                .map(f64::from_bits)
                .map_err(|_| format!("field '{what}': bad f64 bits literal '{s}'")),
            _ => Err(format!("field '{what}': expected a number or 'bits:<16 hex>' literal")),
        },
        _ => Err(format!("field '{what}' must be a number or bits literal")),
    }
}

/// Encode a `u64` exactly: values `f64` can carry losslessly as plain
/// numbers, larger ones as a `"u64:…"` decimal literal.
pub fn exact_u64_to_json(x: u64) -> Json {
    if x < (1u64 << 53) {
        Json::Num(x as f64)
    } else {
        Json::Str(format!("u64:{x}"))
    }
}

/// Decode [`exact_u64_to_json`].
pub fn exact_u64_from_json(j: &Json, what: &str) -> Result<u64, String> {
    match j {
        Json::Num(x) => {
            if x.is_finite() && *x >= 0.0 && x.fract() == 0.0 && *x < (1u64 << 53) as f64 {
                Ok(*x as u64)
            } else {
                Err(format!("field '{what}': {x} is not an exactly-representable u64"))
            }
        }
        Json::Str(s) => match s.strip_prefix("u64:") {
            Some(dec) => dec
                .parse::<u64>()
                .map_err(|_| format!("field '{what}': bad u64 literal '{s}'")),
            None => Err(format!("field '{what}': expected a number or 'u64:<decimal>' literal")),
        },
        _ => Err(format!("field '{what}' must be a number or u64 literal")),
    }
}

/// 16-hex-digit rendering for fingerprints, checksums and digests — they are
/// opaque 64-bit identities, not quantities, and `Json::Num`'s f64 carrier
/// cannot hold all of them exactly.
pub fn hex64(x: u64) -> String {
    format!("{x:016x}")
}

/// Parse [`hex64`] output (exactly 16 hex digits).
pub fn hex64_parse(s: &str, what: &str) -> Result<u64, String> {
    if s.len() != 16 {
        return Err(format!("field '{what}': expected 16 hex digits, got '{s}'"));
    }
    u64::from_str_radix(s, 16).map_err(|_| format!("field '{what}': bad hex literal '{s}'"))
}

fn get<'a>(j: &'a Json, key: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| format!("missing field '{key}'"))
}

fn get_f64(j: &Json, key: &str) -> Result<f64, String> {
    exact_f64_from_json(get(j, key)?, key)
}

fn get_u64(j: &Json, key: &str) -> Result<u64, String> {
    exact_u64_from_json(get(j, key)?, key)
}

fn get_u32(j: &Json, key: &str) -> Result<u32, String> {
    let x = get_u64(j, key)?;
    u32::try_from(x).map_err(|_| format!("field '{key}': {x} exceeds u32"))
}

// ---------------------------------------------------------------------------
// The stencil characterization a key carries (the shard's provenance set)
// ---------------------------------------------------------------------------

/// The six characterization values a [`CacheKey`] pins its stencil by, as
/// bit patterns — a shard declares the distinct set its keys draw from, and
/// the loader cross-checks every key against it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Characterization {
    pub space_dims: u32,
    pub sigma: u32,
    pub flops_bits: u64,
    pub n_buffers_bits: u64,
    pub bytes_bits: u64,
    pub c_iter_bits: u64,
}

impl Characterization {
    pub fn of_key(key: &CacheKey) -> Characterization {
        Characterization {
            space_dims: key.space_dims,
            sigma: key.sigma,
            flops_bits: key.flops_bits,
            n_buffers_bits: key.n_buffers_bits,
            bytes_bits: key.bytes_bits,
            c_iter_bits: key.c_iter_bits,
        }
    }
}

pub fn characterization_to_json(c: &Characterization) -> Json {
    Json::obj(vec![
        ("dims", Json::Num(c.space_dims as f64)),
        ("sigma", Json::Num(c.sigma as f64)),
        ("flops", exact_f64_to_json(f64::from_bits(c.flops_bits))),
        ("n_buffers", exact_f64_to_json(f64::from_bits(c.n_buffers_bits))),
        ("bytes", exact_f64_to_json(f64::from_bits(c.bytes_bits))),
        ("c_iter", exact_f64_to_json(f64::from_bits(c.c_iter_bits))),
    ])
}

pub fn characterization_from_json(j: &Json) -> Result<Characterization, String> {
    Ok(Characterization {
        space_dims: get_u32(j, "dims")?,
        sigma: get_u32(j, "sigma")?,
        flops_bits: get_f64(j, "flops")?.to_bits(),
        n_buffers_bits: get_f64(j, "n_buffers")?.to_bits(),
        bytes_bits: get_f64(j, "bytes")?.to_bits(),
        c_iter_bits: get_f64(j, "c_iter")?.to_bits(),
    })
}

// ---------------------------------------------------------------------------
// Keys
// ---------------------------------------------------------------------------

/// Encode a key **without** its platform fingerprint: all keys in a shard
/// share it, so the shard header carries it once and the decoder
/// reconstructs it — which also makes an in-shard fingerprint mismatch
/// structurally impossible.
pub fn key_to_json(key: &CacheKey) -> Json {
    Json::obj(vec![
        ("n_sm", Json::Num(key.n_sm as f64)),
        ("n_v", Json::Num(key.n_v as f64)),
        ("m_sm_kb", exact_f64_to_json(f64::from_bits(key.m_sm_kb_bits))),
        ("dims", Json::Num(key.space_dims as f64)),
        ("sigma", Json::Num(key.sigma as f64)),
        ("flops", exact_f64_to_json(f64::from_bits(key.flops_bits))),
        ("n_buffers", exact_f64_to_json(f64::from_bits(key.n_buffers_bits))),
        ("bytes", exact_f64_to_json(f64::from_bits(key.bytes_bits))),
        ("c_iter", exact_f64_to_json(f64::from_bits(key.c_iter_bits))),
        ("s1", exact_u64_to_json(key.s1)),
        ("s2", exact_u64_to_json(key.s2)),
        ("s3", exact_u64_to_json(key.s3)),
        ("t", exact_u64_to_json(key.t)),
    ])
}

/// Decode [`key_to_json`], stamping the shard's `platform_fp` back in.
pub fn key_from_json(j: &Json, platform_fp: u64) -> Result<CacheKey, String> {
    Ok(CacheKey {
        platform_fp,
        n_sm: get_u32(j, "n_sm")?,
        n_v: get_u32(j, "n_v")?,
        m_sm_kb_bits: get_f64(j, "m_sm_kb")?.to_bits(),
        space_dims: get_u32(j, "dims")?,
        sigma: get_u32(j, "sigma")?,
        flops_bits: get_f64(j, "flops")?.to_bits(),
        n_buffers_bits: get_f64(j, "n_buffers")?.to_bits(),
        bytes_bits: get_f64(j, "bytes")?.to_bits(),
        c_iter_bits: get_f64(j, "c_iter")?.to_bits(),
        s1: get_u64(j, "s1")?,
        s2: get_u64(j, "s2")?,
        s3: get_u64(j, "s3")?,
        t: get_u64(j, "t")?,
    })
}

// ---------------------------------------------------------------------------
// Entries
// ---------------------------------------------------------------------------

fn bound_name(b: Bound) -> &'static str {
    match b {
        Bound::Compute => "compute",
        Bound::Memory => "memory",
        Bound::Latency => "latency",
    }
}

fn bound_from_name(s: &str) -> Result<Bound, String> {
    match s {
        "compute" => Ok(Bound::Compute),
        "memory" => Ok(Bound::Memory),
        "latency" => Ok(Bound::Latency),
        other => Err(format!("field 'bound': unknown binding constraint '{other}'")),
    }
}

/// Encode one memo slot: `{"kind": "exact" | "infeasible" | "bound", …}`.
pub fn entry_to_json(entry: &CacheEntry) -> Json {
    match entry {
        CacheEntry::Exact(None) => Json::obj(vec![("kind", Json::str("infeasible"))]),
        CacheEntry::Exact(Some(s)) => Json::obj(vec![
            ("kind", Json::str("exact")),
            ("t_s1", exact_u64_to_json(s.sw.tiles.t_s1)),
            ("t_s2", exact_u64_to_json(s.sw.tiles.t_s2)),
            ("t_s3", s.sw.tiles.t_s3.map(exact_u64_to_json).unwrap_or(Json::Null)),
            ("t_t", exact_u64_to_json(s.sw.tiles.t_t)),
            ("k", Json::Num(s.sw.k as f64)),
            ("cycles", exact_f64_to_json(s.est.cycles)),
            ("seconds", exact_f64_to_json(s.est.seconds)),
            ("gflops", exact_f64_to_json(s.est.gflops)),
            ("m_tile_bytes", exact_f64_to_json(s.est.m_tile_bytes)),
            ("compute_cycles", exact_f64_to_json(s.est.compute_cycles)),
            ("mem_cycles", exact_f64_to_json(s.est.mem_cycles)),
            ("rounds", exact_f64_to_json(s.est.rounds)),
            ("bound", Json::str(bound_name(s.est.bound))),
            ("occupancy", exact_f64_to_json(s.est.occupancy)),
            ("evals", exact_u64_to_json(s.evals)),
        ]),
        CacheEntry::BoundedOut { lb_seconds } => Json::obj(vec![
            ("kind", Json::str("bound")),
            ("lb_seconds", exact_f64_to_json(*lb_seconds)),
        ]),
    }
}

/// Decode [`entry_to_json`].
pub fn entry_from_json(j: &Json) -> Result<CacheEntry, String> {
    let kind = get(j, "kind")?
        .as_str()
        .ok_or_else(|| "field 'kind' must be a string".to_string())?;
    match kind {
        "infeasible" => Ok(CacheEntry::Exact(None)),
        "bound" => Ok(CacheEntry::BoundedOut { lb_seconds: get_f64(j, "lb_seconds")? }),
        "exact" => {
            let t_s3 = match get(j, "t_s3")? {
                Json::Null => None,
                v => Some(exact_u64_from_json(v, "t_s3")?),
            };
            let tiles = TileSizes {
                t_s1: get_u64(j, "t_s1")?,
                t_s2: get_u64(j, "t_s2")?,
                t_s3,
                t_t: get_u64(j, "t_t")?,
            };
            let est = TimeEstimate {
                cycles: get_f64(j, "cycles")?,
                seconds: get_f64(j, "seconds")?,
                gflops: get_f64(j, "gflops")?,
                m_tile_bytes: get_f64(j, "m_tile_bytes")?,
                compute_cycles: get_f64(j, "compute_cycles")?,
                mem_cycles: get_f64(j, "mem_cycles")?,
                rounds: get_f64(j, "rounds")?,
                bound: bound_from_name(
                    get(j, "bound")?
                        .as_str()
                        .ok_or_else(|| "field 'bound' must be a string".to_string())?,
                )?,
                occupancy: get_f64(j, "occupancy")?,
            };
            Ok(CacheEntry::Exact(Some(InnerSolution {
                sw: SoftwareParams::new(tiles, get_u32(j, "k")?),
                est,
                evals: get_u64(j, "evals")?,
            })))
        }
        other => Err(format!("field 'kind': unknown entry kind '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn roundtrip_f64(x: f64) -> f64 {
        let text = exact_f64_to_json(x).to_string_compact();
        exact_f64_from_json(&parse(&text).unwrap(), "x").unwrap()
    }

    #[test]
    fn f64_codec_is_bit_exact_for_every_class() {
        for x in [
            0.0,
            -0.0,
            1.0,
            -1.0,
            0.1,
            1.0 / 3.0,
            f64::MIN_POSITIVE,
            f64::MIN_POSITIVE / 4.0, // subnormal
            f64::MAX,
            9e15,
            9.007199254740993e15,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
            f64::from_bits(0x7ff8_dead_beef_0001), // NaN with payload
        ] {
            assert_eq!(roundtrip_f64(x).to_bits(), x.to_bits(), "{x}");
        }
    }

    #[test]
    fn negative_zero_escapes_the_integer_fast_path() {
        // The JSON writer prints integral f64s as integers, which would turn
        // -0.0 into "0"; the codec must sidestep that.
        match exact_f64_to_json(-0.0) {
            Json::Str(s) => assert_eq!(s, "bits:8000000000000000"),
            other => panic!("-0.0 must escape to a bits literal, got {other:?}"),
        }
    }

    #[test]
    fn u64_codec_is_exact_across_the_2_53_boundary() {
        for x in [0u64, 1, 1 << 52, (1 << 53) - 1, 1 << 53, u64::MAX] {
            let text = exact_u64_to_json(x).to_string_compact();
            let back = exact_u64_from_json(&parse(&text).unwrap(), "x").unwrap();
            assert_eq!(back, x);
        }
    }

    #[test]
    fn hex64_roundtrips() {
        for x in [0u64, 1, 0xdead_beef, u64::MAX] {
            assert_eq!(hex64_parse(&hex64(x), "fp").unwrap(), x);
        }
        assert!(hex64_parse("abc", "fp").is_err());
        assert!(hex64_parse("zzzzzzzzzzzzzzzz", "fp").is_err());
    }

    #[test]
    fn entry_kinds_roundtrip_bit_exactly() {
        use crate::timemodel::tiling::TileSizes;
        let exact = CacheEntry::Exact(Some(InnerSolution {
            sw: SoftwareParams::new(TileSizes::d3(32, 64, 4, 8), 3),
            est: TimeEstimate {
                cycles: 1.5e9,
                seconds: 0.125,
                gflops: 123.456,
                m_tile_bytes: 49152.0,
                compute_cycles: 1e6,
                mem_cycles: 2e6 + 0.5,
                rounds: 42.0,
                bound: Bound::Memory,
                occupancy: 0.875,
            },
            evals: 12345,
        }));
        let infeasible = CacheEntry::Exact(None);
        let bounded = CacheEntry::BoundedOut { lb_seconds: 3.0e-4 };
        for e in [exact, infeasible, bounded] {
            let text = entry_to_json(&e).to_string_compact();
            let back = entry_from_json(&parse(&text).unwrap()).unwrap();
            match (&e, &back) {
                (CacheEntry::Exact(Some(a)), CacheEntry::Exact(Some(b))) => {
                    assert_eq!(a.sw.tiles, b.sw.tiles);
                    assert_eq!(a.sw.k, b.sw.k);
                    assert_eq!(a.est.seconds.to_bits(), b.est.seconds.to_bits());
                    assert_eq!(a.est.gflops.to_bits(), b.est.gflops.to_bits());
                    assert_eq!(a.est.occupancy.to_bits(), b.est.occupancy.to_bits());
                    assert!(matches!(b.est.bound, Bound::Memory));
                    assert_eq!(a.evals, b.evals);
                }
                (CacheEntry::Exact(None), CacheEntry::Exact(None)) => {}
                (CacheEntry::BoundedOut { lb_seconds: a }, CacheEntry::BoundedOut { lb_seconds: b }) => {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                other => panic!("entry kind changed: {other:?}"),
            }
        }
    }

    #[test]
    fn key_roundtrips_and_restamps_fingerprint() {
        let key = CacheKey {
            platform_fp: 0xdead_beef_cafe_f00d,
            n_sm: 16,
            n_v: 128,
            m_sm_kb_bits: 96.0f64.to_bits(),
            space_dims: 3,
            sigma: 2,
            flops_bits: 25.0f64.to_bits(),
            n_buffers_bits: 2.0f64.to_bits(),
            bytes_bits: 4.0f64.to_bits(),
            c_iter_bits: 23.5f64.to_bits(),
            s1: 1 << 54, // exercise the u64 escape
            s2: 512,
            s3: 64,
            t: 100,
        };
        let text = key_to_json(&key).to_string_compact();
        let back = key_from_json(&parse(&text).unwrap(), key.platform_fp).unwrap();
        assert_eq!(back, key);
        // The fingerprint comes from the shard header, not the entry.
        let restamped = key_from_json(&parse(&text).unwrap(), 7).unwrap();
        assert_eq!(restamped.platform_fp, 7);
    }

    #[test]
    fn malformed_payloads_name_the_field() {
        let err = entry_from_json(&parse(r#"{"kind": "exotic"}"#).unwrap()).unwrap_err();
        assert!(err.contains("kind"), "{err}");
        let err = entry_from_json(&parse(r#"{"kind": "bound"}"#).unwrap()).unwrap_err();
        assert!(err.contains("lb_seconds"), "{err}");
        let err =
            exact_f64_from_json(&Json::Str("bits:xyz".into()), "seconds").unwrap_err();
        assert!(err.contains("seconds"), "{err}");
    }
}
