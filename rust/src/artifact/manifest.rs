//! Artifact manifest: schema versions plus per-shard integrity and
//! provenance records.
//!
//! The manifest is the load-side gatekeeper: before any shard payload is
//! parsed, the loader checks the artifact schema version, the wire schema
//! range, and each shard's recorded byte length and FNV-1a checksum against
//! the file on disk. Provenance fields (platform canonical name, recorded
//! fingerprint, prune partition flag) are then cross-checked against the
//! shard's own header so an edit to either side is caught no matter which
//! copy was tampered with.

use crate::artifact::payload::{hex64, hex64_parse};
use crate::artifact::ArtifactError;
use crate::util::json::Json;

/// Version of the artifact container format itself (manifest layout, shard
/// header layout, entry encoding). Bump on any incompatible change.
pub const ARTIFACT_SCHEMA_VERSION: u64 = 1;

/// File name of the manifest inside an artifact directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// Integrity + provenance record for one payload shard.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardMeta {
    /// File name relative to the artifact directory.
    pub file: String,
    /// Exact byte length of the shard file.
    pub bytes: u64,
    /// FNV-1a 64-bit checksum over the shard file bytes.
    pub checksum: u64,
    /// Canonical platform name ([`PlatformSpec::canonical_name`]) — parseable
    /// back into the platform the shard was swept under.
    ///
    /// [`PlatformSpec::canonical_name`]: crate::platform::spec::PlatformSpec::canonical_name
    pub platform: String,
    /// The platform fingerprint the shard's cache keys were minted under.
    pub platform_fp: u64,
    /// Whether the shard's sweep ran with bound-and-prune enabled (the prune
    /// partition of the memo store).
    pub prune: bool,
    /// Number of `Exact` entries in the shard (informational, re-derived and
    /// cross-checked on load).
    pub exact_entries: u64,
    /// Number of `BoundedOut` entries in the shard.
    pub bounded_entries: u64,
}

/// The parsed `manifest.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// [`ARTIFACT_SCHEMA_VERSION`] at save time.
    pub artifact_schema: u64,
    /// [`wire::SCHEMA_VERSION`](crate::service::wire::SCHEMA_VERSION) at save
    /// time — the shard's `C_iter`/`SolveOpts` provenance and f64 formatting
    /// ride the wire codecs, so their version gates the load too.
    pub wire_schema: u64,
    /// One record per payload shard, sorted by file name at save time.
    pub shards: Vec<ShardMeta>,
}

impl Manifest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("artifact_schema", Json::Num(self.artifact_schema as f64)),
            ("wire_schema", Json::Num(self.wire_schema as f64)),
            (
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("file", Json::str(&s.file)),
                                ("bytes", Json::Num(s.bytes as f64)),
                                ("checksum", Json::str(hex64(s.checksum))),
                                ("platform", Json::str(&s.platform)),
                                ("platform_fp", Json::str(hex64(s.platform_fp))),
                                ("prune", Json::Bool(s.prune)),
                                ("exact_entries", Json::Num(s.exact_entries as f64)),
                                ("bounded_entries", Json::Num(s.bounded_entries as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a manifest from JSON. `path` is used only in error messages.
    pub fn from_json(j: &Json, path: &str) -> Result<Manifest, ArtifactError> {
        let bad = |detail: String| ArtifactError::BadManifest {
            path: path.to_string(),
            detail,
        };
        let num = |j: &Json, key: &str| -> Result<u64, ArtifactError> {
            match j.get(key) {
                Some(Json::Num(x)) if x.is_finite() && *x >= 0.0 && x.fract() == 0.0 => {
                    Ok(*x as u64)
                }
                Some(_) => Err(bad(format!("field '{key}' must be a non-negative integer"))),
                None => Err(bad(format!("missing field '{key}'"))),
            }
        };
        let string = |j: &Json, key: &str| -> Result<String, ArtifactError> {
            j.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| bad(format!("missing string field '{key}'")))
        };
        let artifact_schema = num(j, "artifact_schema")?;
        let wire_schema = num(j, "wire_schema")?;
        let shards_json = match j.get("shards") {
            Some(Json::Arr(items)) => items,
            _ => return Err(bad("missing array field 'shards'".to_string())),
        };
        let mut shards = Vec::with_capacity(shards_json.len());
        for s in shards_json {
            let prune = match s.get("prune") {
                Some(Json::Bool(b)) => *b,
                _ => return Err(bad("missing boolean field 'prune' in shard record".into())),
            };
            shards.push(ShardMeta {
                file: string(s, "file")?,
                bytes: num(s, "bytes")?,
                checksum: hex64_parse(&string(s, "checksum")?, "checksum")
                    .map_err(&bad)?,
                platform: string(s, "platform")?,
                platform_fp: hex64_parse(&string(s, "platform_fp")?, "platform_fp")
                    .map_err(&bad)?,
                prune,
                exact_entries: num(s, "exact_entries")?,
                bounded_entries: num(s, "bounded_entries")?,
            });
        }
        Ok(Manifest { artifact_schema, wire_schema, shards })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn sample() -> Manifest {
        Manifest {
            artifact_schema: ARTIFACT_SCHEMA_VERSION,
            wire_schema: 4,
            shards: vec![
                ShardMeta {
                    file: "shard-00000000deadbeef-0000000000000007.json".into(),
                    bytes: 12345,
                    checksum: 0xcafe_f00d_1234_5678,
                    platform: "maxwell".into(),
                    platform_fp: 0xdead_beef,
                    prune: true,
                    exact_entries: 40,
                    bounded_entries: 2,
                },
                ShardMeta {
                    file: "shard-00000000deadbef0-0000000000000007.json".into(),
                    bytes: 999,
                    checksum: u64::MAX, // must survive the f64-unsafe range
                    platform: "maxwell:bw20".into(),
                    platform_fp: 0xdead_bef0,
                    prune: false,
                    exact_entries: 3,
                    bounded_entries: 0,
                },
            ],
        }
    }

    #[test]
    fn manifest_roundtrips_through_json_text() {
        let m = sample();
        let text = m.to_json().to_string_pretty();
        let back = Manifest::from_json(&parse(&text).unwrap(), "manifest.json").unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn manifest_serialization_is_deterministic() {
        let a = sample().to_json().to_string_pretty();
        let b = sample().to_json().to_string_pretty();
        assert_eq!(a, b);
    }

    #[test]
    fn bad_manifests_name_the_offending_field() {
        let missing = parse(r#"{"artifact_schema": 1, "shards": []}"#).unwrap();
        let err = Manifest::from_json(&missing, "m.json").unwrap_err();
        assert!(err.to_string().contains("wire_schema"), "{err}");

        let bad_checksum = parse(
            r#"{"artifact_schema": 1, "wire_schema": 4, "shards": [{
                "file": "f", "bytes": 1, "checksum": "xyz",
                "platform": "maxwell", "platform_fp": "0000000000000001",
                "prune": true, "exact_entries": 0, "bounded_entries": 0}]}"#,
        )
        .unwrap();
        let err = Manifest::from_json(&bad_checksum, "m.json").unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }
}
