//! E10 — model-vs-simulator validation (the stand-in for the paper's
//! real-GPU validation of [27]).

use crate::area::params::HwParams;
use crate::platform::spec::PlatformSpec;
use crate::sim::run::simulate;
use crate::stencil::defs::{Stencil, StencilId};
use crate::stencil::workload::ProblemSize;
use crate::timemodel::talg::SoftwareParams;
use crate::timemodel::tiling::TileSizes;
use crate::util::stats;

/// One compared configuration.
#[derive(Clone, Debug)]
pub struct ValidationCase {
    pub label: String,
    pub model_seconds: f64,
    pub sim_seconds: f64,
}

impl ValidationCase {
    pub fn rel_err_pct(&self) -> f64 {
        100.0 * (self.model_seconds - self.sim_seconds) / self.sim_seconds
    }
}

/// Aggregate validation report.
#[derive(Clone, Debug)]
pub struct ValidationReport {
    pub cases: Vec<ValidationCase>,
    /// Mean absolute percentage error of model vs simulator.
    pub mape_pct: f64,
    /// Kendall-τ rank agreement between model and simulator orderings —
    /// the property the codesign search actually relies on (it compares
    /// configurations, it does not need absolute times).
    pub kendall_tau: f64,
}

/// Kendall rank-correlation τ (pairwise concordance).
pub fn kendall_tau(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let mut concordant = 0i64;
    let mut discordant = 0i64;
    for i in 0..n {
        for j in i + 1..n {
            let s = (a[i] - a[j]) * (b[i] - b[j]);
            if s > 0.0 {
                concordant += 1;
            } else if s < 0.0 {
                discordant += 1;
            }
        }
    }
    (concordant - discordant) as f64 / (n * (n - 1) / 2) as f64
}

/// The default validation sweep: a grid of hardware shapes × tile shapes ×
/// both dimensionalities, at simulator-tractable problem sizes. Hardware
/// shapes are variations of the platform's first reference architecture
/// (formerly a hard-coded GTX 980).
pub fn default_cases(platform: &PlatformSpec) -> Vec<(Stencil, ProblemSize, HwParams, SoftwareParams)> {
    let base = platform
        .references
        .first()
        .map(|r| r.hw)
        .expect("platform has no reference architectures");
    let mut cases = Vec::new();
    let hw_variants = [
        base,
        HwParams { n_sm: 8, n_v: 256, ..base },
        HwParams { n_sm: 32, n_v: 64, ..base },
        HwParams { n_sm: 16, n_v: 128, m_sm_kb: 48.0, ..base },
    ];
    let sw_2d = [
        SoftwareParams::new(TileSizes::d2(32, 64, 8), 2),
        SoftwareParams::new(TileSizes::d2(64, 128, 4), 1),
        SoftwareParams::new(TileSizes::d2(16, 32, 16), 4),
    ];
    for id in [StencilId::Jacobi2D, StencilId::Heat2D] {
        let st = *Stencil::get(id);
        for hw in &hw_variants {
            for sw in &sw_2d {
                cases.push((st, ProblemSize::d2(1024, 128), *hw, *sw));
            }
        }
    }
    let sw_3d = [
        SoftwareParams::new(TileSizes::d3(8, 32, 4, 4), 1),
        SoftwareParams::new(TileSizes::d3(16, 32, 2, 8), 2),
    ];
    let st = *Stencil::get(StencilId::Heat3D);
    for hw in &hw_variants[..2] {
        for sw in &sw_3d {
            cases.push((st, ProblemSize::d3(128, 32), *hw, *sw));
        }
    }
    cases
}

/// Run the sweep and aggregate, under the platform's time model.
pub fn validate_sweep(platform: &PlatformSpec) -> ValidationReport {
    let model = platform.time_model();
    let mut cases = Vec::new();
    for (stencil, size, hw, sw) in default_cases(platform) {
        if model.feasibility(&stencil, &hw, &sw).is_err() {
            continue;
        }
        let est = model.evaluate(&stencil, &size, &hw, &sw);
        let sim = simulate(&model.machine, &stencil, &size, &hw, &sw);
        cases.push(ValidationCase {
            label: format!(
                "{} {} {} {} k{}",
                stencil.name(),
                size.label(),
                hw.label(),
                sw.tiles.label(),
                sw.k
            ),
            model_seconds: est.seconds,
            sim_seconds: sim.seconds,
        });
    }
    let model_t: Vec<f64> = cases.iter().map(|c| c.model_seconds).collect();
    let sim_t: Vec<f64> = cases.iter().map(|c| c.sim_seconds).collect();
    ValidationReport {
        mape_pct: stats::mape(&model_t, &sim_t),
        kendall_tau: kendall_tau(&model_t, &sim_t),
        cases,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kendall_basics() {
        assert_eq!(kendall_tau(&[1.0, 2.0, 3.0], &[10.0, 20.0, 30.0]), 1.0);
        assert_eq!(kendall_tau(&[1.0, 2.0, 3.0], &[30.0, 20.0, 10.0]), -1.0);
        assert!(kendall_tau(&[1.0, 2.0, 3.0, 4.0], &[1.0, 3.0, 2.0, 4.0]) > 0.5);
    }

    #[test]
    fn model_tracks_simulator() {
        // The analytical model must track the independent simulator within a
        // generous envelope (the paper's own model-vs-silicon errors are
        // ~10–30% per [27]) and, crucially, preserve configuration ranking.
        let rep = validate_sweep(crate::platform::registry::Platform::default_spec());
        assert!(rep.cases.len() >= 20, "only {} cases", rep.cases.len());
        assert!(rep.mape_pct < 40.0, "MAPE {}%", rep.mape_pct);
        assert!(rep.kendall_tau > 0.7, "kendall tau {}", rep.kendall_tau);
    }
}
