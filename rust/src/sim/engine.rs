//! The fluid discrete-event engine.
//!
//! One SM holds up to `k` resident blocks. Each block walks through
//! `Dispatch → Load → Compute → Store`. At any instant every active block
//! has a rate (bytes/cycle for memory phases, lane-cycles/cycle for compute)
//! determined by water-filling the SM's resources; the engine jumps from
//! block-phase-completion event to event. Blocks of one wavefront are
//! dispatched greedily to whichever SM frees a slot first; a wavefront
//! barrier separates dependent phases of the hexagonal schedule.

use crate::timemodel::machine::MachineSpec;

/// One threadblock's static requirements.
#[derive(Clone, Copy, Debug)]
pub struct BlockSpec {
    /// Threads in the block (t_S2 × t_S3, clipped at boundaries).
    pub threads: f64,
    /// Lane-cycles of compute: threads × iterations × C_iter.
    pub compute_lane_cycles: f64,
    /// Bytes to stream in before compute.
    pub load_bytes: f64,
    /// Bytes to stream out after compute.
    pub store_bytes: f64,
}

/// Simulated machine shape.
#[derive(Clone, Copy, Debug)]
pub struct SimMachine {
    pub n_sm: u32,
    pub n_v: u32,
    /// Resident block slots per SM (the schedule's `k`).
    pub k: u32,
    /// Shared-memory capacity, kB (drives access-latency scaling).
    pub m_sm_kb: f64,
    pub spec: MachineSpec,
}

/// Aggregate outcome.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimOutcome {
    pub cycles: f64,
    /// Total bytes moved (for bandwidth-utilization reporting).
    pub bytes: f64,
    /// Total lane-cycles of compute executed.
    pub lane_cycles: f64,
    /// Events processed (cost accounting).
    pub events: u64,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Phase {
    /// Fixed-latency dispatch/setup.
    Dispatch,
    Load,
    Compute,
    Store,
    Done,
}

#[derive(Clone, Copy, Debug)]
struct Resident {
    spec: BlockSpec,
    phase: Phase,
    /// Remaining work in the current phase (cycles, bytes or lane-cycles).
    remaining: f64,
}

impl Resident {
    fn new(spec: BlockSpec, dispatch_cycles: f64) -> Resident {
        Resident { spec, phase: Phase::Dispatch, remaining: dispatch_cycles }
    }

    fn advance_phase(&mut self) {
        self.phase = match self.phase {
            Phase::Dispatch => {
                self.remaining = self.spec.load_bytes;
                Phase::Load
            }
            Phase::Load => {
                self.remaining = self.spec.compute_lane_cycles;
                Phase::Compute
            }
            Phase::Compute => {
                self.remaining = self.spec.store_bytes;
                Phase::Store
            }
            Phase::Store | Phase::Done => Phase::Done,
        };
        // Skip empty phases.
        if self.phase != Phase::Done && self.remaining <= 0.0 {
            self.advance_phase();
        }
    }
}

/// The engine. Simulates one wavefront at a time over all SMs.
pub struct FluidSim {
    pub machine: SimMachine,
}

impl FluidSim {
    pub fn new(machine: SimMachine) -> FluidSim {
        assert!(machine.k >= 1 && machine.n_sm >= 1 && machine.n_v >= 1);
        FluidSim { machine }
    }

    /// Simulate a sequence of wavefronts (each a list of blocks, with a
    /// barrier between consecutive wavefronts). Returns the aggregate.
    pub fn run(&self, wavefronts: &[Vec<BlockSpec>]) -> SimOutcome {
        let mut out = SimOutcome::default();
        for wf in wavefronts {
            let o = self.run_wavefront(wf);
            out.cycles += o.cycles;
            out.bytes += o.bytes;
            out.lane_cycles += o.lane_cycles;
            out.events += o.events;
        }
        out
    }

    /// Simulate one wavefront to completion.
    pub fn run_wavefront(&self, blocks: &[BlockSpec]) -> SimOutcome {
        let m = &self.machine;
        let dispatch_cycles = m.spec.sync_cycles;
        let mut queue: std::collections::VecDeque<BlockSpec> = blocks.iter().copied().collect();
        let mut sms: Vec<Vec<Resident>> = (0..m.n_sm).map(|_| Vec::new()).collect();
        // Per-SM independent execution with a *global* FIFO queue: an SM
        // admits a new block the moment one of its k slots frees.
        let mut now = 0.0f64;
        let mut out = SimOutcome {
            bytes: blocks.iter().map(|b| b.load_bytes + b.store_bytes).sum(),
            lane_cycles: blocks.iter().map(|b| b.compute_lane_cycles).sum(),
            ..Default::default()
        };

        // Initial fill, round-robin.
        'fill: for sm in 0..sms.len() {
            while (sms[sm].len() as u32) < m.k {
                match queue.pop_front() {
                    Some(b) => sms[sm].push(Resident::new(b, dispatch_cycles)),
                    None => break 'fill,
                }
            }
        }

        let bw = m.spec.bytes_per_cycle_per_sm();
        let lam = m.spec.latency_factor_for(m.m_sm_kb);
        loop {
            // Compute rates per SM and find the earliest completion event.
            let mut best_dt = f64::INFINITY;
            let mut rates: Vec<Vec<f64>> = Vec::with_capacity(sms.len());
            for residents in &sms {
                let mut sm_rates = vec![0.0f64; residents.len()];
                // Memory: bandwidth shared equally among Load/Store blocks.
                let mem_users = residents
                    .iter()
                    .filter(|r| matches!(r.phase, Phase::Load | Phase::Store))
                    .count();
                // Compute: n_V lanes water-filled subject to per-block caps.
                let caps: Vec<(usize, f64)> = residents
                    .iter()
                    .enumerate()
                    .filter(|(_, r)| r.phase == Phase::Compute)
                    .map(|(i, r)| (i, r.spec.threads / lam))
                    .collect();
                let cap_sum: f64 = caps.iter().map(|c| c.1).sum();
                let scale = if cap_sum > m.n_v as f64 { m.n_v as f64 / cap_sum } else { 1.0 };
                for (i, r) in residents.iter().enumerate() {
                    sm_rates[i] = match r.phase {
                        Phase::Dispatch => 1.0, // cycles tick at rate 1
                        Phase::Load | Phase::Store => bw / mem_users as f64,
                        Phase::Compute => {
                            let cap = r.spec.threads / lam;
                            (cap * scale).min(m.n_v as f64)
                        }
                        Phase::Done => 0.0,
                    };
                    if sm_rates[i] > 0.0 && r.remaining > 0.0 {
                        best_dt = best_dt.min(r.remaining / sm_rates[i]);
                    }
                }
                rates.push(sm_rates);
            }
            if !best_dt.is_finite() {
                break; // nothing active anywhere
            }
            now += best_dt;
            out.events += 1;

            // Advance everything by best_dt, transition completed phases,
            // admit queued blocks into freed slots.
            for (residents, sm_rates) in sms.iter_mut().zip(&rates) {
                for (r, &rate) in residents.iter_mut().zip(sm_rates) {
                    if rate > 0.0 {
                        r.remaining -= rate * best_dt;
                        if r.remaining <= 1e-9 {
                            r.advance_phase();
                        }
                    }
                }
                residents.retain(|r| r.phase != Phase::Done);
                while (residents.len() as u32) < self.machine.k {
                    match queue.pop_front() {
                        Some(b) => residents.push(Resident::new(b, dispatch_cycles)),
                        None => break,
                    }
                }
            }
            if out.events > 50_000_000 {
                panic!("simulator runaway: too many events for this instance");
            }
        }
        out.cycles = now;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine(n_sm: u32, n_v: u32, k: u32) -> SimMachine {
        // 96 kB shared memory = the reference latency point (λ exactly 4).
        SimMachine { n_sm, n_v, k, m_sm_kb: 96.0, spec: MachineSpec::maxwell() }
    }

    fn block(threads: f64, compute: f64, load: f64, store: f64) -> BlockSpec {
        BlockSpec { threads, compute_lane_cycles: compute, load_bytes: load, store_bytes: store }
    }

    #[test]
    fn single_block_compute_only_latency_bound() {
        // 64 threads, λ=4 → cap 16 lanes; 16000 lane-cycles → 1000 cycles
        // (+600 dispatch).
        let sim = FluidSim::new(machine(1, 128, 1));
        let o = sim.run_wavefront(&[block(64.0, 16_000.0, 0.0, 0.0)]);
        assert!((o.cycles - (600.0 + 1000.0)).abs() < 1.0, "{}", o.cycles);
    }

    #[test]
    fn single_block_issue_bound() {
        // 1024 threads, cap 256 > n_V=128 → rate 128.
        let sim = FluidSim::new(machine(1, 128, 1));
        let o = sim.run_wavefront(&[block(1024.0, 128_000.0, 0.0, 0.0)]);
        assert!((o.cycles - (600.0 + 1000.0)).abs() < 1.0, "{}", o.cycles);
    }

    #[test]
    fn memory_phase_uses_bandwidth_slice() {
        // 11666.7 bytes at 11.667 B/cycle → 1000 cycles.
        let sim = FluidSim::new(machine(1, 128, 1));
        let spec = MachineSpec::maxwell();
        let bytes = spec.bytes_per_cycle_per_sm() * 1000.0;
        let o = sim.run_wavefront(&[block(64.0, 0.0, bytes, 0.0)]);
        assert!((o.cycles - 1600.0).abs() < 1.0, "{}", o.cycles);
    }

    #[test]
    fn two_sms_halve_the_work() {
        let blocks: Vec<BlockSpec> =
            (0..8).map(|_| block(128.0, 32_000.0, 0.0, 0.0)).collect();
        let one = FluidSim::new(machine(1, 128, 1)).run_wavefront(&blocks);
        let two = FluidSim::new(machine(2, 128, 1)).run_wavefront(&blocks);
        assert!(
            (one.cycles / two.cycles - 2.0).abs() < 0.05,
            "1 SM {} vs 2 SM {}",
            one.cycles,
            two.cycles
        );
    }

    #[test]
    fn double_buffering_overlaps_load_and_compute() {
        // With k=2, a memory-phase block overlaps a compute-phase block;
        // serial execution (k=1) pays the sum.
        let spec = MachineSpec::maxwell();
        let bytes = spec.bytes_per_cycle_per_sm() * 2000.0; // 2000-cycle load
        let blocks = vec![
            block(512.0, 128.0 * 2000.0, 0.0, 0.0), // pure compute, 2000 cyc
            block(512.0, 0.0, bytes, 0.0),          // pure load, 2000 cyc
        ];
        let k1 = FluidSim::new(machine(1, 128, 1)).run_wavefront(&blocks);
        let k2 = FluidSim::new(machine(1, 128, 2)).run_wavefront(&blocks);
        // k=1: 600+2000 + 600+2000 = 5200; k=2: 600+2000 = 2600.
        assert!(k2.cycles < k1.cycles * 0.6, "k1 {} vs k2 {}", k1.cycles, k2.cycles);
    }

    #[test]
    fn wavefront_barrier_serializes() {
        let sim = FluidSim::new(machine(4, 128, 2));
        let wf: Vec<BlockSpec> = (0..4).map(|_| block(128.0, 16_000.0, 0.0, 0.0)).collect();
        let once = sim.run(&[wf.clone()]);
        let twice = sim.run(&[wf.clone(), wf]);
        assert!((twice.cycles / once.cycles - 2.0).abs() < 1e-6);
    }

    #[test]
    fn accounting_totals() {
        let sim = FluidSim::new(machine(2, 128, 2));
        let blocks = vec![block(64.0, 1000.0, 500.0, 250.0); 5];
        let o = sim.run_wavefront(&blocks);
        assert_eq!(o.bytes, 5.0 * 750.0);
        assert_eq!(o.lane_cycles, 5000.0);
        assert!(o.events > 0);
    }

    #[test]
    fn empty_wavefront_is_free() {
        let sim = FluidSim::new(machine(2, 128, 2));
        let o = sim.run_wavefront(&[]);
        assert_eq!(o.cycles, 0.0);
    }
}
