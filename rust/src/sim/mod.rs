//! Cycle-approximate GPU microarchitecture simulator.
//!
//! The paper validates its execution-time model against real GTX 980 / Titan
//! X silicon; no GPU exists in this environment, so this simulator is the
//! substituted ground truth (DESIGN.md §2). It executes the *same tile
//! schedules* the model describes, but with a deliberately different and
//! finer abstraction, so that model-vs-simulator agreement is a meaningful
//! check rather than a tautology:
//!
//! * **greedy block dispatch** to SM slots as they free up (the model
//!   assumes uniform synchronized rounds with a global `ceil`);
//! * **clipped boundary tiles** with their true iteration counts and
//!   footprints (the model assumes every tile is full-size);
//! * **fluid-rate resource sharing** inside an SM: resident blocks share the
//!   `n_V` issue lanes (capped per block by warp latency limits) and the
//!   SM's memory-bandwidth slice, with load/compute/store phases overlapping
//!   across blocks (the model takes a per-round `max(compute, mem)`);
//! * **per-block dispatch latency** instead of a per-round sync constant.
//!
//! Experiment E10 (`benches/model_validation.rs`) sweeps both over hardware
//! and tile configurations and reports MAPE + rank agreement.

pub mod engine;
pub mod run;
pub mod validate;

pub use engine::{BlockSpec, FluidSim, SimOutcome};
pub use run::{simulate, SimEstimate};
pub use validate::{validate_sweep, ValidationReport};
