//! Build the concrete tile schedule for one (stencil, size, hw, sw) instance
//! — with true clipped boundary tiles — and run it through the fluid engine.

use crate::area::params::HwParams;
use crate::sim::engine::{BlockSpec, FluidSim, SimMachine, SimOutcome};
use crate::stencil::defs::Stencil;
use crate::stencil::workload::ProblemSize;
use crate::timemodel::machine::MachineSpec;
use crate::timemodel::talg::SoftwareParams;
use crate::timemodel::tiling;

/// Simulator output mapped onto the model's units.
#[derive(Clone, Copy, Debug)]
pub struct SimEstimate {
    pub cycles: f64,
    pub seconds: f64,
    pub gflops: f64,
    pub outcome: SimOutcome,
    /// Total blocks simulated.
    pub blocks: u64,
}

/// Enumerate the wavefronts of the hybrid hexagonal schedule with clipped
/// boundary tiles.
///
/// Hexagons of one phase are spaced `2·avg_w` apart along S1 (the opposite
/// phase fills the gaps, offset by `avg_w`); the tile at the S1 edge is
/// clipped to the remaining extent. S2/S3 strips clip likewise. The final
/// time band clips `t_T` to the remaining steps.
pub fn build_wavefronts(
    stencil: &Stencil,
    size: &ProblemSize,
    sw: &SoftwareParams,
) -> Vec<Vec<BlockSpec>> {
    let t = &sw.tiles;
    let sigma = stencil.sigma;
    let avg_w = tiling::hex_avg_width(t.t_s1, t.t_t, sigma);
    let bytes = stencil.bytes_per_cell;

    // Clipped strip widths along S2 (and S3).
    let strips = |extent: u64, width: u64| -> Vec<f64> {
        let mut v = Vec::new();
        let mut pos = 0u64;
        while pos < extent {
            let w = width.min(extent - pos);
            v.push(w as f64);
            pos += width;
        }
        v
    };
    let s2_strips = strips(size.s2, t.t_s2);
    let s3_strips = match (stencil.is_3d(), size.s3, t.t_s3) {
        (true, Some(s3), Some(ts3)) => strips(s3, ts3),
        _ => vec![1.0],
    };

    let mut wavefronts = Vec::new();
    let mut t_done = 0u64;
    while t_done < size.t {
        let band_t = t.t_t.min(size.t - t_done) as f64;
        for phase in 0..2u32 {
            // Hexagons of this phase: centers at offset `phase·avg_w`,
            // period 2·avg_w, each covering avg_w of S1 on average.
            let offset = phase as f64 * avg_w;
            let mut blocks = Vec::new();
            let mut pos = offset;
            // Phase 0 also owns the leading partial tile when offset > 0.
            if phase == 1 && offset > 0.0 {
                blocks.extend(make_blocks(
                    stencil, bytes, band_t, offset.min(size.s1 as f64), sigma, &s2_strips,
                    &s3_strips, t,
                ));
            }
            while pos < size.s1 as f64 {
                let w1 = avg_w.min(size.s1 as f64 - pos);
                blocks.extend(make_blocks(
                    stencil, bytes, band_t, w1, sigma, &s2_strips, &s3_strips, t,
                ));
                pos += 2.0 * avg_w;
            }
            if !blocks.is_empty() {
                wavefronts.push(blocks);
            }
        }
        t_done += t.t_t;
    }
    wavefronts
}

#[allow(clippy::too_many_arguments)]
fn make_blocks(
    stencil: &Stencil,
    bytes: f64,
    band_t: f64,
    w1: f64,
    sigma: u32,
    s2_strips: &[f64],
    s3_strips: &[f64],
    t: &tiling::TileSizes,
) -> Vec<BlockSpec> {
    let sigma = sigma as f64;
    let mut out = Vec::new();
    let footprint_w1 = w1 + 2.0 * sigma * (band_t - 1.0) + 2.0 * sigma;
    for &w2 in s2_strips {
        for &w3 in s3_strips {
            let threads = (w2 * w3).max(1.0);
            let iters = band_t * w1.max(1.0);
            let load = bytes * footprint_w1 * (w2 + 2.0 * sigma) * w3_halo(stencil, w3, sigma);
            let store = bytes * w1.max(1.0) * w2 * w3;
            out.push(BlockSpec {
                threads,
                compute_lane_cycles: threads * iters * stencil.c_iter_cycles,
                load_bytes: load,
                store_bytes: store,
            });
        }
    }
    let _ = t;
    out
}

fn w3_halo(stencil: &Stencil, w3: f64, sigma: f64) -> f64 {
    if stencil.is_3d() {
        w3 + 2.0 * sigma
    } else {
        1.0
    }
}

/// Simulate one instance end to end.
pub fn simulate(
    spec: &MachineSpec,
    stencil: &Stencil,
    size: &ProblemSize,
    hw: &HwParams,
    sw: &SoftwareParams,
) -> SimEstimate {
    let wavefronts = build_wavefronts(stencil, size, sw);
    let blocks: u64 = wavefronts.iter().map(|w| w.len() as u64).sum();
    let sim = FluidSim::new(SimMachine {
        n_sm: hw.n_sm,
        n_v: hw.n_v,
        k: sw.k,
        m_sm_kb: hw.m_sm_kb,
        spec: *spec,
    });
    let outcome = sim.run(&wavefronts);
    let seconds = outcome.cycles / (spec.clock_ghz * 1e9);
    let gflops = stencil.flops_per_point * size.points() / seconds / 1e9;
    SimEstimate { cycles: outcome.cycles, seconds, gflops, outcome, blocks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::defs::{Stencil, StencilId};
    use crate::timemodel::tiling::TileSizes;

    fn jac() -> &'static Stencil {
        Stencil::get(StencilId::Jacobi2D)
    }

    #[test]
    fn wavefronts_cover_all_points() {
        let size = ProblemSize::d2(1024, 64);
        let sw = SoftwareParams::new(TileSizes::d2(32, 64, 8), 2);
        let wfs = build_wavefronts(jac(), &size, &sw);
        // Two phases per band, 8 bands.
        assert_eq!(wfs.len(), 16);
        // Lane-cycle accounting: total iterations ≈ S1·S2·T (each point once).
        let total_iters: f64 = wfs
            .iter()
            .flatten()
            .map(|b| b.compute_lane_cycles / jac().c_iter_cycles)
            .sum();
        let points = size.points();
        assert!(
            (total_iters / points - 1.0).abs() < 0.05,
            "iters {total_iters} vs points {points}"
        );
    }

    #[test]
    fn boundary_tiles_are_clipped() {
        // S2 = 100 with t_S2 = 64 -> strips 64 + 36.
        let size = ProblemSize { s1: 64, s2: 100, s3: None, t: 8 };
        let sw = SoftwareParams::new(TileSizes::d2(16, 64, 8), 1);
        let wfs = build_wavefronts(jac(), &size, &sw);
        let threads: Vec<f64> = wfs[0].iter().map(|b| b.threads).collect();
        assert!(threads.contains(&64.0) && threads.contains(&36.0), "{threads:?}");
    }

    #[test]
    fn simulate_produces_sane_estimate() {
        let size = ProblemSize::d2(512, 64);
        let sw = SoftwareParams::new(TileSizes::d2(32, 64, 8), 2);
        let est = simulate(&MachineSpec::maxwell(), jac(), &size, &HwParams::gtx980(), &sw);
        assert!(est.gflops > 1.0 && est.gflops < 10_000.0, "{}", est.gflops);
        assert!(est.blocks > 10);
        // Identical blocks complete simultaneously and share events, so the
        // event count can be far below the block count — but never zero.
        assert!(est.outcome.events > 0);
    }

    #[test]
    fn simulate_3d() {
        let st = Stencil::get(StencilId::Heat3D);
        let size = ProblemSize::d3(64, 16);
        let sw = SoftwareParams::new(TileSizes::d3(8, 32, 4, 4), 1);
        let est = simulate(&MachineSpec::maxwell(), st, &size, &HwParams::gtx980(), &sw);
        assert!(est.gflops > 0.0);
    }

    #[test]
    fn more_sms_reduce_time() {
        let size = ProblemSize::d2(2048, 32);
        let sw = SoftwareParams::new(TileSizes::d2(32, 64, 8), 2);
        let small = simulate(&MachineSpec::maxwell(), jac(), &size, &HwParams::gtx980(), &sw);
        let mut big = HwParams::gtx980();
        big.n_sm = 32;
        let fast = simulate(&MachineSpec::maxwell(), jac(), &size, &big, &sw);
        assert!(fast.seconds < small.seconds);
    }
}
