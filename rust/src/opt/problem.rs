//! Inner-problem definition and candidate grids.

use crate::area::params::HwParams;
use crate::stencil::defs::Stencil;
use crate::stencil::workload::ProblemSize;
use crate::timemodel::talg::TimeModel;

/// One inner optimization instance: fixed stencil (with its `C_iter`
/// applied), problem size and hardware point; free software parameters.
#[derive(Clone, Copy, Debug)]
pub struct InnerProblem {
    pub stencil: Stencil,
    pub size: ProblemSize,
    pub hw: HwParams,
}

/// Solver options. `PartialEq` so the batched coordinator can assert that
/// every scenario sharing one sweep solves the same inner problem.
#[derive(Clone, Debug, PartialEq)]
pub struct SolveOpts {
    /// Evaluate every feasible `k` instead of the candidate heuristic.
    pub all_k: bool,
    /// Hill-climb integer refinement around the grid optimum.
    pub refine: bool,
    /// Cap on the hexagon time height grid.
    pub max_t_t: u64,
    /// Bound-and-prune (default on): skip grid subtrees whose certified
    /// lower bound exceeds the incumbent, and let objective-driven sweep
    /// paths answer `BoundedOut` from the bound alone. Results are
    /// bit-identical either way (certified by `integration_prune.rs`);
    /// `--no-prune` forces the full-evaluation path for auditing. Included
    /// here (rather than as an engine flag) so pruned and unpruned sweeps
    /// can never share a memo store: the session partitions coordinators by
    /// `SolveOpts`, and `evals` telemetry differs between the two paths.
    pub prune: bool,
    /// Route the inner solver's grid phase through the legacy point-at-a-time
    /// evaluation loop instead of the SoA group batches (the `--scalar-eval`
    /// audit knob). Results — solutions, tie-winners, eval counts and prune
    /// telemetry — are bit-identical either way (certified by
    /// `integration_batch_eval.rs`); the batched default only changes wall
    /// clock. A `SolveOpts` field for the same reason as `prune`: the
    /// session partitions coordinators by `SolveOpts`, so the differential
    /// tier can hold both live paths in one binary without sharing a memo
    /// store between them.
    pub scalar_eval: bool,
}

impl Default for SolveOpts {
    fn default() -> Self {
        SolveOpts { all_k: false, refine: true, max_t_t: 128, prune: true, scalar_eval: false }
    }
}

impl SolveOpts {
    /// This option set with bound-and-prune disabled (the `--no-prune` CLI
    /// path).
    pub fn without_prune(mut self) -> SolveOpts {
        self.prune = false;
        self
    }

    /// This option set routed through the scalar evaluation loop (the
    /// `--scalar-eval` CLI path).
    pub fn with_scalar_eval(mut self) -> SolveOpts {
        self.scalar_eval = true;
        self
    }
}

/// Geometric-ish grid for `t_S1` (the hexagon base width). `T_alg` is smooth
/// in `t_S1` between ceil breakpoints, so a coarse grid plus local refinement
/// recovers the integer optimum (certified against [`crate::opt::exhaustive`]
/// by the property tests).
pub fn t_s1_grid(s1: u64) -> Vec<u64> {
    const GRID: [u64; 17] = [1, 2, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512];
    GRID.iter().copied().filter(|&v| v <= s1).collect()
}

/// Grid for `t_S2`: positive multiples of 32 up to the thread limit.
pub fn t_s2_grid(s2: u64, max_threads: u32) -> Vec<u64> {
    const GRID: [u64; 10] = [32, 64, 96, 128, 192, 256, 384, 512, 768, 1024];
    GRID.iter()
        .copied()
        .filter(|&v| v <= s2.max(32) && v <= max_threads as u64)
        .collect()
}

/// Grid for `t_S3` (3-D only).
pub fn t_s3_grid(s3: u64) -> Vec<u64> {
    const GRID: [u64; 9] = [1, 2, 4, 6, 8, 12, 16, 24, 32];
    GRID.iter().copied().filter(|&v| v <= s3).collect()
}

/// Grid for `t_T`: even values, denser at the small end where the
/// reuse-vs-footprint trade-off lives.
pub fn t_t_grid(t: u64, cap: u64) -> Vec<u64> {
    const GRID: [u64; 16] = [2, 4, 6, 8, 10, 12, 16, 20, 24, 32, 40, 48, 64, 80, 96, 128];
    GRID.iter().copied().filter(|&v| v <= t.max(2) && v <= cap).collect()
}

/// Candidate `k` values for given tiles: the occupancy-saturating `k`, the
/// resource-maximal `k`, and their immediate neighbours (the only points
/// where the piecewise behaviour of the round model can turn — validated
/// against all-k enumeration by `prop_invariants`).
pub fn k_candidates(
    model: &TimeModel,
    _stencil: &Stencil,
    hw: &HwParams,
    threads_per_block: u64,
    m_tile_bytes: f64,
) -> Vec<u32> {
    let m = &model.machine;
    let k_max = k_max_for(model, hw, threads_per_block, m_tile_bytes);
    if k_max == 0 {
        return Vec::new();
    }
    let k_occ = ((m.latency_factor_for(hw.m_sm_kb) * hw.n_v as f64) / threads_per_block as f64)
        .ceil() as u64;
    // Three candidates suffice: k=1 (sync-amortization floor), the
    // occupancy-saturating k, and the resource-maximal k. The ±1 neighbours
    // were measured to change no optimum across the brute-force property
    // sweep while costing ~40% more evaluations (§Perf); the refinement
    // phase still explores k±1 and the coupled tile/k_max moves.
    let (arr, n) = k_candidates_inline(k_max, k_occ);
    arr[..n].to_vec()
}

/// Allocation-free core of [`k_candidates`]: `(candidates, count)`, sorted
/// and deduplicated. The inner solver calls this once per tile vector on the
/// DSE hot path (§Perf).
pub fn k_candidates_inline(k_max: u64, k_occ: u64) -> ([u32; 3], usize) {
    let mut arr = [1u32, k_occ.clamp(1, k_max) as u32, k_max as u32];
    arr.sort_unstable();
    let mut n = 0usize;
    for i in 0..3 {
        if n == 0 || arr[i] != arr[n - 1] {
            arr[n] = arr[i];
            n += 1;
        }
    }
    (arr, n)
}

/// The raw resource cap on `k` for given tiles (shared by the solver and the
/// refinement's coupled moves).
pub fn k_max_for(
    model: &TimeModel,
    hw: &HwParams,
    threads_per_block: u64,
    m_tile_bytes: f64,
) -> u64 {
    let m = &model.machine;
    let by_blocks = m.max_blocks_per_sm as u64;
    let by_warps = (m.max_warps_per_sm as u64 * m.warp as u64) / threads_per_block.max(1);
    let by_shmem = (hw.m_sm_kb * 1024.0 / m_tile_bytes.max(1.0)).floor() as u64;
    by_blocks.min(by_warps).min(by_shmem)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::defs::{Stencil, StencilId};

    #[test]
    fn grids_respect_bounds() {
        assert!(t_s1_grid(16384).contains(&512));
        assert_eq!(t_s1_grid(5), vec![1, 2, 4]);
        assert_eq!(t_s2_grid(4096, 1024).last(), Some(&1024));
        assert_eq!(t_s2_grid(4096, 256).last(), Some(&256));
        assert!(t_t_grid(1024, 128).iter().all(|&v| v % 2 == 0));
        assert_eq!(t_t_grid(7, 128), vec![2, 4, 6]);
        assert_eq!(t_s3_grid(4), vec![1, 2, 4]);
    }

    #[test]
    fn t_s2_grid_never_empty() {
        // Even a tiny S2 must offer the minimum warp width.
        assert_eq!(t_s2_grid(8, 1024), vec![32]);
    }

    #[test]
    fn k_candidates_within_limits() {
        let model = TimeModel::maxwell();
        let st = Stencil::get(StencilId::Jacobi2D);
        let hw = HwParams::gtx980();
        let ks = k_candidates(&model, st, &hw, 128, 20_000.0);
        assert!(!ks.is_empty());
        // shmem cap: floor(98304 / 20000) = 4.
        assert!(ks.iter().all(|&k| k >= 1 && k <= 4), "{ks:?}");
        assert!(ks.contains(&4));
        assert!(ks.contains(&1));
    }

    #[test]
    fn k_candidates_empty_when_tile_too_big() {
        let model = TimeModel::maxwell();
        let st = Stencil::get(StencilId::Jacobi2D);
        let hw = HwParams::gtx980();
        assert!(k_candidates(&model, st, &hw, 128, 1e9).is_empty());
    }
}
