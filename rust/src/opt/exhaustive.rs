//! Brute-force reference solver over a fine integer grid.
//!
//! Used (a) by tests to certify that [`crate::opt::inner`] finds the true
//! optimum of the discretized problem, and (b) by the solver-cost bench (E8)
//! as the "what bonmin was up against" yardstick. Not used in production
//! sweeps.

use crate::opt::inner::InnerSolution;
use crate::opt::problem::InnerProblem;
use crate::timemodel::talg::{SoftwareParams, TimeModel};
use crate::timemodel::tiling::TileSizes;

/// Exhaustively enumerate every feasible software point with
/// `t_S1 ≤ max_t_s1`, `t_T ≤ max_t_t`, `t_S2 ≤ max_t_s2` (step 32),
/// `t_S3 ≤ max_t_s3`, and all `k ≤ MTB_SM`.
///
/// Complexity is the full product — keep the bounds small in tests.
pub fn solve_exhaustive(
    model: &TimeModel,
    p: &InnerProblem,
    max_t_s1: u64,
    max_t_s2: u64,
    max_t_s3: u64,
    max_t_t: u64,
) -> Option<InnerSolution> {
    let mut best: Option<InnerSolution> = None;
    let mut evals = 0u64;
    let s3_range: Vec<Option<u64>> = if p.stencil.is_3d() {
        (1..=max_t_s3.min(p.size.s3.unwrap_or(1))).map(Some).collect()
    } else {
        vec![None]
    };
    for t_t in (2..=max_t_t.min(p.size.t.max(2))).step_by(2) {
        for t_s2 in (32..=max_t_s2.min(p.size.s2.max(32))).step_by(32) {
            for &t_s3 in &s3_range {
                for t_s1 in 1..=max_t_s1.min(p.size.s1) {
                    let tiles = TileSizes { t_s1, t_s2, t_s3, t_t };
                    for k in 1..=model.machine.max_blocks_per_sm {
                        let sw = SoftwareParams::new(tiles, k);
                        if model.feasibility(&p.stencil, &p.hw, &sw).is_err() {
                            continue;
                        }
                        evals += 1;
                        let est = model.evaluate(&p.stencil, &p.size, &p.hw, &sw);
                        if best.as_ref().map_or(true, |b| est.seconds < b.est.seconds) {
                            best = Some(InnerSolution { sw, est, evals });
                        }
                    }
                }
            }
        }
    }
    best.map(|b| InnerSolution { evals, ..b })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::params::HwParams;
    use crate::opt::inner::solve_inner;
    use crate::opt::problem::SolveOpts;
    use crate::stencil::defs::{Stencil, StencilId};
    use crate::stencil::workload::ProblemSize;

    #[test]
    fn exhaustive_finds_a_solution() {
        let model = TimeModel::maxwell();
        let p = InnerProblem {
            stencil: *Stencil::get(StencilId::Jacobi2D),
            size: ProblemSize::d2(1024, 256),
            hw: HwParams::gtx980(),
        };
        let sol = solve_exhaustive(&model, &p, 64, 128, 1, 16).unwrap();
        assert!(sol.est.gflops > 0.0);
        assert!(sol.evals > 1000);
    }

    #[test]
    fn smart_solver_matches_exhaustive_on_radius2_3d_family() {
        // PR 3 opened the workload space beyond the six radius-1 presets;
        // the oracle certification follows: on a fully-enumerated small
        // grid (all_k removes the k heuristic from the comparison), the
        // production solver must land on the radius-2 3-D family optimum.
        use crate::stencil::spec::{Dim, StencilSpec};
        let model = TimeModel::maxwell();
        let st = *Stencil::get(StencilSpec::star(Dim::D3, 2).register());
        let size = ProblemSize::d3(32, 8);
        let opts = SolveOpts { all_k: true, refine: true, max_t_t: 8, ..SolveOpts::default() };
        let p = InnerProblem { stencil: st, size, hw: HwParams::gtx980() };
        let brute =
            solve_exhaustive(&model, &p, size.s1, size.s2, size.s3.unwrap(), opts.max_t_t)
                .expect("radius-2 star fits GTX 980 shared memory");
        let smart = solve_inner(&model, &p, &opts).expect("solver must agree on feasibility");
        assert!(
            smart.est.seconds <= brute.est.seconds * (1.0 + 1e-9),
            "smart {} ({:?}) worse than exhaustive {} ({:?})",
            smart.est.seconds,
            smart.sw,
            brute.est.seconds,
            brute.sw
        );
        let on_grid =
            smart.sw.tiles.t_s2 <= size.s2 && smart.sw.k <= model.machine.max_blocks_per_sm;
        if on_grid {
            let rel = (smart.est.seconds - brute.est.seconds).abs() / brute.est.seconds;
            assert!(rel < 1e-9, "rel {rel:e}: {:?} vs {:?}", smart.sw, brute.sw);
        }
        assert!(smart.evals < brute.evals, "smart {} vs brute {}", smart.evals, brute.evals);
    }

    #[test]
    fn smart_solver_matches_exhaustive_on_maxwell_nocache_hardware() {
        // PR 4 opened the platform space; certify the inner solver against
        // brute force under the maxwell-nocache platform's time model on a
        // cache-stripped reference point.
        let platform = crate::platform::registry::Platform::by_name("maxwell-nocache")
            .expect("preset platform");
        let model = platform.spec.time_model();
        let hw = HwParams::gtx980().without_caches();
        let p = InnerProblem {
            stencil: *Stencil::get(StencilId::Heat2D),
            size: ProblemSize::d2(1024, 256),
            hw,
        };
        let brute = solve_exhaustive(&model, &p, 96, 256, 1, 24).unwrap();
        let smart = solve_inner(&model, &p, &SolveOpts::default()).unwrap();
        assert!(
            smart.est.seconds <= brute.est.seconds * 1.03,
            "smart {} vs brute {}",
            smart.est.seconds,
            brute.est.seconds
        );
        assert!(smart.evals < brute.evals);
    }

    #[test]
    fn batched_path_certified_exactly_on_radius2_3d_star() {
        // The exhaustive oracle pins the batched SoA path (PR 8) on the same
        // radius-2 3-D point the scalar path was certified on: batched and
        // scalar land on bit-identical optima, and on a fully-enumerated
        // grid both match brute force exactly.
        use crate::stencil::spec::{Dim, StencilSpec};
        let model = TimeModel::maxwell();
        let st = *Stencil::get(StencilSpec::star(Dim::D3, 2).register());
        let size = ProblemSize::d3(32, 8);
        let opts = SolveOpts { all_k: true, refine: false, max_t_t: 8, ..SolveOpts::default() };
        let p = InnerProblem { stencil: st, size, hw: HwParams::gtx980() };
        let brute =
            solve_exhaustive(&model, &p, size.s1, size.s2, size.s3.unwrap(), opts.max_t_t)
                .expect("radius-2 star fits GTX 980 shared memory");
        let batched = solve_inner(&model, &p, &opts).expect("batched path feasible");
        let scalar = solve_inner(&model, &p, &opts.clone().with_scalar_eval())
            .expect("scalar path feasible");
        assert_eq!(
            batched.est.seconds.to_bits(),
            scalar.est.seconds.to_bits(),
            "batched {:?} vs scalar {:?}",
            batched.sw,
            scalar.sw
        );
        assert_eq!(batched.sw, scalar.sw);
        assert_eq!(batched.evals, scalar.evals);
        let rel = (batched.est.seconds - brute.est.seconds).abs() / brute.est.seconds;
        assert!(
            batched.est.seconds <= brute.est.seconds * (1.0 + 1e-9) && rel < 1e-9,
            "batched {} ({:?}) vs exhaustive {} ({:?})",
            batched.est.seconds,
            batched.sw,
            brute.est.seconds,
            brute.sw
        );
    }

    #[test]
    fn batched_path_certified_on_maxwell_nocache_point() {
        // Same oracle discipline on a cache-stripped platform point: the
        // batched path must answer bit-identically to scalar and stay within
        // the established 3% envelope of brute force.
        let platform = crate::platform::registry::Platform::by_name("maxwell-nocache")
            .expect("preset platform");
        let model = platform.spec.time_model();
        let hw = HwParams::gtx980().without_caches();
        let p = InnerProblem {
            stencil: *Stencil::get(StencilId::Heat2D),
            size: ProblemSize::d2(1024, 256),
            hw,
        };
        let brute = solve_exhaustive(&model, &p, 96, 256, 1, 24).unwrap();
        let batched = solve_inner(&model, &p, &SolveOpts::default()).unwrap();
        let scalar =
            solve_inner(&model, &p, &SolveOpts::default().with_scalar_eval()).unwrap();
        assert_eq!(batched.est.seconds.to_bits(), scalar.est.seconds.to_bits());
        assert_eq!(batched.sw, scalar.sw);
        assert_eq!(batched.evals, scalar.evals);
        assert!(
            batched.est.seconds <= brute.est.seconds * 1.03,
            "batched {} vs brute {}",
            batched.est.seconds,
            brute.est.seconds
        );
    }

    #[test]
    fn smart_solver_matches_exhaustive_on_fused_chain() {
        // PR 10 opens the workload space to fused chains; the oracle
        // certification follows the PR 3/PR 8 pattern: on a fully-enumerated
        // small grid the production solver (batched AND scalar, bit-identical
        // to each other) must land on the chain's optimum — the chain enters
        // both solvers purely through its derived characterization.
        use crate::stencil::spec::FusedChain;
        let model = TimeModel::maxwell();
        let st = *Stencil::get(
            FusedChain::parse("fuse:heat2d+laplacian2d:t2").unwrap().register(),
        );
        let size = ProblemSize::d2(64, 8);
        let opts = SolveOpts { all_k: true, refine: false, max_t_t: 8, ..SolveOpts::default() };
        let p = InnerProblem { stencil: st, size, hw: HwParams::gtx980() };
        let brute = solve_exhaustive(&model, &p, size.s1, size.s2, 1, opts.max_t_t)
            .expect("σ=4 chain fits GTX 980 shared memory on a 64² block");
        let batched = solve_inner(&model, &p, &opts).expect("chain point feasible");
        let scalar = solve_inner(&model, &p, &opts.clone().with_scalar_eval())
            .expect("scalar path feasible");
        assert_eq!(
            batched.est.seconds.to_bits(),
            scalar.est.seconds.to_bits(),
            "batched {:?} vs scalar {:?}",
            batched.sw,
            scalar.sw
        );
        assert_eq!(batched.sw, scalar.sw);
        assert_eq!(batched.evals, scalar.evals);
        assert!(
            batched.est.seconds <= brute.est.seconds * (1.0 + 1e-9),
            "smart {} ({:?}) worse than exhaustive {} ({:?})",
            batched.est.seconds,
            batched.sw,
            brute.est.seconds,
            brute.sw
        );
        let on_grid = batched.sw.tiles.t_s2 <= size.s2
            && batched.sw.k <= model.machine.max_blocks_per_sm;
        if on_grid {
            let rel = (batched.est.seconds - brute.est.seconds).abs() / brute.est.seconds;
            assert!(rel < 1e-9, "rel {rel:e}: {:?} vs {:?}", batched.sw, brute.sw);
        }
    }

    #[test]
    fn smart_solver_matches_exhaustive_on_small_instance() {
        // On an instance whose optimum lies inside the smart solver's grid
        // coverage, the two must agree closely; the smart solver may even be
        // better thanks to refinement beyond the exhaustive bounds, but must
        // never be more than 3% worse.
        let model = TimeModel::maxwell();
        for id in [StencilId::Jacobi2D, StencilId::Gradient2D] {
            let p = InnerProblem {
                stencil: *Stencil::get(id),
                size: ProblemSize::d2(1024, 256),
                hw: HwParams::gtx980(),
            };
            let brute = solve_exhaustive(&model, &p, 96, 256, 1, 24).unwrap();
            let smart = solve_inner(&model, &p, &SolveOpts::default()).unwrap();
            assert!(
                smart.est.seconds <= brute.est.seconds * 1.03,
                "{id:?}: smart {} vs brute {} ({:?} vs {:?})",
                smart.est.seconds,
                brute.est.seconds,
                smart.sw,
                brute.sw
            );
            assert!(smart.evals < brute.evals / 3, "smart not cheaper");
        }
    }
}
