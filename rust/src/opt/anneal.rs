//! The joint-problem baseline: simulated annealing over the *full* variable
//! vector of eq. (17) — hardware parameters plus every entry's tile sizes at
//! once (the paper counts 642 integer variables for the 6-benchmark mix).
//!
//! The paper dismisses the joint problem as "too large to be solved by
//! existing solvers"; this module makes that argument quantitative (bench
//! E8): annealing needs orders of magnitude more model evaluations than the
//! separable exact approach to reach a *worse* objective, because the
//! software variables are independent given the hardware — exactly the
//! structure eq. (18) exploits and a generic joint search ignores.

use crate::area::params::HwParams;
use crate::stencil::defs::Stencil;
use crate::stencil::workload::Workload;
use crate::timemodel::citer::CIterTable;
use crate::timemodel::talg::{SoftwareParams, TimeModel};
use crate::timemodel::tiling::TileSizes;
use crate::util::prng::Rng;

/// Full joint state: one hardware point + one software vector per entry.
#[derive(Clone, Debug)]
pub struct JointState {
    pub hw: HwParams,
    pub sw: Vec<SoftwareParams>,
}

/// Annealing configuration.
#[derive(Clone, Copy, Debug)]
pub struct AnnealOpts {
    pub iterations: u64,
    pub seed: u64,
    /// Initial temperature as a fraction of the initial objective.
    pub t0_frac: f64,
}

impl Default for AnnealOpts {
    fn default() -> Self {
        AnnealOpts { iterations: 50_000, seed: 7, t0_frac: 0.3 }
    }
}

/// Outcome of a joint annealing run.
#[derive(Clone, Debug)]
pub struct AnnealResult {
    pub state: JointState,
    /// Weighted objective, seconds (penalized entries excluded -> None).
    pub weighted_seconds: Option<f64>,
    /// Total model evaluations consumed.
    pub evals: u64,
    /// Number of joint variables (the paper's 642-variable count analogue).
    pub n_variables: usize,
}

const PENALTY: f64 = 1e9; // seconds, for infeasible entries

fn objective(
    model: &TimeModel,
    workload: &Workload,
    citer: &CIterTable,
    state: &JointState,
    evals: &mut u64,
) -> f64 {
    let mut acc = 0.0;
    for (entry, sw) in workload.entries.iter().zip(&state.sw) {
        let stencil = citer.apply(Stencil::get(entry.stencil));
        if model.feasibility(&stencil, &state.hw, sw).is_err() {
            // Graded penalty: over-budget shared-memory states slope back
            // towards feasibility instead of presenting a flat plateau.
            let m_tile = crate::timemodel::tiling::tile_footprint_bytes(&stencil, &sw.tiles);
            let excess = (sw.k as f64 * m_tile / (state.hw.m_sm_kb * 1024.0)).max(1.0);
            acc += entry.weight * PENALTY * excess;
            continue;
        }
        *evals += 1;
        acc += entry.weight * model.evaluate(&stencil, &entry.size, &state.hw, sw).seconds;
    }
    acc
}

fn random_sw(rng: &mut Rng, is_3d: bool) -> SoftwareParams {
    // Constraint-aware initialization (any serious MINLP run would do the
    // same): bias towards small tiles so the starting footprint usually fits.
    let t_s1 = 1 << rng.range_u64(0, 6);
    let t_s2 = 32 * rng.range_u64(1, 4);
    let t_s3 = is_3d.then(|| 1 << rng.range_u64(0, 3));
    let t_t = 2 * rng.range_u64(1, 8);
    SoftwareParams::new(TileSizes { t_s1, t_s2, t_s3, t_t }, rng.range_u64(1, 4) as u32)
}

fn mutate(rng: &mut Rng, state: &JointState, hw_feasible: &dyn Fn(&HwParams) -> bool) -> JointState {
    let mut s = state.clone();
    // With small probability move a hardware variable, else one entry's
    // software variable — mirroring a generic MINLP neighbourhood.
    if rng.bernoulli(0.1) {
        for _ in 0..64 {
            let mut hw = s.hw;
            match rng.range_u64(0, 2) {
                0 => {
                    let delta: i64 = *rng.choose(&[-2i64, 2]);
                    hw.n_sm = (hw.n_sm as i64 + delta).clamp(2, 32) as u32;
                }
                1 => {
                    let delta: i64 = *rng.choose(&[-32i64, 32, 64, -64]);
                    hw.n_v = (hw.n_v as i64 + delta).clamp(32, 2048) as u32;
                }
                _ => {
                    let delta: f64 = *rng.choose(&[-48.0, -12.0, 12.0, 48.0]);
                    hw.m_sm_kb = (hw.m_sm_kb + delta).clamp(12.0, 480.0);
                }
            }
            if hw_feasible(&hw) {
                s.hw = hw;
                break;
            }
        }
    } else {
        let i = rng.index(s.sw.len());
        let t = s.sw[i].tiles;
        let mut sw = s.sw[i];
        match rng.range_u64(0, 4) {
            0 => {
                let d: i64 = *rng.choose(&[-8i64, -2, -1, 1, 2, 8]);
                sw.tiles = TileSizes { t_s1: (t.t_s1 as i64 + d).max(1) as u64, ..t };
            }
            1 => {
                let d: i64 = *rng.choose(&[-32i64, 32]);
                sw.tiles = TileSizes { t_s2: (t.t_s2 as i64 + d).max(32) as u64, ..t };
            }
            2 => {
                let d: i64 = *rng.choose(&[-2i64, 2]);
                sw.tiles = TileSizes { t_t: (t.t_t as i64 + d).max(2) as u64, ..t };
            }
            3 => {
                if let Some(s3) = t.t_s3 {
                    let d: i64 = *rng.choose(&[-1i64, 1]);
                    sw.tiles = TileSizes { t_s3: Some((s3 as i64 + d).max(1) as u64), ..t };
                } else {
                    let d: i64 = *rng.choose(&[-1i64, 1]);
                    sw.k = (sw.k as i64 + d).clamp(1, 32) as u32;
                }
            }
            _ => {
                let d: i64 = *rng.choose(&[-1i64, 1]);
                sw.k = (sw.k as i64 + d).clamp(1, 32) as u32;
            }
        }
        s.sw[i] = sw;
    }
    s
}

/// Run the joint annealing baseline over `workload` subject to an arbitrary
/// hardware feasibility predicate (e.g. the area budget).
pub fn solve_joint(
    model: &TimeModel,
    workload: &Workload,
    citer: &CIterTable,
    hw_start: HwParams,
    hw_feasible: impl Fn(&HwParams) -> bool,
    opts: &AnnealOpts,
) -> AnnealResult {
    assert!(hw_feasible(&hw_start), "starting hardware point must be feasible");
    let mut rng = Rng::new(opts.seed);
    let mut evals = 0u64;
    let mut cur = JointState {
        hw: hw_start,
        sw: workload
            .entries
            .iter()
            .map(|e| random_sw(&mut rng, Stencil::get(e.stencil).is_3d()))
            .collect(),
    };
    let n_variables = 3 + cur
        .sw
        .iter()
        .map(|sw| 4 + sw.tiles.t_s3.map(|_| 1).unwrap_or(0) + 5 /* aux floor/ceil vars */)
        .sum::<usize>();

    let mut cur_obj = objective(model, workload, citer, &cur, &mut evals);
    let mut best = cur.clone();
    let mut best_obj = cur_obj;
    let t0 = cur_obj.max(1e-6) * opts.t0_frac;
    for it in 0..opts.iterations {
        let temp = t0 * (1.0 - it as f64 / opts.iterations as f64).max(1e-4);
        let cand = mutate(&mut rng, &cur, &hw_feasible);
        let cand_obj = objective(model, workload, citer, &cand, &mut evals);
        let accept = cand_obj <= cur_obj || rng.f64() < ((cur_obj - cand_obj) / temp).exp();
        if accept {
            cur = cand;
            cur_obj = cand_obj;
            if cur_obj < best_obj {
                best = cur.clone();
                best_obj = cur_obj;
            }
        }
    }
    let weighted_seconds = (best_obj < PENALTY / 2.0).then_some(best_obj);
    AnnealResult { state: best, weighted_seconds, evals, n_variables }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::problem::SolveOpts;
    use crate::opt::separable::solve_hardware_point;

    fn small_workload() -> Workload {
        let mut w = Workload::uniform_2d();
        w.entries.truncate(4);
        let total: f64 = w.entries.iter().map(|e| e.weight).sum();
        for e in &mut w.entries {
            e.weight /= total;
        }
        w
    }

    #[test]
    fn anneal_finds_feasible_solution() {
        let model = TimeModel::maxwell();
        let w = small_workload();
        let res = solve_joint(
            &model,
            &w,
            &CIterTable::paper(),
            HwParams::gtx980(),
            |_| true,
            &AnnealOpts { iterations: 3000, ..Default::default() },
        );
        assert!(res.weighted_seconds.is_some());
        assert!(res.evals > 0);
    }

    #[test]
    fn variable_count_scales_like_paper() {
        // 6 stencils × 25 sizes ≈ the paper's 642-variable claim shape:
        // 10 vars per (c, Sz) instance + 2 extra hardware vars beyond n_SM.
        let model = TimeModel::maxwell();
        let w = small_workload();
        let res = solve_joint(
            &model,
            &w,
            &CIterTable::paper(),
            HwParams::gtx980(),
            |_| true,
            &AnnealOpts { iterations: 10, ..Default::default() },
        );
        assert_eq!(res.n_variables, 3 + 4 * 9);
    }

    #[test]
    fn separable_beats_annealing_given_equal_hardware() {
        let model = TimeModel::maxwell();
        let w = small_workload();
        let citer = CIterTable::paper();
        let hw = HwParams::gtx980();
        let exact = solve_hardware_point(&model, &w, &citer, &hw, &SolveOpts::default());
        let sa = solve_joint(
            &model,
            &w,
            &citer,
            hw,
            |h| *h == hw, // pin hardware: compare software search only
            &AnnealOpts { iterations: 8000, ..Default::default() },
        );
        let exact_t = exact.weighted_seconds.unwrap();
        let sa_t = sa.weighted_seconds.unwrap();
        assert!(
            exact_t <= sa_t * 1.0001,
            "separable exact {exact_t} should beat annealing {sa_t}"
        );
    }
}
