//! The non-linear mixed-integer optimization substrate (§IV).
//!
//! The paper solves problem (17) — minimize workload-weighted `T_alg` over
//! hardware *and* software parameters — by the separability transformation
//! (18): exhaustive search over hardware points, and for each hardware point
//! an independent *inner problem* per (stencil, size) pair over the ~10
//! integer software variables (tile sizes, hyperthreading factor, plus the
//! auxiliary floor/ceil variables that our evaluator computes directly).
//! The paper hands the inner problem to bonmin (≈ 19 s per instance); we
//! solve it exactly over the constraint-pruned candidate grid:
//!
//! * [`inner`] — the production inner solver: constraint-directed candidate
//!   enumeration with a monotonicity-based `k` selection and local integer
//!   refinement around the grid optimum (µs–ms per instance).
//! * [`bounds`] — certified analytical lower bounds on `T_alg`
//!   (compute/bandwidth rooflines tightened by the shared-memory resident
//!   cap): the bound-and-prune substrate behind [`inner`]'s subtree pruning
//!   and the sweep engine's `BoundedOut` fast path.
//! * [`exhaustive`] — a brute-force reference solver over a *fine* grid,
//!   used by tests and the solver-cost bench to certify [`inner`].
//! * [`separable`] — the eq. (18) driver: workload-weighted objective for
//!   one hardware point from memoizable inner solutions.
//! * [`anneal`] — the joint 600-odd-variable baseline (simulated annealing
//!   over hardware and all tile vectors simultaneously), reproducing the
//!   paper's argument that the unstructured problem is computationally
//!   infeasible (E8).

pub mod anneal;
pub mod bounds;
pub mod exhaustive;
pub mod inner;
pub mod problem;
pub mod separable;

pub use bounds::{lower_bound, lower_bound_entry, PruneStats, PRUNE_SLACK};
pub use inner::{solve_inner, solve_inner_cut, InnerOutcome, InnerSolution};
pub use problem::{InnerProblem, SolveOpts};
pub use separable::{aggregate_weighted, solve_entry, solve_hardware_point, HardwarePointSolution};
