//! Certified analytical lower bounds on `T_alg` — the bound-and-prune
//! substrate of the sweep engine.
//!
//! Every bound here is provably ≤ the model value of **every feasible
//! [`SoftwareParams`](crate::timemodel::talg::SoftwareParams)** in its scope
//! (the whole instance, one `t_T` subtree, or one `(t_T, t_S2, t_S3)` group),
//! so skipping a subtree whose bound exceeds the incumbent can never change
//! which optimum a search returns. The derivation walks the exact terms of
//! [`TimeModel::evaluate_pre`] (DESIGN.md §5 has the full argument):
//!
//! * **Compute roofline.** A round's compute phase issues at
//!   `issue_lanes = min(n_V, resident/λ)` lane-ops per cycle per SM, and the
//!   lane-work charged over all rounds is at least the real iteration count:
//!   tile coverage satisfies `total_blocks · threads · iters_per_thread ≥
//!   S1·S2(·S3)·T` (each ceil only over-covers). Hence total compute cycles
//!   `≥ points · C_iter / (n_SM · issue_cap)`.
//! * **Resident-thread cap.** `issue_cap` itself is bounded by shared
//!   memory: `k · M_tile ≤ M_SM` with `threads ≤ w2·w3` gives `resident =
//!   k·threads ≤ M_SM / (bytes · n_buf · w1_min)` where `w1_min =
//!   1 + 2σ(t_T − 1) + 2σ` is the narrowest possible staged hexagon row at
//!   this `t_T`. Large time tiles therefore *cannot* hide latency — the term
//!   that gives the per-`t_T` bound its interior minimum.
//! * **Bandwidth roofline.** Per block, `traffic ≥ 2 · out_bytes` (the
//!   staged footprint is never smaller than the written face), and summed
//!   over all blocks `out ≥ bytes · points / t_T`; each SM streams its own
//!   bandwidth slice, so total memory cycles `≥ 2 · bytes · points /
//!   (t_T · n_SM · B_cyc)`.
//! * **Sync floor.** Every wavefront dispatches at least one round:
//!   `rounds ≥ 2 · ceil(T / t_T)`.
//!
//! Compute and memory phases overlap (`max`), sync does not, so
//! `cycles ≥ max(compute_lb, mem_lb) + sync_lb`. A final `1 − 1e-9` safety
//! factor absorbs f64 rounding in the derivation chain; it only ever makes
//! the bound smaller (= prune less), never unsound.
//!
//! Every term above is parametric in the stencil's six characterization
//! fields (σ, flops, buffers, bytes, `C_iter`, dimensionality) and monotone
//! in each — nothing assumes a preset radius or a single kernel. A fused
//! chain (DESIGN.md §10) enters as exactly such a characterization (its
//! macro step carries the fused halo as σ and the redundancy-inflated
//! `C_iter`), so the one-sided derivation holds verbatim over composed
//! kernels; `chain_bounds_sound_on_sample_evaluations` and the differential
//! prune tier re-certify it over the deeper-σ regime chains reach.
//!
//! The instance-level bound additionally needs the *feasible* `t_T` range:
//! `t_T ≤ opts.max_t_t` (nothing the solver — grid or refinement — ever
//! evaluates exceeds it) and the shared-memory cap from `w1_min` above.
//! [`lower_bound`] returning `f64::INFINITY` is *equivalent* to the instance
//! having no feasible software point at all (certified by
//! `prop_lower_bound_finite_iff_feasible`), which is what lets the gated
//! Pareto path count feasible/infeasible designs without solving them.

use crate::area::model::AreaBreakdown;
use crate::area::params::HwParams;
use crate::codesign::power::PowerModel;
use crate::opt::problem::{self, SolveOpts};
use crate::stencil::defs::Stencil;
use crate::stencil::workload::{ProblemSize, WorkloadEntry};
use crate::timemodel::citer::CIterTable;
use crate::timemodel::talg::TimeModel;

/// Subtree-pruning slack: a grid subtree is skipped only when its bound
/// exceeds `incumbent × PRUNE_SLACK`. The value is pinned to the refinement
/// phase's start-retention cutoff in `opt::inner` (starts with
/// `est > best × 1.25` are discarded there), which is exactly what makes
/// pruning invisible: every pruned point is strictly worse than
/// `final_best × 1.25`, so it could neither become the incumbent nor survive
/// as a refinement start.
pub const PRUNE_SLACK: f64 = 1.25;

/// One-sided f64 safety margin on every bound (see module docs).
const SAFETY: f64 = 1.0 - 1e-9;

/// Pruning + evaluation-shape telemetry: how much bound-and-prune work a
/// solve / sweep did, and the shape of the grid enumeration it ran.
///
/// The three prune counters (`bounds_computed`, `subtrees_cut`,
/// `bounded_out`) are zero on the `--no-prune` path. The two shape counters
/// (`groups_evaluated`, `lanes_evaluated`) tick on every path — and tick
/// **identically** on the batched and `--scalar-eval` evaluation paths;
/// every counter here is path-invariant by design, which is what lets the
/// batched-evaluation differential tier (`integration_batch_eval.rs`)
/// assert whole-struct equality instead of carving out exceptions.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Lower-bound evaluations (each a handful of flops). Granularity
    /// follows the consumer: the inner solver ticks once per subtree/group
    /// bound, the gated sweep paths once per instance-level [`lower_bound`]
    /// (itself a loop of per-`t_T` bounds) — a work indicator, not a count
    /// of comparable units.
    pub bounds_computed: u64,
    /// Grid subtrees ((t_T) or (t_T, t_S2, t_S3)) skipped inside the inner
    /// solver.
    pub subtrees_cut: u64,
    /// Whole instances answered `BoundedOut` (never evaluated) because their
    /// bound already exceeded the caller's cutoff.
    pub bounded_out: u64,
    /// `(t_T, t_S2[, t_S3])` grid groups whose candidate lanes were
    /// evaluated (survived the subtree + group prunes). Identical across
    /// the batched and scalar evaluation paths.
    pub groups_evaluated: u64,
    /// Candidate `(t_S1, k)` lanes evaluated in the grid phase (refinement
    /// evaluations are counted in `evals`, not here). Identical across the
    /// batched and scalar evaluation paths.
    pub lanes_evaluated: u64,
}

impl PruneStats {
    pub fn add(&mut self, other: &PruneStats) {
        self.bounds_computed += other.bounds_computed;
        self.subtrees_cut += other.subtrees_cut;
        self.bounded_out += other.bounded_out;
        self.groups_evaluated += other.groups_evaluated;
        self.lanes_evaluated += other.lanes_evaluated;
    }
}

/// Largest `t_T` any feasible software point of this instance can carry:
/// the solver's own cap, clamped by shared memory (`w1_min(t_T)` staged at
/// `t_S2 = 32`, `t_S3 = 1` must fit `M_SM` — larger tiles only grow the
/// footprint). Returns 0 when not even `t_T = 2` fits (no feasible point).
pub fn t_t_cap(stencil: &Stencil, hw: &HwParams, max_t_t: u64) -> u64 {
    let sigma = stencil.sigma as f64;
    let w3 = if stencil.is_3d() { 1.0 + 2.0 * sigma } else { 1.0 };
    let denom = stencil.bytes_per_cell * stencil.n_buffers * (32.0 + 2.0 * sigma) * w3;
    if denom <= 0.0 {
        return 0;
    }
    // footprint(t_S1 = 1, t_T) = denom · (1 + 2σ(t_T − 1) + 2σ) ≤ M_SM·1024.
    let a = hw.m_sm_kb * 1024.0 / denom - 1.0 - 2.0 * sigma;
    if a < 2.0 * sigma {
        return 0; // t_T = 2 already busts shared memory
    }
    let cap = (1.0 + a / (2.0 * sigma)).floor() as u64;
    cap.min(max_t_t)
}

/// Lower bound (seconds) over every feasible software point whose time-tile
/// height is exactly `t_t`. `INFINITY` when no such point exists.
pub fn lower_bound_tt(
    model: &TimeModel,
    stencil: &Stencil,
    size: &ProblemSize,
    hw: &HwParams,
    t_t: u64,
) -> f64 {
    let m = &model.machine;
    let sigma = stencil.sigma as f64;
    let points = size.points();
    // Shared memory caps resident threads per SM (see module docs).
    let w1_min = 1.0 + 2.0 * sigma * (t_t as f64 - 1.0) + 2.0 * sigma;
    let mut resident_cap =
        hw.m_sm_kb * 1024.0 / (stencil.bytes_per_cell * stencil.n_buffers * w1_min);
    resident_cap = resident_cap.min((m.max_warps_per_sm * m.warp) as f64);
    if resident_cap < 1.0 {
        return f64::INFINITY;
    }
    let lam = m.latency_factor_for(hw.m_sm_kb);
    let issue_cap = (hw.n_v as f64).min(resident_cap / lam);
    let cc_lb = points * stencil.c_iter_cycles / (hw.n_sm as f64 * issue_cap);
    let mem_lb = 2.0 * stencil.bytes_per_cell * points
        / t_t as f64
        / (hw.n_sm as f64 * m.bytes_per_cycle_per_sm());
    let sync_lb = 2.0 * (size.t as f64 / t_t as f64).ceil() * m.sync_cycles;
    let cycles = cc_lb.max(mem_lb) + sync_lb;
    cycles / (m.clock_ghz * 1e9) * SAFETY
}

/// Lower bound (seconds) over every feasible `(t_S1, k)` completion of one
/// `(t_T, t_S2, t_S3)` grid group. Tighter than [`lower_bound_tt`]: with the
/// thread shape known, the resource-maximal `k` (blocks, warps, shared
/// memory at the minimal `t_S1 = 1` footprint) caps the issue rate exactly.
pub fn lower_bound_group(
    model: &TimeModel,
    stencil: &Stencil,
    size: &ProblemSize,
    hw: &HwParams,
    t_t: u64,
    t_s2: u64,
    t_s3: Option<u64>,
) -> f64 {
    use crate::timemodel::tiling::{self, TileSizes};
    let m = &model.machine;
    let threads = t_s2 * t_s3.unwrap_or(1);
    if threads > m.max_threads_per_block as u64 {
        return f64::INFINITY;
    }
    let min_tile = TileSizes { t_s1: 1, t_s2, t_s3, t_t };
    let min_fp = tiling::tile_footprint_bytes(stencil, &min_tile);
    let k_cap = problem::k_max_for(model, hw, threads, min_fp);
    if k_cap == 0 {
        return f64::INFINITY;
    }
    let points = size.points();
    let lam = m.latency_factor_for(hw.m_sm_kb);
    let issue_cap = (hw.n_v as f64).min(k_cap as f64 * threads as f64 / lam);
    let cc_lb = points * stencil.c_iter_cycles / (hw.n_sm as f64 * issue_cap);
    let mem_lb = 2.0 * stencil.bytes_per_cell * points
        / t_t as f64
        / (hw.n_sm as f64 * m.bytes_per_cycle_per_sm());
    let sync_lb = 2.0 * (size.t as f64 / t_t as f64).ceil() * m.sync_cycles;
    let cycles = cc_lb.max(mem_lb) + sync_lb;
    cycles / (m.clock_ghz * 1e9) * SAFETY
}

/// Certified lower bound (seconds) on the inner problem's optimum: the
/// minimum of [`lower_bound_tt`] over every even `t_T` the instance can
/// feasibly carry under `opts`. `INFINITY` iff no feasible software point
/// exists at all (the inner solver would return `None`).
pub fn lower_bound(
    model: &TimeModel,
    stencil: &Stencil,
    size: &ProblemSize,
    hw: &HwParams,
    opts: &SolveOpts,
) -> f64 {
    let cap = t_t_cap(stencil, hw, opts.max_t_t);
    if cap < 2 {
        return f64::INFINITY;
    }
    let mut best = f64::INFINITY;
    let mut t_t = 2;
    while t_t <= cap {
        let b = lower_bound_tt(model, stencil, size, hw, t_t);
        if b < best {
            best = b;
        }
        t_t += 2;
    }
    best
}

/// [`lower_bound`] for one workload entry, with the scenario's `C_iter`
/// applied — the per-entry term of an objective-level cutoff
/// `Σ wᵢ · lower_bound_entry(i) ≤ Σ wᵢ · Tᵢ`.
pub fn lower_bound_entry(
    model: &TimeModel,
    citer: &CIterTable,
    hw: &HwParams,
    entry: &WorkloadEntry,
    opts: &SolveOpts,
) -> f64 {
    let stencil = citer.apply(Stencil::get(entry.stencil));
    lower_bound(model, &stencil, &entry.size, hw, opts)
}

/// Certified floor (W) on [`PowerModel::power_w`] at `active_sm_frac = 1`
/// — the configuration the energy objective charges
/// (`codesign::energy::weighted_power_w` evaluates every phase fully
/// active): leakage over the whole die (`leakage · (sm_area + l2) =
/// leakage · total`) plus the constant baseboard draw. Both dynamic terms
/// are ≥ 0, so every per-phase power — and therefore every time-weighted
/// average of them — is ≥ this floor. Per-design, not per-entry: the floor
/// depends only on the hardware point's area breakdown.
pub fn power_floor_w(power: &PowerModel, breakdown: &AreaBreakdown) -> f64 {
    power.leakage_w_per_mm2 * breakdown.total() + power.base_w
}

/// Certified lower bound (J per sweep-unit) on a design's workload energy:
/// [`power_floor_w`] × a certified lower bound on its weighted seconds
/// (`Σ wᵢ · lower_bound_entry(i)`).
///
/// Soundness composes one-sidedly: true energy is
/// `avg_power × weighted_seconds`, the average of per-phase powers each
/// ≥ the floor is ≥ the floor, and `weighted_seconds ≥ weighted_seconds_lb`
/// (each with the seconds bound's strict `1 − 1e-9` safety margin). The
/// product is therefore **strictly below** the measured energy of any
/// feasible design — which is what lets the tri-objective gate treat
/// "some front entry is ≤ the candidate's optimistic energy corner" as
/// strict domination. Finite ⟺ feasible is inherited from the seconds
/// bound: the floor is finite and positive, so the energy bound is
/// `INFINITY` exactly when [`lower_bound`] is.
pub fn energy_lower_bound(
    power: &PowerModel,
    breakdown: &AreaBreakdown,
    weighted_seconds_lb: f64,
) -> f64 {
    power_floor_w(power, breakdown) * weighted_seconds_lb
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::defs::StencilId;
    use crate::timemodel::talg::SoftwareParams;
    use crate::timemodel::tiling::TileSizes;

    fn model() -> TimeModel {
        TimeModel::maxwell()
    }

    #[test]
    fn bound_is_below_sample_evaluations() {
        let m = model();
        let st = Stencil::get(StencilId::Jacobi2D);
        let hw = HwParams::gtx980();
        let size = ProblemSize::d2(8192, 4096);
        let lb = lower_bound(&m, st, &size, &hw, &SolveOpts::default());
        assert!(lb.is_finite() && lb > 0.0);
        for (tiles, k) in [
            (TileSizes::d2(32, 64, 8), 2),
            (TileSizes::d2(64, 128, 16), 4),
            (TileSizes::d2(1, 96, 12), 5),
        ] {
            let sw = SoftwareParams::new(tiles, k);
            assert!(m.feasibility(st, &hw, &sw).is_ok());
            let est = m.evaluate(st, &size, &hw, &sw);
            assert!(lb <= est.seconds, "lb {lb} vs {}", est.seconds);
            let tt_lb = lower_bound_tt(&m, st, &size, &hw, tiles.t_t);
            assert!(tt_lb <= est.seconds, "tt lb {tt_lb} vs {}", est.seconds);
            let g_lb =
                lower_bound_group(&m, st, &size, &hw, tiles.t_t, tiles.t_s2, tiles.t_s3);
            assert!(g_lb <= est.seconds, "group lb {g_lb} vs {}", est.seconds);
        }
    }

    #[test]
    fn group_bound_dominates_subtree_bound() {
        // The group bound only adds information, so it can never be below
        // the t_T bound it refines.
        let m = model();
        let st = Stencil::get(StencilId::Heat3D);
        let hw = HwParams::gtx980();
        let size = ProblemSize::d3(256, 128);
        for t_t in [2u64, 8, 16] {
            let tt = lower_bound_tt(&m, st, &size, &hw, t_t);
            for t_s2 in [32u64, 128] {
                let g = lower_bound_group(&m, st, &size, &hw, t_t, t_s2, Some(4));
                assert!(g >= tt, "t_t {t_t} t_s2 {t_s2}: group {g} < subtree {tt}");
            }
        }
    }

    #[test]
    fn chain_bounds_sound_on_sample_evaluations() {
        // The bound derivation is parametric in the characterization, so a
        // fused chain's deeper σ and heavier C_iter must still bound every
        // feasible evaluation from below — sampled across the chain's
        // feasible tile range.
        use crate::stencil::spec::FusedChain;
        let m = model();
        let st = Stencil::get(FusedChain::parse("fuse:heat2d+laplacian2d:t2").unwrap().register());
        let hw = HwParams::gtx980();
        let size = ProblemSize::d2(4096, 1024);
        let lb = lower_bound(&m, st, &size, &hw, &SolveOpts::default());
        assert!(lb.is_finite() && lb > 0.0, "chain instance must be feasible: {lb}");
        let mut checked = 0;
        for (tiles, k) in [
            (TileSizes::d2(32, 64, 2), 2),
            (TileSizes::d2(16, 96, 4), 3),
            (TileSizes::d2(1, 32, 2), 1),
        ] {
            let sw = SoftwareParams::new(tiles, k);
            if m.feasibility(st, &hw, &sw).is_err() {
                continue;
            }
            checked += 1;
            let est = m.evaluate(st, &size, &hw, &sw);
            assert!(lb <= est.seconds, "lb {lb} vs {}", est.seconds);
            let tt_lb = lower_bound_tt(&m, st, &size, &hw, tiles.t_t);
            assert!(tt_lb <= est.seconds, "tt lb {tt_lb} vs {}", est.seconds);
            let g_lb = lower_bound_group(&m, st, &size, &hw, tiles.t_t, tiles.t_s2, tiles.t_s3);
            assert!(g_lb <= est.seconds, "group lb {g_lb} vs {}", est.seconds);
        }
        assert!(checked >= 2, "chain sample points must mostly be feasible ({checked})");
        // σ = 4 shrinks the feasible time-tile range vs the σ = 1 presets.
        let cap = t_t_cap(st, &hw, 1 << 20);
        let preset_cap = t_t_cap(Stencil::get(StencilId::Heat2D), &hw, 1 << 20);
        assert!(cap > 0 && cap < preset_cap, "chain cap {cap} vs preset {preset_cap}");
    }

    #[test]
    fn infeasible_instance_bounds_to_infinity() {
        let m = model();
        let st = Stencil::get(StencilId::Jacobi2D);
        let mut hw = HwParams::gtx980();
        hw.m_sm_kb = 0.25; // nothing fits — same setup inner.rs certifies as None
        let lb = lower_bound(&m, st, &ProblemSize::d2(4096, 1024), &hw, &SolveOpts::default());
        assert!(lb.is_infinite());
        assert_eq!(t_t_cap(st, &hw, 128), 0);
    }

    #[test]
    fn t_t_cap_shrinks_with_radius_and_memory() {
        let st1 = Stencil::get(StencilId::Jacobi2D);
        let hw = HwParams::gtx980();
        let cap1 = t_t_cap(st1, &hw, 1 << 20);
        assert!(cap1 > 128, "96 kB allows deep time tiles at sigma 1: {cap1}");
        let mut small = hw;
        small.m_sm_kb = 12.0;
        assert!(t_t_cap(st1, &small, 1 << 20) < cap1);
        // The solver cap clamps.
        assert_eq!(t_t_cap(st1, &hw, 128), 128);
    }

    #[test]
    fn instance_bound_has_interior_minimum() {
        // The resident-thread cap makes very deep time tiles latency-starved,
        // so the best t_T is interior — neither 2 nor the cap.
        let m = model();
        let st = Stencil::get(StencilId::Jacobi2D);
        let hw = HwParams { n_sm: 8, n_v: 256, m_sm_kb: 96.0, ..HwParams::gtx980() };
        let size = ProblemSize::d2(12288, 2048);
        let opts = SolveOpts::default();
        let lb = lower_bound(&m, st, &size, &hw, &opts);
        let at_2 = lower_bound_tt(&m, st, &size, &hw, 2);
        let cap = t_t_cap(st, &hw, opts.max_t_t);
        let at_cap = lower_bound_tt(&m, st, &size, &hw, cap);
        assert!(lb < at_2, "lb {lb} vs t_T=2 {at_2}");
        assert!(lb < at_cap, "lb {lb} vs t_T=cap {at_cap}");
    }

    #[test]
    fn power_floor_is_below_power_of_sampled_phases() {
        // Every fully-active power evaluation the energy accumulation can
        // produce sits at or above the floor — over real solver-shaped
        // estimates from several stencils, sizes and software points.
        let m = model();
        let hw = HwParams::gtx980();
        let power = PowerModel::maxwell();
        let breakdown = crate::area::model::AreaModel::paper().breakdown(&hw);
        let floor = power_floor_w(&power, &breakdown);
        assert!(floor.is_finite() && floor > 0.0);
        for (st_id, size) in [
            (StencilId::Jacobi2D, ProblemSize::d2(8192, 4096)),
            (StencilId::Heat2D, ProblemSize::d2(4096, 1024)),
        ] {
            let st = Stencil::get(st_id);
            for (tiles, k) in [
                (TileSizes::d2(32, 64, 8), 2),
                (TileSizes::d2(64, 128, 16), 4),
                (TileSizes::d2(1, 96, 12), 5),
            ] {
                let sw = SoftwareParams::new(tiles, k);
                assert!(m.feasibility(st, &hw, &sw).is_ok());
                let est = m.evaluate(st, &size, &hw, &sw);
                let pw = power.power_w(&hw, &breakdown, &est, &m.machine, 1.0);
                assert!(
                    floor <= pw,
                    "{st_id:?} {tiles:?}: floor {floor} above power {pw}"
                );
            }
        }
    }

    #[test]
    fn energy_bound_composes_one_sidedly() {
        // energy_lb = floor × ws_lb ≤ avg_power × ws whenever
        // avg_power ≥ floor and ws ≥ ws_lb — the exact shape the gated
        // sweep relies on. Also: finite ⟺ feasible inherited from the
        // seconds bound.
        let m = model();
        let st = Stencil::get(StencilId::Jacobi2D);
        let hw = HwParams::gtx980();
        let power = PowerModel::maxwell();
        let breakdown = crate::area::model::AreaModel::paper().breakdown(&hw);
        let size = ProblemSize::d2(8192, 4096);
        let ws_lb = lower_bound(&m, st, &size, &hw, &SolveOpts::default());
        assert!(ws_lb.is_finite() && ws_lb > 0.0);
        let elb = energy_lower_bound(&power, &breakdown, ws_lb);
        assert!(elb.is_finite() && elb > 0.0);
        let sw = SoftwareParams::new(TileSizes::d2(32, 64, 8), 2);
        let est = m.evaluate(st, &size, &hw, &sw);
        let pw = power.power_w(&hw, &breakdown, &est, &m.machine, 1.0);
        assert!(ws_lb <= est.seconds);
        assert!(elb <= pw * est.seconds, "energy lb {elb} above {}", pw * est.seconds);

        // Infeasible instance → infinite seconds bound → infinite energy bound.
        let mut tiny = hw;
        tiny.m_sm_kb = 0.25;
        let inf = lower_bound(&m, st, &ProblemSize::d2(4096, 1024), &tiny, &SolveOpts::default());
        assert!(inf.is_infinite());
        let tiny_breakdown = crate::area::model::AreaModel::paper().breakdown(&tiny);
        assert!(energy_lower_bound(&power, &tiny_breakdown, inf).is_infinite());
    }

    #[test]
    fn entry_bound_respects_citer_override() {
        // Doubling C_iter can only raise (or keep) the bound.
        let m = model();
        let hw = HwParams::gtx980();
        let entry = WorkloadEntry {
            stencil: StencilId::Jacobi2D,
            size: ProblemSize::d2(8192, 4096),
            weight: 1.0,
        };
        let opts = SolveOpts::default();
        let base = lower_bound_entry(&m, &CIterTable::paper(), &hw, &entry, &opts);
        let doubled = CIterTable::with_measured(&[(StencilId::Jacobi2D, 22.0)]);
        let scaled = lower_bound_entry(&m, &doubled, &hw, &entry, &opts);
        assert!(scaled >= base);
    }
}
