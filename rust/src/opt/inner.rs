//! The production inner solver: exact minimization of `T_alg` over the
//! software parameters for one (stencil, size, hardware) instance.
//!
//! Strategy (replacing the paper's bonmin):
//! 1. enumerate the constraint-pruned candidate grid
//!    (`t_T × t_S2 [× t_S3] × t_S1`), `t_T` subtrees in ascending order of
//!    their certified lower bound ([`crate::opt::bounds`]) so the incumbent
//!    tightens as early as possible, skipping whole subtrees whose minimal
//!    footprint already violates the shared-memory constraint;
//! 2. with pruning enabled (the default), skip `t_T` subtrees and
//!    `(t_T, t_S2, t_S3)` groups whose lower bound exceeds
//!    `incumbent × PRUNE_SLACK` — provably invisible: every skipped point is
//!    strictly worse than `final_best × 1.25`, so it could neither become
//!    the incumbent (updates require a strict improvement) nor survive as a
//!    refinement start (the start filter discards anything above
//!    `best × 1.25`). `--no-prune` evaluates the identical enumeration
//!    without the skips; results are certified bit-identical by
//!    `integration_prune.rs`;
//! 3. per tile vector, evaluate only the candidate `k` values where the
//!    piecewise round model can turn ([`problem::k_candidates`]);
//! 4. evaluate each surviving `(t_T, t_S2[, t_S3])` group as one SoA batch
//!    (DESIGN.md §8): *fill* the group's candidate `(t_S1, k)` lanes into
//!    [`LaneBatch`] columns in canonical enumeration order, *eval* them
//!    through the shared [`crate::timemodel::talg::eval_lane`] kernel in
//!    one flat loop with the instance invariants
//!    ([`TimeModel::invariants`]) and the group
//!    geometry ([`tiling::group_geometry`]) hoisted, then *scan* the
//!    results in lane order for the strict-improvement incumbent updates.
//!    Bounds are only consulted at subtree/group entry — never between the
//!    lanes of a group — so the batched incumbent trajectory is the scalar
//!    one. `--scalar-eval` ([`SolveOpts::scalar_eval`]) keeps the legacy
//!    point-at-a-time loop callable; `integration_batch_eval.rs` certifies
//!    the two paths bit-identical (solutions, ties, telemetry);
//! 5. optionally hill-climb integer refinement around the incumbent
//!    (`t_S1 ± δ`, `t_T ± 2`, `t_S2 ± 32`, `k ± 1`).
//!
//! The result is certified against brute force by `exhaustive` in the
//! property tests, and is typically 4–6 orders of magnitude faster than the
//! paper's 19 s/instance average.

use crate::opt::bounds::{self, PruneStats, PRUNE_SLACK};
use crate::opt::problem::{self, InnerProblem, SolveOpts};
use crate::timemodel::batch::{LaneBatch, LANE_CAPACITY_HINT};
use crate::timemodel::talg::{SoftwareParams, TimeEstimate, TimeModel};
use crate::timemodel::tiling::{self, TileSizes};

/// Best software parameters found for one instance.
#[derive(Clone, Copy, Debug)]
pub struct InnerSolution {
    pub sw: SoftwareParams,
    pub est: TimeEstimate,
    /// Model evaluations spent (for the solver-cost experiment E8).
    pub evals: u64,
}

/// What a cutoff-aware inner solve can answer (see [`solve_inner_cut`]).
#[derive(Clone, Copy, Debug)]
pub enum InnerOutcome {
    /// The exact optimum, identical to what [`solve_inner`] returns.
    Solved(InnerSolution),
    /// No feasible software point exists.
    Infeasible,
    /// The instance's certified lower bound already meets the caller's
    /// cutoff: its exact optimum is **strictly** above every bound (the
    /// bound carries a one-sided safety margin), so it cannot beat — or even
    /// tie — an incumbent at the cutoff. Nothing was evaluated.
    BoundedOut {
        /// The instance-level bound that killed it (what the memo cache
        /// records so later exact consumers re-solve instead of aliasing).
        bound_seconds: f64,
    },
}

impl InnerOutcome {
    /// The exact solution, if this outcome carries one.
    pub fn solved(self) -> Option<InnerSolution> {
        match self {
            InnerOutcome::Solved(s) => Some(s),
            _ => None,
        }
    }
}

/// Number of distinct (t_S2, t_S3) groups whose incumbents seed the
/// refinement phase. Single-start refinement gets trapped in local minima of
/// the ceil-quantized landscape (e.g. the grid optimum at t_S2 = 32 hiding a
/// better basin at t_S2 = 64); a handful of diverse starts closes the gap to
/// brute force (certified by `prop_smart_solver_matches_brute_force_…`).
const REFINE_STARTS: usize = 12;

/// Solve one inner instance. Returns `None` when no feasible software point
/// exists (e.g. the minimal tile footprint exceeds `M_SM`).
pub fn solve_inner(model: &TimeModel, p: &InnerProblem, opts: &SolveOpts) -> Option<InnerSolution> {
    solve_inner_cut(model, p, opts, None, &mut PruneStats::default()).solved()
}

/// [`solve_inner`] with an optional objective cutoff and pruning telemetry.
///
/// With `cutoff: Some(c)` and pruning enabled, the solver first evaluates
/// the instance's certified lower bound; when it already reaches `c`, the
/// instance is answered [`InnerOutcome::BoundedOut`] without a single model
/// evaluation — the fast-exit the objective-driven sweep paths (tune,
/// gated Pareto) lean on. Otherwise the exact search runs, and the
/// `Solved` result is **bit-identical** to [`solve_inner`]'s (subtree
/// pruning is invisible by construction — see the module docs).
pub fn solve_inner_cut(
    model: &TimeModel,
    p: &InnerProblem,
    opts: &SolveOpts,
    cutoff: Option<f64>,
    stats: &mut PruneStats,
) -> InnerOutcome {
    if opts.prune {
        if let Some(c) = cutoff {
            let b0 = bounds::lower_bound(model, &p.stencil, &p.size, &p.hw, opts);
            stats.bounds_computed += 1;
            if b0.is_infinite() {
                // Certified equivalent to the search finding nothing
                // (`prop_lower_bound_finite_iff_feasible`).
                return InnerOutcome::Infeasible;
            }
            if b0 >= c {
                stats.bounded_out += 1;
                return InnerOutcome::BoundedOut { bound_seconds: b0 };
            }
        }
    }
    let mut best: Option<InnerSolution> = None;
    // Group refinement starts by (t_S2, t_T): the two axes whose ceil
    // interactions create distinct local basins. BTreeMap keeps the start
    // selection deterministic under time ties (HashMap order would leak
    // its per-instance hash seed into the result).
    let mut group_best: std::collections::BTreeMap<(u64, u64), InnerSolution> =
        std::collections::BTreeMap::new();
    let mut evals = 0u64;

    // t_T subtrees in ascending order of their certified lower bound: the
    // best-bound subtree almost always holds the optimum, so the incumbent
    // is tight after one subtree and the remaining bounds can cut. The
    // order is a pure function of the instance (shared by the pruned and
    // `--no-prune` paths, so both enumerate identically and tie-winners
    // can never diverge).
    let t_t_grid = problem::t_t_grid(p.size.t, opts.max_t_t);
    if opts.prune {
        // The ordering bounds are computed either way (both paths share the
        // enumeration order); only the pruning path reports them, so
        // `--no-prune` telemetry reads all-zeros as expected.
        stats.bounds_computed += t_t_grid.len() as u64;
    }
    let mut keyed: Vec<(f64, u64)> = t_t_grid
        .iter()
        .map(|&t| (bounds::lower_bound_tt(model, &p.stencil, &p.size, &p.hw, t), t))
        .collect();
    keyed.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let t_s2_grid = problem::t_s2_grid(p.size.s2, model.machine.max_threads_per_block);
    let t_s3_grid: Vec<Option<u64>> = if p.stencil.is_3d() {
        problem::t_s3_grid(p.size.s3.expect("3-D size")).into_iter().map(Some).collect()
    } else {
        vec![None]
    };
    let t_s1_grid = problem::t_s1_grid(p.size.s1);
    let m_sm_bytes = p.hw.m_sm_kb * 1024.0;
    // Instance-level invariant hoist (§4 of the module docs): every subterm
    // of T_alg that depends only on (machine, stencil, size, hw), computed
    // once per solve. The scalar audit path recomputes them per point via
    // `evaluate_pre` — same expressions, same bits, more work.
    let inv = model.invariants(&p.stencil, &p.size, &p.hw);
    // One reusable SoA buffer for the whole solve (capacity 0 on the scalar
    // path, where it is never filled and must not allocate).
    let mut batch =
        LaneBatch::with_capacity(if opts.scalar_eval { 0 } else { LANE_CAPACITY_HINT });

    for &(tt_lb, t_t) in &keyed {
        // Minimal footprint at this t_T (t_S1 = 1, t_S2 = 32, t_S3 = 1): if
        // even that cannot fit, no larger tile can — prune the subtree.
        let min_tile = TileSizes {
            t_s1: 1,
            t_s2: 32,
            t_s3: if p.stencil.is_3d() { Some(1) } else { None },
            t_t,
        };
        if tiling::tile_footprint_bytes(&p.stencil, &min_tile) > m_sm_bytes {
            continue;
        }
        // Bound-and-prune: a subtree whose bound exceeds the incumbent by
        // more than the slack cannot contain the final optimum nor any
        // surviving refinement start (see module docs) — skip it whole.
        if opts.prune {
            if let Some(b) = &best {
                if tt_lb > b.est.seconds * PRUNE_SLACK {
                    stats.subtrees_cut += 1;
                    continue;
                }
            }
        }
        for &t_s2 in &t_s2_grid {
            for &t_s3 in &t_s3_grid {
                let threads = t_s2 * t_s3.unwrap_or(1);
                if threads > model.machine.max_threads_per_block as u64 {
                    continue;
                }
                // Group-level bound: the thread shape pins the resource-
                // maximal k, so latency-starved groups (small blocks on
                // wide SMs) bound far above the incumbent and are cut.
                if opts.prune {
                    if let Some(b) = &best {
                        let g_lb = bounds::lower_bound_group(
                            model, &p.stencil, &p.size, &p.hw, t_t, t_s2, t_s3,
                        );
                        stats.bounds_computed += 1;
                        if g_lb > b.est.seconds * PRUNE_SLACK {
                            stats.subtrees_cut += 1;
                            continue;
                        }
                    }
                }
                // Both evaluation paths see the identical candidate stream
                // (`for_each_t_s1`) and consult bounds only above this
                // point, so their incumbent trajectories — and therefore
                // the prune decisions on *later* groups — cannot diverge.
                stats.groups_evaluated += 1;
                if opts.scalar_eval {
                    // Legacy point-at-a-time loop (the `--scalar-eval`
                    // audit path).
                    for_each_t_s1(p, &t_s1_grid, t_t, |t_s1| {
                        let tiles = TileSizes { t_s1, t_s2, t_s3, t_t };
                        try_tiles(
                            model,
                            p,
                            &tiles,
                            opts,
                            &mut best,
                            &mut group_best,
                            &mut evals,
                            stats,
                        );
                    });
                } else {
                    // Fill: stage this group's candidate lanes in canonical
                    // order, with the t_S1-invariant geometry hoisted.
                    let g = tiling::group_geometry(&p.stencil, &p.size, t_s2, t_s3, t_t);
                    let n_wavefronts = (2 * g.n_bands) as f64;
                    batch.clear();
                    for_each_t_s1(p, &t_s1_grid, t_t, |t_s1| {
                        let tiles = TileSizes { t_s1, t_s2, t_s3, t_t };
                        stage_lanes(model, p, &tiles, opts, &g, &mut batch);
                    });
                    // Eval: one flat branch-free kernel loop over the SoA
                    // columns.
                    batch.evaluate(&inv, g.threads_per_block, n_wavefronts);
                    // Scan: lane order == scalar enumeration order, so the
                    // strict-improvement updates replay the identical
                    // incumbent trajectory (and the identical `evals`
                    // stamps on every solution).
                    for i in 0..batch.len() {
                        evals += 1;
                        stats.lanes_evaluated += 1;
                        let tiles = TileSizes { t_s1: batch.t_s1[i], t_s2, t_s3, t_t };
                        let sw = SoftwareParams::new(tiles, batch.k[i]);
                        update_incumbents(sw, batch.est[i], evals, &mut best, &mut group_best);
                    }
                }
            }
        }
    }

    if opts.refine {
        // Multi-start: refine the global incumbent plus the best point of
        // the strongest (t_S2, t_S3) groups.
        let mut starts: Vec<((u64, u64), InnerSolution)> = group_best.into_iter().collect();
        starts.sort_by(|(ka, a), (kb, b)| {
            a.est
                .seconds
                .partial_cmp(&b.est.seconds)
                .unwrap()
                .then(ka.cmp(kb)) // deterministic tie-break
        });
        starts.truncate(REFINE_STARTS);
        let mut starts: Vec<InnerSolution> = starts.into_iter().map(|(_, s)| s).collect();
        if let Some(b) = best {
            starts.push(b);
        }
        // Prune hopeless starts: a basin whose grid incumbent is already
        // >25% off the global incumbent has never been observed to refine
        // past it (certified by the brute-force property test); skipping
        // them removes most of the multi-start cost on production instances
        // (§Perf). The retention factor IS `PRUNE_SLACK` — the subtree
        // pruning above is invisible precisely because everything it skips
        // would be discarded here; never let the two constants diverge
        // (pruning harder than retention would break bit-identity).
        if let Some(b) = &best {
            let cutoff = b.est.seconds * PRUNE_SLACK;
            starts.retain(|s| s.est.seconds <= cutoff);
        }
        for start in starts {
            let mut cand = Some(start);
            refine(model, p, opts, &mut cand, &mut evals);
            if let Some(c) = cand {
                if best.as_ref().map_or(true, |b| c.est.seconds < b.est.seconds) {
                    best = Some(c);
                }
            }
        }
    }
    match best {
        Some(b) => InnerOutcome::Solved(InnerSolution { evals, ..b }),
        None => InnerOutcome::Infeasible,
    }
}

/// Drive `f` over every candidate `t_S1` of one grid group, in the solver's
/// canonical order: the coarse grid first, then the wavefront-quantization
/// extras. Shared by the batched fill phase and the `--scalar-eval` loop, so
/// the two paths cannot enumerate differently.
///
/// Wavefront-quantization candidates: on small domains the optimum often
/// sits exactly where the per-phase tile count drops to m
/// (tiles = ceil((S1+w)/2w) ≤ m ⇔ avg width w ≥ S1/(2m−1)), a basin a coarse
/// grid plus local descent cannot reach. Enumerate those widths directly;
/// for the production SZ sizes (S1 ≥ 4096) wavefronts hold hundreds of tiles
/// and the effect is < 1%, so gate on S1.
fn for_each_t_s1(p: &InnerProblem, t_s1_grid: &[u64], t_t: u64, mut f: impl FnMut(u64)) {
    for &t_s1 in t_s1_grid {
        f(t_s1);
    }
    if p.size.s1 <= 2048 {
        let sigma = p.stencil.sigma as u64;
        let slope = sigma * (t_t - 1);
        let mut cands = std::collections::BTreeSet::new();
        for m in 1..=96u64 {
            let w = p.size.s1.div_ceil(2 * m - 1);
            if w > slope {
                cands.insert(w - slope);
            }
        }
        for t_s1 in cands {
            if t_s1_grid.contains(&t_s1) {
                continue; // already enumerated above
            }
            f(t_s1);
        }
    }
}

/// The candidate `k` list for one tile vector, written into the
/// allocation-free `buf` (hot path: millions of tile vectors). Returns the
/// candidate count; 0 means the tile admits no resident block at all.
/// Shared by both evaluation paths — the list, like the enumeration order,
/// must be one implementation.
fn k_list(
    model: &TimeModel,
    p: &InnerProblem,
    threads: u64,
    m_tile: f64,
    opts: &SolveOpts,
    buf: &mut [u32; 32],
) -> usize {
    if opts.all_k {
        let n = model.machine.max_blocks_per_sm as usize;
        for (i, slot) in buf.iter_mut().enumerate().take(n) {
            *slot = i as u32 + 1;
        }
        n
    } else {
        let k_max = problem::k_max_for(model, &p.hw, threads, m_tile);
        if k_max == 0 {
            return 0;
        }
        let k_occ = ((model.machine.latency_factor_for(p.hw.m_sm_kb) * p.hw.n_v as f64)
            / threads as f64)
            .ceil() as u64;
        let (arr, n) = problem::k_candidates_inline(k_max, k_occ);
        buf[..n].copy_from_slice(&arr[..n]);
        n
    }
}

/// One strict-improvement incumbent update: the global incumbent plus the
/// per-(t_S2, t_S3) refinement-start incumbents. Shared by the scalar k-loop
/// and the batched scan phase — the update rule (strict `<`, deterministic
/// BTreeMap keying) is what makes tie-winners enumeration-order-defined, so
/// it must exist exactly once.
fn update_incumbents(
    sw: SoftwareParams,
    est: TimeEstimate,
    evals: u64,
    best: &mut Option<InnerSolution>,
    group_best: &mut std::collections::BTreeMap<(u64, u64), InnerSolution>,
) {
    let sol = InnerSolution { sw, est, evals };
    if best.as_ref().map_or(true, |b| est.seconds < b.est.seconds) {
        *best = Some(sol);
    }
    let key = (sw.tiles.t_s2 * 64 + sw.tiles.t_s3.unwrap_or(0), sw.tiles.t_t);
    match group_best.entry(key) {
        std::collections::btree_map::Entry::Occupied(mut e) => {
            if est.seconds < e.get().est.seconds {
                e.insert(sol);
            }
        }
        std::collections::btree_map::Entry::Vacant(e) => {
            e.insert(sol);
        }
    }
}

/// Fill-phase twin of [`try_tiles`]: run the identical per-tile admission
/// pipeline (footprint, k candidates, tile-level feasibility, per-k resource
/// limits) but stage the surviving lanes into the SoA batch instead of
/// evaluating them. The `t_S1`-invariant geometry arrives precomputed in
/// `g`; [`tiling::complete_geometry`] adds only the `t_S1`-dependent terms
/// (bit-identical to the full [`tiling::geometry`] by construction — they
/// are one implementation).
fn stage_lanes(
    model: &TimeModel,
    p: &InnerProblem,
    tiles: &TileSizes,
    opts: &SolveOpts,
    g: &tiling::GroupGeometry,
    batch: &mut LaneBatch,
) {
    let m_tile = tiling::tile_footprint_bytes(&p.stencil, tiles);
    if m_tile > p.hw.m_sm_kb * 1024.0 {
        return;
    }
    let threads = tiles.t_s2 * tiles.t_s3.unwrap_or(1);
    let mut buf = [0u32; 32];
    let n_ks = k_list(model, p, threads, m_tile, opts, &mut buf);
    if n_ks == 0 {
        return;
    }
    let ks = &buf[..n_ks];
    // Tile-level feasibility once (patterns, thread limits); geometry and
    // traffic are k-invariant — staged once per tile, shared by its lanes.
    if model.feasibility(&p.stencil, &p.hw, &SoftwareParams::new(*tiles, 1)).is_err() {
        return;
    }
    let geo = tiling::complete_geometry(&p.stencil, &p.size, tiles.t_s1, tiles.t_t, g);
    let traffic = tiling::tile_traffic_bytes(&p.stencil, tiles);
    let bpw = geo.blocks_per_wavefront() as f64;
    let m = &model.machine;
    for &k in ks {
        // k-dependent resource limits (already satisfied by k_candidates;
        // needed for the all_k reference mode). A rejected k stages no lane,
        // exactly as the scalar loop spends no evaluation on it.
        if k > m.max_blocks_per_sm
            || (k as u64 * threads) / m.warp as u64 > m.max_warps_per_sm as u64
            || k as f64 * m_tile > p.hw.m_sm_kb * 1024.0
        {
            continue;
        }
        batch.push(tiles.t_s1, k, geo.iters_per_thread, traffic, bpw, m_tile);
    }
}

/// Evaluate one tile vector across its candidate `k`s, updating the global
/// incumbent and the per-(t_S2, t_S3) group incumbents — the legacy
/// `--scalar-eval` evaluation loop, kept callable so the differential tier
/// can compare both live paths in one binary.
#[allow(clippy::too_many_arguments)]
fn try_tiles(
    model: &TimeModel,
    p: &InnerProblem,
    tiles: &TileSizes,
    opts: &SolveOpts,
    best: &mut Option<InnerSolution>,
    group_best: &mut std::collections::BTreeMap<(u64, u64), InnerSolution>,
    evals: &mut u64,
    stats: &mut PruneStats,
) {
    let m_tile = tiling::tile_footprint_bytes(&p.stencil, tiles);
    if m_tile > p.hw.m_sm_kb * 1024.0 {
        return;
    }
    let threads = tiles.t_s2 * tiles.t_s3.unwrap_or(1);
    let mut buf = [0u32; 32];
    let n_ks = k_list(model, p, threads, m_tile, opts, &mut buf);
    if n_ks == 0 {
        return;
    }
    let ks = &buf[..n_ks];
    // Tile-level feasibility once (patterns, thread limits); geometry and
    // traffic are k-invariant — hoist them out of the k loop (§Perf).
    if model.feasibility(&p.stencil, &p.hw, &SoftwareParams::new(*tiles, 1)).is_err() {
        return;
    }
    let geo = tiling::geometry(&p.stencil, &p.size, tiles);
    let traffic = tiling::tile_traffic_bytes(&p.stencil, tiles);
    let m = &model.machine;
    for &k in ks {
        let sw = SoftwareParams::new(*tiles, k);
        if k > m.max_blocks_per_sm
            || (k as u64 * threads) / m.warp as u64 > m.max_warps_per_sm as u64
            || k as f64 * m_tile > p.hw.m_sm_kb * 1024.0
        {
            continue;
        }
        *evals += 1;
        stats.lanes_evaluated += 1;
        let est = model.evaluate_pre(&p.stencil, &p.size, &p.hw, &sw, &geo, m_tile, traffic);
        update_incumbents(sw, est, *evals, best, group_best);
    }
}

/// Steepest-descent integer refinement around the incumbent.
fn refine(
    model: &TimeModel,
    p: &InnerProblem,
    opts: &SolveOpts,
    best: &mut Option<InnerSolution>,
    evals: &mut u64,
) {
    let Some(start) = *best else { return };
    let mut cur = start;
    for _ in 0..64 {
        let t = cur.sw.tiles;
        let mut moves: Vec<SoftwareParams> = Vec::new();
        for ds1 in [-4i64, -2, -1, 1, 2, 4] {
            let v = t.t_s1 as i64 + ds1;
            if v >= 1 && v <= p.size.s1 as i64 {
                moves.push(SoftwareParams::new(TileSizes { t_s1: v as u64, ..t }, cur.sw.k));
            }
        }
        for dt in [-2i64, 2] {
            let v = t.t_t as i64 + dt;
            if v >= 2 && v <= opts.max_t_t as i64 {
                moves.push(SoftwareParams::new(TileSizes { t_t: v as u64, ..t }, cur.sw.k));
            }
        }
        for ds2 in [-32i64, 32] {
            let v = t.t_s2 as i64 + ds2;
            if v >= 32 {
                moves.push(SoftwareParams::new(TileSizes { t_s2: v as u64, ..t }, cur.sw.k));
            }
        }
        if let Some(s3) = t.t_s3 {
            for ds3 in [-1i64, 1] {
                let v = s3 as i64 + ds3;
                if v >= 1 && v <= p.size.s3.unwrap_or(1) as i64 {
                    moves.push(SoftwareParams::new(
                        TileSizes { t_s3: Some(v as u64), ..t },
                        cur.sw.k,
                    ));
                }
            }
        }
        for dk in [-1i64, 1] {
            let v = cur.sw.k as i64 + dk;
            if v >= 1 {
                moves.push(SoftwareParams::new(t, v as u32));
            }
        }
        // Coupled moves: shrinking a tile often unlocks a higher k_max (the
        // shared-memory bound k·M_tile ≤ M_SM); plain one-variable descent
        // cannot cross that ridge, so re-maximize k for every tile move.
        let coupled: Vec<SoftwareParams> = moves
            .iter()
            .filter_map(|m| {
                let m_tile = tiling::tile_footprint_bytes(&p.stencil, &m.tiles);
                let threads = m.tiles.t_s2 * m.tiles.t_s3.unwrap_or(1);
                problem::k_candidates(model, &p.stencil, &p.hw, threads, m_tile)
                    .last()
                    .map(|&k_max| SoftwareParams::new(m.tiles, k_max))
            })
            .collect();
        moves.extend(coupled);
        let mut improved = false;
        for sw in moves {
            if model.feasibility(&p.stencil, &p.hw, &sw).is_err() {
                continue;
            }
            *evals += 1;
            let est = model.evaluate(&p.stencil, &p.size, &p.hw, &sw);
            if est.seconds < cur.est.seconds {
                cur = InnerSolution { sw, est, evals: *evals };
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    if cur.est.seconds < start.est.seconds {
        *best = Some(cur);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::area::params::HwParams;
    use crate::stencil::defs::{Stencil, StencilId};
    use crate::stencil::workload::ProblemSize;

    fn prob(id: StencilId, size: ProblemSize, hw: HwParams) -> InnerProblem {
        InnerProblem { stencil: *Stencil::get(id), size, hw }
    }

    #[test]
    fn solves_gtx980_jacobi() {
        let model = TimeModel::maxwell();
        let p = prob(StencilId::Jacobi2D, ProblemSize::d2(8192, 4096), HwParams::gtx980());
        let sol = solve_inner(&model, &p, &SolveOpts::default()).unwrap();
        assert!(sol.est.gflops > 200.0, "GFLOP/s = {}", sol.est.gflops);
        assert!(sol.evals > 100);
        // Solution must satisfy its own constraints.
        assert!(model.feasibility(&p.stencil, &p.hw, &sol.sw).is_ok());
    }

    #[test]
    fn solves_3d() {
        let model = TimeModel::maxwell();
        let p = prob(StencilId::Heat3D, ProblemSize::d3(256, 128), HwParams::gtx980());
        let sol = solve_inner(&model, &p, &SolveOpts::default()).unwrap();
        assert!(sol.sw.tiles.t_s3.is_some());
        assert!(sol.est.gflops > 100.0);
    }

    #[test]
    fn infeasible_hardware_returns_none() {
        let model = TimeModel::maxwell();
        let mut hw = HwParams::gtx980();
        hw.m_sm_kb = 0.25; // 256 B — nothing fits
        let p = prob(StencilId::Jacobi2D, ProblemSize::d2(4096, 1024), hw);
        assert!(solve_inner(&model, &p, &SolveOpts::default()).is_none());
    }

    #[test]
    fn refinement_never_hurts() {
        let model = TimeModel::maxwell();
        let p = prob(StencilId::Heat2D, ProblemSize::d2(4096, 2048), HwParams::gtx980());
        let coarse =
            solve_inner(&model, &p, &SolveOpts { refine: false, ..Default::default() }).unwrap();
        let refined = solve_inner(&model, &p, &SolveOpts::default()).unwrap();
        assert!(refined.est.seconds <= coarse.est.seconds);
    }

    #[test]
    fn more_shared_memory_never_hurts_optimum() {
        let model = TimeModel::maxwell();
        let base = prob(StencilId::Heat3D, ProblemSize::d3(256, 128), HwParams::gtx980());
        let small = solve_inner(&model, &base, &SolveOpts::default()).unwrap();
        let mut hw2 = base.hw;
        hw2.m_sm_kb = 192.0;
        let big = solve_inner(
            &model,
            &prob(StencilId::Heat3D, ProblemSize::d3(256, 128), hw2),
            &SolveOpts::default(),
        )
        .unwrap();
        assert!(big.est.seconds <= small.est.seconds * 1.0001);
    }

    #[test]
    fn pruned_and_unpruned_results_are_bit_identical() {
        // The whole point of the bound-and-prune layer: identical results,
        // strictly fewer model evaluations (same instances as the paper
        // sweep samples, plus a 3-D one).
        let model = TimeModel::maxwell();
        let cases = [
            prob(StencilId::Jacobi2D, ProblemSize::d2(8192, 4096), HwParams::gtx980()),
            prob(StencilId::Gradient2D, ProblemSize::d2(12288, 2048), HwParams {
                n_sm: 8,
                n_v: 256,
                ..HwParams::gtx980()
            }),
            prob(StencilId::Heat3D, ProblemSize::d3(256, 128), HwParams::gtx980()),
        ];
        for p in cases {
            let pruned = solve_inner(&model, &p, &SolveOpts::default()).unwrap();
            let full =
                solve_inner(&model, &p, &SolveOpts::default().without_prune()).unwrap();
            assert_eq!(
                pruned.est.seconds.to_bits(),
                full.est.seconds.to_bits(),
                "{:?}: pruned {} vs full {}",
                p.stencil.id,
                pruned.est.seconds,
                full.est.seconds
            );
            assert_eq!(pruned.sw, full.sw, "{:?}", p.stencil.id);
            assert!(pruned.evals <= full.evals, "{:?}", p.stencil.id);
        }
    }

    #[test]
    fn batched_and_scalar_eval_are_bit_identical() {
        // The batched SoA path vs the --scalar-eval legacy loop: solutions,
        // eval counts and the *whole* telemetry struct must match to the
        // bit, with pruning on and off (four path combinations per case).
        let model = TimeModel::maxwell();
        let cases = [
            prob(StencilId::Jacobi2D, ProblemSize::d2(8192, 4096), HwParams::gtx980()),
            prob(StencilId::Gradient2D, ProblemSize::d2(12288, 2048), HwParams {
                n_sm: 8,
                n_v: 256,
                ..HwParams::gtx980()
            }),
            prob(StencilId::Heat3D, ProblemSize::d3(256, 128), HwParams::gtx980()),
            // Small domain: exercises the wavefront-quantization extras.
            prob(StencilId::Heat2D, ProblemSize::d2(1024, 256), HwParams::gtx980()),
        ];
        for p in cases {
            for base in [SolveOpts::default(), SolveOpts::default().without_prune()] {
                let mut batched_stats = PruneStats::default();
                let mut scalar_stats = PruneStats::default();
                let batched =
                    solve_inner_cut(&model, &p, &base, None, &mut batched_stats)
                        .solved()
                        .unwrap();
                let scalar = solve_inner_cut(
                    &model,
                    &p,
                    &base.clone().with_scalar_eval(),
                    None,
                    &mut scalar_stats,
                )
                .solved()
                .unwrap();
                assert_eq!(
                    batched.est.seconds.to_bits(),
                    scalar.est.seconds.to_bits(),
                    "{:?} prune={}: batched {} vs scalar {}",
                    p.stencil.id,
                    base.prune,
                    batched.est.seconds,
                    scalar.est.seconds
                );
                assert_eq!(batched.sw, scalar.sw, "{:?}", p.stencil.id);
                assert_eq!(batched.evals, scalar.evals, "{:?}", p.stencil.id);
                assert_eq!(batched_stats, scalar_stats, "{:?}", p.stencil.id);
                assert!(batched_stats.groups_evaluated > 0);
                assert!(batched_stats.lanes_evaluated > 0);
            }
        }
    }

    #[test]
    fn all_k_batched_matches_scalar() {
        // all_k floods a group with up to 32 lanes per tile — the widest
        // batches the solver ever builds; the scan must still replay the
        // scalar trajectory exactly.
        let model = TimeModel::maxwell();
        let p = prob(StencilId::Laplacian2D, ProblemSize::d2(4096, 1024), HwParams::gtx980());
        let opts = SolveOpts { all_k: true, refine: false, ..Default::default() };
        let batched = solve_inner(&model, &p, &opts).unwrap();
        let scalar = solve_inner(&model, &p, &opts.clone().with_scalar_eval()).unwrap();
        assert_eq!(batched.est.seconds.to_bits(), scalar.est.seconds.to_bits());
        assert_eq!(batched.sw, scalar.sw);
        assert_eq!(batched.evals, scalar.evals);
    }

    #[test]
    fn cutoff_fast_exit_spends_no_evals() {
        use crate::opt::bounds::PruneStats;
        let model = TimeModel::maxwell();
        let p = prob(StencilId::Jacobi2D, ProblemSize::d2(8192, 4096), HwParams::gtx980());
        let opts = SolveOpts::default();
        let exact = solve_inner(&model, &p, &opts).unwrap();
        // A cutoff below the certified bound: the instance is bounded out
        // without a single evaluation, and the recorded bound is a true
        // lower bound on the exact optimum.
        let mut stats = PruneStats::default();
        let out = solve_inner_cut(&model, &p, &opts, Some(1e-12), &mut stats);
        let InnerOutcome::BoundedOut { bound_seconds } = out else {
            panic!("tiny cutoff must bound out, got {out:?}");
        };
        assert!(bound_seconds <= exact.est.seconds);
        assert_eq!(stats.bounded_out, 1);
        // A cutoff the instance can beat: the exact solution comes back
        // bit-identical to the cutoff-free solve.
        let mut stats = PruneStats::default();
        let out =
            solve_inner_cut(&model, &p, &opts, Some(exact.est.seconds * 2.0), &mut stats);
        let sol = out.solved().expect("achievable cutoff must solve exactly");
        assert_eq!(sol.est.seconds.to_bits(), exact.est.seconds.to_bits());
        assert_eq!(sol.sw, exact.sw);
        assert_eq!(stats.bounded_out, 0);
        assert!(stats.bounds_computed > 0);
    }

    #[test]
    fn cutoff_on_infeasible_instance_reports_infeasible() {
        use crate::opt::bounds::PruneStats;
        let model = TimeModel::maxwell();
        let mut hw = HwParams::gtx980();
        hw.m_sm_kb = 0.25;
        let p = prob(StencilId::Jacobi2D, ProblemSize::d2(4096, 1024), hw);
        let out = solve_inner_cut(
            &model,
            &p,
            &SolveOpts::default(),
            Some(1.0),
            &mut PruneStats::default(),
        );
        assert!(matches!(out, InnerOutcome::Infeasible));
    }

    #[test]
    fn all_k_at_least_as_good_but_slower() {
        let model = TimeModel::maxwell();
        let p = prob(StencilId::Laplacian2D, ProblemSize::d2(4096, 1024), HwParams::gtx980());
        let fast = solve_inner(&model, &p, &SolveOpts { refine: false, ..Default::default() })
            .unwrap();
        let full = solve_inner(
            &model,
            &p,
            &SolveOpts { all_k: true, refine: false, ..Default::default() },
        )
        .unwrap();
        assert!(full.evals > fast.evals);
        // Heuristic k must be within a hair of full enumeration.
        assert!(
            fast.est.seconds <= full.est.seconds * 1.02,
            "fast {} vs full {}",
            fast.est.seconds,
            full.est.seconds
        );
    }
}
