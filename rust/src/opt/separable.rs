//! The separability transformation, eq. (18): the workload-weighted
//! objective for one hardware point decomposes into independent inner
//! minimizations per (stencil, size) entry.

use crate::area::params::HwParams;
use crate::opt::bounds::PruneStats;
use crate::opt::inner::{solve_inner, solve_inner_cut, InnerOutcome, InnerSolution};
use crate::opt::problem::{InnerProblem, SolveOpts};
use crate::stencil::defs::Stencil;
use crate::stencil::workload::{Workload, WorkloadEntry};
use crate::timemodel::citer::CIterTable;
use crate::timemodel::talg::TimeModel;

/// Result of optimizing every workload entry on one hardware point.
#[derive(Clone, Debug)]
pub struct HardwarePointSolution {
    pub hw: HwParams,
    /// Per-entry optimal software parameters (None where infeasible).
    pub per_entry: Vec<Option<InnerSolution>>,
    /// Workload-weighted execution time `T_alg^Cd` (eq. 17), seconds.
    /// `None` if any positively-weighted entry is infeasible.
    pub weighted_seconds: Option<f64>,
    /// Workload-weighted GFLOP/s (the Fig 3 y-axis).
    pub weighted_gflops: Option<f64>,
    /// Total model evaluations across the inner solves.
    pub evals: u64,
}

/// Solve eq. (18)'s inner stage for one hardware point: independent inner
/// problems per entry, then the weighted sums.
///
/// The weighted GFLOP/s is the flop-weighted aggregate
/// `Σ w_i · flops_i / Σ w_i · T_i` — the workload's aggregate throughput if
/// instances arrive with frequency `w`.
pub fn solve_hardware_point(
    model: &TimeModel,
    workload: &Workload,
    citer: &CIterTable,
    hw: &HwParams,
    opts: &SolveOpts,
) -> HardwarePointSolution {
    let per_entry: Vec<Option<InnerSolution>> = workload
        .entries
        .iter()
        .map(|e| solve_entry(model, citer, hw, e, opts))
        .collect();
    let evals = per_entry.iter().flatten().map(|s| s.evals).sum();
    let (weighted_seconds, weighted_gflops) = aggregate_weighted(workload, &per_entry).unzip();
    HardwarePointSolution { hw: *hw, per_entry, weighted_seconds, weighted_gflops, evals }
}

/// Workload-weighted `(seconds, GFLOP/s)` over already-solved per-entry
/// optima, aligned with `workload.entries`. `None` if any positively-weighted
/// entry is infeasible; zero-weight entries never affect the result.
///
/// This is the single aggregation path shared by the direct scenario runner,
/// the batched coordinator's serve phase and [`reweight`] — one accumulation
/// order, so re-serving memoized solutions is bit-identical to a from-scratch
/// solve under the same weights.
pub fn aggregate_weighted(
    workload: &Workload,
    per_entry: &[Option<InnerSolution>],
) -> Option<(f64, f64)> {
    aggregate_weighted_entries(&workload.entries, per_entry)
}

/// [`aggregate_weighted`] over a bare entry slice — the same accumulation,
/// for callers (the bound-gated sweep paths) that hold entries without a
/// `Workload` wrapper.
pub fn aggregate_weighted_entries(
    entries: &[WorkloadEntry],
    per_entry: &[Option<InnerSolution>],
) -> Option<(f64, f64)> {
    debug_assert_eq!(entries.len(), per_entry.len(), "entry/solution mismatch");
    let mut t_weighted = 0.0;
    let mut flops_weighted = 0.0;
    let mut feasible = true;
    for (entry, sol) in entries.iter().zip(per_entry) {
        if entry.weight == 0.0 {
            continue;
        }
        match sol {
            Some(s) => {
                t_weighted += entry.weight * s.est.seconds;
                let st = Stencil::get(entry.stencil);
                flops_weighted += entry.weight * st.flops_per_point * entry.size.points();
            }
            None => feasible = false,
        }
    }
    feasible.then(|| (t_weighted, flops_weighted / t_weighted / 1e9))
}

/// Solve one workload entry on one hardware point.
pub fn solve_entry(
    model: &TimeModel,
    citer: &CIterTable,
    hw: &HwParams,
    entry: &WorkloadEntry,
    opts: &SolveOpts,
) -> Option<InnerSolution> {
    let stencil = citer.apply(Stencil::get(entry.stencil));
    let p = InnerProblem { stencil, size: entry.size, hw: *hw };
    solve_inner(model, &p, opts)
}

/// [`solve_entry`] with an objective cutoff and pruning telemetry — the
/// per-entry step of the objective-driven sweep paths. `Solved` outcomes
/// are bit-identical to [`solve_entry`]'s.
pub fn solve_entry_cut(
    model: &TimeModel,
    citer: &CIterTable,
    hw: &HwParams,
    entry: &WorkloadEntry,
    opts: &SolveOpts,
    cutoff: Option<f64>,
    stats: &mut PruneStats,
) -> InnerOutcome {
    let stencil = citer.apply(Stencil::get(entry.stencil));
    let p = InnerProblem { stencil, size: entry.size, hw: *hw };
    solve_inner_cut(model, &p, opts, cutoff, stats)
}

/// Re-aggregate an already-solved hardware point under a different workload
/// weighting — §V-B's "explore other scenarios for free". The `solution`
/// must have been produced over the *same entry list* (same order).
pub fn reweight(
    solution: &HardwarePointSolution,
    base: &Workload,
    reweighted: &Workload,
) -> HardwarePointSolution {
    assert_eq!(base.entries.len(), reweighted.entries.len(), "workload mismatch");
    for (e_base, e_new) in base.entries.iter().zip(&reweighted.entries) {
        assert_eq!(e_base.stencil, e_new.stencil, "workload mismatch");
    }
    let (weighted_seconds, weighted_gflops) =
        aggregate_weighted(reweighted, &solution.per_entry).unzip();
    HardwarePointSolution {
        hw: solution.hw,
        per_entry: solution.per_entry.clone(),
        weighted_seconds,
        weighted_gflops,
        evals: 0, // no new model evaluations — the point of eq. (18)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::defs::StencilId;

    #[test]
    fn gtx980_uniform_2d_solves() {
        let model = TimeModel::maxwell();
        let w = Workload::uniform_2d();
        let sol = solve_hardware_point(
            &model,
            &w,
            &CIterTable::paper(),
            &HwParams::gtx980(),
            &SolveOpts::default(),
        );
        assert_eq!(sol.per_entry.len(), 64);
        assert!(sol.per_entry.iter().all(|s| s.is_some()));
        let g = sol.weighted_gflops.unwrap();
        assert!(g > 200.0 && g < 6000.0, "weighted GFLOP/s = {g}");
    }

    #[test]
    fn infeasible_hw_flagged() {
        let model = TimeModel::maxwell();
        let mut hw = HwParams::gtx980();
        hw.m_sm_kb = 0.25;
        let sol = solve_hardware_point(
            &model,
            &Workload::uniform_2d(),
            &CIterTable::paper(),
            &hw,
            &SolveOpts::default(),
        );
        assert!(sol.weighted_seconds.is_none());
    }

    #[test]
    fn reweight_matches_direct_solve_for_free() {
        let model = TimeModel::maxwell();
        let base = Workload::uniform_2d();
        let hw = HwParams::gtx980();
        let opts = SolveOpts::default();
        let citer = CIterTable::paper();
        let solved = solve_hardware_point(&model, &base, &citer, &hw, &opts);

        let jaconly =
            base.reweighted(|e| if e.stencil == StencilId::Jacobi2D { 1.0 } else { 0.0 });
        let cheap = reweight(&solved, &base, &jaconly);
        assert_eq!(cheap.evals, 0);
        // Per-entry optima don't depend on weights, so re-aggregation must
        // equal a from-scratch solve under the new weights.
        let direct = solve_hardware_point(&model, &jaconly, &citer, &hw, &opts);
        let a = cheap.weighted_seconds.unwrap();
        let b = direct.weighted_seconds.unwrap();
        assert!((a - b).abs() / b < 1e-12, "{a} vs {b}");
    }
}
