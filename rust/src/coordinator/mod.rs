//! The DSE coordinator — Layer 3's orchestration core.
//!
//! Owns the event loop of a design-space exploration: a memoized result
//! store keyed by (hardware, stencil, size) — the concrete realization of
//! eq. (18)'s separability, which makes §V-B's scenario re-weighting free —
//! a work queue fanned across a thread pool, and progress/statistics
//! reporting for the CLI.

pub mod cache;
pub mod driver;

pub use cache::{CacheKey, CacheStats, MemoCache};
pub use driver::{Coordinator, SweepReport};
