//! The DSE coordinator — Layer 3's orchestration core.
//!
//! Owns the event loop of a design-space exploration: a memoized result
//! store keyed by (hardware, stencil, size) — the concrete realization of
//! eq. (18)'s separability, which makes §V-B's scenario re-weighting free —
//! a work queue fanned across a thread pool, and progress/statistics
//! reporting for the CLI.
//!
//! [`Coordinator::run_batch`] is the production entry point: it answers an
//! arbitrary batch of scenarios (workload re-weightings, area budgets,
//! per-stencil subsets) from **one** shared, sharded hardware sweep, so
//! scenario throughput scales with cores while sweep cost stays flat in the
//! number of scenarios.

pub mod cache;
pub mod driver;

pub use cache::{CacheKey, CacheStats, MemoCache, StatsSnapshot};
pub use driver::{BatchReport, Coordinator, SweepReport};
