//! The DSE coordinator — Layer 3's orchestration core.
//!
//! Owns the event loop of a design-space exploration: a memoized result
//! store keyed by (hardware, stencil, size) — the concrete realization of
//! eq. (18)'s separability, which makes §V-B's scenario re-weighting free —
//! a work queue fanned across a thread pool, and progress/statistics
//! reporting for the CLI.
//!
//! [`Coordinator::run_batch`] is the production entry point: it answers an
//! arbitrary batch of scenarios (workload re-weightings, area budgets,
//! per-stencil subsets) from **one** shared, sharded hardware sweep, so
//! scenario throughput scales with cores while sweep cost stays flat in the
//! number of scenarios.
//!
//! # Examples
//!
//! ```no_run
//! use codesign::codesign::scenario::Scenario;
//! use codesign::coordinator::Coordinator;
//! use codesign::platform::PlatformSpec;
//!
//! // The default baseline…
//! let coord = Coordinator::paper();
//! let batch = coord.run_batch(&[Scenario::paper_2d(), Scenario::paper_3d()]);
//! // A repeated batch over the same grids is ~100% cache hits.
//! assert_eq!(batch.len(), 2);
//!
//! // …or any platform: memo-cache keys carry the platform fingerprint, so
//! // a bandwidth-tweaked coordinator can never alias the baseline's cache.
//! let hbm = Coordinator::new(PlatformSpec::parse("maxwell:bw28").unwrap());
//! ```

pub mod cache;
pub mod driver;

pub use cache::{
    entry_footprint_bytes, CacheEntry, CacheKey, CacheStats, EvictionSnapshot, MemoBudget,
    MemoCache, MemoPin, StatsSnapshot,
};
pub use driver::{
    BatchReport, Coordinator, GatedEnergyFrontPoint, GatedFrontPoint, GatedParetoEnergyResult,
    GatedParetoResult, PruneCounters, SweepReport,
};
