//! Memoized inner-solution store.
//!
//! Keyed by the full (hardware, stencil-characterization, size) instance —
//! see [`CacheKey`] for why characterization, not identity. Sharded mutexes
//! keep contention negligible under the worker pool (the inner solve costs
//! 10³–10⁵ model evaluations; a lock round-trip is noise).
//!
//! Accounting is *exact*: every lookup increments exactly one of
//! `hits`/`misses`. In [`MemoCache::get_or_compute`] a miss is only charged
//! by the thread whose insert actually created the entry (a thread that
//! loses a compute race finds the entry present on re-lock and is charged a
//! hit), so `get_or_compute` misses equal the number of distinct instances
//! ever solved. [`MemoCache::get`] probes of never-solved keys also count
//! as misses without creating entries — the batch engine's serve phase
//! never takes that path (it only reads keys its sweep populated), which is
//! what lets the batched-sweep hit-rate tests certify the reported rate
//! against recomputed ground truth.
//!
//! **`BoundedOut` contract.** The objective-driven sweep paths (tune, gated
//! Pareto) may decide an instance cannot matter from its certified lower
//! bound alone; they record that as [`CacheEntry::BoundedOut`] via
//! [`MemoCache::insert_bound`]. A bounded entry is *never* served where an
//! exact solution is expected: the exact paths ([`MemoCache::get`],
//! [`MemoCache::get_or_compute`]) treat it as absent — a later batch that
//! needs the instance exactly re-solves it (upgrading the slot; charged as
//! the miss it is) instead of aliasing a bound as a solution. Bound marks
//! themselves are bookkeeping, not lookups: `insert_bound` and
//! [`MemoCache::bound_of`] touch no counters, and an exact entry is never
//! downgraded to a bound.

use crate::area::params::HwParams;
use crate::opt::inner::{InnerOutcome, InnerSolution};
use crate::stencil::defs::Stencil;
use crate::stencil::workload::ProblemSize;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Exact instance key. `f64` fields are stored as bits — they come from
/// finite enumeration grids, so bit-equality is the right notion.
///
/// The stencil is keyed by its **derived characterization** — everything the
/// time model actually consumes (dimensionality, halo σ, flops/point,
/// buffers, bytes/cell, effective `C_iter`) — not by its registry identity.
/// Two differently-named stencils with identical characterization (e.g. a
/// preset and an equivalent parametric spec) therefore share one memoized
/// solution, and any parametric family member caches exactly like a preset.
///
/// The platform enters the same way: `platform_fp` is the
/// [`PlatformSpec::fingerprint`](crate::platform::PlatformSpec::fingerprint)
/// of the bundle the solution was computed under, so two differently-spelled
/// but identically-valued platforms share memoized sweeps while any model
/// delta (a tweaked clock or bandwidth) can never alias a cached solution.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CacheKey {
    /// Fingerprint of the platform bundle the inner problem was posed under.
    pub platform_fp: u64,
    pub n_sm: u32,
    pub n_v: u32,
    pub m_sm_kb_bits: u64,
    pub space_dims: u32,
    pub sigma: u32,
    pub flops_bits: u64,
    pub n_buffers_bits: u64,
    pub bytes_bits: u64,
    /// The *effective* per-iteration cost: callers must pass a stencil that
    /// already carries its table value (`CIterTable::apply`).
    pub c_iter_bits: u64,
    pub s1: u64,
    pub s2: u64,
    pub s3: u64,
    pub t: u64,
}

impl CacheKey {
    /// Build the key for one (platform, hardware, stencil, size) instance.
    /// `stencil` must be the stencil *as solved* — i.e. with the scenario's
    /// `C_iter` table already applied — so the key pins the exact inner
    /// problem; `platform_fp` pins the model bundle it was solved under.
    pub fn new(
        platform_fp: u64,
        hw: &HwParams,
        stencil: &Stencil,
        size: &ProblemSize,
    ) -> CacheKey {
        CacheKey {
            platform_fp,
            n_sm: hw.n_sm,
            n_v: hw.n_v,
            m_sm_kb_bits: hw.m_sm_kb.to_bits(),
            space_dims: stencil.space_dims,
            sigma: stencil.sigma,
            flops_bits: stencil.flops_per_point.to_bits(),
            n_buffers_bits: stencil.n_buffers.to_bits(),
            bytes_bits: stencil.bytes_per_cell.to_bits(),
            c_iter_bits: stencil.c_iter_cycles.to_bits(),
            s1: size.s1,
            s2: size.s2,
            s3: size.s3.unwrap_or(0),
            t: size.t,
        }
    }
}

/// Monotonic hit/miss counters with snapshot ("epoch") support, so callers
/// can attribute lookups to one sweep on a long-lived coordinator.
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
}

/// A point-in-time copy of the counters, from [`CacheStats::snapshot`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub hits: u64,
    pub misses: u64,
}

impl StatsSnapshot {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.hits + self.misses == 0 {
            0.0
        } else {
            self.hits as f64 / (self.hits + self.misses) as f64
        }
    }
}

impl CacheStats {
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Counter deltas accumulated since `since` was snapshotted.
    pub fn delta_since(&self, since: StatsSnapshot) -> StatsSnapshot {
        let now = self.snapshot();
        StatsSnapshot { hits: now.hits - since.hits, misses: now.misses - since.misses }
    }

    /// Lifetime hit rate.
    pub fn hit_rate(&self) -> f64 {
        self.snapshot().hit_rate()
    }
}

const DEFAULT_SHARDS: usize = 64;

/// One memoized slot: the exact inner solution (with `Exact(None)`
/// memoizing infeasibility), or a certified lower bound for an instance an
/// objective-driven sweep pruned away without solving (see the module-level
/// `BoundedOut` contract).
#[derive(Clone, Copy, Debug)]
pub enum CacheEntry {
    Exact(Option<InnerSolution>),
    BoundedOut {
        /// The certified lower bound (seconds) that killed the instance.
        lb_seconds: f64,
    },
}

/// The sharded memo store: N-way lock striping keyed by the `CacheKey` hash.
pub struct MemoCache {
    /// Invariant: `shards.len()` is a power of two (shard selection masks
    /// the key hash).
    shards: Vec<Mutex<HashMap<CacheKey, CacheEntry>>>,
    pub stats: CacheStats,
}

impl Default for MemoCache {
    fn default() -> Self {
        Self::new()
    }
}

impl MemoCache {
    pub fn new() -> MemoCache {
        MemoCache::with_shards(DEFAULT_SHARDS)
    }

    /// A cache striped over at least `n` locks (rounded up to a power of
    /// two, minimum 1). More stripes buy concurrency at a fixed small memory
    /// cost; the default suits typical core counts.
    pub fn with_shards(n: usize) -> MemoCache {
        let n = n.max(1).next_power_of_two();
        MemoCache {
            shards: (0..n).map(|_| Mutex::new(HashMap::new())).collect(),
            stats: CacheStats::default(),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn shard(&self, key: &CacheKey) -> &Mutex<HashMap<CacheKey, CacheEntry>> {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) & (self.shards.len() - 1)]
    }

    /// Get the memoized **exact** solution or compute and store it. A
    /// `BoundedOut` slot is treated as absent: the instance is re-solved
    /// exactly and the slot upgraded (charged as a miss — real solver work
    /// happened).
    ///
    /// The compute runs outside the lock; when two threads race on the same
    /// key both compute (deterministic result, so this is harmless), but the
    /// first insert wins and is the only one charged a miss — the loser is
    /// charged a hit and returns the stored value.
    pub fn get_or_compute(
        &self,
        key: CacheKey,
        compute: impl FnOnce() -> Option<InnerSolution>,
    ) -> Option<InnerSolution> {
        if let Some(CacheEntry::Exact(v)) = self.shard(&key).lock().unwrap().get(&key) {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            return *v;
        }
        let v = compute();
        let mut shard = self.shard(&key).lock().unwrap();
        match shard.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => match e.get() {
                CacheEntry::Exact(v) => {
                    self.stats.hits.fetch_add(1, Ordering::Relaxed);
                    *v
                }
                CacheEntry::BoundedOut { .. } => {
                    // Upgrade: the bound mark never aliases as a solution.
                    self.stats.misses.fetch_add(1, Ordering::Relaxed);
                    e.insert(CacheEntry::Exact(v));
                    v
                }
            },
            std::collections::hash_map::Entry::Vacant(slot) => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                slot.insert(CacheEntry::Exact(v));
                v
            }
        }
    }

    /// Look up without computing. `None` means the instance was never
    /// solved exactly (absent or only `BoundedOut`); `Some(None)` means it
    /// was solved and found infeasible. Counted as a hit or miss like any
    /// other lookup.
    pub fn get(&self, key: &CacheKey) -> Option<Option<InnerSolution>> {
        let found = self.shard(key).lock().unwrap().get(key).copied();
        match found {
            Some(CacheEntry::Exact(v)) => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            Some(CacheEntry::BoundedOut { .. }) | None => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// The memoizing entry point of the objective-driven sweep paths: get
    /// the exact solution if the store has one (a hit), reuse a recorded
    /// bound when it already meets the caller's `cutoff` (bookkeeping, no
    /// counters), and otherwise run `solve` and record its outcome — exact
    /// results (including infeasibility) are stored as `Exact` and charged
    /// as the miss they are, `BoundedOut` outcomes become bound marks.
    ///
    /// Monotone by construction: a slot only ever goes absent → bound →
    /// exact, never backwards, so no consumer can observe a bound where it
    /// awaited a solution.
    pub fn get_or_solve_cut(
        &self,
        key: CacheKey,
        cutoff: Option<f64>,
        solve: impl FnOnce() -> InnerOutcome,
    ) -> InnerOutcome {
        {
            let shard = self.shard(&key).lock().unwrap();
            match shard.get(&key) {
                Some(CacheEntry::Exact(v)) => {
                    self.stats.hits.fetch_add(1, Ordering::Relaxed);
                    return match v {
                        Some(s) => InnerOutcome::Solved(*s),
                        None => InnerOutcome::Infeasible,
                    };
                }
                Some(CacheEntry::BoundedOut { lb_seconds }) => {
                    // A recorded bound is a pure property of the instance:
                    // if it meets this cutoff too, the solve is unneeded.
                    if let Some(c) = cutoff {
                        if *lb_seconds >= c {
                            return InnerOutcome::BoundedOut { bound_seconds: *lb_seconds };
                        }
                    }
                }
                None => {}
            }
        }
        let out = solve();
        let mut shard = self.shard(&key).lock().unwrap();
        match shard.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => match (*e.get(), out) {
                // Someone exact-solved the key while we worked: their value
                // wins (deterministic solver — it is the same value).
                (CacheEntry::Exact(v), _) => {
                    self.stats.hits.fetch_add(1, Ordering::Relaxed);
                    match v {
                        Some(s) => InnerOutcome::Solved(s),
                        None => InnerOutcome::Infeasible,
                    }
                }
                (CacheEntry::BoundedOut { .. }, InnerOutcome::Solved(s)) => {
                    self.stats.misses.fetch_add(1, Ordering::Relaxed);
                    e.insert(CacheEntry::Exact(Some(s)));
                    InnerOutcome::Solved(s)
                }
                (CacheEntry::BoundedOut { .. }, InnerOutcome::Infeasible) => {
                    self.stats.misses.fetch_add(1, Ordering::Relaxed);
                    e.insert(CacheEntry::Exact(None));
                    InnerOutcome::Infeasible
                }
                // Keep the first mark (they are equal anyway: the bound is
                // deterministic per instance).
                (CacheEntry::BoundedOut { .. }, out @ InnerOutcome::BoundedOut { .. }) => out,
            },
            std::collections::hash_map::Entry::Vacant(slot) => {
                match out {
                    InnerOutcome::Solved(s) => {
                        self.stats.misses.fetch_add(1, Ordering::Relaxed);
                        slot.insert(CacheEntry::Exact(Some(s)));
                    }
                    InnerOutcome::Infeasible => {
                        self.stats.misses.fetch_add(1, Ordering::Relaxed);
                        slot.insert(CacheEntry::Exact(None));
                    }
                    InnerOutcome::BoundedOut { bound_seconds } => {
                        slot.insert(CacheEntry::BoundedOut { lb_seconds: bound_seconds });
                    }
                }
                out
            }
        }
    }

    /// Record a certified lower bound for an instance a pruned sweep never
    /// solved. First mark wins; an existing entry of either kind is kept
    /// (exact solutions are never downgraded). Not a lookup — no counters.
    pub fn insert_bound(&self, key: CacheKey, lb_seconds: f64) {
        self.shard(&key)
            .lock()
            .unwrap()
            .entry(key)
            .or_insert(CacheEntry::BoundedOut { lb_seconds });
    }

    /// The recorded bound of a `BoundedOut` slot, if that is what the slot
    /// holds. Bookkeeping probe — no counters.
    pub fn bound_of(&self, key: &CacheKey) -> Option<f64> {
        match self.shard(key).lock().unwrap().get(key) {
            Some(CacheEntry::BoundedOut { lb_seconds }) => Some(*lb_seconds),
            _ => None,
        }
    }

    /// Total slots, bound marks included.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Exactly-solved slots only (what sweep-coverage invariants count).
    pub fn exact_len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                s.lock()
                    .unwrap()
                    .values()
                    .filter(|e| matches!(e, CacheEntry::Exact(_)))
                    .count()
            })
            .sum()
    }

    /// `BoundedOut` marks currently held.
    pub fn bounded_len(&self) -> usize {
        self.len() - self.exact_len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Every slot — exact solutions, memoized infeasibilities and bound
    /// marks alike — in deterministic key order (`CacheKey` derives `Ord`
    /// field-wise). This is the persistence surface: a saved artifact's
    /// payload is exactly this sequence, so save→load→save is byte-stable
    /// regardless of shard layout or insertion history. Bookkeeping, no
    /// counters.
    pub fn export_entries(&self) -> Vec<(CacheKey, CacheEntry)> {
        let mut out: Vec<(CacheKey, CacheEntry)> = Vec::with_capacity(self.len());
        for shard in &self.shards {
            out.extend(shard.lock().unwrap().iter().map(|(k, v)| (*k, *v)));
        }
        out.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Install one persisted slot, honoring the monotone contract: a vacant
    /// slot takes the entry, a bound mark may upgrade to `Exact`, and an
    /// existing `Exact` entry is never downgraded or overwritten (the solver
    /// is deterministic — an equal-keyed exact value is the same value).
    /// Returns whether the store changed. Imports are neither hits nor
    /// misses: no counters, so warm-started sessions keep exact accounting
    /// for the work they actually perform.
    pub fn import_entry(&self, key: CacheKey, entry: CacheEntry) -> bool {
        let mut shard = self.shard(&key).lock().unwrap();
        match shard.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                match (e.get(), &entry) {
                    (CacheEntry::BoundedOut { .. }, CacheEntry::Exact(_)) => {
                        e.insert(entry);
                        true
                    }
                    _ => false,
                }
            }
            std::collections::hash_map::Entry::Vacant(slot) => {
                slot.insert(entry);
                true
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timemodel::talg::{SoftwareParams, TimeEstimate};
    use crate::timemodel::tiling::TileSizes;

    fn fp() -> u64 {
        crate::platform::registry::Platform::default_spec().fingerprint()
    }

    fn key(n_v: u32) -> CacheKey {
        CacheKey::new(
            fp(),
            &HwParams { n_v, ..HwParams::gtx980() },
            Stencil::get(crate::stencil::defs::StencilId::Jacobi2D),
            &ProblemSize::d2(1024, 256),
        )
    }

    fn dummy_solution() -> Option<InnerSolution> {
        Some(InnerSolution {
            sw: SoftwareParams::new(TileSizes::d2(32, 64, 8), 2),
            est: TimeEstimate {
                cycles: 1.0,
                seconds: 1.0,
                gflops: 1.0,
                m_tile_bytes: 1.0,
                compute_cycles: 1.0,
                mem_cycles: 0.5,
                rounds: 1.0,
                bound: crate::timemodel::talg::Bound::Compute,
                occupancy: 1.0,
            },
            evals: 1,
        })
    }

    #[test]
    fn key_is_characterization_not_identity() {
        use crate::stencil::defs::StencilId;
        use crate::stencil::spec::{Dim, StencilSpec};
        let hw = HwParams::gtx980();
        let size = ProblemSize::d2(1024, 256);
        let jac = Stencil::get(StencilId::Jacobi2D);
        // A parametric spec pinned to Jacobi's exact characterization shares
        // its key; bumping the radius (different σ, flops) does not.
        let twin = Stencil::get(
            StencilSpec::star(Dim::D2, 1).with_flops(4.0).with_c_iter(11.0).register(),
        );
        assert_ne!(jac.id, twin.id, "distinct identities");
        assert_eq!(CacheKey::new(fp(), &hw, jac, &size), CacheKey::new(fp(), &hw, twin, &size));
        let r2 = Stencil::get(StencilSpec::star(Dim::D2, 2).register());
        assert_ne!(CacheKey::new(fp(), &hw, jac, &size), CacheKey::new(fp(), &hw, r2, &size));
    }

    #[test]
    fn key_separates_platforms_by_fingerprint() {
        use crate::platform::spec::PlatformSpec;
        let hw = HwParams::gtx980();
        let size = ProblemSize::d2(1024, 256);
        let jac = Stencil::get(crate::stencil::defs::StencilId::Jacobi2D);
        // An identity override fingerprints like the preset: same key.
        let same = PlatformSpec::parse("maxwell:clk1.2").unwrap().fingerprint();
        assert_eq!(CacheKey::new(fp(), &hw, jac, &size), CacheKey::new(same, &hw, jac, &size));
        // A bandwidth tweak is a different model: distinct key.
        let tweaked = PlatformSpec::parse("maxwell:bw20").unwrap().fingerprint();
        assert_ne!(CacheKey::new(fp(), &hw, jac, &size), CacheKey::new(tweaked, &hw, jac, &size));
    }

    #[test]
    fn memoizes_and_counts() {
        let cache = MemoCache::new();
        let mut calls = 0;
        for _ in 0..3 {
            cache.get_or_compute(key(128), || {
                calls += 1;
                dummy_solution()
            });
        }
        assert_eq!(calls, 1);
        assert_eq!(cache.stats.hits.load(Ordering::Relaxed), 2);
        assert_eq!(cache.stats.misses.load(Ordering::Relaxed), 1);
        assert!((cache.stats.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_keys_distinct_slots() {
        let cache = MemoCache::new();
        cache.get_or_compute(key(128), dummy_solution);
        cache.get_or_compute(key(256), || None);
        assert_eq!(cache.len(), 2);
        // Infeasibility (None) is memoized too.
        let v = cache.get_or_compute(key(256), dummy_solution);
        assert!(v.is_none());
    }

    #[test]
    fn get_distinguishes_unsolved_from_infeasible() {
        let cache = MemoCache::new();
        assert!(cache.get(&key(128)).is_none(), "unsolved instance");
        cache.get_or_compute(key(128), || None);
        assert!(matches!(cache.get(&key(128)), Some(None)), "memoized infeasible");
        cache.get_or_compute(key(256), dummy_solution);
        assert!(cache.get(&key(256)).unwrap().is_some());
        // Tally: get(miss), get_or_compute(miss), get(hit),
        // get_or_compute(miss), get(hit).
        assert_eq!(cache.stats.snapshot(), StatsSnapshot { hits: 2, misses: 3 });
    }

    #[test]
    fn snapshot_deltas_isolate_epochs() {
        let cache = MemoCache::new();
        cache.get_or_compute(key(32), dummy_solution);
        let epoch = cache.stats.snapshot();
        cache.get_or_compute(key(32), dummy_solution);
        cache.get_or_compute(key(64), dummy_solution);
        let d = cache.stats.delta_since(epoch);
        assert_eq!((d.hits, d.misses), (1, 1));
        assert_eq!(d.lookups(), 2);
        assert!((d.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn bounded_out_never_aliases_as_exact() {
        let cache = MemoCache::new();
        cache.insert_bound(key(128), 0.125);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.exact_len(), 0);
        assert_eq!(cache.bounded_len(), 1);
        assert_eq!(cache.bound_of(&key(128)), Some(0.125));
        // Bound marks are bookkeeping: no lookup was charged yet.
        assert_eq!(cache.stats.snapshot(), StatsSnapshot::default());
        // Exact readers see the instance as unsolved…
        assert!(cache.get(&key(128)).is_none(), "bound must not read as solved");
        // …and an exact demand re-solves and upgrades the slot (a miss).
        let mut calls = 0;
        let v = cache.get_or_compute(key(128), || {
            calls += 1;
            dummy_solution()
        });
        assert_eq!(calls, 1);
        assert!(v.is_some());
        assert_eq!(cache.exact_len(), 1);
        assert_eq!(cache.bounded_len(), 0);
        assert_eq!(cache.bound_of(&key(128)), None, "slot was upgraded");
        // get(miss on bound), get_or_compute(miss on upgrade).
        assert_eq!(cache.stats.snapshot(), StatsSnapshot { hits: 0, misses: 2 });
    }

    #[test]
    fn bound_marks_never_downgrade_or_overwrite() {
        let cache = MemoCache::new();
        cache.get_or_compute(key(128), dummy_solution);
        // Marking a solved instance is a no-op.
        cache.insert_bound(key(128), 9.0);
        assert!(cache.get(&key(128)).unwrap().is_some());
        assert_eq!(cache.bound_of(&key(128)), None);
        // First bound mark wins over later (possibly looser) marks.
        cache.insert_bound(key(256), 1.0);
        cache.insert_bound(key(256), 2.0);
        assert_eq!(cache.bound_of(&key(256)), Some(1.0));
    }

    #[test]
    fn export_is_key_sorted_and_complete() {
        let cache = MemoCache::with_shards(4);
        cache.get_or_compute(key(256), dummy_solution);
        cache.get_or_compute(key(64), || None);
        cache.insert_bound(key(128), 0.25);
        let entries = cache.export_entries();
        assert_eq!(entries.len(), 3);
        let keys: Vec<u32> = entries.iter().map(|(k, _)| k.n_v).collect();
        assert_eq!(keys, vec![64, 128, 256], "deterministic key order");
        assert!(matches!(entries[0].1, CacheEntry::Exact(None)));
        assert!(matches!(entries[1].1, CacheEntry::BoundedOut { lb_seconds } if lb_seconds == 0.25));
        assert!(matches!(entries[2].1, CacheEntry::Exact(Some(_))));
        // Export is bookkeeping: no counters moved beyond the three inserts.
        assert_eq!(cache.stats.snapshot(), StatsSnapshot { hits: 0, misses: 2 });
    }

    #[test]
    fn import_honors_monotone_contract_without_counters() {
        let cache = MemoCache::new();
        // Vacant slots take either kind.
        assert!(cache.import_entry(key(32), CacheEntry::BoundedOut { lb_seconds: 0.5 }));
        assert!(cache.import_entry(key(64), CacheEntry::Exact(dummy_solution())));
        // A bound mark upgrades to exact…
        assert!(cache.import_entry(key(32), CacheEntry::Exact(None)));
        assert!(matches!(cache.get(&key(32)), Some(None)));
        // …but exact never downgrades to a bound or gets overwritten.
        assert!(!cache.import_entry(key(32), CacheEntry::BoundedOut { lb_seconds: 9.0 }));
        assert!(!cache.import_entry(key(64), CacheEntry::Exact(None)));
        assert!(cache.get(&key(64)).unwrap().is_some());
        // Duplicate bound marks keep the first.
        assert!(cache.import_entry(key(96), CacheEntry::BoundedOut { lb_seconds: 1.0 }));
        assert!(!cache.import_entry(key(96), CacheEntry::BoundedOut { lb_seconds: 2.0 }));
        assert_eq!(cache.bound_of(&key(96)), Some(1.0));
        // Imports charged nothing; only the two explicit `get` probes did.
        assert_eq!(cache.stats.snapshot().misses + cache.stats.snapshot().hits, 2);
    }

    #[test]
    fn export_import_roundtrip_preserves_every_slot() {
        let src = MemoCache::with_shards(8);
        src.get_or_compute(key(128), dummy_solution);
        src.get_or_compute(key(192), || None);
        src.insert_bound(key(320), 0.125);
        let dst = MemoCache::with_shards(2);
        for (k, e) in src.export_entries() {
            assert!(dst.import_entry(k, e));
        }
        assert_eq!(dst.len(), src.len());
        assert_eq!(dst.exact_len(), src.exact_len());
        assert_eq!(dst.bounded_len(), src.bounded_len());
        // Shard layout is irrelevant to the exported view.
        let a = src.export_entries();
        let b = dst.export_entries();
        assert_eq!(a.len(), b.len());
        for ((ka, ea), (kb, eb)) in a.iter().zip(b.iter()) {
            assert_eq!(ka, kb);
            match (ea, eb) {
                (CacheEntry::Exact(Some(x)), CacheEntry::Exact(Some(y))) => {
                    assert_eq!(x.est.seconds.to_bits(), y.est.seconds.to_bits());
                    assert_eq!(x.evals, y.evals);
                }
                (CacheEntry::Exact(None), CacheEntry::Exact(None)) => {}
                (CacheEntry::BoundedOut { lb_seconds: x }, CacheEntry::BoundedOut { lb_seconds: y }) => {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
                other => panic!("slot kind changed across round-trip: {other:?}"),
            }
        }
        assert_eq!(dst.stats.snapshot(), StatsSnapshot::default(), "imports are not lookups");
    }

    #[test]
    fn shard_count_rounds_to_power_of_two() {
        assert_eq!(MemoCache::with_shards(0).shard_count(), 1);
        assert_eq!(MemoCache::with_shards(1).shard_count(), 1);
        assert_eq!(MemoCache::with_shards(48).shard_count(), 64);
        assert_eq!(MemoCache::new().shard_count(), 64);
    }

    #[test]
    fn concurrent_access() {
        use std::sync::Arc;
        let cache = Arc::new(MemoCache::new());
        std::thread::scope(|s| {
            for t in 0..8 {
                let cache = Arc::clone(&cache);
                s.spawn(move || {
                    for i in 0..100 {
                        cache.get_or_compute(key(32 * (i % 10 + 1) + t), dummy_solution);
                    }
                });
            }
        });
        assert!(cache.len() <= 8 * 10 + 8);
    }

    #[test]
    fn concurrent_accounting_is_exact() {
        // 8 threads hammer the same 16 keys: regardless of compute races,
        // exactly one miss may be charged per distinct key.
        use std::sync::Arc;
        let cache = Arc::new(MemoCache::with_shards(4));
        std::thread::scope(|s| {
            for _ in 0..8 {
                let cache = Arc::clone(&cache);
                s.spawn(move || {
                    for i in 0..400 {
                        let v = cache.get_or_compute(key(32 * (i % 16 + 1)), dummy_solution);
                        assert_eq!(v.unwrap().evals, 1);
                    }
                });
            }
        });
        let snap = cache.stats.snapshot();
        assert_eq!(cache.len(), 16);
        assert_eq!(snap.misses, 16, "misses must equal distinct instances");
        assert_eq!(snap.lookups(), 8 * 400);
    }
}
